"""Policy registry + composed-selector tests.

The load-bearing property: EVERY policy in the registry — including ones
registered after this file was written — is differential-tested jax vs
python with zero extra test code, because both engines dispatch through
the same registry entry (``policy.select`` / ``policy.select_py``).
"""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import (JSCC_SYSTEMS, SimConfig, Scheduler, make_npb_workload,
                        make_policy, parse_policy_spec, policy_names,
                        register_policy, simulate_py, MODES)
from repro.core.policy import (Policy, BIG, _paper_rule, _paper_rule_py,
                               _lex_argmin)


# ------------------------------------------------------------------ registry

def test_registry_covers_all_legacy_modes():
    names = policy_names()
    for mode in MODES:
        assert mode in names
    assert len(set(names)) == len(names)


def test_make_policy_unknown_name():
    with pytest.raises(ValueError, match="unknown policy"):
        make_policy("definitely_not_registered")


def test_select_system_accepts_registered_extensions():
    """The legacy shim dispatches through the registry, so post-paper
    registrations work via the mode-string surface too."""
    from repro.core import select_system
    import jax
    idx = int(select_system(
        "fastest_completion",
        c_row=jnp.asarray([1.0, 2.0], jnp.float32),
        t_row=jnp.asarray([30.0, 20.0], jnp.float32),
        runs_row=jnp.ones(2, jnp.int32),
        avail_row=jnp.asarray([100.0, 0.0], jnp.float32),
        k=jnp.float32(0.1),
        c_pred_row=jnp.asarray([1.0, 2.0], jnp.float32),
        t_pred_row=jnp.asarray([30.0, 20.0], jnp.float32),
        key=jax.random.key(0)))
    assert idx == 1
    with pytest.raises(ValueError, match="unknown policy"):
        select_system("not_a_policy", c_row=jnp.zeros(2), t_row=jnp.zeros(2),
                      runs_row=jnp.ones(2, jnp.int32),
                      avail_row=jnp.zeros(2), k=0.0)


def test_policy_validates_axes():
    with pytest.raises(ValueError, match="exploration"):
        Policy(exploration="psychic")
    with pytest.raises(ValueError, match="objective"):
        Policy(objective="min_vibes")
    with pytest.raises(ValueError, match="queue"):
        Policy(queue="lifo")
    with pytest.raises(ValueError, match="window"):
        Policy(queue="easy_backfill", window=0)
    # CLI specs deliver floats; the frozen instance normalizes to int
    assert Policy(queue="easy_backfill", window=4.0).window == 4


def test_parse_queue_spec():
    from repro.core import parse_queue_spec
    assert parse_queue_spec("fcfs") == ("fcfs", None)
    assert parse_queue_spec("easy_backfill") == ("easy_backfill", None)
    assert parse_queue_spec("easy_backfill:window=16") == \
        ("easy_backfill", 16)
    with pytest.raises(ValueError, match="unknown queue"):
        parse_queue_spec("lifo")
    with pytest.raises(ValueError, match="window=W"):
        parse_queue_spec("easy_backfill:depth=3")


def test_parse_policy_spec_queue_params():
    p = parse_policy_spec("paper:k=0.2,queue=easy_backfill,window=12")
    assert p.queue == "easy_backfill" and p.window == 12
    assert float(p.k) == pytest.approx(0.2)
    assert parse_policy_spec("easy_backfill").queue == "easy_backfill"
    assert parse_policy_spec("easy_backfill:window=3").window == 3


def test_register_policy_rejects_duplicates():
    with pytest.raises(ValueError, match="already registered"):
        @register_policy("paper")
        def dup(**kw):
            return Policy(**kw)


def test_parse_policy_spec():
    p = parse_policy_spec("ucb:k=0.15,ucb_scale=0.25")
    assert p.name == "ucb" and p.exploration == "optimistic_bound"
    assert float(p.k) == pytest.approx(0.15)
    assert float(p.ucb_scale) == pytest.approx(0.25)
    assert parse_policy_spec("paper").name == "paper"
    with pytest.raises(ValueError, match="key=val"):
        parse_policy_spec("paper:k")
    # defaults fill unset hyperparameters; explicit spec values win
    assert float(parse_policy_spec("paper", k=0.1).k) == pytest.approx(0.1)
    assert float(parse_policy_spec("paper:k=0.3", k=0.1).k) == \
        pytest.approx(0.3)


def test_with_params_and_grid_size():
    p = make_policy("paper", k=np.linspace(0, 0.3, 8).astype(np.float32))
    assert p.grid_size == 8
    assert make_policy("paper").grid_size is None
    p2 = p.with_params(k=0.1)
    assert p2.grid_size is None and p2.name == "paper"


# ------------------------------------- whole-registry differential property

@pytest.fixture(scope="module", params=[11, 23], ids=["stream-a", "stream-b"])
def stream(request):
    """20 mixed jobs, staggered arrivals, per-job K overrides, noisy
    predictions — exercises every selector input."""
    rng = np.random.default_rng(request.param)
    order = tuple(rng.choice(["BT", "EP", "IS", "LU", "SP"], 20))
    arrivals = np.cumsum(rng.exponential(25.0, 20)).astype(np.float32)
    k_job = np.full(20, np.nan, np.float32)
    k_job[::4] = 0.25
    return make_npb_workload(JSCC_SYSTEMS, order=order, arrivals=arrivals,
                             k_job=k_job, pred_noise=0.10,
                             noise_seed=request.param)


@pytest.mark.parametrize("name", policy_names())
@pytest.mark.parametrize("warm", [True, False], ids=["warm", "cold"])
def test_every_registered_policy_is_differential_tested(stream, name, warm):
    """A newly registered policy gets jax-vs-python placement equality for
    free: both sides dispatch through the registry."""
    cfg = SimConfig(mode=name, k=0.1, warm_start=warm, seed=7)
    res = Scheduler(make_policy(name, k=0.1), warm_start=warm, seeds=7).run(
        stream)
    ref = simulate_py(stream, cfg)
    np.testing.assert_array_equal(np.asarray(res.system), ref["system"])
    np.testing.assert_allclose(np.asarray(res.start), ref["start"],
                               rtol=1e-5, atol=1e-3)
    np.testing.assert_allclose(float(res.total_energy), ref["total_energy"],
                               rtol=1e-5)


# -------------------------------------------- hardened paper-rule tie-break

def test_paper_rule_zero_c_ties_break_on_time():
    """Freshly-learned zero-C rows: the old relative tolerance degenerated
    at cbest == 0; the masked lexicographic argmin must still tie-break
    zero-C candidates on T."""
    c = jnp.asarray([0.0, 0.0, 1.0], jnp.float32)
    t = jnp.asarray([50.0, 30.0, 10.0], jnp.float32)
    assert int(_paper_rule(c, t, 10.0)) == 1
    assert _paper_rule_py(np.asarray(c, np.float64),
                          np.asarray(t, np.float64), 10.0) == 1


def test_paper_rule_big_sentinel_does_not_widen_ties():
    """A BIG sentinel in the row must not drag real candidates into the tie
    set (the old ``cbest * (1 + 1e-9)`` widened with the magnitude)."""
    c = jnp.asarray([BIG, 2.0, 2.0 + 1e-3], jnp.float32)
    t = jnp.asarray([1.0, 20.0, 5.0], jnp.float32)
    # 2.0 is the unique best C; 2.001 must NOT tie despite BIG in the row
    assert int(_paper_rule(c, t, 100.0)) == 1


def test_paper_rule_all_big_candidates():
    c = jnp.asarray([BIG, BIG], jnp.float32)
    t = jnp.asarray([5.0, 3.0], jnp.float32)
    assert int(_paper_rule(c, t, 1.0)) == 1          # tie on C=BIG -> min T


def test_paper_rule_all_infeasible_falls_back_in_range():
    """Pathological K < 0 empties the feasible set; the rule must still
    return an in-range lexicographic argmin, not a BIG-biased index 0."""
    c = jnp.asarray([5.0, 1.0, 3.0], jnp.float32)
    t = jnp.asarray([100.0, 200.0, 300.0], jnp.float32)
    idx = int(_paper_rule(c, t, -0.9))               # t <= t_min*0.1: none
    assert idx == 1                                  # falls back to argmin C
    assert _paper_rule_py(np.asarray(c, np.float64),
                          np.asarray(t, np.float64), -0.9) == 1


def test_lex_argmin_empty_feasible_mask():
    c = jnp.asarray([3.0, 1.0], jnp.float32)
    t = jnp.asarray([1.0, 2.0], jnp.float32)
    idx = int(_lex_argmin(c, t, jnp.zeros(2, bool)))
    assert idx == 1
