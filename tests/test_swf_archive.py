"""SWF archive replay: the gzipped fixture through the loader, the
vectorized column builder, and the phase-model calibration path."""

import pathlib

import numpy as np
import pytest

from repro.core import JSCC_SYSTEMS, Scheduler
from repro.core.workload_model import predict_phases
from repro.data.scenarios import (SWF_PHASE_FRACTIONS, load_swf, swf_lines,
                                  synthetic_swf_arrays, workload_from_arrays,
                                  workload_from_swf, workload_from_trace)

FIXTURE = pathlib.Path(__file__).parent / "data" / "jscc_sample.swf.gz"


@pytest.fixture(scope="module")
def fixture_jobs():
    return load_swf(FIXTURE)


def test_fixture_gzip_parse(fixture_jobs):
    """Gzipped archive file: comments and malformed / unknown-runtime /
    zero-proc records dropped, submits rebased to the first job."""
    assert len(fixture_jobs) == 48
    assert fixture_jobs[0].submit == 0.0
    assert all(j.runtime > 0 and j.procs > 0 for j in fixture_jobs)
    subs = [j.submit for j in fixture_jobs]
    assert subs == sorted(subs)


def test_swf_lines_round_trip():
    """Columns -> SWF text -> loader reproduces the columns."""
    sub, run, pr = synthetic_swf_arrays(64, seed=5)
    jobs = load_swf(swf_lines(sub, run, pr))
    assert len(jobs) == 64
    np.testing.assert_array_equal([j.runtime for j in jobs], run)
    np.testing.assert_array_equal([j.procs for j in jobs], pr)
    # loader rebases submits; relative spacing survives
    np.testing.assert_array_equal([j.submit for j in jobs], sub - sub[0])


def test_arrays_builder_matches_trace_builder(fixture_jobs):
    """workload_from_arrays is the core workload_from_trace delegates to
    — identical Workload from columns or TraceJob records."""
    w_t = workload_from_trace(fixture_jobs, JSCC_SYSTEMS)
    w_a = workload_from_arrays(
        np.asarray([j.submit for j in fixture_jobs]),
        np.asarray([j.runtime for j in fixture_jobs]),
        np.asarray([j.procs for j in fixture_jobs]), JSCC_SYSTEMS)
    for f in ("prog", "arrival", "n_req", "T_true", "C_true", "E_true"):
        np.testing.assert_array_equal(np.asarray(getattr(w_t, f)),
                                      np.asarray(getattr(w_a, f)))
    assert w_t.programs == w_a.programs
    assert w_t.T_comp is None and w_a.T_comp is None


def test_calibrated_runtime_round_trips_reference(fixture_jobs):
    """Calibration inverts each class's JobProfile from its median
    runtime on the reference system, so predict_phases must reproduce
    that runtime there (when the node request isn't capacity-clipped)."""
    w = workload_from_trace(fixture_jobs, JSCC_SYSTEMS, calibrate=True)
    theta = np.asarray([s.peak_flops_node * s.efficiency
                        for s in JSCC_SYSTEMS])
    cores = np.asarray([s.cores_per_node for s in JSCC_SYSTEMS], float)
    ref = int(np.argmax(theta * cores))
    runt = np.asarray([j.runtime for j in fixture_jobs])
    procs = np.asarray([j.procs for j in fixture_jobs], float)
    prog = np.asarray(w.prog)
    checked = 0
    for pi in range(len(w.programs)):
        m = prog == pi
        if np.ceil(np.median(procs[m]) / cores[ref]) \
                <= JSCC_SYSTEMS[ref].n_nodes:
            np.testing.assert_allclose(w.T_true[pi, ref],
                                       np.median(runt[m]), rtol=1e-9)
            checked += 1
    assert checked > 0


def test_calibrated_carries_phase_split(fixture_jobs):
    """calibrate=True fills the DVFS phase split from predict_phases:
    T_comp is the compute share everywhere, bounded by T_true, with the
    reference column matching the assumed compute fraction."""
    w = workload_from_trace(fixture_jobs, JSCC_SYSTEMS, calibrate=True)
    assert w.T_comp is not None and w.E_comp is not None
    T, Tc = np.asarray(w.T_true), np.asarray(w.T_comp)
    assert ((0 < Tc) & (Tc <= T + 1e-9)).all()
    assert (np.asarray(w.E_comp) <= np.asarray(w.E_true) + 1e-9).all()
    theta = np.asarray([s.peak_flops_node * s.efficiency
                        for s in JSCC_SYSTEMS])
    cores = np.asarray([s.cores_per_node for s in JSCC_SYSTEMS], float)
    ref = int(np.argmax(theta * cores))
    np.testing.assert_allclose(Tc[:, ref] / T[:, ref],
                               SWF_PHASE_FRACTIONS[0], rtol=1e-9)


def test_net_disk_scale_with_system_bandwidth(fixture_jobs):
    """The calibrated net/disk phases follow each system's bandwidth —
    the behaviour the first-order throughput model cannot express."""
    w = workload_from_trace(fixture_jobs, JSCC_SYSTEMS, calibrate=True)
    from repro.core.workload_model import JobProfile
    # reconstruct one class's non-compute share per system and check it
    # moves opposite to net+disk node bandwidth at fixed node count
    noncomp = np.asarray(w.T_true) - np.asarray(w.T_comp)
    assert (noncomp > 0).all()
    # same class, different systems: slower fabric => longer phases
    for pi in range(noncomp.shape[0]):
        n = np.asarray(w.n_req)[pi].astype(float)
        bw = np.asarray([s.net_bw_node for s in JSCC_SYSTEMS])
        dk = np.asarray([s.disk_bw_node for s in JSCC_SYSTEMS])
        # t_noncomp * n is volume / per-node-bandwidth mix: verify it is
        # NOT constant across systems unless bandwidths match
        spread = (noncomp[pi] * n)
        if len(set(bw)) > 1 or len(set(dk)) > 1:
            assert spread.max() / spread.min() > 1.0 + 1e-6
            break


def test_workload_from_swf_end_to_end():
    """One-call archive replay runs through the engine."""
    w = workload_from_swf(FIXTURE, JSCC_SYSTEMS)
    assert w.T_comp is not None          # calibrated by default
    res = Scheduler("paper", warm_start=True).run(w)
    assert float(res.total_energy) > 0
    assert np.asarray(res.system).shape == (48,)


def test_uncalibrated_default_unchanged(fixture_jobs):
    """calibrate defaults off for the legacy builders: first-order
    tables, no phase split (pinned by the trace-replay suites)."""
    w = workload_from_trace(fixture_jobs, JSCC_SYSTEMS)
    assert w.T_comp is None and w.E_comp is None
