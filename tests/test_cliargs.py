"""The unified CLI option grammar (core/cliargs.py, ISSUE 9).

One parser now feeds both launch CLIs; these tests pin (a) every
pre-consolidation spelling still resolving to the same Policy, (b) the
canonical ``policy_spec`` rendering round-tripping through
``parse_policy_spec``, and (c) the ``--engine``/``--core`` resolution
(deprecation included).
"""

import argparse
import warnings

import numpy as np
import pytest

from repro.core import make_policy, parse_policy_spec
from repro.core.cliargs import (add_policy_options, add_scale_options,
                                build_engine, build_fault, build_policy,
                                build_scale, policy_spec)


def parse(*argv):
    ap = argparse.ArgumentParser()
    add_policy_options(ap, engine=True)
    add_scale_options(ap)
    return ap.parse_args(list(argv))


# ---------------------------------------------------- existing spellings

@pytest.mark.parametrize("argv,expect", [
    # legacy --mode/--k pair
    (["--mode", "paper", "--k", "0.2"], make_policy("paper", k=0.2)),
    # spec with explicit k
    (["--policy", "paper:k=0.1"], make_policy("paper", k=0.1)),
    # --k fills in when the spec leaves k unset (--policy paper == --mode)
    (["--policy", "paper", "--k", "0.3"], make_policy("paper", k=0.3)),
    # multi-param spec
    (["--policy", "ucb:k=0.1,ucb_scale=0.25"],
     make_policy("ucb", k=0.1, ucb_scale=0.25)),
    # queue override with window
    (["--mode", "paper", "--queue", "easy_backfill:window=16"],
     make_policy("paper", k=0.1, queue="easy_backfill", window=16)),
    (["--mode", "paper", "--queue", "conservative:window=4"],
     make_policy("paper", k=0.1, queue="conservative", window=4)),
    # power cap override
    (["--mode", "paper", "--power-cap", "60000"],
     make_policy("paper", k=0.1, power_cap=60000.0)),
    # DVFS tier grid: '+'-separated tiers, freq_weight leaf
    (["--policy", "dvfs_paper:freq_tiers=1.0+0.8+0.6,freq_weight=0.5"],
     make_policy("dvfs_paper", k=0.1, freq_tiers=(1.0, 0.8, 0.6),
                 freq_weight=0.5)),
])
def test_existing_spellings_unchanged(argv, expect):
    assert build_policy(parse(*argv)) == expect


def test_spec_precedence_over_mode():
    """--policy wins over --mode; --queue/--power-cap still apply on top
    (the precedence both CLIs historically used)."""
    args = parse("--policy", "ucb:k=0.05", "--mode", "paper",
                 "--queue", "easy_backfill:window=8",
                 "--power-cap", "45000")
    pol = build_policy(args)
    assert pol.name == "ucb" and float(np.asarray(pol.k)) == 0.05
    assert pol.queue == "easy_backfill" and pol.window == 8
    assert float(np.asarray(pol.power_cap)) == 45000.0


def test_bad_specs_rejected():
    with pytest.raises(ValueError, match="key=val"):
        build_policy(parse("--policy", "paper:k"))
    with pytest.raises(ValueError, match="queue"):
        build_policy(parse("--mode", "paper", "--queue", "nope"))
    with pytest.raises(ValueError, match="window"):
        build_policy(parse("--mode", "paper", "--queue", "fcfs:depth=3"))


# ----------------------------------------------------------- round-trip

@pytest.mark.parametrize("pol", [
    make_policy("paper", k=0.1),
    make_policy("ucb", k=0.2, ucb_scale=0.75),
    make_policy("paper", k=0.1, queue="easy_backfill", window=16),
    make_policy("conservative", k=0.15, window=4),
    make_policy("paper", k=0.1, power_cap=52000.0),
    make_policy("dvfs_paper", k=0.1, freq_tiers=(1.0, 0.8, 0.6),
                freq_weight=0.5, power_cap=60000.0),
])
def test_policy_spec_round_trips(pol):
    """parse(spec(p)) == p — the canonical rendering is a faithful CLI
    spelling of any scalar registered policy."""
    spec = policy_spec(pol)
    assert parse_policy_spec(spec) == pol
    # and the rendering is stable (spec of the reparse is identical)
    assert policy_spec(parse_policy_spec(spec)) == spec


def test_policy_spec_rejects_grids_and_anonymous():
    with pytest.raises(ValueError, match="grid"):
        policy_spec(make_policy("paper", k=np.asarray([0.1, 0.2],
                                                      np.float32)))


# -------------------------------------------------- engine / fault flags

def test_engine_flag_resolution():
    assert build_engine(parse("--mode", "paper")) is None
    assert build_engine(parse("--engine", "events")) == "events"
    with pytest.warns(DeprecationWarning, match="--core is deprecated"):
        assert build_engine(parse("--core", "events")) == "events"
    with pytest.warns(DeprecationWarning):
        with pytest.raises(ValueError, match="conflicts"):
            build_engine(parse("--core", "arrival", "--engine", "events"))
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        assert build_engine(parse("--engine", "arrival")) == "arrival"


def test_fault_flag_resolution():
    assert build_fault(parse("--mode", "paper")) is None
    f = build_fault(parse("--failures", "0.1", "--stragglers", "0.05"))
    assert f.failure_prob == 0.1 and f.straggler_prob == 0.05


# ------------------------------------------------------ scale-out flags

def test_scale_flag_round_trip():
    """--shards/--chunk resolve to Scheduler kwargs; absent flags give
    the single-device monolithic defaults so **build_scale always
    composes."""
    assert build_scale(parse("--mode", "paper")) \
        == {"shards": None, "chunk": None}
    assert build_scale(parse("--shards", "auto")) \
        == {"shards": "auto", "chunk": None}
    assert build_scale(parse("--shards", "4", "--chunk", "65536")) \
        == {"shards": 4, "chunk": 65536}
    assert build_scale(parse("--chunk", "0")) == \
        {"shards": None, "chunk": None}
    with pytest.raises(ValueError, match="--shards expects"):
        build_scale(parse("--shards", "many"))
    # a parser without the scale options still resolves (the service CLI)
    ap = argparse.ArgumentParser()
    add_policy_options(ap, engine=True)
    assert build_scale(ap.parse_args(["--mode", "paper"])) \
        == {"shards": None, "chunk": None}


def test_scale_flags_accepted_by_scheduler():
    """The resolved kwargs construct a Scheduler verbatim."""
    from repro.core import Scheduler
    sc = Scheduler("paper",
                   **build_scale(parse("--shards", "1", "--chunk", "128")))
    assert sc.shards == 1 and sc.chunk == 128
