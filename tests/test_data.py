"""Data pipeline: determinism, packing, masks, stream resume."""

import numpy as np

from repro.configs import get_config, smoke_reduce
from repro.configs.base import ShapeConfig
from repro.data import DataConfig, SyntheticStream, host_batch, EOS, PAD


CFG = smoke_reduce(get_config("tinyllama-1.1b"))
SHAPE = ShapeConfig("t", seq_len=128, global_batch=4, kind="train")


def test_batch_is_pure_function_of_step():
    b1 = host_batch(CFG, SHAPE, step=7)
    b2 = host_batch(CFG, SHAPE, step=7)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = host_batch(CFG, SHAPE, step=8)
    assert not np.array_equal(b1["tokens"], b3["tokens"])


def test_tokens_in_vocab_and_labels_shifted():
    b = host_batch(CFG, SHAPE, step=0)
    assert b["tokens"].min() >= 0
    assert b["tokens"].max() < CFG.vocab_size
    np.testing.assert_array_equal(b["labels"][:, :-1], b["tokens"][:, 1:])
    assert set(np.unique(b["mask"])) <= {0, 1}


def test_packing_contains_document_boundaries():
    b = host_batch(CFG, SHAPE, step=3, dcfg=DataConfig(mean_doc_len=16))
    assert (b["tokens"] == EOS).sum() > 0, "packed stream must contain EOS"


def test_stream_resume_matches():
    s1 = SyntheticStream(CFG, SHAPE, start_step=0)
    batches = [next(s1) for _ in range(5)]
    s2 = SyntheticStream(CFG, SHAPE, start_step=3)
    b3 = next(s2)
    np.testing.assert_array_equal(np.asarray(batches[3]["tokens"]),
                                  np.asarray(b3["tokens"]))


def test_modality_stubs_present():
    vcfg = smoke_reduce(get_config("phi-3-vision-4.2b"))
    b = host_batch(vcfg, SHAPE, step=0)
    assert b["patch_embeds"].shape == (4, vcfg.n_patches, vcfg.d_model)
    acfg = smoke_reduce(get_config("whisper-medium"))
    b = host_batch(acfg, SHAPE, step=0)
    assert b["frame_embeds"].shape == (4, acfg.encoder_seq, acfg.d_model)
