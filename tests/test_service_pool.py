"""Multi-session scale-out (ISSUE 9): the vmapped session pool.

Acceptance criteria pinned here:

  - an N-session ``SessionPool`` fed per-session streams is bit-identical,
    per session, to N independent ``Dispatcher``s — across fcfs / EASY /
    conservative disciplines, a power-capped config and a DVFS-tier
    config (leaves differ per session, composition shared);
  - one compile serves the whole pool (the jit cache stays at 1);
  - buffered intake (submit-many, flush in one scatter at the next
    drive) realizes the same decisions as immediate per-job submission;
  - pool checkpoints are per-session namespaced and a restored pool
    resumes bit-identically (sync and async save paths);
  - ``whatif`` answers from the member's cached fork without mutating
    the lane's carry and matches the independent session's projection;
  - the ``AsyncWriter`` runs its queue in order, drains on close, and
    surfaces worker exceptions at the API boundary;
  - the decision log carries every placement with its session tag.
"""

import json

import numpy as np
import pytest
import jax

from repro.core import JSCC_SYSTEMS, Scheduler, make_npb_workload, \
    make_policy
from repro.service import AsyncWriter, Dispatcher, SessionPool

from test_service import FIELDS, assert_bit_identical, small_stream


def pool_scheds(kind):
    """Three-session configurations: leaves differ, composition shared."""
    if kind == "fcfs":
        pols = [make_policy("paper", k=k) for k in (0.05, 0.1, 0.2)]
    elif kind == "easy":
        pols = [make_policy("paper", k=k, queue="easy_backfill", window=4)
                for k in (0.05, 0.1, 0.2)]
    elif kind == "conservative":
        pols = [make_policy("paper", k=k, queue="conservative", window=4)
                for k in (0.05, 0.1, 0.2)]
    elif kind == "capped":
        pols = [make_policy("paper", k=0.1, power_cap=c)
                for c in (45000.0, 60000.0, 80000.0)]
    elif kind == "dvfs":
        pols = [make_policy("dvfs_paper", k=0.1,
                            freq_tiers=(1.0, 0.8, 0.6), freq_weight=fw)
                for fw in (0.0, 0.5, 1.0)]
    else:
        raise ValueError(kind)
    return [Scheduler(p, warm_start=True, seeds=i)
            for i, p in enumerate(pols)]


def pool_replay(w, pool):
    """The live protocol, session-interleaved: every lane is driven to
    each arrival (the others hold their horizon) and submits the job."""
    for j in range(len(w.prog)):
        t = float(w.arrival[j])
        for i in range(pool.n):
            pool.drive(t, session=i)
            pool.submit(i, int(w.prog[j]), t)
    pool.drain()
    return pool


def independent_replay(w, scheds, capacity=None):
    ds = [Dispatcher.from_scheduler(s, w, capacity=capacity)
          for s in scheds]
    for d in ds:
        for j in range(len(w.prog)):
            d.drive(until=float(w.arrival[j]))
            d.submit(int(w.prog[j]), float(w.arrival[j]))
        d.drain()
    return ds


# ------------------------------------------------- per-session identity

@pytest.mark.parametrize("kind", ["fcfs", "easy", "conservative",
                                  "capped", "dvfs"])
def test_pool_bit_identical_to_independent_sessions(kind):
    """The correctness bar: every lane of the pool realizes the same
    decisions and the same SimResult, bitwise, as an independent
    Dispatcher with the same spec — and ONE compile served all lanes."""
    w = small_stream()
    inds = independent_replay(w, pool_scheds(kind))
    pool = pool_replay(w, SessionPool(pool_scheds(kind), w))
    for i, d in enumerate(inds):
        assert pool.sessions[i].decisions == d.decisions
        assert_bit_identical(d.result(), pool.result(i))
    assert pool._step._cache_size() == 1
    pool.close()


def test_pool_rejects_mixed_composition():
    w = small_stream()
    with pytest.raises(ValueError, match="static"):
        SessionPool([Scheduler(make_policy("paper", k=0.1)),
                     Scheduler(make_policy("paper", k=0.1,
                                           queue="easy_backfill",
                                           window=4))], w)


# ------------------------------------------------------- batched intake

def test_batched_intake_matches_immediate_submission():
    """Many buffered submissions flush in one scatter at the next drive
    and realize exactly what per-job submission realizes."""
    w = small_stream()
    inds = independent_replay(w, pool_scheds("easy"))
    pool = SessionPool(pool_scheds("easy"), w)
    # buffer the whole stream for every session, then one global drain
    for i in range(pool.n):
        for j in range(len(w.prog)):
            jid = pool.submit(i, int(w.prog[j]), float(w.arrival[j]))
            assert jid == j
    assert sum(len(b) for b in pool._buffers) == pool.n * len(w.prog)
    pool.drain()
    for i, d in enumerate(inds):
        assert pool.sessions[i].decisions == d.decisions
        assert_bit_identical(d.result(), pool.result(i))
    pool.close()


def test_intake_validation_at_buffer_time():
    w = small_stream()
    pool = SessionPool(pool_scheds("fcfs")[:2], w, capacity=3)
    pool.submit(0, 0, 0.0)
    pool.submit(0, 1, 5.0)
    with pytest.raises(ValueError, match="arrival-ordered"):
        pool.submit(0, 2, 1.0)          # behind the buffered tail
    pool.submit(0, 2, 9.0)
    with pytest.raises(RuntimeError, match="session full"):
        pool.submit(0, 3, 10.0)         # capacity counts the buffer
    with pytest.raises(ValueError, match="catalog"):
        pool.submit(1, 99, 0.0)
    pool.close()


def test_undriven_lanes_hold_state():
    """Driving one session leaves the others' clocks and decision lists
    untouched (their steps are carry no-ops)."""
    w = small_stream()
    pool = SessionPool(pool_scheds("fcfs"), w)
    for i in range(pool.n):
        pool.submit(i, int(w.prog[0]), 0.0)
    pool.drive(300.0, session=0)
    assert pool.now(0) > 0.0
    assert pool.now(1) == 0.0 and pool.now(2) == 0.0
    assert not pool.sessions[1].decisions and not pool.sessions[2].decisions
    pool.close()


# ---------------------------------------------------- checkpoint/restore

def _feed(pool, w, lo, hi):
    for j in range(lo, hi):
        t = float(w.arrival[j])
        for i in range(pool.n):
            pool.drive(t, session=i)
            pool.submit(i, int(w.prog[j]), t)


@pytest.mark.parametrize("blocking", [True, False])
def test_pool_checkpoint_restore_bit_identical(tmp_path, blocking):
    """Kill a pool mid-stream, restore a fresh one from the namespaced
    checkpoints, replay the remainder: decisions and totals match the
    uninterrupted pool bitwise (sync and async-writer save paths)."""
    w = small_stream()
    half = len(w.prog) // 2
    ref = pool_replay(w, SessionPool(pool_scheds("easy"), w))

    ck = str(tmp_path / "ck")
    pool = SessionPool(pool_scheds("easy"), w, checkpoint_dir=ck)
    _feed(pool, w, 0, half)
    steps = pool.save(blocking=blocking)
    assert steps == [0] * pool.n
    pool.close()                          # drains the async writer
    del pool

    pool2 = SessionPool(pool_scheds("easy"), w, checkpoint_dir=ck)
    assert pool2.restore() is True
    assert [d.n_submitted for d in pool2.sessions] == [half] * pool2.n
    _feed(pool2, w, half, len(w.prog))
    pool2.drain()
    for i in range(ref.n):
        assert pool2.sessions[i].decisions == ref.sessions[i].decisions
        assert_bit_identical(ref.result(i), pool2.result(i))
    pool2.close()
    ref.close()


def test_pool_restore_single_session(tmp_path):
    """One lane can be rolled back while the others keep their state."""
    w = small_stream()
    ck = str(tmp_path / "ck")
    pool = SessionPool(pool_scheds("fcfs"), w, checkpoint_dir=ck)
    _feed(pool, w, 0, 3)
    pool.save()
    _feed(pool, w, 3, 6)
    pool.drain()                # restore refuses buffered submissions
    n_after = pool.sessions[2].n_submitted
    assert pool.restore(session=1) is True
    assert pool.sessions[1].n_submitted == 3
    assert pool.sessions[2].n_submitted == n_after
    pool.close()


# --------------------------------------------------------------- whatif

def test_pool_whatif_pure_and_matches_member():
    w = small_stream()
    # capacity > stream length: the what-if needs a free slot
    inds = independent_replay(w, pool_scheds("easy"), capacity=12)
    pool = pool_replay(w, SessionPool(pool_scheds("easy"), w, capacity=12))
    before = pool.sessions[1].carry_snapshot()
    proj = pool.whatif(1, 2)
    after = pool.sessions[1].carry_snapshot()
    for a, b in zip(jax.tree.leaves(before), jax.tree.leaves(after)):
        assert np.asarray(a).tobytes() == np.asarray(b).tobytes()
    from repro.service import whatif
    assert proj == whatif(inds[1], 2)
    pool.close()


# --------------------------------------------------------- async writer

def test_async_writer_orders_and_drains():
    out = []
    with AsyncWriter(maxsize=4) as wtr:
        for i in range(200):
            wtr.submit(out.append, i)   # backpressure past maxsize
    assert out == list(range(200))      # in order, fully drained


def test_async_writer_surfaces_worker_errors():
    wtr = AsyncWriter()

    def boom():
        raise RuntimeError("disk full")

    wtr.submit(boom)
    with pytest.raises(RuntimeError, match="disk full"):
        wtr.close()
    with pytest.raises(RuntimeError, match="closed"):
        wtr.submit(print)


def test_async_writer_flush_waits():
    import time
    out = []

    def slow(i):
        time.sleep(0.005)
        out.append(i)

    wtr = AsyncWriter()
    for i in range(10):
        wtr.submit(slow, i)
    wtr.flush()
    assert out == list(range(10))
    wtr.close()


# --------------------------------------------------------- decision log

def test_pool_decision_log(tmp_path):
    log = tmp_path / "decisions.jsonl"
    with SessionPool(pool_scheds("fcfs"), w := small_stream(),
                     decision_log=str(log)) as pool:
        pool_replay(w, pool)
        per_session = {i: list(pool.sessions[i].decisions)
                       for i in range(pool.n)}
    recs = [json.loads(line) for line in log.read_text().splitlines()]
    assert len(recs) == sum(len(d) for d in per_session.values())
    for i, decs in per_session.items():
        got = [{k: v for k, v in r.items() if k != "session"}
               for r in recs if r["session"] == i]
        assert got == decs
