"""Single-tier bit-identity regressions (ISSUE 8 acceptance).

The DVFS tier axis must be EXACTLY free when it is trivial: for every
registered policy, forcing ``freq_tiers=(1.0, 1.0)`` — a duplicate unit
grid, which activates the whole tier-expansion machinery (tier-major
candidate rows, tier-aware power tables, the tier decision channel) —
must reproduce the pre-DVFS ``Scheduler.run`` bit for bit, warm and
cold, on every scan core (arrival FCFS / batched EASY / conservative
reservations / capped event-granular).  The unit short-circuit in
``dvfs._tier_model`` (``where(phi == 1.0, base, ...)``) plus the
tier-major argmin tie-break (duplicate tiers produce identical scores;
the first flat index wins, so f = 0 everywhere) make this exact even
under f32 rounding.

The one exception: the ``random`` objective draws
``randint(0, F * S)`` over the expanded candidate axis, so a duplicate
tier changes the draw's bound — the behavior stays valid but is not
bit-comparable; it is skipped with that reason.
"""

from dataclasses import replace

import numpy as np
import pytest

from repro.core import (JSCC_SYSTEMS, Scheduler, make_policy, policy_names)
from repro.data.scenarios import make_stream_workload

#: Result fields the bitwise comparison covers (everything the engine
#: emits except the tier channel itself, which only the forced run has).
_FIELDS = ("total_energy", "makespan", "total_wait", "slowdown_sum",
           "max_wait", "n_backfilled", "peak_power", "idle_energy",
           "capped_delay", "system", "start", "finish", "wait", "energy",
           "runtime", "nodes", "backfilled", "busy", "C_tab", "T_tab",
           "runs")

FORCED = (1.0, 1.0)


def _stream(n=25, seed=3):
    return make_stream_workload(JSCC_SYSTEMS, n, arrival="poisson", rate=0.6,
                                seed=seed, pred_noise=0.08)


def assert_bit_identical(base_res, forced_res):
    for f in _FIELDS:
        a, b = getattr(base_res, f), getattr(forced_res, f)
        if a is None:
            assert b is None, f"forced-tier run grew field {f}"
            continue
        a, b = np.asarray(a), np.asarray(b)
        assert a.tobytes() == b.tobytes(), \
            f"duplicate unit tier changed {f}: {b} != {a}"
    # the trivially-expanded run records the anchor tier everywhere
    # (identical scores across duplicate tiers; first flat index wins)
    assert (np.asarray(forced_res.tier) == 0).all()


def _skip_random(name):
    if make_policy(name).objective == "random":
        pytest.skip("random objective draws randint(0, F*S): a duplicate "
                    "tier changes the draw bound, so the run is valid but "
                    "not bit-comparable")


@pytest.mark.parametrize("warm", [True, False], ids=["warm", "cold"])
@pytest.mark.parametrize("name", policy_names())
def test_single_tier_bit_identity_all_policies(name, warm):
    """Every registered policy, on its own registered queue discipline:
    untier vs duplicate-unit-tier, bitwise."""
    _skip_random(name)
    w = _stream()
    pol = make_policy(name, k=0.15)
    base = Scheduler(replace(pol, freq_tiers=(1.0,)), warm_start=warm).run(w)
    forced = Scheduler(replace(pol, freq_tiers=FORCED), warm_start=warm).run(w)
    assert_bit_identical(base, forced)


@pytest.mark.parametrize("warm", [True, False], ids=["warm", "cold"])
@pytest.mark.parametrize("queue", ["fcfs", "easy_backfill:window=6",
                                   "conservative:window=6"])
def test_single_tier_bit_identity_queues(queue, warm):
    """The three scan cores under the paper selector: the tier expansion
    threads the batched EASY window evaluation and the conservative
    hole-aware reservation math without perturbing either."""
    w = _stream(n=30, seed=5)
    pol = make_policy("paper", k=0.2)
    base = Scheduler(replace(pol, freq_tiers=(1.0,)), warm_start=warm,
                     queue=queue).run(w)
    forced = Scheduler(replace(pol, freq_tiers=FORCED), warm_start=warm,
                       queue=queue).run(w)
    assert_bit_identical(base, forced)


def test_single_tier_bit_identity_capped_event_core():
    """A binding power cap routes onto the event-granular core with its
    node-power table; the duplicate unit tier must not move a single
    placement or the power trace."""
    w = _stream(n=28, seed=8)
    pol = make_policy("paper", k=0.2, power_cap=48_000.0)
    base = Scheduler(replace(pol, freq_tiers=(1.0,)), warm_start=True).run(w)
    forced = Scheduler(replace(pol, freq_tiers=FORCED),
                       warm_start=True).run(w)
    assert_bit_identical(base, forced)


def test_dvfs_entry_with_unit_grid_is_plain_paper():
    """``dvfs_paper`` differs from ``paper`` ONLY through its tier grid:
    collapse the grid to ``(1.0,)`` and the runs are bit-identical."""
    w = _stream(n=25, seed=2)
    plain = Scheduler(make_policy("paper", k=0.2), warm_start=True).run(w)
    collapsed = Scheduler(
        replace(make_policy("dvfs_paper", k=0.2), freq_tiers=(1.0,)),
        warm_start=True).run(w)
    for f in ("system", "start", "total_energy", "makespan", "T_tab"):
        a = np.asarray(getattr(plain, f))
        b = np.asarray(getattr(collapsed, f))
        assert a.tobytes() == b.tobytes(), f"dvfs_paper@(1.0,) != paper: {f}"


def test_non_dvfs_registry_entries_default_untier():
    """No registered policy silently grows a tier axis: everything but
    the ``dvfs_*`` entries defaults to the trivial grid."""
    for name in policy_names():
        tiers = make_policy(name).freq_tiers
        if name.startswith("dvfs_"):
            assert tiers == (1.0, 0.8, 0.6), (name, tiers)
        else:
            assert tiers == (1.0,), (name, tiers)
