"""Elastic scaling: rebuild a smaller mesh from surviving devices and
resume from a checkpoint written at a different mesh shape (subprocess —
needs a multi-device host platform)."""

import os
import subprocess
import sys

import pytest

pytestmark = pytest.mark.slow       # subprocess, 64-device host platform

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=64"
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.launch.mesh import make_elastic_mesh
from repro.configs import get_config, smoke_reduce
from repro.configs.base import ShapeConfig
from repro.models import build_model
from repro.launch.specs import build_all_specs, named
from repro.sharding import use_rules
from repro.sharding.ctx import lm_rules
from repro.checkpoint import CheckpointManager
import tempfile, numpy as np

cfg = smoke_reduce(get_config("tinyllama-1.1b")).with_overrides(dtype="float32")
api = build_model(cfg)
params = api.init_params(jax.random.key(0))

d = tempfile.mkdtemp()
mgr = CheckpointManager(d)
mgr.save(1, params, blocking=True)

# "full" mesh 64 = (4, 16); a host dies -> elastic 48 = (3, 16)
for n in (64, 48):
    mesh = make_elastic_mesh(n, model_parallel=16)
    assert mesh.devices.size == n, mesh.devices.shape
    restored, step, _ = mgr.restore(params)
    rules = lm_rules(multi_pod=False, fsdp=False)
    with mesh, use_rules(mesh, rules):
        from repro.sharding.params import tree_partition_specs
        part = tree_partition_specs(api.param_specs(), rules, mesh)
        sharded = jax.device_put(restored, named(mesh, part))
        # one forward on the elastic mesh proves the resharded state works
        batch = {
            "tokens": jnp.zeros((8, 32), jnp.int32),
            "labels": jnp.zeros((8, 32), jnp.int32),
            "mask": jnp.ones((8, 32), jnp.int32),
        }
        loss, _ = jax.jit(api.train_loss)(sharded, batch)
        assert np.isfinite(float(loss)), (n, loss)
    print(f"elastic mesh {mesh.devices.shape}: loss={float(loss):.4f} OK")
print("ELASTIC OK")
"""


def test_elastic_mesh_resume():
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "ELASTIC OK" in out.stdout
