"""Integration: the multi-pod dry-run pipeline end-to-end (subprocess —
the 512-device XLA flag must not leak into this test process)."""

import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

pytestmark = pytest.mark.slow       # subprocess + 256/512-device compiles


@pytest.mark.parametrize("arch,shape,mp", [
    ("tinyllama-1.1b", "decode_32k", False),
    ("mamba2-780m", "decode_32k", True),
])
def test_dryrun_cell_subprocess(tmp_path, arch, shape, mp):
    cmd = [sys.executable, "-m", "repro.launch.dryrun",
           "--arch", arch, "--shape", shape, "--out", str(tmp_path),
           "--tag", "test"]
    if mp:
        cmd.append("--multi-pod")
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    out = subprocess.run(cmd, env=env, capture_output=True, text=True,
                         timeout=900)
    assert out.returncode == 0, out.stdout + out.stderr
    mesh = "pod2x16x16" if mp else "pod16x16"
    rec = json.load(open(tmp_path / f"{arch}__{shape}__{mesh}__test.json"))
    assert rec["applicable"] and "error" not in rec
    assert rec["n_devices"] == (512 if mp else 256)
    assert rec["hlo_walk"]["flops_per_device"] > 0
    assert rec["memory_analysis"]["temp_bytes"] > 0
    # collective census present (decode w/ sharded caches communicates)
    assert "coll_link_bytes_per_device" in rec["hlo_walk"]


def test_skip_cell_recorded(tmp_path):
    cmd = [sys.executable, "-m", "repro.launch.dryrun",
           "--arch", "gemma-7b", "--shape", "long_500k",
           "--out", str(tmp_path), "--tag", "test"]
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    out = subprocess.run(cmd, env=env, capture_output=True, text=True,
                         timeout=300)
    assert out.returncode == 0, out.stdout + out.stderr
    rec = json.load(open(tmp_path / "gemma-7b__long_500k__pod16x16__test.json"))
    assert rec["applicable"] is False
    assert "sub-quadratic" in rec["skip_reason"]
