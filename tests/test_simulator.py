"""Simulator tests: differential (jax == python), queueing invariants,
exploration coverage, fault model."""

import numpy as np
import pytest

from repro.core import (JSCC_SYSTEMS, SimConfig, make_npb_workload,
                        simulate_jax, simulate_py, sweep_k)


@pytest.fixture(scope="module")
def npb():
    return make_npb_workload(JSCC_SYSTEMS)


@pytest.mark.parametrize("mode", ["paper", "fastest", "greenest",
                                  "first_free", "oracle"])
@pytest.mark.parametrize("k", [0.0, 0.1, 0.3])
def test_differential_jax_vs_python(npb, mode, k):
    for warm in (True, False):
        cfg = SimConfig(mode=mode, k=k, warm_start=warm)
        rj = simulate_jax(npb, cfg)
        rp = simulate_py(npb, cfg)
        assert np.array_equal(np.asarray(rj["system"]), rp["system"]), \
            (mode, k, warm)
        np.testing.assert_allclose(float(rj["total_energy"]),
                                   rp["total_energy"], rtol=1e-5)
        np.testing.assert_allclose(float(rj["makespan"]), rp["makespan"],
                                   rtol=1e-5)


def test_exploration_fills_tables(npb):
    w4 = make_npb_workload(JSCC_SYSTEMS, repeats=4)
    r = simulate_jax(w4, SimConfig(mode="paper", k=0.1))
    assert (np.asarray(r["runs"]) == 1).all(), \
        "4 suite repeats must explore every (program, system) exactly once"


def test_queueing_contention():
    # 30 copies of BT at once exceed any single system's nodes -> waits > 0
    w = make_npb_workload(JSCC_SYSTEMS, order=("BT",) * 30)
    r = simulate_jax(w, SimConfig(mode="fastest", warm_start=True))
    waits = np.asarray(r["wait"])
    assert waits.max() > 0
    # starts within one system must not overlap more jobs than nodes allow
    sel = np.asarray(r["system"])
    starts, finishes = np.asarray(r["start"]), np.asarray(r["finish"])
    for s in range(4):
        mask = sel == s
        if mask.sum() < 2:
            continue
        n_nodes = int(w.n_nodes[s])
        need = int(w.n_req[0, s])
        cap = n_nodes // need
        # at any start time, concurrently running jobs on s must fit
        for t in starts[mask]:
            running = ((starts[mask] <= t) & (finishes[mask] > t)).sum()
            assert running <= cap, (s, t, running, cap)


def test_energy_decreases_with_k(npb):
    ks = np.array([0.0, 0.05, 0.10, 0.20, 0.50])
    res = sweep_k(npb, SimConfig(mode="paper", warm_start=True), ks)
    E = np.asarray(res["total_energy"])
    assert (np.diff(E) <= 1e-6).all(), f"energy must be non-increasing in K: {E}"


def test_greenest_lower_energy_than_fastest(npb):
    rf = simulate_jax(npb, SimConfig(mode="fastest", warm_start=True))
    rg = simulate_jax(npb, SimConfig(mode="greenest", warm_start=True))
    assert float(rg["total_energy"]) <= float(rf["total_energy"])
    assert float(rg["makespan"]) >= float(rf["makespan"]) - 1e-6


def test_oracle_equals_paper_when_tables_warm(npb):
    rp = simulate_jax(npb, SimConfig(mode="paper", k=0.1, warm_start=True))
    ro = simulate_jax(npb, SimConfig(mode="oracle", k=0.1, warm_start=True))
    assert np.array_equal(np.asarray(rp["system"]), np.asarray(ro["system"]))


def test_fault_model_increases_runtime_and_energy(npb):
    base = simulate_jax(npb, SimConfig(mode="paper", k=0.1, warm_start=True))
    faulty = simulate_jax(npb, SimConfig(
        mode="paper", k=0.1, warm_start=True,
        straggler_prob=1.0, straggler_factor=2.0))
    assert float(faulty["total_energy"]) > float(base["total_energy"]) * 1.5
    assert float(faulty["makespan"]) > float(base["makespan"]) * 1.5


def test_history_routes_around_degraded_system():
    """The paper's mechanism as fault tolerance: if a system chronically
    straggles, its learned T rises and the algorithm stops choosing it."""
    w = make_npb_workload(JSCC_SYSTEMS, order=("BT",) * 12)
    # degrade: scale T/C/E of Skylake (idx 2) by 3x in the ground truth
    w.T_true[:, 2] *= 3.0
    w.C_true[:, 2] *= 3.0
    w.E_true[:, 2] *= 3.0
    r = simulate_jax(w, SimConfig(mode="paper", k=0.2))
    sel = np.asarray(r["system"])
    # after the exploration phase (first 4 jobs hit all systems),
    # the degraded system must never be chosen again
    assert (sel[4:] != 2).all(), sel


def test_queue_aware_cuts_waiting_under_contention():
    """The paper's stated future work: feasibility on wait+run.  16
    simultaneous SP jobs overload the greenest-feasible system under the
    plain algorithm (16x queued on Skylake); queue-aware spreads them —
    waiting collapses while makespan stays within a few percent (it trades
    energy for responsiveness; measured: wait 390 s -> 0, makespan +0.2%)."""
    w = make_npb_workload(JSCC_SYSTEMS, order=("SP",) * 16)
    rp = simulate_jax(w, SimConfig(mode="paper", k=0.05, warm_start=True))
    rq = simulate_jax(w, SimConfig(mode="queue_aware", k=0.05, warm_start=True))
    assert float(rq["total_wait"]) < 0.25 * float(rp["total_wait"])
    assert float(rq["makespan"]) <= float(rp["makespan"]) * 1.05
