"""DVFS virtual-system tests (beyond-paper extension)."""

import numpy as np
import pytest

from repro.core import JSCC_SYSTEMS, SimConfig, simulate_jax, sweep_k
from repro.core.dvfs import dvfs_variant, expand_with_dvfs, dvfs_npb_workload
from repro.core.systems import SKYLAKE
from repro.core.workload_model import NPB_PROFILES, predict_energy


def test_dvfs_variant_scaling():
    v = dvfs_variant(SKYLAKE, 0.8)
    assert v.name == "Skylake@80"
    assert v.peak_flops_node == pytest.approx(SKYLAKE.peak_flops_node * 0.8)
    assert v.cpu_w == pytest.approx(SKYLAKE.cpu_w * 0.8 ** 3)
    assert v.idle_w == SKYLAKE.idle_w


def test_capping_trades_time_for_compute_energy():
    """On a compute-bound job, phi=0.6 must be slower but spend less
    *dynamic* compute energy per op (idle can eat the gain at low phi —
    the scheduler decides when it's worth it)."""
    prof = NPB_PROFILES["EP"]
    e1, _, t1 = predict_energy(prof, SKYLAKE, 4)
    e6, _, t6 = predict_energy(prof, dvfs_variant(SKYLAKE, 0.6), 4)
    assert t6 > t1 * 1.3
    # dynamic compute part: cpu_w * t_comp
    assert (dvfs_variant(SKYLAKE, 0.6).cpu_w * t6) < (SKYLAKE.cpu_w * t1)


def test_dvfs_expansion_count():
    exp = expand_with_dvfs(JSCC_SYSTEMS, phis=(1.0, 0.8))
    assert len(exp) == 8
    assert {s.name for s in exp} >= {"KNL@100", "KNL@80", "Skylake@100"}


def test_dvfs_never_worse_than_selection_only():
    """The phi=1.0 virtual systems embed the plain decision space, so the
    expanded optimum can only improve (at every K)."""
    from repro.core import make_npb_workload
    ks = np.array([0.0, 0.1, 0.3, 0.85])
    w_plain = make_npb_workload(JSCC_SYSTEMS)
    w_dvfs = dvfs_npb_workload(JSCC_SYSTEMS, phis=(1.0, 0.8, 0.6))
    rp = sweep_k(w_plain, SimConfig(mode="paper", warm_start=True), ks)
    rd = sweep_k(w_dvfs, SimConfig(mode="paper", warm_start=True), ks)
    Ep = np.asarray(rp["total_energy"])
    Ed = np.asarray(rd["total_energy"])
    assert (Ed <= Ep * (1 + 1e-6)).all(), (Ep, Ed)
