"""DVFS tests: the legacy virtual-system expansion and the first-class
``Policy.freq_tiers`` axis (ISSUE 8) — registry entries, jax==python
differential coverage of the tier decision sequence on every core
(arrival / EASY / conservative / capped event), totals_only equivalence,
and a live ``Dispatcher`` session picking non-unit tiers bit-identically
to the batch scan.  The deterministic tier-model/frontier invariants
(``assert_tier_monotone`` / ``assert_front_nondominated``) are shared
with the hypothesis sweeps in tests/test_property_dvfs.py."""

from dataclasses import replace

import numpy as np
import pytest

from repro.core import (JSCC_SYSTEMS, Scheduler, SimConfig, make_npb_workload,
                        make_policy, simulate_jax, simulate_py, sweep_k)
from repro.core.dvfs import (dvfs_variant, expand_with_dvfs,
                             dvfs_npb_workload, pareto_mask, phase_split,
                             tier_tables, tier_tables_py)
from repro.core.systems import SKYLAKE
from repro.core.workload_model import NPB_PROFILES, predict_energy
from repro.data.scenarios import (load_swf, maintenance_windows,
                                  make_stream_workload, workload_from_trace)


def test_dvfs_variant_scaling():
    v = dvfs_variant(SKYLAKE, 0.8)
    assert v.name == "Skylake@80"
    assert v.peak_flops_node == pytest.approx(SKYLAKE.peak_flops_node * 0.8)
    assert v.cpu_w == pytest.approx(SKYLAKE.cpu_w * 0.8 ** 3)
    assert v.idle_w == SKYLAKE.idle_w


def test_capping_trades_time_for_compute_energy():
    """On a compute-bound job, phi=0.6 must be slower but spend less
    *dynamic* compute energy per op (idle can eat the gain at low phi —
    the scheduler decides when it's worth it)."""
    prof = NPB_PROFILES["EP"]
    e1, _, t1 = predict_energy(prof, SKYLAKE, 4)
    e6, _, t6 = predict_energy(prof, dvfs_variant(SKYLAKE, 0.6), 4)
    assert t6 > t1 * 1.3
    # dynamic compute part: cpu_w * t_comp
    assert (dvfs_variant(SKYLAKE, 0.6).cpu_w * t6) < (SKYLAKE.cpu_w * t1)


def test_dvfs_expansion_count():
    exp = expand_with_dvfs(JSCC_SYSTEMS, phis=(1.0, 0.8))
    assert len(exp) == 8
    assert {s.name for s in exp} >= {"KNL@100", "KNL@80", "Skylake@100"}


def test_dvfs_never_worse_than_selection_only():
    """The phi=1.0 virtual systems embed the plain decision space, so the
    expanded optimum can only improve (at every K)."""
    from repro.core import make_npb_workload
    ks = np.array([0.0, 0.1, 0.3, 0.85])
    w_plain = make_npb_workload(JSCC_SYSTEMS)
    w_dvfs = dvfs_npb_workload(JSCC_SYSTEMS, phis=(1.0, 0.8, 0.6))
    rp = sweep_k(w_plain, SimConfig(mode="paper", warm_start=True), ks)
    rd = sweep_k(w_dvfs, SimConfig(mode="paper", warm_start=True), ks)
    Ep = np.asarray(rp["total_energy"])
    Ed = np.asarray(rd["total_energy"])
    assert (Ed <= Ep * (1 + 1e-6)).all(), (Ep, Ed)


# ================== first-class tier axis (Policy.freq_tiers, ISSUE 8)

DVFS_MODES = ("dvfs_paper", "dvfs_queue_aware")


def _tier_stream(n=30, seed=3, rate=0.8, **kw):
    """Contended mixed stream: enough queueing that tier choices interact
    with waits, node availability and (when capped) the power trace."""
    return make_stream_workload(JSCC_SYSTEMS, n, arrival="poisson",
                                rate=rate, seed=seed, pred_noise=0.05, **kw)


def assert_differential_dvfs(w, cfg):
    """jax == float64-mirror on the tier decision sequence: exact tier and
    system indices, close energies/starts/totals."""
    rj = simulate_jax(w, cfg)
    rp = simulate_py(w, cfg)
    np.testing.assert_array_equal(np.asarray(rj["system"]), rp["system"])
    np.testing.assert_array_equal(np.asarray(rj["tier"]), rp["tier"])
    np.testing.assert_allclose(np.asarray(rj["energy"]), rp["energy"],
                               rtol=1e-5)
    np.testing.assert_allclose(np.asarray(rj["start"]), rp["start"],
                               rtol=1e-5, atol=1e-3)
    np.testing.assert_allclose(float(rj["total_energy"]), rp["total_energy"],
                               rtol=1e-5)
    np.testing.assert_allclose(float(rj["makespan"]), rp["makespan"],
                               rtol=1e-5)
    return rj


def test_dvfs_registry_entries():
    for name in DVFS_MODES:
        pol = make_policy(name)
        assert pol.freq_tiers == (1.0, 0.8, 0.6)
        assert pol.tiered
    assert not make_policy("paper").tiered
    assert make_policy("paper").freq_tiers == (1.0,)


@pytest.mark.parametrize("mode", DVFS_MODES)
@pytest.mark.parametrize("warm", [True, False], ids=["warm", "cold"])
def test_differential_dvfs_fcfs(mode, warm):
    w = _tier_stream()
    k_job = np.full(len(w.prog), np.nan, np.float32)
    k_job[::4] = 0.6                       # per-job K opens deeper tiers
    rj = assert_differential_dvfs(
        replace(w, k_job=k_job),
        SimConfig(mode=mode, k=0.2, warm_start=warm, seed=3))
    if warm:
        assert (np.asarray(rj["tier"]) > 0).any(), \
            "warm DVFS run never left the unit tier (axis inert?)"


@pytest.mark.parametrize("mode", DVFS_MODES)
@pytest.mark.parametrize("queue", ["easy_backfill", "conservative"])
def test_differential_dvfs_backfill_queues(mode, queue):
    """Tier decisions through the batched EASY window and the hole-aware
    conservative reservations, jax == mirror."""
    w = _tier_stream(n=36, seed=7, rate=1.2)
    assert_differential_dvfs(
        w, SimConfig(mode=mode, k=0.4, warm_start=True, queue=queue,
                     queue_window=6))


def test_differential_dvfs_capped_event_core():
    """DVFS x finite power cap composes on the event-granular core; the
    mirror replays the tier-aware node-power table in float64."""
    w = _tier_stream(n=30, seed=9, rate=1.0)
    assert_differential_dvfs(
        w, SimConfig(mode="dvfs_paper", k=0.4, warm_start=True,
                     power_cap=50_000.0))


def test_differential_dvfs_outage_windows():
    outage = maintenance_windows(
        4, {2: [(0.0, 400.0)], 0: [(100.0, 250.0)]})
    w = _tier_stream(n=28, seed=5, rate=0.4, outage=outage)
    assert_differential_dvfs(
        w, SimConfig(mode="dvfs_paper", k=0.3, warm_start=True))


def test_differential_dvfs_trace_replay():
    swf = "\n".join(
        f"{i + 1} {i * 30} 0 {150 + 53 * i % 1200} {2 ** (2 + i % 6)} 100.0 "
        f"0 {2 ** (2 + i % 6)} 1000 0 1 1 1 1 1 1 -1 -1"
        for i in range(40)).splitlines()
    w = workload_from_trace(load_swf(swf), JSCC_SYSTEMS)
    for mode in DVFS_MODES:
        assert_differential_dvfs(
            w, SimConfig(mode=mode, k=0.4, warm_start=True))


def test_dvfs_totals_only_matches_full():
    """``totals_only=True`` must drop the per-job channels (tier included)
    without perturbing any total, bit for bit."""
    w = _tier_stream(n=25, seed=2)
    sched = Scheduler(make_policy("dvfs_paper", k=0.4), warm_start=True)
    full = sched.run(w)
    totals = sched.run(w, totals_only=True)
    assert totals.tier is None and totals.totals_only
    assert full.tier is not None
    for f in ("total_energy", "makespan", "total_wait", "max_wait",
              "peak_power", "idle_energy"):
        a, b = np.asarray(getattr(full, f)), np.asarray(getattr(totals, f))
        assert a.tobytes() == b.tobytes(), f"totals_only changed {f}"


def test_dvfs_saves_energy_on_npb():
    """With K slack the tier axis must find non-unit tiers and spend less
    energy than selection-only at the same K (the tier-0 candidates embed
    the plain decision space, so warm argmin-C can only improve)."""
    w = make_npb_workload(JSCC_SYSTEMS, repeats=2)
    base = Scheduler(make_policy("paper", k=0.5), warm_start=True).run(w)
    dvfs = Scheduler(make_policy("dvfs_paper", k=0.5), warm_start=True).run(w)
    counts = np.asarray(dvfs.tier_counts)
    assert counts.sum() == dvfs.n_jobs
    assert counts[1:].sum() > 0, "no job ever downclocked at K=0.5"
    assert float(dvfs.total_energy) < float(base.total_energy)
    # tier_energy partitions the job-attributed energy
    np.testing.assert_allclose(
        np.asarray(dvfs.tier_energy).sum(),
        np.asarray(dvfs.energy).sum(), rtol=1e-6)


def test_dispatcher_session_picks_nonunit_tier():
    """A live service session under ``dvfs_paper`` downclocks jobs and
    stays bit-identical to the batch event-core run — the tier channel
    survives the decision record, the result epilogue and checkpointing's
    per-job tree (ISSUE 8 service acceptance)."""
    from repro.service import Dispatcher

    w = _tier_stream(n=24, seed=4)
    pol = make_policy("dvfs_paper", k=0.5)
    qs = "easy_backfill:window=8"
    batch = Scheduler(pol, warm_start=True, queue=qs, engine="events").run(w)
    disp = Dispatcher(w, pol, warm_start=True, queue=qs)
    for j in range(len(w.prog)):
        disp.drive(until=float(w.arrival[j]))
        disp.submit(int(w.prog[j]), float(w.arrival[j]))
    decisions = disp.drain()
    res = disp.result()
    assert any(d["tier"] > 0 for d in disp.decisions), \
        "live session never picked a non-unit tier at K=0.5"
    assert decisions is not None
    np.testing.assert_array_equal(np.asarray(res.tier),
                                  np.asarray(batch.tier))
    assert res.freq_tiers == pol.freq_tiers
    for f in ("total_energy", "makespan", "total_wait", "peak_power"):
        a, b = np.asarray(getattr(batch, f)), np.asarray(getattr(res, f))
        assert a.tobytes() == b.tobytes(), \
            f"live session diverged from batch on {f}: {b} != {a}"


# ------------- deterministic tier-model / frontier invariants (shared
# with the hypothesis sweeps in tests/test_property_dvfs.py)

def assert_tier_monotone(w, tiers):
    """The power-model monotonicities on ``tier_tables_py`` outputs, for a
    strictly descending phi grid: downclocking stretches the compute
    phase and lowers the power it draws (the phi^3 law), monotonically
    in phi."""
    tt = tier_tables_py(w, tiers)
    Tc, Ec = phase_split(w)
    T = np.asarray(w.T_true, np.float64)
    E = np.asarray(w.E_true, np.float64)
    idle = (np.zeros(len(w.n_nodes)) if w.idle_w is None
            else np.asarray(w.idle_w, np.float64))
    n_idle = np.asarray(w.n_req, np.float64) * idle[None, :]
    comp = Tc > 1e-12
    for f in range(1, len(tiers)):
        assert tiers[f] < tiers[f - 1], "grid must be strictly descending"
        # compute-phase runtime grows as phi drops ...
        stretch_hi = np.asarray(tt["T"][:, f - 1, :]) - T
        stretch_lo = np.asarray(tt["T"][:, f, :]) - T
        assert (stretch_lo[comp] > stretch_hi[comp]).all()
        # ... and the compute-phase power draw shrinks (dynamic energy
        # E_comp * phi^2 over the stretched Tc / phi window)
        for a, b in ((f - 1, f),):
            e_hi = (np.asarray(tt["E"][:, a, :]) - E + Ec
                    - n_idle * stretch_hi)
            e_lo = (np.asarray(tt["E"][:, b, :]) - E + Ec
                    - n_idle * stretch_lo)
            p_hi = e_hi[comp] / (Tc + stretch_hi)[comp]
            p_lo = e_lo[comp] / (Tc + stretch_lo)[comp]
            assert (p_lo < p_hi * (1 + 1e-12)).all(), \
                "downclocking failed to lower compute-phase power"
    # tier 0 (and any duplicate unit tier) is the base table bit for bit
    for f, phi in enumerate(tiers):
        if phi == 1.0:
            assert np.asarray(tt["T"][:, f, :]).tobytes() == T.tobytes()
            assert np.asarray(tt["E"][:, f, :]).tobytes() == E.tobytes()


def assert_front_nondominated(energy, makespan):
    """``pareto_mask`` returns exactly the non-dominated points: nothing
    on the front is dominated, everything off it is."""
    e = np.asarray(energy, np.float64).ravel()
    m = np.asarray(makespan, np.float64).ravel()
    mask = pareto_mask(e, m)
    assert mask.any(), "a non-empty point set always has a frontier"
    dominated = np.array(
        [((e <= e[i]) & (m <= m[i]) & ((e < e[i]) | (m < m[i]))).any()
         for i in range(len(e))])
    np.testing.assert_array_equal(mask, ~dominated)
    return mask


def test_tier_monotone_npb():
    w = make_npb_workload(JSCC_SYSTEMS)
    assert_tier_monotone(w, (1.0, 0.9, 0.75, 0.6, 0.4))


def test_tier_monotone_trace_defaults():
    """Stream workloads have no explicit phase split; the engine default
    (all-compute, all-dynamic) must satisfy the same monotonicities."""
    assert_tier_monotone(_tier_stream(n=20, seed=1), (1.0, 0.8, 0.5))


def test_tier_tables_unit_grid_is_base():
    """A duplicate all-unit grid reproduces the base tables exactly in
    BOTH table builders (the f32 scan tables and the float64 mirror)."""
    from repro.core.engine import _workload_arrays
    w = _tier_stream(n=15, seed=6)
    arrs = _workload_arrays(w)
    tt = tier_tables(arrs, (1.0, 1.0))
    for f in range(2):
        for key, base in (("T", arrs["T_true"]), ("E", arrs["E_true"]),
                          ("C", arrs["C_true"]), ("w", arrs["w_pow"])):
            assert (np.asarray(tt[key][:, f, :]).tobytes()
                    == np.asarray(base).tobytes())
    assert_tier_monotone(w, (1.0,))        # degenerate grid: unit checks


def test_pareto_mask_deterministic():
    rng = np.random.default_rng(0)
    for n in (1, 2, 17, 60):
        e, m = rng.uniform(1.0, 10.0, (2, n))
        assert_front_nondominated(e, m)
    # ties survive together; a strictly better point kills both
    mask = pareto_mask([1.0, 1.0, 2.0], [5.0, 5.0, 4.0])
    assert mask.tolist() == [True, True, True]
    mask = pareto_mask([1.0, 1.0, 0.5], [5.0, 5.0, 5.0])
    assert mask.tolist() == [False, False, True]
