"""Hypothesis property tests for the scheduler's invariants — every mode
in ``algorithm.MODES`` is swept (hypothesis optional: suite skips cleanly
where the dev extra isn't installed; see requirements-dev.txt)."""

import pytest

pytest.importorskip("hypothesis")

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.algorithm import MODES, select_system

N_SYS = st.integers(min_value=2, max_value=6)


@st.composite
def tables(draw):
    n = draw(N_SYS)
    c = draw(st.lists(st.floats(0.001, 10.0), min_size=n, max_size=n))
    t = draw(st.lists(st.floats(1.0, 1e4), min_size=n, max_size=n))
    k = draw(st.floats(0.0, 2.0))
    return np.array(c), np.array(t), k


def run_mode(mode, c, t, k, runs=None, avail=None):
    n = len(c)
    return int(select_system(
        mode,
        c_row=jnp.asarray(c, jnp.float32), t_row=jnp.asarray(t, jnp.float32),
        runs_row=jnp.ones(n, jnp.int32) if runs is None
        else jnp.asarray(runs, jnp.int32),
        avail_row=jnp.zeros(n, jnp.float32) if avail is None
        else jnp.asarray(avail, jnp.float32), k=jnp.float32(k),
        c_pred_row=jnp.asarray(c, jnp.float32),
        t_pred_row=jnp.asarray(t, jnp.float32), key=jax.random.key(0)))


def run_paper(c, t, k):
    return run_mode("paper", c, t, k)


@settings(max_examples=60, deadline=None)
@given(tables())
def test_selection_always_feasible(tab):
    """Invariant: T[sel] <= T_min * (1 + K)  (the paper's constraint)."""
    c, t, k = tab
    sel = run_paper(c, t, k)
    # fp32 semantics inside the selector
    t32 = t.astype(np.float32)
    assert t32[sel] <= t32.min() * (1.0 + np.float32(k)) * (1 + 1e-6)


@settings(max_examples=60, deadline=None)
@given(tables())
def test_selection_minimizes_c_over_feasible(tab):
    c, t, k = tab
    sel = run_paper(c, t, k)
    c32, t32 = c.astype(np.float32), t.astype(np.float32)
    feasible = t32 <= t32.min() * (1.0 + np.float32(k)) * (1 + 1e-6)
    assert feasible[sel]
    assert c32[sel] <= c32[feasible].min() * (1 + 1e-5)


@settings(max_examples=40, deadline=None)
@given(tables())
def test_k_monotonicity_of_selected_c(tab):
    """Growing K can only unlock greener (or equal) selections."""
    c, t, _ = tab
    prev = np.inf
    for k in (0.0, 0.1, 0.3, 1.0, 3.0):
        sel = run_paper(c, t, k)
        assert c[sel] <= prev * (1 + 1e-6)
        prev = c[sel]


@settings(max_examples=40, deadline=None)
@given(tables())
def test_k_zero_is_fastest_tier(tab):
    """K=0 must select within the fastest tier (minimal T)."""
    c, t, _ = tab
    sel = run_paper(c, t, 0.0)
    t32 = t.astype(np.float32)
    assert t32[sel] <= t32.min() * (1 + 1e-6)


@settings(max_examples=30, deadline=None)
@given(tables(), st.integers(0, 5))
def test_exploration_prefers_first_released_unexplored(tab, seed):
    """With unexplored systems present, the algorithm must pick the
    earliest-available unexplored one (paper exploration rule)."""
    c, t, k = tab
    n = len(c)
    rng = np.random.default_rng(seed)
    runs = rng.integers(0, 2, n)
    if runs.all():
        runs[rng.integers(0, n)] = 0
    avail = rng.uniform(0, 100, n)
    sel = int(select_system(
        "paper",
        c_row=jnp.asarray(c * runs, jnp.float32),
        t_row=jnp.asarray(t * runs, jnp.float32),
        runs_row=jnp.asarray(runs, jnp.int32),
        avail_row=jnp.asarray(avail, jnp.float32), k=jnp.float32(k),
        c_pred_row=jnp.asarray(c, jnp.float32),
        t_pred_row=jnp.asarray(t, jnp.float32), key=jax.random.key(0)))
    unexplored = np.where(runs == 0)[0]
    assert sel in unexplored
    assert avail[sel] == avail[unexplored].min()


# --------------------------------------------------- whole-family properties

@pytest.mark.parametrize("mode", MODES)
@settings(max_examples=25, deadline=None)
@given(tables())
def test_every_mode_returns_valid_index(mode, tab):
    """Totality: every selector returns an index in range on fully-known
    tables, for any (C, T, K)."""
    c, t, k = tab
    sel = run_mode(mode, c, t, k)
    assert 0 <= sel < len(c)


@pytest.mark.parametrize("mode", MODES)
@settings(max_examples=25, deadline=None)
@given(tables(), st.integers(0, 5))
def test_every_mode_valid_with_unknowns(mode, tab, seed):
    """Totality under cold start: selectors must stay in range with any
    mix of explored/unexplored systems and arbitrary availability."""
    c, t, k = tab
    n = len(c)
    rng = np.random.default_rng(seed)
    runs = rng.integers(0, 2, n)
    avail = rng.uniform(0, 100, n)
    sel = int(select_system(
        mode,
        c_row=jnp.asarray(c * runs, jnp.float32),
        t_row=jnp.asarray(t * runs, jnp.float32),
        runs_row=jnp.asarray(runs, jnp.int32),
        avail_row=jnp.asarray(avail, jnp.float32), k=jnp.float32(k),
        c_pred_row=jnp.asarray(c, jnp.float32),
        t_pred_row=jnp.asarray(t, jnp.float32), key=jax.random.key(seed)))
    assert 0 <= sel < n


@settings(max_examples=30, deadline=None)
@given(tables())
def test_queue_aware_reduces_to_paper_when_no_queue(tab):
    """With identical availability everywhere, wait is uniformly zero and
    the queue-aware rule must coincide with the paper rule."""
    c, t, k = tab
    assert run_mode("queue_aware", c, t, k) == run_paper(c, t, k)


@settings(max_examples=30, deadline=None)
@given(tables())
def test_oracle_matches_paper_on_true_tables(tab):
    """Oracle evaluates the paper rule on the predicted(=true here) tables."""
    c, t, k = tab
    assert run_mode("oracle", c, t, k) == run_paper(c, t, k)


@settings(max_examples=30, deadline=None)
@given(tables())
def test_greenest_is_energy_lower_bound(tab):
    """No mode's fully-known selection beats greenest on C."""
    c, t, k = tab
    cg = c[run_mode("greenest", c, t, k)]
    for mode in ("paper", "queue_aware", "predictive", "ucb", "oracle"):
        assert cg <= c[run_mode(mode, c, t, k)] * (1 + 1e-6)


@settings(max_examples=30, deadline=None)
@given(tables())
def test_fastest_is_runtime_lower_bound(tab):
    c, t, k = tab
    tf = t[run_mode("fastest", c, t, k)]
    for mode in ("paper", "queue_aware", "predictive", "ucb", "oracle"):
        assert tf <= t[run_mode(mode, c, t, k)] * (1 + 1e-6)
