"""SUPPZ-style front-end tests (paper §Implementation)."""

import pytest

from repro.core.suppz import SuppzFrontend, Submission, program_id

SYS = ["KNL", "Broadwell", "Skylake", "CascadeLake"]


@pytest.fixture
def fe(tmp_path):
    return SuppzFrontend(str(tmp_path / "suppz.msgpack"), SYS)


def test_program_identity_is_executable_hash(fe):
    assert program_id(b"binary-A") != program_id(b"binary-B")
    assert program_id(b"binary-A") == program_id(b"binary-A")


def test_never_run_explores_first_released(fe):
    d = fe.submit(Submission(b"prog", np_=144, t_max=600.0),
                  availability=[5.0, 1.0, 3.0, 4.0])
    assert d.explored and d.system == "Broadwell"   # earliest available
    assert d.auto_queued


def test_pinned_type_is_notification_only(fe):
    d = fe.submit(Submission(b"prog", np_=144, t_max=600.0,
                             resource_type="Skylake"))
    assert not d.auto_queued          # user pinned: recommendation only


def test_learning_and_k_auto(fe):
    exe = b"my-solver-v1"
    # fill the tables (paper Tables 1-4 regime)
    profiles = {"KNL": (1.0, 150.0), "Broadwell": (2.8, 130.0),
                "Skylake": (1.7, 76.0), "CascadeLake": (1.4, 80.0)}
    for s, (c, t) in profiles.items():
        fe.report_completion(exe, s, c=c, t=t)
    # admin K=10%: CascadeLake (within 10% of Skylake, lower C)
    d = fe.submit(Submission(exe, np_=144, t_max=600.0, k=0.10))
    assert not d.explored and d.system == "CascadeLake"
    # K=0: fastest tier only
    d0 = fe.submit(Submission(exe, np_=144, t_max=600.0, k=0.0))
    assert d0.system == "Skylake"
    # auto-K from ordered time: t_max=83 vs best T=76 -> K ~ 9.2% -> CLK
    da = fe.submit(Submission(exe, np_=144, t_max=83.0))
    assert da.k_used == pytest.approx(83.0 / 76.0 - 1.0, rel=1e-6)
    assert da.system == "CascadeLake"


def test_persistence_across_restart(tmp_path):
    path = str(tmp_path / "db.msgpack")
    fe1 = SuppzFrontend(path, SYS)
    fe1.report_completion(b"p", "Skylake", c=1.5, t=100.0)
    fe1.submit(Submission(b"p", np_=16, t_max=200.0))
    fe2 = SuppzFrontend(path, SYS)       # restart
    ent = fe2.db["programs"][program_id(b"p")]
    assert ent["runs"]["Skylake"] == 1
    assert ent["T"]["Skylake"] == pytest.approx(100.0)


def test_repeat_completions_average(fe):
    exe = b"q"
    fe.report_completion(exe, "KNL", c=2.0, t=100.0)
    fe.report_completion(exe, "KNL", c=4.0, t=200.0)
    ent = fe.db["programs"][program_id(exe)]
    assert ent["C"]["KNL"] == pytest.approx(3.0)
    assert ent["T"]["KNL"] == pytest.approx(150.0)
    assert ent["runs"]["KNL"] == 2
