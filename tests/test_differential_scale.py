"""Differential harness at campaign scale: the vectorized numpy mirror
(``simulate_py``) against the jitted f32 engine on >=10k-job streams — the
whole policy registry (``policy_names()``, queue-bearing and DVFS entries
included) plus the explicit queue-override / event-core dispatch paths.

Placements must agree EXACTLY (system choice is the load-bearing output;
the float64/float32 gap cannot flip an argmin unless two candidates tie to
within f32 resolution, which the synthetic stream avoids).  Float totals
accumulate ~sqrt(J)·eps_f32 of drift at J=10^4, so they get a relaxed
relative tolerance instead of the 1e-5 used by the 25-job harness.

The conservative discipline is the one exception on per-job ``backfilled``
flags: over a 10^4-s horizon f32 reservation starts tie to within
resolution, and a tie flips WHICH pending slot realizes first (slot 0 vs a
backfill) without changing the chosen system — so those flags get a
count-band check instead of exact equality, and the wait sum (the one
total the realization order feeds back into, via table-update order)
gets a correspondingly wider band."""

import numpy as np
import pytest

from repro.core import JSCC_SYSTEMS, SimConfig, simulate_jax, simulate_py
from repro.core.policy import policy_names
from repro.data.scenarios import make_stream_workload

pytestmark = pytest.mark.slow          # ~10k-job engine runs per case

J_SCALE = 10_000
RTOL = 1e-4                            # f32 totals over 10^4-job sums

#: policies whose per-job backfilled flags are tie-order-sensitive
_TIE_ORDER_SENSITIVE = ("conservative",)


@pytest.fixture(scope="module")
def stream_10k():
    """10k-job mixed NPB stream, Poisson arrivals, noisy predictions."""
    return make_stream_workload(JSCC_SYSTEMS, J_SCALE, arrival="poisson",
                                rate=0.5, seed=3, pred_noise=0.05)


def assert_scale_differential(w, cfg, *, check_backfill=True):
    rj = simulate_jax(w, cfg)
    rp = simulate_py(w, cfg)
    np.testing.assert_array_equal(np.asarray(rj["system"]), rp["system"])
    if check_backfill:
        np.testing.assert_array_equal(np.asarray(rj["backfilled"]),
                                      rp["backfilled"])
    else:
        # realization order may flip on f32 ties; the count stays close
        assert abs(int(rj["n_backfilled"]) - rp["n_backfilled"]) \
            <= max(16, len(w.prog) // 100)
    np.testing.assert_allclose(float(rj["total_energy"]),
                               rp["total_energy"], rtol=RTOL)
    np.testing.assert_allclose(float(rj["makespan"]), rp["makespan"],
                               rtol=RTOL)
    np.testing.assert_allclose(float(rj["total_wait"]), rp["total_wait"],
                               rtol=RTOL if check_backfill else 5e-3,
                               atol=1.0)
    return rj, rp


@pytest.mark.parametrize("mode", policy_names())
def test_scale_whole_registry(stream_10k, mode):
    """Acceptance: every registered policy — legacy selectors, the
    backfilling disciplines, and the DVFS pair — differentially validated
    on a >=10k-job stream under its own default dispatch."""
    assert_scale_differential(
        stream_10k, SimConfig(mode=mode, k=0.1, warm_start=True, seed=3),
        check_backfill=mode not in _TIE_ORDER_SENSITIVE)


def test_scale_easy_queue_override(stream_10k):
    """queue="easy_backfill" forced onto a non-queue policy."""
    rj, rp = assert_scale_differential(
        stream_10k, SimConfig(mode="paper", k=0.1, warm_start=True,
                              queue="easy_backfill", queue_window=6))
    assert int(rj["n_backfilled"]) == rp["n_backfilled"]


def test_scale_event_core_override(stream_10k):
    """core="events" forced onto the FCFS arrival path."""
    assert_scale_differential(
        stream_10k, SimConfig(mode="paper", k=0.1, warm_start=True,
                              core="events"))
