"""Scheduler facade + structured results + legacy-shim equivalence.

Acceptance (ISSUE 2): for every legacy mode, ``simulate_jax`` / ``sweep_k``
/ ``run_campaign`` must produce bit-identical placements and totals to the
equivalent ``Scheduler(...).run(...)``; a single jitted ``Scheduler.run``
must vmap a >=32-point policy-hyperparameter grid without re-tracing; the
``totals_only`` path must match the full path's aggregates.
"""

import numpy as np
import pytest

from repro.core import (JSCC_SYSTEMS, FaultConfig, Scheduler, SimConfig,
                        CampaignResult, SimResult, make_npb_workload,
                        make_policy, policy_names, simulate_jax, sweep_k,
                        run_campaign, MODES)
from repro.core.engine import _batched_run
from repro.data.scenarios import make_stream_workload


@pytest.fixture(scope="module")
def stream():
    return make_stream_workload(JSCC_SYSTEMS, 30, arrival="poisson",
                                rate=0.1, seed=9, pred_noise=0.05)


# ------------------------------------------------------- deprecation shims

@pytest.mark.parametrize("mode", MODES)
def test_simulate_jax_shim_bit_identical(stream, mode):
    scfg = SimConfig(mode=mode, k=0.1, warm_start=True, seed=5)
    legacy = simulate_jax(stream, scfg)
    res = Scheduler(make_policy(mode, k=0.1), warm_start=True, seeds=5).run(
        stream)
    np.testing.assert_array_equal(np.asarray(legacy["system"]),
                                  np.asarray(res.system))
    for key in ("start", "finish", "energy", "total_energy", "makespan",
                "total_wait"):
        np.testing.assert_array_equal(np.asarray(legacy[key]),
                                      np.asarray(getattr(res, key)))


def test_sweep_k_shim_bit_identical(stream):
    ks = np.asarray([0.0, 0.1, 0.3], np.float32)
    legacy = sweep_k(stream, SimConfig(mode="paper", warm_start=True), ks)
    res = Scheduler(make_policy("paper", k=ks), warm_start=True).run(stream)
    assert res.axes == ("policy",)
    np.testing.assert_array_equal(np.asarray(legacy["system"]),
                                  np.asarray(res.system))
    np.testing.assert_array_equal(np.asarray(legacy["total_energy"]),
                                  np.asarray(res.total_energy))


def test_run_campaign_shim_bit_identical(stream):
    ks, seeds = [0.0, 0.2], [0, 1, 2]
    faults = [FaultConfig(), FaultConfig(straggler_prob=0.3)]
    scfg = SimConfig(mode="paper")
    legacy = run_campaign(stream, scfg, ks=ks, seeds=seeds, faults=faults)
    res = Scheduler(make_policy("paper", k=np.asarray(ks, np.float32)),
                    faults=faults, seeds=seeds).run(stream)
    assert res.axes == ("fault", "policy", "seed")
    assert np.asarray(res.total_energy).shape == (2, 2, 3)
    np.testing.assert_array_equal(np.asarray(legacy["system"]),
                                  np.asarray(res.system))
    np.testing.assert_array_equal(np.asarray(legacy["total_energy"]),
                                  np.asarray(res.total_energy))
    np.testing.assert_array_equal(np.asarray(legacy["makespan"]),
                                  np.asarray(res.makespan))


# ------------------------------------------------- campaign memory (totals)

def test_totals_only_matches_full_path(stream):
    pol = make_policy("paper", k=np.asarray([0.0, 0.1], np.float32))
    sched = Scheduler(pol, seeds=[0, 1], warm_start=False)
    full = sched.run(stream)
    tot = sched.run(stream, totals_only=True)
    assert tot.totals_only and tot.system is None and tot.start is None
    assert not full.totals_only
    for key in ("total_energy", "makespan", "total_wait", "slowdown_sum",
                "busy"):
        np.testing.assert_allclose(np.asarray(getattr(tot, key)),
                                   np.asarray(getattr(full, key)),
                                   rtol=2e-5)
    np.testing.assert_array_equal(np.asarray(tot.runs),
                                  np.asarray(full.runs))
    np.testing.assert_allclose(np.asarray(tot.mean_slowdown),
                               np.asarray(full.mean_slowdown), rtol=2e-5)
    np.testing.assert_allclose(np.asarray(tot.utilization),
                               np.asarray(full.utilization), rtol=2e-5)


def test_totals_only_compensated_sum_long_stream():
    """The Kahan-compensated carry must track the full path's array
    reduction tightly even over thousands of sequential f32 adds."""
    w = make_stream_workload(JSCC_SYSTEMS, 2000, arrival="poisson",
                             rate=0.5, seed=3)
    sched = Scheduler(make_policy("paper", k=0.1), warm_start=True)
    full = sched.run(w)
    tot = sched.run(w, totals_only=True)
    np.testing.assert_allclose(float(tot.total_energy),
                               float(full.total_energy), rtol=1e-5)
    np.testing.assert_allclose(float(tot.slowdown_sum),
                               float(full.slowdown_sum), rtol=1e-5)


# ------------------------------------- policy-hyperparameter grid, one jit

def test_policy_grid_32_points_single_compile(stream):
    kk, uu = np.meshgrid(np.linspace(0.0, 0.35, 8).astype(np.float32),
                         np.asarray([0.25, 0.5, 0.75, 1.0], np.float32))
    pol = make_policy("ucb", k=kk.ravel(), ucb_scale=uu.ravel())
    cache0 = _batched_run._cache_size()
    res = Scheduler(pol).run(stream, totals_only=True)
    assert _batched_run._cache_size() - cache0 <= 1, \
        "32-point hyperparameter grid must share one compilation"
    E = np.asarray(res.total_energy)
    assert E.shape == (32,)
    assert np.isfinite(E).all() and (E > 0).all()
    # second run with different grid VALUES (same shape): cache hit
    pol2 = pol.with_params(k=kk.ravel() + 0.01)
    cache1 = _batched_run._cache_size()
    Scheduler(pol2).run(stream, totals_only=True)
    assert _batched_run._cache_size() == cache1


# --------------------------------------------------------- structured results

def test_simresult_metrics(stream):
    res = Scheduler("paper", warm_start=True).run(stream)
    assert isinstance(res, SimResult) and not isinstance(res, CampaignResult)
    assert res.axes == () and res.n_jobs == 30
    assert float(res.mean_slowdown) >= 1.0 - 1e-6
    util = np.asarray(res.utilization)
    assert util.shape == (4,)
    assert (util >= 0).all() and (util <= 1 + 1e-6).all()
    busy = np.asarray(res.busy)
    np.testing.assert_allclose(
        busy.sum(), float((np.asarray(res.runtime)
                           * np.asarray(res.nodes)).sum()), rtol=1e-6)
    d = res.to_dict()
    for key in ("system", "total_energy", "mean_slowdown", "utilization"):
        assert key in d
    assert "system" not in res.to_dict(arrays=False)


def test_campaign_result_axes_and_index(stream):
    faults = [FaultConfig(), FaultConfig(straggler_prob=0.5)]
    res = Scheduler(make_policy("paper", k=np.asarray([0.0, 0.1], np.float32)),
                    faults=faults, seeds=[0, 1, 2]).run(stream)
    assert isinstance(res, CampaignResult)
    assert res.axes == ("fault", "policy", "seed")
    assert set(res.coords) == {"fault", "policy", "seed"}
    one = res.index(fault=1, policy=0, seed=2)
    assert isinstance(one, SimResult) and one.axes == ()
    np.testing.assert_array_equal(np.asarray(one.system),
                                  np.asarray(res.system)[1, 0, 2])
    part = res.index(seed=0)
    assert part.axes == ("fault", "policy")
    with pytest.raises(KeyError):
        res.index(bogus=0)
    with pytest.raises(TypeError, match="integer points"):
        res.index(seed=slice(0, 2))


# ------------------------------------------------- queue-discipline axis

@pytest.mark.parametrize("name", [n for n in policy_names()
                                  if make_policy(n).queue == "fcfs"])
def test_explicit_fcfs_bit_identical_per_mode(stream, name):
    """Acceptance (ISSUE 3): --queue fcfs must reproduce the pre-axis
    engine bit for bit, asserted per registered policy.  The legacy
    ``simulate_jax`` path is the pre-axis behaviour anchor (its own
    bit-identity to the seed engine is pinned by the differential and
    shim suites above)."""
    legacy = simulate_jax(stream, SimConfig(mode=name, k=0.1,
                                            warm_start=True, seed=2))
    res = Scheduler(make_policy(name, k=0.1), warm_start=True, seeds=2,
                    queue="fcfs").run(stream)
    np.testing.assert_array_equal(np.asarray(legacy["system"]),
                                  np.asarray(res.system))
    for key in ("start", "finish", "total_energy", "makespan"):
        np.testing.assert_array_equal(np.asarray(legacy[key]),
                                      np.asarray(getattr(res, key)))
    assert int(res.n_backfilled) == 0
    assert not np.asarray(res.backfilled).any()


def test_legacy_shims_honor_queue_override(stream):
    """sweep_k / run_campaign must respect SimConfig.queue, not silently
    fall back to FCFS (regression: the shims rebuilt the policy from
    scfg.mode and dropped the override)."""
    scfg = SimConfig(mode="paper", warm_start=True, queue="easy_backfill",
                     queue_window=4)
    ks = [0.0, 0.1]
    swept = sweep_k(stream, scfg, ks)
    camp = run_campaign(stream, scfg, ks=ks, seeds=[0])
    for i, k in enumerate(ks):
        single = Scheduler(make_policy("easy_backfill", k=k, window=4),
                           warm_start=True).run(stream)
        np.testing.assert_array_equal(np.asarray(swept["system"])[i],
                                      np.asarray(single.system))
        np.testing.assert_array_equal(np.asarray(camp["system"])[i, 0],
                                      np.asarray(single.system))


def test_legacy_shims_honor_power_cap_and_conservative(stream):
    """sweep_k / run_campaign must pass the new power_cap and
    queue="conservative" config keys through to the engine (ISSUE 5:
    same class of bug as the PR 3 queue-override drop — the shims
    rebuild the policy from scfg and silently dropped new knobs)."""
    cap = 47_000.0
    scfg = SimConfig(mode="paper", warm_start=True, queue="conservative",
                     queue_window=6, power_cap=cap)
    ks = [0.0, 0.1]
    swept = sweep_k(stream, scfg, ks)
    camp = run_campaign(stream, scfg, ks=ks, seeds=[0])
    assert float(np.asarray(swept["peak_power"]).max()) <= cap * (1 + 1e-6)
    for i, k in enumerate(ks):
        single = Scheduler(make_policy("conservative", k=k, window=6),
                           power_cap=cap, warm_start=True).run(stream)
        np.testing.assert_array_equal(np.asarray(swept["system"])[i],
                                      np.asarray(single.system))
        np.testing.assert_array_equal(np.asarray(camp["system"])[i, 0],
                                      np.asarray(single.system))
        np.testing.assert_array_equal(
            np.asarray(swept["peak_power"])[i],
            np.asarray(single.peak_power))
        np.testing.assert_array_equal(
            np.asarray(swept["capped_delay"])[i],
            np.asarray(single.capped_delay))


def test_scheduler_queue_kwarg_overrides_policy():
    s = Scheduler("paper", queue="easy_backfill:window=4")
    assert s.policy.queue == "easy_backfill" and s.policy.window == 4
    s2 = Scheduler("easy_backfill", queue="fcfs")
    assert s2.policy.queue == "fcfs"
    with pytest.raises(ValueError, match="unknown queue"):
        Scheduler("paper", queue="lifo")


def test_easy_backfill_metrics_and_grid(stream):
    """Backfill metrics flow through CampaignResult axes, .index(), and
    the totals_only path; the K-grid easy run shares one compilation."""
    ks = np.asarray([0.0, 0.1], np.float32)
    pol = make_policy("easy_backfill", k=ks, window=6)
    sched = Scheduler(pol, seeds=[0, 1], warm_start=True)
    full = sched.run(stream)
    assert full.axes == ("policy", "seed")
    assert np.asarray(full.n_backfilled).shape == (2, 2)
    assert np.asarray(full.backfilled).shape == (2, 2, 30)
    assert np.asarray(full.max_wait).shape == (2, 2)
    one = full.index(policy=0, seed=1)
    assert np.asarray(one.backfilled).shape == (30,)
    np.testing.assert_array_equal(
        np.asarray(one.backfilled).sum(), np.asarray(one.n_backfilled))
    d = one.to_dict()
    for key in ("n_backfilled", "max_wait", "backfill_rate", "backfilled"):
        assert key in d
    tot = sched.run(stream, totals_only=True)
    assert tot.backfilled is None
    np.testing.assert_array_equal(np.asarray(tot.n_backfilled),
                                  np.asarray(full.n_backfilled))
    np.testing.assert_allclose(np.asarray(tot.total_wait),
                               np.asarray(full.total_wait),
                               rtol=2e-5, atol=1e-2)
    np.testing.assert_allclose(np.asarray(tot.max_wait),
                               np.asarray(full.max_wait),
                               rtol=2e-5, atol=1e-2)


def test_easy_backfill_grid_single_compile(stream):
    """The queue discipline keeps hyperparameter leaves batched: a K x ucb
    grid under easy_backfill is still ONE compilation."""
    kk = np.linspace(0.0, 0.3, 8).astype(np.float32)
    pol = make_policy("easy_backfill", k=kk, window=4)
    cache0 = _batched_run._cache_size()
    res = Scheduler(pol).run(stream, totals_only=True)
    assert _batched_run._cache_size() - cache0 <= 1
    assert np.asarray(res.total_energy).shape == (8,)


def test_scheduler_accepts_name_or_policy(stream):
    r1 = Scheduler("greenest", warm_start=True).run(stream)
    r2 = Scheduler(make_policy("greenest"), warm_start=True).run(stream)
    np.testing.assert_array_equal(np.asarray(r1.system),
                                  np.asarray(r2.system))


def test_seed_axis_changes_faulty_runs():
    w = make_npb_workload(JSCC_SYSTEMS, repeats=3)
    res = Scheduler("paper", seeds=range(4), warm_start=True,
                    faults=FaultConfig(straggler_prob=0.5)).run(w)
    assert res.axes == ("seed",)
    E = np.asarray(res.total_energy)
    assert len(np.unique(E)) > 1          # fault draws differ per seed
