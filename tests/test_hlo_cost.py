"""HLO cost analyzer: trip-count multiplication, dot flops, collective
bytes — validated against modules with analytically-known costs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.utils.hlo_cost import analyze_hlo, parse_hlo_module
from repro.utils.hlo import parse_collective_bytes


def _compiled_hlo(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_single_dot_flops_exact():
    m, k, n = 128, 256, 64
    hlo = _compiled_hlo(
        lambda a, b: a @ b,
        jax.ShapeDtypeStruct((m, k), jnp.float32),
        jax.ShapeDtypeStruct((k, n), jnp.float32))
    res = analyze_hlo(hlo)
    expect = 2.0 * m * k * n
    assert res["flops"] == pytest.approx(expect, rel=0.05)


def test_scan_trip_count_multiplies():
    m = 64
    a_spec = jax.ShapeDtypeStruct((m, m), jnp.float32)

    def body(x, _):
        return x @ x, None

    def once(a):
        return a @ a

    def scanned(a):
        out, _ = jax.lax.scan(body, a, None, length=17)
        return out

    f1 = analyze_hlo(_compiled_hlo(once, a_spec))["flops"]
    f17 = analyze_hlo(_compiled_hlo(scanned, a_spec))["flops"]
    assert f17 == pytest.approx(17 * f1, rel=0.15)


def test_batched_dot_flops():
    b, m, k, n = 4, 32, 64, 16
    hlo = _compiled_hlo(
        lambda a, c: jnp.einsum("bmk,bkn->bmn", a, c),
        jax.ShapeDtypeStruct((b, m, k), jnp.float32),
        jax.ShapeDtypeStruct((b, k, n), jnp.float32))
    res = analyze_hlo(hlo)
    assert res["flops"] == pytest.approx(2.0 * b * m * k * n, rel=0.05)


def test_memory_bytes_elementwise_stream():
    n = 1 << 20
    hlo = _compiled_hlo(lambda x: x * 2.0 + 1.0,
                        jax.ShapeDtypeStruct((n,), jnp.float32))
    res = analyze_hlo(hlo)
    # read + write one fused stream: ~8 MB (allow fusion-model slack)
    assert 0.5 * 8e6 <= res["mem_bytes"] <= 3 * 8e6


def test_parse_module_structure():
    hlo = _compiled_hlo(lambda x: jnp.tanh(x).sum(),
                        jax.ShapeDtypeStruct((128, 128), jnp.float32))
    comps = parse_hlo_module(hlo)
    assert len(comps) >= 1
    entry = [c for c in comps.values() if c.instrs]
    assert entry
    # every computation tracked symbol shapes
    for c in comps.values():
        for inst in c.instrs:
            assert inst.name in c.symbols


def test_fused_dot_flops_counted():
    """A dot folded into a fusion must still contribute its flops (fusion
    interiors count flops; memory is boundary-level)."""
    m, k, n = 64, 128, 32
    hlo = _compiled_hlo(
        lambda a, b, c: jnp.maximum(a @ b + c, 0.0),
        jax.ShapeDtypeStruct((m, k), jnp.float32),
        jax.ShapeDtypeStruct((k, n), jnp.float32),
        jax.ShapeDtypeStruct((m, n), jnp.float32))
    res = analyze_hlo(hlo)
    assert res["flops"] >= 2.0 * m * k * n * 0.95, res["flops"]


_UNFUSED_HLO = """\
HloModule manual

ENTRY %main (a: f32[8,16], b: f32[16,4]) -> f32[8,4] {
  %a = f32[8,16]{1,0} parameter(0)
  %b = f32[16,4]{1,0} parameter(1)
  ROOT %dot.1 = f32[8,4]{1,0} dot(f32[8,16]{1,0} %a, f32[16,4]{1,0} %b), lhs_contracting_dims={1}, rhs_contracting_dims={0}
}
"""

_BARE_OPERAND_HLO = """\
HloModule manual

ENTRY %main (a: f32[8,16], b: f32[16,4]) -> f32[8,4] {
  %a = f32[8,16]{1,0} parameter(0)
  %b = f32[16,4]{1,0} parameter(1)
  ROOT %dot.1 = f32[8,4]{1,0} dot(%a, %b), lhs_contracting_dims={1}, rhs_contracting_dims={0}
}
"""


@pytest.mark.parametrize("text", [_UNFUSED_HLO, _BARE_OPERAND_HLO],
                         ids=["typed-operands", "bare-operands"])
def test_dot_flops_both_operand_syntaxes(text):
    """XLA emits 'dot(f32[..] %a, ..)' (typed) or 'dot(%a, ..)' (bare)
    depending on version; the contracting-dim flops must parse from both."""
    res = analyze_hlo(text)
    assert res["flops"] == pytest.approx(2.0 * 8 * 16 * 4)


def test_collective_census_on_psum():
    try:
        devs = jax.devices()
    except RuntimeError:
        pytest.skip("no devices")
    if len(devs) < 2:
        # single device: psum compiles away; just ensure parser tolerance
        hlo = _compiled_hlo(lambda x: x + 1, jax.ShapeDtypeStruct((4,), jnp.float32))
        out = parse_collective_bytes(hlo)
        assert out["link_bytes"] == 0.0
        return
