"""Full differential harness: simulate_py == simulate_jax over the whole
selector family (every mode in algorithm.MODES) x warm/cold start x per-job
K overrides x scenario features (staggered arrivals, maintenance windows,
trace replay), plus bit-exactness of the kth-free placement kernel against
the jnp.sort oracle and the campaign grid's consistency with single runs."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import (JSCC_SYSTEMS, SimConfig, FaultConfig,
                        make_npb_workload, simulate_jax, simulate_py,
                        run_campaign, MODES)
from repro.data.scenarios import (make_stream_workload, maintenance_windows,
                                  load_swf, workload_from_trace)
from repro.kernels.kth_free import (kth_free_ref, kth_free_pallas,
                                    radix_select_kth)


@pytest.fixture(scope="module")
def stream():
    """25 mixed jobs, staggered Poisson arrivals, per-job K overrides on
    every 5th job, noisy predictions — exercises every selector input."""
    rng = np.random.default_rng(1)
    order = tuple(rng.choice(["BT", "EP", "IS", "LU", "SP"], 25))
    arrivals = np.cumsum(rng.exponential(30.0, 25)).astype(np.float32)
    k_job = np.full(25, np.nan, np.float32)
    k_job[::5] = 0.3
    return make_npb_workload(JSCC_SYSTEMS, order=order, arrivals=arrivals,
                             k_job=k_job, pred_noise=0.10)


def assert_differential(w, cfg):
    rj = simulate_jax(w, cfg)
    rp = simulate_py(w, cfg)
    np.testing.assert_array_equal(np.asarray(rj["system"]), rp["system"])
    np.testing.assert_allclose(np.asarray(rj["energy"]), rp["energy"],
                               rtol=1e-5)
    np.testing.assert_allclose(np.asarray(rj["start"]), rp["start"],
                               rtol=1e-5, atol=1e-3)
    np.testing.assert_allclose(float(rj["total_energy"]), rp["total_energy"],
                               rtol=1e-5)
    np.testing.assert_allclose(float(rj["makespan"]), rp["makespan"],
                               rtol=1e-5)


@pytest.mark.parametrize("mode", MODES)
@pytest.mark.parametrize("warm", [True, False], ids=["warm", "cold"])
def test_differential_all_modes(stream, mode, warm):
    assert_differential(stream, SimConfig(mode=mode, k=0.1, warm_start=warm,
                                          seed=3))


@pytest.mark.parametrize("mode", ["paper", "queue_aware", "random"])
def test_differential_per_job_k_extremes(stream, mode):
    """K overrides spanning 0 (fastest tier) to huge (pure greenest)."""
    rng = np.random.default_rng(7)
    k_job = rng.choice([0.0, 0.05, 0.5, 5.0], len(stream.prog)).astype(np.float32)
    from dataclasses import replace
    w = replace(stream, k_job=k_job)
    assert_differential(w, SimConfig(mode=mode, k=0.1, warm_start=True))


@pytest.mark.parametrize("mode", ["paper", "first_free", "queue_aware",
                                  "predictive"])
def test_differential_with_outage_windows(mode):
    outage = maintenance_windows(
        4, {2: [(0.0, 500.0), (800.0, 900.0)], 0: [(100.0, 300.0)]})
    w = make_stream_workload(JSCC_SYSTEMS, 30, arrival="poisson", rate=0.05,
                             seed=5, outage=outage)
    assert_differential(w, SimConfig(mode=mode, k=0.1))


def test_differential_trace_replay():
    swf = "\n".join(
        f"{i+1} {i*40} 0 {120 + 37*i % 900} {2 ** (2 + i % 6)} 100.0 0 "
        f"{2 ** (2 + i % 6)} 1000 0 1 1 1 1 1 1 -1 -1"
        for i in range(40)).splitlines()
    w = workload_from_trace(load_swf(swf), JSCC_SYSTEMS)
    for mode in ("paper", "fastest", "oracle"):
        assert_differential(w, SimConfig(mode=mode, k=0.2))


def test_no_notimplemented_paths():
    """Acceptance: simulate_py must cover every mode in MODES."""
    w = make_npb_workload(JSCC_SYSTEMS)
    for mode in MODES:
        simulate_py(w, SimConfig(mode=mode, k=0.1, warm_start=True))


# ------------------------------------------- EASY backfilling differentials

def _contended_stream(n=50, rate=1.2, kind="poisson", seed=3):
    """High arrival rate => real queueing, so the EASY window actually
    holds heads and evaluates backfill candidates."""
    return make_stream_workload(JSCC_SYSTEMS, n, arrival=kind, rate=rate,
                                seed=seed, pred_noise=0.05)


@pytest.mark.parametrize("warm", [True, False], ids=["warm", "cold"])
@pytest.mark.parametrize("window", [2, 8])
def test_differential_easy_backfill(warm, window):
    """jax == python across the reservation/backfill decision sequence,
    warm and cold tables, small and default windows."""
    w = _contended_stream()
    cfg = SimConfig(mode="easy_backfill", k=0.1, warm_start=warm,
                    queue_window=window)
    assert_differential(w, cfg)
    # the mirror's n_backfilled must agree too (placement ORDER, not just
    # final placements)
    rj = simulate_jax(w, cfg)
    rp = simulate_py(w, cfg)
    np.testing.assert_array_equal(np.asarray(rj["backfilled"]),
                                  rp["backfilled"])
    assert int(rj["n_backfilled"]) == rp["n_backfilled"]


@pytest.mark.parametrize("mode", ["queue_aware", "fastest", "predictive"])
def test_differential_easy_composes_with_selectors(mode):
    """The queue discipline is an orthogonal axis: any selector composes
    with easy_backfill and stays differentially exact."""
    w = _contended_stream(n=40, kind="bursty", rate=0.8, seed=5)
    assert_differential(w, SimConfig(mode=mode, k=0.1, warm_start=True,
                                     queue="easy_backfill", queue_window=4))


def test_differential_easy_with_outage_windows():
    outage = maintenance_windows(
        4, {1: [(0.0, 400.0)], 3: [(50.0, 250.0)]})
    w = make_stream_workload(JSCC_SYSTEMS, 35, arrival="poisson", rate=0.8,
                             seed=8, outage=outage)
    assert_differential(w, SimConfig(mode="easy_backfill", k=0.1,
                                     warm_start=True, queue_window=6))


def test_differential_easy_trace_replay():
    swf = "\n".join(
        f"{i+1} {i*15} 0 {200 + 61*i % 2400} {2 ** (2 + i % 7)} 100.0 0 "
        f"{2 ** (2 + i % 7)} 1000 0 1 1 1 1 1 1 -1 -1"
        for i in range(50)).splitlines()
    w = workload_from_trace(load_swf(swf), JSCC_SYSTEMS)
    assert_differential(w, SimConfig(mode="easy_backfill", k=0.2,
                                     warm_start=True))


def test_differential_easy_window_full_fallback():
    """window=1 leaves no backfill slots: every placement is the forced
    head (FCFS fallback), so placements must be identical to fcfs — and
    the python mirror must agree."""
    w = _contended_stream(n=30)
    cfg = SimConfig(mode="paper", k=0.1, warm_start=True,
                    queue="easy_backfill", queue_window=1)
    assert_differential(w, cfg)
    easy = simulate_jax(w, cfg)
    fcfs = simulate_jax(w, SimConfig(mode="paper", k=0.1, warm_start=True))
    np.testing.assert_array_equal(np.asarray(easy["system"]),
                                  np.asarray(fcfs["system"]))
    np.testing.assert_array_equal(np.asarray(easy["start"]),
                                  np.asarray(fcfs["start"]))
    assert int(easy["n_backfilled"]) == 0


def _blocking_workload(n_ep=4):
    """Hand-built EASY showcase on the real NPB tables: with K huge every
    job picks min-C KNL (38 nodes); LU needs 4 nodes there, so ten LUs
    saturate it (9 run, the 10th is the held head reserving the first LU
    finish); EP needs only 2 nodes — the idle pair — and runs ~8 s, far
    inside the ~100 s reservation gap."""
    from dataclasses import replace
    order = ("LU",) * 10 + ("EP",) * n_ep
    w = make_npb_workload(JSCC_SYSTEMS, order=order,
                          arrivals=np.zeros(len(order), np.float32))
    return replace(w, k_job=np.full(len(order), 5.0, np.float32))


def test_easy_backfill_never_delays_head():
    """The EASY no-delay guard: the narrow EP jobs backfill into the
    2-node gap under the head's reservation, and the held head (10th LU)
    starts exactly when it would under FCFS."""
    w = _blocking_workload()
    cfg = SimConfig(mode="paper", warm_start=True,
                    queue="easy_backfill", queue_window=8)
    assert_differential(w, cfg)
    fcfs = simulate_jax(w, SimConfig(mode="paper", warm_start=True))
    easy = simulate_jax(w, cfg)
    f_start = np.asarray(fcfs["start"])
    e_start = np.asarray(easy["start"])
    # the held head is not delayed by the backfills
    np.testing.assert_allclose(e_start[9], f_start[9], rtol=1e-6)
    # no job starts later than under FCFS in this scenario
    assert (e_start <= f_start * (1 + 1e-6) + 1e-3).all()
    # the EPs really did jump the queue
    assert np.asarray(easy["backfilled"])[10:].all()


def test_easy_backfill_improves_wait_when_gap_exists():
    """The blocked wide head + narrow short jobs: EASY strictly beats
    FCFS wait (the gap under the reservation is capacity FCFS wastes),
    and the metrics fields report it."""
    w = _blocking_workload()
    fcfs = simulate_jax(w, SimConfig(mode="paper", warm_start=True))
    easy = simulate_jax(w, SimConfig(mode="paper", warm_start=True,
                                     queue="easy_backfill", queue_window=8))
    assert float(easy["total_wait"]) < float(fcfs["total_wait"])
    assert float(easy["max_wait"]) <= float(fcfs["max_wait"]) + 1e-3
    assert int(easy["n_backfilled"]) >= 4
    # the python mirror agrees on the improvement, not just placements
    rp = simulate_py(w, SimConfig(mode="paper", warm_start=True,
                                  queue="easy_backfill", queue_window=8))
    np.testing.assert_allclose(float(easy["total_wait"]), rp["total_wait"],
                               rtol=1e-5, atol=1e-3)


# ------------------------------------------------- kth-free placement kernel

def test_kth_free_matches_sort_bitexact():
    """Radix select == jnp.sort oracle, bit for bit, across shapes, ties,
    BIG sentinels and full k range."""
    rng = np.random.default_rng(0)
    for _ in range(20):
        S = int(rng.integers(2, 9))
        N = int(rng.integers(3, 260))
        free = rng.uniform(0, 1e7, (S, N)).astype(np.float32)
        free[rng.random((S, N)) < 0.25] = 1e30       # nonexistent nodes
        free[rng.random((S, N)) < 0.25] = 0.0        # idle ties
        nreq = rng.integers(1, N + 1, S).astype(np.int32)
        ref = np.asarray(kth_free_ref(jnp.asarray(free), jnp.asarray(nreq)))
        sel = np.asarray(radix_select_kth(jnp.asarray(free), jnp.asarray(nreq)))
        np.testing.assert_array_equal(ref, sel)


def test_kth_free_pallas_interpret_matches_sort():
    rng = np.random.default_rng(1)
    free = rng.uniform(0, 1e6, (4, 136)).astype(np.float32)
    free[:, 100:] = 1e30
    nreq = np.array([2, 5, 8, 3], np.int32)
    ref = np.asarray(kth_free_ref(jnp.asarray(free), jnp.asarray(nreq)))
    pal = np.asarray(kth_free_pallas(jnp.asarray(free), jnp.asarray(nreq),
                                     interpret=True))
    np.testing.assert_array_equal(ref, pal)


def test_simulator_identical_under_all_placers():
    """The engine's answer must not depend on the placement backend."""
    w = make_stream_workload(JSCC_SYSTEMS, 40, arrival="bursty", rate=0.2,
                             seed=2)
    base = simulate_jax(w, SimConfig(mode="paper", k=0.1, placer="sort"))
    for placer in ("jnp", "pallas_interpret"):
        r = simulate_jax(w, SimConfig(mode="paper", k=0.1, placer=placer))
        np.testing.assert_array_equal(np.asarray(base["system"]),
                                      np.asarray(r["system"]))
        np.testing.assert_array_equal(np.asarray(base["start"]),
                                      np.asarray(r["start"]))


# --------------------------------------------------------------- campaigns

def test_campaign_grid_matches_single_runs():
    """run_campaign[K, R] must reproduce independent simulate_jax calls."""
    w = make_stream_workload(JSCC_SYSTEMS, 30, arrival="poisson", rate=0.1,
                             seed=4)
    ks, seeds = [0.0, 0.1], [0, 1]
    cfg = SimConfig(mode="paper", straggler_prob=0.2, straggler_factor=2.0)
    res = run_campaign(w, cfg, ks=ks, seeds=seeds)
    assert np.asarray(res["total_energy"]).shape == (2, 2)
    for i, k in enumerate(ks):
        for r, seed in enumerate(seeds):
            single = simulate_jax(w, SimConfig(
                mode="paper", k=k, seed=seed,
                straggler_prob=0.2, straggler_factor=2.0))
            np.testing.assert_array_equal(
                np.asarray(res["system"])[i, r], np.asarray(single["system"]))
            np.testing.assert_allclose(
                float(np.asarray(res["total_energy"])[i, r]),
                float(single["total_energy"]), rtol=1e-6)


def test_campaign_fault_axis():
    w = make_stream_workload(JSCC_SYSTEMS, 20, seed=6)
    res = run_campaign(
        w, SimConfig(mode="paper"), ks=[0.1], seeds=[0],
        faults=[FaultConfig(), FaultConfig(straggler_prob=1.0,
                                           straggler_factor=3.0)])
    E = np.asarray(res["total_energy"])
    assert E.shape == (2, 1, 1)
    assert E[1] > E[0] * 1.5            # universal stragglers cost energy


@pytest.mark.slow
def test_campaign_10k_jobs_single_jit():
    """Acceptance: a 10,000-job stream over an 8-K x 4-seed grid in one
    jitted call."""
    w = make_stream_workload(JSCC_SYSTEMS, 10_000, arrival="poisson",
                             rate=0.5, seed=0)
    res = run_campaign(w, SimConfig(mode="paper", straggler_prob=0.02),
                       ks=np.linspace(0.0, 0.35, 8), seeds=range(4))
    E = np.asarray(res["total_energy"])
    assert E.shape == (8, 4)
    assert np.isfinite(E).all() and (E > 0).all()
    assert np.asarray(res["system"]).shape == (8, 4, 10_000)
    # more K slack never costs energy on average
    assert E.mean(axis=1)[-1] <= E.mean(axis=1)[0]
