"""Checkpoint manager: roundtrip, atomicity, GC, exact-resume equivalence."""

import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.configs import get_config, smoke_reduce
from repro.configs.base import ShapeConfig
from repro.models import build_model
from repro.optim import AdamWConfig
from repro.train import LoopConfig, run_training


@pytest.fixture
def tmpdir_ck(tmp_path):
    return str(tmp_path / "ck")


def _tree(key):
    k1, k2 = jax.random.split(key)
    return {"a": jax.random.normal(k1, (8, 4)),
            "nested": {"b": jax.random.normal(k2, (3,)),
                       "c": jnp.int32(7)}}


def test_roundtrip(tmpdir_ck):
    mgr = CheckpointManager(tmpdir_ck)
    tree = _tree(jax.random.key(0))
    mgr.save(10, tree, metadata={"note": "x"}, blocking=True)
    out, step, meta = mgr.restore(tree)
    assert step == 10 and meta["note"] == "x"
    jax.tree.map(lambda a, b: np.testing.assert_allclose(a, b), tree, out)


def test_async_save_then_restore(tmpdir_ck):
    mgr = CheckpointManager(tmpdir_ck)
    tree = _tree(jax.random.key(1))
    mgr.save(5, tree)            # async
    mgr.wait()
    out, step, _ = mgr.restore(tree)
    assert step == 5


def test_gc_keeps_latest_n(tmpdir_ck):
    mgr = CheckpointManager(tmpdir_ck, keep_n=2)
    tree = _tree(jax.random.key(2))
    for s in (1, 2, 3, 4):
        mgr.save(s, tree, blocking=True)
    assert mgr.all_steps() == [3, 4]


def test_partial_checkpoint_invisible(tmpdir_ck):
    """A crash mid-write must not surface a corrupt checkpoint."""
    mgr = CheckpointManager(tmpdir_ck)
    tree = _tree(jax.random.key(3))
    mgr.save(1, tree, blocking=True)
    # simulate a crashed half-written save: tmp dir exists, no manifest
    os.makedirs(os.path.join(tmpdir_ck, ".tmp_step_2"))
    bad = os.path.join(tmpdir_ck, "step_3")
    os.makedirs(bad)             # step dir without manifest
    assert mgr.all_steps() == [1]
    out, step, _ = mgr.restore(tree)
    assert step == 1


def test_shape_mismatch_raises(tmpdir_ck):
    mgr = CheckpointManager(tmpdir_ck)
    tree = _tree(jax.random.key(4))
    mgr.save(1, tree, blocking=True)
    bad_tmpl = {"a": jnp.zeros((9, 4)), "nested": tree["nested"]}
    with pytest.raises(ValueError):
        mgr.restore(bad_tmpl)


@pytest.mark.slow
def test_resume_is_bitwise_equivalent(tmp_path):
    """Train 8 straight vs 4 + crash + resume 4: identical loss trajectory
    (data is a pure function of step; optimizer state fully checkpointed)."""
    cfg = smoke_reduce(get_config("qwen2-1.5b"))
    api = build_model(cfg)
    shape = ShapeConfig("t", seq_len=32, global_batch=2, kind="train")
    ocfg = AdamWConfig(lr_peak=1e-3, warmup_steps=2, total_steps=8)

    d1 = str(tmp_path / "run1")
    res_full = run_training(api, shape, ocfg,
                            LoopConfig(steps=8, ckpt_dir=d1, ckpt_every=4))

    d2 = str(tmp_path / "run2")
    with pytest.raises(RuntimeError):
        run_training(api, shape, ocfg,
                     LoopConfig(steps=8, ckpt_dir=d2, ckpt_every=4),
                     crash_at_step=6)
    res_resumed = run_training(api, shape, ocfg,
                               LoopConfig(steps=8, ckpt_dir=d2, ckpt_every=4))
    assert res_resumed.resumed_from == 4
    np.testing.assert_allclose(res_full.losses[4:], res_resumed.losses,
                               rtol=1e-5)
