"""Hypothesis property sweeps for the event-granular core (ISSUE 5) —
the three acceptance invariants on arbitrary generated streams:
event-FCFS bit-identity, conservative reservations never delayed by a
backfill, and cluster power never exceeding a binding cap.  Hypothesis
is a dev extra: the suite skips cleanly where it isn't installed (see
requirements-dev.txt); tests/test_event_core.py carries the
non-hypothesis coverage of the same invariants."""

import pytest

pytest.importorskip("hypothesis")

from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import Scheduler, SimConfig  # noqa: E402
from test_event_core import (  # noqa: E402
    _stream, assert_differential, assert_event_fcfs_bit_identical,
    reconstruct_peak_power)


@settings(max_examples=12, deadline=None)
@given(st.integers(0, 10_000), st.sampled_from([0.3, 0.8, 1.5]))
def test_property_event_fcfs_bit_identical(seed, rate):
    """Event-granular FCFS == arrival-indexed FCFS on arbitrary streams
    (shapes fixed so every example shares one compilation)."""
    w = _stream(n=16, rate=rate, seed=seed)
    assert_event_fcfs_bit_identical(w, "paper")


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000), st.sampled_from([2, 8]))
def test_property_conservative_never_delays_reservations(seed, window):
    """The conservative invariant on arbitrary streams: every placement
    realizes its admission-time reservation (the mirror asserts
    realizable <= reserved at every placement, and the differential
    equality transfers the guarantee to the jax engine)."""
    w = _stream(n=16, rate=1.2, seed=seed)
    assert_differential(
        w, SimConfig(mode="conservative", k=0.1, warm_start=True,
                     queue_window=window), check_reservations=True)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000), st.sampled_from([40_000.0, 50_000.0]))
def test_property_power_never_exceeds_cap(seed, cap):
    """Cluster power stays under any binding cap on arbitrary streams,
    by the engine's own accounting AND the independent reconstruction."""
    w = _stream(n=16, rate=1.2, seed=seed)
    res = Scheduler("paper", warm_start=True, power_cap=cap).run(w)
    assert float(res.peak_power) <= cap * (1 + 1e-6)
    assert reconstruct_peak_power(w, res) <= cap * (1 + 1e-4)
