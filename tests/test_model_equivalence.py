"""Numerical-equivalence tests for the attention and SSD cores.

These are the invariants the serving path depends on:
  - blocked (flash-style) attention == plain attention;
  - decode_attention over a cache == last row of causal attention;
  - Mamba2 chunked-SSD prefill == token-by-token decode recurrence.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, smoke_reduce
from repro.models.attention import (
    plain_attention, blocked_attention, decode_attention)
from repro.models.mamba import (
    init_mamba, mamba_forward, mamba_decode, mamba_decode_cache_specs,
    ssd_chunked)


@pytest.mark.parametrize("sq,h,kv,hd,bq,bk", [
    (256, 8, 2, 32, 64, 64),
    (128, 4, 4, 16, 32, 128),
    (512, 6, 2, 64, 512, 64),
])
def test_blocked_equals_plain(sq, h, kv, hd, bq, bk):
    ks = jax.random.split(jax.random.key(0), 3)
    b = 2
    q = jax.random.normal(ks[0], (b, sq, h, hd), jnp.float32)
    k = jax.random.normal(ks[1], (b, sq, kv, hd), jnp.float32)
    v = jax.random.normal(ks[2], (b, sq, kv, hd), jnp.float32)
    o1 = plain_attention(q, k, v, causal=True)
    o2 = blocked_attention(q, k, v, causal=True, block_q=bq, block_k=bk)
    np.testing.assert_allclose(o1, o2, atol=3e-5)


def test_blocked_non_causal():
    ks = jax.random.split(jax.random.key(1), 3)
    b, sq, h, kv, hd = 1, 128, 4, 2, 32
    q = jax.random.normal(ks[0], (b, sq, h, hd), jnp.float32)
    k = jax.random.normal(ks[1], (b, sq, kv, hd), jnp.float32)
    v = jax.random.normal(ks[2], (b, sq, kv, hd), jnp.float32)
    o1 = plain_attention(q, k, v, causal=False)
    o2 = blocked_attention(q, k, v, causal=False, block_q=32, block_k=32)
    np.testing.assert_allclose(o1, o2, atol=3e-5)


def test_decode_matches_causal_last_row():
    ks = jax.random.split(jax.random.key(2), 3)
    b, s, h, kv, hd = 2, 96, 8, 4, 32
    q = jax.random.normal(ks[0], (b, s, h, hd), jnp.float32)
    k = jax.random.normal(ks[1], (b, s, kv, hd), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, kv, hd), jnp.float32)
    full = plain_attention(q, k, v, causal=True)
    dec = decode_attention(q[:, -1:], k, v, length=s)
    np.testing.assert_allclose(full[:, -1:], dec, atol=2e-5)


def test_decode_respects_length_mask():
    ks = jax.random.split(jax.random.key(3), 3)
    b, s, h, kv, hd = 1, 64, 4, 2, 16
    q = jax.random.normal(ks[0], (b, 1, h, hd), jnp.float32)
    k = jax.random.normal(ks[1], (b, s, kv, hd), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, kv, hd), jnp.float32)
    o_half = decode_attention(q, k, v, length=32)
    # garbage beyond position 32 must not change the result
    k2 = k.at[:, 32:].set(99.0)
    v2 = v.at[:, 32:].set(-99.0)
    o_half2 = decode_attention(q, k2, v2, length=32)
    np.testing.assert_allclose(o_half, o_half2, atol=1e-6)


def test_mamba_prefill_equals_decode_chain():
    cfg = smoke_reduce(get_config("mamba2-780m"))
    key = jax.random.key(4)
    p = init_mamba(cfg, key, jnp.float32)
    b, s = 2, 64
    u = jax.random.normal(key, (b, s, cfg.d_model), jnp.float32) * 0.5
    y_pre, (tail, st) = mamba_forward(p, u, cfg, return_state=True)
    conv, state = [jnp.zeros(sd.shape, sd.dtype)
                   for sd in mamba_decode_cache_specs(cfg, b)]
    step = jax.jit(lambda u1, c, s_: mamba_decode(p, u1, cfg, c, s_))
    ys = []
    for t in range(s):
        y, conv, state = step(u[:, t:t + 1], conv, state)
        ys.append(y)
    y_dec = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(y_pre, y_dec, atol=2e-3)
    np.testing.assert_allclose(st, state, atol=2e-3)
    np.testing.assert_allclose(tail, conv, atol=1e-4)


def test_ssd_chunk_size_invariance():
    """The chunk size is an implementation detail: results must not change."""
    key = jax.random.key(5)
    ks = jax.random.split(key, 4)
    b, l, h, p, g, n = 2, 64, 4, 8, 1, 16
    x = jax.random.normal(ks[0], (b, l, h, p), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, l, h), jnp.float32))
    A = -jnp.exp(jax.random.normal(ks[2], (h,), jnp.float32) * 0.3)
    B = jax.random.normal(ks[3], (b, l, g, n), jnp.float32)
    C = jax.random.normal(ks[0], (b, l, g, n), jnp.float32)
    y8, s8 = ssd_chunked(x, dt, A, B, C, chunk=8)
    y32, s32 = ssd_chunked(x, dt, A, B, C, chunk=32)
    y64, s64 = ssd_chunked(x, dt, A, B, C, chunk=64)
    np.testing.assert_allclose(y8, y32, atol=1e-4)
    np.testing.assert_allclose(y8, y64, atol=1e-4)
    np.testing.assert_allclose(s8, s64, atol=1e-4)
