"""Paper energy-formalism tests (core/energy.py, core/workload_model.py)."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import energy
from repro.core.systems import JSCC_SYSTEMS, BROADWELL
from repro.core.workload_model import (
    JobProfile, predict_phases, predict_energy, predict_runtime,
    energy_coefficient, NPB_PROFILES)


def test_node_power_is_component_sum():
    assert float(energy.node_power(100.0, 10.0, 5.0)) == 115.0


def test_average_power_constant_trace():
    w = np.full((4, 11), 50.0)      # 4 nodes, 50 W each, 10 s
    assert float(energy.average_power(w, dt=1.0)) == pytest.approx(200.0)


def test_average_power_matches_trapezoid():
    t = np.linspace(0, 10, 11)
    w = np.stack([t, 2 * t])        # two ramping nodes
    expect = (np.trapezoid(t, t) + np.trapezoid(2 * t, t)) / 10.0
    assert float(energy.average_power(w, dt=1.0)) == pytest.approx(expect)


def test_energy_coefficient_units():
    # C = W / P: 1000 W at 1e6 Mop/s -> 1e-3 J/Mop
    assert float(energy.energy_coefficient(1000.0, 1e6)) == pytest.approx(1e-3)


def test_predict_energy_consistency():
    prof = NPB_PROFILES["BT"]
    e, w_avg, t = predict_energy(prof, BROADWELL, 5)
    assert e == pytest.approx(w_avg * t, rel=1e-9)
    assert t == pytest.approx(predict_runtime(prof, BROADWELL, 5), rel=1e-9)
    assert energy_coefficient(prof, BROADWELL, 5) == pytest.approx(
        e / (prof.flops / 1e6), rel=1e-9)


def test_phases_scale_with_nodes():
    prof = JobProfile("x", flops=1e12, net_bytes=1e9, disk_bytes=1e9)
    t1 = predict_phases(prof, BROADWELL, 1)
    t4 = predict_phases(prof, BROADWELL, 4)
    for a, b in zip(t1, t4):
        assert b == pytest.approx(a / 4)


def test_memory_bound_correction():
    prof = JobProfile("membound", flops=1.0, net_bytes=0, disk_bytes=0,
                      mem_bytes=1e12)
    t_comp, _, _ = predict_phases(prof, BROADWELL, 1)
    assert t_comp == pytest.approx(1e12 / BROADWELL.mem_bw_node)


def test_more_power_hungry_system_has_higher_c_at_same_speed():
    prof = NPB_PROFILES["EP"]
    for sys in JSCC_SYSTEMS:
        c = energy_coefficient(prof, sys, 4)
        assert 1e-5 < c < 1.0, (sys.name, c)
