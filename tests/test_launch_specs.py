"""Unit tests for launch-level input/cache sharding specs (the divisibility
fallback logic the dry-run depends on)."""

import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config, SHAPES
from repro.models import build_model
from repro.launch.specs import batch_partition_specs, cache_partition_specs
from repro.sharding.ctx import lm_rules
from repro.utils.tree import flatten_with_names


class _FakeMesh:
    axis_names = ("data", "model")

    class devices:
        shape = (16, 16)


class _FakeMeshMP:
    axis_names = ("pod", "data", "model")

    class devices:
        shape = (2, 16, 16)


def _cache_specs(arch, shape_name, mesh=_FakeMesh, multi_pod=False):
    cfg = get_config(arch)
    api = build_model(cfg)
    shape = SHAPES[shape_name]
    rules = lm_rules(multi_pod, cfg.fsdp)
    cache = api.decode_cache_specs(shape.global_batch, shape.seq_len)
    return dict(flatten_with_names(
        cache_partition_specs(cfg, shape, mesh, rules, cache)))


def test_kv_heads_sharded_when_divisible():
    # phi-3-vision: kv=32 divides model=16 -> heads axis sharded
    specs = _cache_specs("phi-3-vision-4.2b", "decode_32k")
    k_spec = next(v for n, v in specs.items() if n.endswith("/k"))
    assert k_spec[3] == "model"          # kv-head dim
    assert k_spec[2] is None             # seq unsharded


def test_seq_fallback_when_kv_small():
    # internlm2: kv=8 does not divide 16 -> sequence dim takes 'model'
    specs = _cache_specs("internlm2-20b", "decode_32k")
    k_spec = next(v for n, v in specs.items() if n.endswith("/k"))
    assert k_spec[3] is None
    assert k_spec[2] == "model"


def test_long_context_batch1_shards_seq_over_both_axes():
    specs = _cache_specs("jamba-v0.1-52b", "long_500k")
    k_spec = next(v for n, v in specs.items() if n.endswith("/k"))
    assert k_spec[1] is None             # batch=1: no batch sharding
    assert k_spec[2] == ("data", "model")


def test_mamba_state_heads_sharded():
    specs = _cache_specs("mamba2-780m", "decode_32k")
    st = next(v for n, v in specs.items() if n.endswith("/state"))
    # [G, b, h=48, p, n]: h divides 16
    assert st[2] == "model"


def test_batch_specs_divisibility():
    cfg = get_config("tinyllama-1.1b")
    rules = lm_rules(False, False)
    sp = batch_partition_specs(cfg, SHAPES["train_4k"], _FakeMesh, rules)
    assert sp["tokens"] == P(("data",), None)
    # multi-pod: batch over (pod, data)
    rules_mp = lm_rules(True, False)
    sp2 = batch_partition_specs(cfg, SHAPES["train_4k"], _FakeMeshMP, rules_mp)
    assert sp2["tokens"] == P(("pod", "data"), None)


def test_whisper_cross_memory_specs_build():
    specs = _cache_specs("whisper-medium", "decode_32k")
    mem = next(v for n, v in specs.items() if n.endswith("mem_k"))
    assert len(mem) == 5                 # [L, b, enc_seq, kv, hd]
    # enc_seq=1500 not divisible by 16 -> seq fallback must not shard it...
    # kv=16 IS divisible -> heads sharded, seq untouched
    assert mem[3] == "model"
