"""Sharded & chunked campaigns (the million-job scale-out).

Three contracts, per ISSUE 10's acceptance criteria:

- chunked-vs-monolithic: streaming the event scan in fixed windows with
  the carry threaded between chunks is the SAME op trace as the
  monolithic ``lax.scan``, so results are bit-identical on every core —
  arrival, EASY, event-granular, conservative — for totals and the
  per-job full path alike.
- sharded-vs-single-device: the campaign grid axis partitioned over a
  ``("grid",)`` mesh of 8 host CPU devices (subprocess —
  ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` must be set
  before jax initializes, and conftest.py forbids a global override)
  is bit-identical to the single-device vmap, including non-divisible
  batch sizes (pad lanes duplicated and sliced back off).
- J=10^6: a million-job synthetic-SWF campaign completes on the 8-device
  mesh under ``totals_only`` + chunking without materializing any
  [grid, J] array (compiled peak-temp asserted well under one such
  array's footprint).
"""

import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core import JSCC_SYSTEMS, Scheduler, parse_policy_spec
from repro.data.scenarios import make_stream_workload

pytestmark = pytest.mark.slow

TOTAL_FIELDS = ("total_energy", "makespan", "total_wait", "slowdown_sum",
                "max_wait", "peak_power", "capped_delay")
PERJOB_FIELDS = ("system", "start", "finish", "energy", "backfilled")

CORES = {
    "fcfs": dict(policy="paper"),
    "easy": dict(policy="easy_backfill:window=6"),
    "events": dict(policy="paper", engine="events"),
    "conservative": dict(policy="conservative:window=6"),
}


@pytest.fixture(scope="module")
def stream_150():
    return make_stream_workload(JSCC_SYSTEMS, 150, arrival="poisson",
                                rate=0.5, seed=3, pred_noise=0.05)


def _sched(policy, engine=None, **kw):
    return Scheduler(parse_policy_spec(policy), warm_start=True,
                     seeds=[0, 1, 2], engine=engine, **kw)


def _dicts_equal(a, b, fields):
    for f in fields:
        va, vb = a.get(f), b.get(f)
        if va is None and vb is None:
            continue
        np.testing.assert_array_equal(
            np.asarray(va), np.asarray(vb), err_msg=f)


@pytest.mark.parametrize("core", CORES)
def test_chunked_bit_identity_totals(stream_150, core):
    """chunk boundaries must be invisible: same steps, same carries,
    same totals, bit for bit, on every scan core."""
    kw = dict(CORES[core])
    pol, eng = kw.pop("policy"), kw.pop("engine", None)
    mono = _sched(pol, eng).run(stream_150, totals_only=True).to_dict()
    chunked = _sched(pol, eng, chunk=37).run(
        stream_150, totals_only=True).to_dict()
    _dicts_equal(mono, chunked, TOTAL_FIELDS)


@pytest.mark.parametrize("core", ["fcfs", "easy"])
def test_chunked_bit_identity_full_path(stream_150, core):
    """Per-job outputs spilled chunk by chunk and reassembled must equal
    the monolithic scan's stacked ys exactly."""
    kw = dict(CORES[core])
    pol, eng = kw.pop("policy"), kw.pop("engine", None)
    mono = _sched(pol, eng).run(stream_150).to_dict()
    chunked = _sched(pol, eng, chunk=41).run(stream_150).to_dict()
    _dicts_equal(mono, chunked, PERJOB_FIELDS + TOTAL_FIELDS)


def test_chunk_validation():
    with pytest.raises(ValueError):
        Scheduler("paper", chunk=0)
    with pytest.raises(ValueError):
        Scheduler("paper", shards=0)
    with pytest.raises(ValueError):
        Scheduler("paper", shards="many")


def _run_subprocess(script, devices=8, timeout=900):
    env = dict(os.environ)
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count={devices} "
                        + env.get("XLA_FLAGS", "")).strip()
    env["PYTHONPATH"] = "src"
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=timeout,
                         cwd=os.path.dirname(os.path.dirname(
                             os.path.abspath(__file__))))
    assert out.returncode == 0, out.stderr[-4000:]
    return json.loads(out.stdout.splitlines()[-1])


def test_sharded_vs_single_device_bit_identity():
    """8 host devices: 'auto' sharding, explicit sharding + chunking, and
    a non-divisible batch (10 lanes on 8 devices -> pad to 16) must all
    reproduce the single-device vmap bitwise."""
    rep = _run_subprocess("""
import json
import numpy as np
from repro.core import JSCC_SYSTEMS, Scheduler, make_policy
from repro.data.scenarios import make_stream_workload

w = make_stream_workload(JSCC_SYSTEMS, 200, arrival="poisson", rate=0.5,
                         seed=3, pred_noise=0.05)
ks = np.linspace(0.0, 0.4, 5, dtype=np.float32)      # 5 K x 2 seeds = 10
def run(**kw):
    res = Scheduler(make_policy("ucb", k=ks), warm_start=True, seeds=[0, 1],
                    **kw).run(w, totals_only=True).to_dict()
    return {f: np.asarray(res[f]) for f in
            ("total_energy", "makespan", "total_wait", "max_wait")}

base = run()
eq = {}
for tag, kw in (("auto", dict(shards="auto")),
                ("eight_chunked", dict(shards=8, chunk=64)),
                ("one", dict(shards=1))):
    got = run(**kw)
    eq[tag] = all(np.array_equal(base[f], got[f]) for f in base)
import jax
print(json.dumps({"devices": len(jax.devices()), "eq": eq}))
""")
    assert rep["devices"] == 8
    assert all(rep["eq"].values()), rep


def test_million_job_campaign_8dev():
    """Acceptance: J=10^6 synthetic-SWF campaign, 8-lane grid sharded
    over an 8-device host mesh, chunked totals_only — completes, returns
    finite totals with no J-sized leaf, and the compiled chunk advance's
    peak temp memory stays far under one [grid, J] f32 array."""
    rep = _run_subprocess("""
import json
import numpy as np
import jax
from repro.core import Scheduler, make_policy
from repro.core import engine as eng
from repro.core.systems import ComputeSystem
from repro.data.scenarios import synthetic_swf_arrays, workload_from_arrays

SMALL = (
    ComputeSystem(name="alpha", n_nodes=8, cores_per_node=64,
                  peak_flops_node=2e12, mem_bw_node=200e9,
                  net_bw_node=10e9, disk_bw_node=2e9, idle_w=100.0,
                  cpu_w=200.0, net_w=20.0, disk_w=10.0, efficiency=0.5),
    ComputeSystem(name="beta", n_nodes=12, cores_per_node=48,
                  peak_flops_node=1.2e12, mem_bw_node=150e9,
                  net_bw_node=8e9, disk_bw_node=1.5e9, idle_w=80.0,
                  cpu_w=160.0, net_w=15.0, disk_w=8.0, efficiency=0.55),
)
J = 1_000_000
w = workload_from_arrays(*synthetic_swf_arrays(J, seed=11), SMALL)

captured = {}
orig = eng._chunk_advance
def spy(*a, **k):
    captured.setdefault("args", (a, k))
    return orig(*a, **k)
eng._chunk_advance = spy

ks = np.linspace(0.0, 0.35, 4, dtype=np.float32)     # 4 K x 2 seeds = 8
res = Scheduler(make_policy("ucb", k=ks), warm_start=True, seeds=[0, 1],
                shards="auto", chunk=131_072).run(w, totals_only=True)
out = res.to_dict()
leaf_shapes = {f: list(np.shape(v)) for f, v in out.items()
               if v is not None and np.ndim(np.asarray(v))}
finite = all(np.isfinite(np.asarray(out[f])).all()
             for f in ("total_energy", "makespan", "total_wait"))
no_J_leaf = all(J not in s for s in leaf_shapes.values())

temp_bytes = None
a, k = captured["args"]
try:
    ma = orig.lower(*a, **k).compile().memory_analysis()
    temp_bytes = int(getattr(ma, "temp_size_in_bytes"))
except Exception:
    pass

print(json.dumps({
    "devices": len(jax.devices()), "finite": bool(finite),
    "no_J_leaf": bool(no_J_leaf), "leaf_shapes": leaf_shapes,
    "temp_bytes": temp_bytes,
    "energy0": float(np.asarray(out["total_energy"]).reshape(-1)[0]),
}))
""", timeout=1800)
    assert rep["devices"] == 8
    assert rep["finite"] and rep["no_J_leaf"], rep
    assert rep["energy0"] > 0
    grid_J_bytes = 8 * 1_000_000 * 4          # one [grid, J] f32 array
    if rep["temp_bytes"] is not None:         # best-effort on CPU
        assert rep["temp_bytes"] < grid_J_bytes // 4, rep["temp_bytes"]
