"""Markdown link check over README + docs/ (CI satellite, ISSUE 3).

Every relative markdown link must resolve to a real file, and every
``python <path>`` / ``python -m <module>`` entry point a doc claims must
exist — so the quickstart can't rot silently.  No network: http(s) links
are only syntax-checked.
"""

import re
import pathlib

import pytest

ROOT = pathlib.Path(__file__).resolve().parent.parent

# The curated docs (ISSUE 3: README + docs/, plus the repo logs they link
# to).  PAPERS.md / SNIPPETS.md / PAPER.md are retrieval artifacts and may
# reference assets that were never vendored.
DOCS = sorted(
    [p for p in ROOT.glob("*.md")
     if p.name in ("README.md", "ROADMAP.md", "CHANGES.md", "ISSUE.md")]
    + list((ROOT / "docs").glob("*.md"))
)

_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
_PY_FILE = re.compile(r"python\s+((?:[\w./-]+/)?[\w-]+\.py)")
_PY_MOD = re.compile(r"python\s+-m\s+([\w.]+)")


def _md_links(text):
    for target in _LINK.findall(text):
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        yield target.split("#", 1)[0]


@pytest.mark.parametrize("doc", DOCS, ids=[str(p.relative_to(ROOT))
                                           for p in DOCS])
def test_markdown_links_resolve(doc):
    text = doc.read_text()
    missing = []
    for target in _md_links(text):
        if not target:
            continue                       # pure-anchor link (#section)
        if not (doc.parent / target).exists() and not (ROOT / target).exists():
            missing.append(target)
    assert not missing, f"{doc.name}: broken relative links {missing}"


@pytest.mark.parametrize("doc", DOCS, ids=[str(p.relative_to(ROOT))
                                           for p in DOCS])
def test_claimed_entry_points_exist(doc):
    text = doc.read_text()
    missing = []
    for path in _PY_FILE.findall(text):
        if not (ROOT / path).exists():
            missing.append(path)
    for mod in _PY_MOD.findall(text):
        if not mod.startswith("repro"):
            continue                       # stdlib/third-party (-m pytest)
        rel = mod.replace(".", "/")
        if not ((ROOT / "src" / f"{rel}.py").exists()
                or (ROOT / "src" / rel / "__init__.py").exists()):
            missing.append(f"-m {mod}")
    assert not missing, f"{doc.name}: claimed entry points missing {missing}"
