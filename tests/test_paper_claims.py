"""Validation of the paper's experimental claims against our reproduction
(EXPERIMENTS.md 'faithful baseline').  Claim bands are deliberately loose:
the paper's absolute numbers depend on unpublished JSCC power data; what we
assert is the calibrated model reproducing the paper's REPORTED effects."""

import numpy as np
import pytest

from repro.core import (JSCC_SYSTEMS, SimConfig, make_npb_workload,
                        simulate_jax, sweep_k)


@pytest.fixture(scope="module")
def suite_sweep():
    w = make_npb_workload(JSCC_SYSTEMS)
    ks = np.array([0.0, 0.05, 0.10, 0.20, 0.85])
    res = sweep_k(w, SimConfig(mode="paper", warm_start=True), ks)
    return w, ks, res


def test_claim_energy_reduction_at_modest_k(suite_sweep):
    """Paper: 'reduce power consumption by an average of 21.5%, while the
    test suite execution time increased by 3.8%'."""
    _, ks, res = suite_sweep
    E = np.asarray(res["total_energy"])
    M = np.asarray(res["makespan"])
    dE = (E - E[0]) / E[0]
    dM = (M - M[0]) / M[0]
    # at K in [0.05, 0.2]: >= 12% energy saving with <= 10% runtime increase
    best = dE[1:4].min()
    assert best <= -0.12, f"expected >=12% energy saving, got {dE}"
    assert dM[1:4].max() <= 0.10, f"expected <=10% runtime increase, got {dM}"


def test_claim_significant_reduction_between_k5_and_k10(suite_sweep):
    """Paper: 'even with a slight increase in the parameter K value
    (from 5 to 10%), a significant reduction ... is achieved'."""
    _, ks, res = suite_sweep
    E = np.asarray(res["total_energy"])
    saving_at_10 = (E[0] - E[2]) / E[0]
    assert saving_at_10 >= 0.10


def test_claim_all_but_lu_switch_below_5pct(suite_sweep):
    """Paper: 'for all tests except LU, it was possible to achieve a
    reduction ... with an allowable increase ... by less than 5%'."""
    w, ks, res = suite_sweep
    sel0 = np.asarray(res["system"])[0]        # K=0 placement
    sel5 = np.asarray(res["system"])[1]        # K=5% placement
    prog_names = [w.programs[p] for p in w.prog]
    switched = {prog_names[j]: sel0[j] != sel5[j] for j in range(len(w.prog))}
    assert not switched["LU"], "LU must NOT find a greener system at K=5%"
    assert sum(switched.values()) >= 3, \
        f"most non-LU tests should switch at K=5%: {switched}"
    # and LU does switch eventually (energy saving exists at high K)
    sel85 = np.asarray(res["system"])[4]
    lu_idx = prog_names.index("LU")
    assert sel85[lu_idx] != sel0[lu_idx]


def test_energy_never_increases_with_k(suite_sweep):
    _, ks, res = suite_sweep
    E = np.asarray(res["total_energy"])
    assert (np.diff(E) <= 1e-6).all()


def test_c_magnitudes_match_paper_units():
    """Table 5 reports C in 1e-3..7.5e-3 J/op (NPB Mop/s units => J/Mop);
    our calibrated compute-bound benchmarks must land in that decade."""
    w = make_npb_workload(JSCC_SYSTEMS)
    C = w.C_true
    names = list(w.programs)
    for prog in ("BT", "EP", "LU", "SP"):
        row = C[names.index(prog)]
        assert (row > 5e-4).all() and (row < 5e-2).all(), (prog, row)


def test_paper_vs_baselines_pareto():
    """The paper algorithm at K>0 must dominate 'fastest' on energy and
    'greenest' on makespan (it is the tunable middle of the Pareto front)."""
    w = make_npb_workload(JSCC_SYSTEMS)
    fast = simulate_jax(w, SimConfig(mode="fastest", warm_start=True))
    green = simulate_jax(w, SimConfig(mode="greenest", warm_start=True))
    alg10 = simulate_jax(w, SimConfig(mode="paper", k=0.10, warm_start=True))
    assert float(alg10["total_energy"]) < float(fast["total_energy"])
    assert float(alg10["makespan"]) <= float(green["makespan"]) + 1e-6
