"""Shared fixtures. NOTE: no XLA_FLAGS device-count override here — smoke
tests and benches must see the real single CPU device (the 512-device
override lives exclusively at the top of src/repro/launch/dryrun.py)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def make_lm_batch(cfg, b, s, key):
    k1, k2 = jax.random.split(key)
    batch = {
        "tokens": jax.random.randint(k1, (b, s), 0, cfg.vocab_size, jnp.int32),
        "labels": jax.random.randint(k2, (b, s), 0, cfg.vocab_size, jnp.int32),
        "mask": jnp.ones((b, s), jnp.int32),
    }
    if cfg.is_encoder_decoder:
        batch["frame_embeds"] = jax.random.normal(
            k1, (b, cfg.encoder_seq, cfg.d_model), jnp.float32)
    if cfg.frontend == "vision":
        batch["patch_embeds"] = jax.random.normal(
            k1, (b, cfg.n_patches, cfg.d_model), jnp.float32)
    return batch
