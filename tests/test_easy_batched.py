"""Bit-identity of the batched EASY candidate evaluation vs the PR 3 loop.

The batched step (``easy_eval="batched"``, the default) is semantics-
preserving by construction: every trial allocation in a step is computed
against the SAME starting node-free table, so the window slots are
independent and the first-fit choice is a masked argmin over slot index.
These tests pin the construction against the historical python-unrolled
loop (``easy_eval="unrolled"``): placements, starts, totals, learned
tables, and backfill flags must agree BIT-EXACTLY — no tolerances — for
every registered policy, warm and cold, with and without outage windows,
on synthetic and trace-replay streams, under both result paths
(full per-job arrays and ``totals_only``) and under forced placement
backends.
"""

import numpy as np
import pytest

from repro.core import (JSCC_SYSTEMS, FaultConfig, Scheduler, make_policy,
                        policy_names)
from repro.data.scenarios import (load_swf, maintenance_windows,
                                  make_stream_workload, workload_from_trace)

PER_JOB = ("system", "start", "finish", "wait", "energy", "runtime",
           "nodes", "backfilled")
TOTALS = ("total_energy", "makespan", "total_wait", "max_wait",
          "slowdown_sum", "busy", "n_backfilled", "C_tab", "T_tab", "runs")


def assert_bit_identical(w, pol, *, warm=True, seeds=7, faults=None,
                         placer=None, totals_only=False):
    kw = dict(warm_start=warm, seeds=seeds, faults=faults, placer=placer)
    rb = Scheduler(pol, **kw).run(w, totals_only=totals_only)
    ru = Scheduler(pol, easy_eval="unrolled", **kw).run(
        w, totals_only=totals_only)
    fields = TOTALS if totals_only else PER_JOB + TOTALS
    for field in fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(rb, field)), np.asarray(getattr(ru, field)),
            err_msg=f"batched != unrolled on {field!r}")


def _contended_stream(n=40, rate=1.0, kind="poisson", seed=3):
    """High arrival rate => real queueing: held heads and live backfill
    candidates, so the two evaluation strategies face real decisions."""
    return make_stream_workload(JSCC_SYSTEMS, n, arrival=kind, rate=rate,
                                seed=seed, pred_noise=0.05)


# ----------------------------------------------- whole-registry sweep (slow)

@pytest.mark.slow
@pytest.mark.parametrize("name", policy_names())
@pytest.mark.parametrize("warm", [True, False], ids=["warm", "cold"])
def test_registry_bit_identity(name, warm):
    """Every registered policy, warm and cold: the batched scan must be
    indistinguishable from the PR 3 loop, down to the last bit."""
    pol = make_policy(name, k=0.1).with_params(
        queue="easy_backfill", window=4)
    if pol.tiered:
        pytest.skip("the unrolled loop predates the tier axis and rejects "
                    "freq_tiers; dvfs_* single-tier bit-identity lives in "
                    "test_dvfs_bitidentity.py")
    w = _contended_stream()
    assert_bit_identical(w, pol, warm=warm)


# --------------------------------------------------- targeted quick coverage

@pytest.mark.parametrize("name", ["paper", "random", "queue_aware", "ucb"])
def test_bit_identity_quick(name):
    """Quick-tier subset: selector axes that exercise every batched input
    (tables, availability, PRNG keys, optimism bounds)."""
    assert_bit_identical(_contended_stream(),
                         make_policy(name, k=0.1).with_params(
                             queue="easy_backfill", window=6))


def test_bit_identity_cold_with_faults():
    """Cold tables + straggler/failure draws: the per-candidate fault
    factors are keyed by job id and must replay identically."""
    w = _contended_stream(seed=11)
    pol = make_policy("easy_backfill", k=0.1)
    assert_bit_identical(
        w, pol, warm=False,
        faults=FaultConfig(straggler_prob=0.3, straggler_factor=2.5,
                           failure_prob=0.2, restart_overhead=0.5))


def test_bit_identity_with_outage_windows():
    """Outage pushes hit both the candidate scoring and the head recheck
    (the reduced single-system push must match the full per-system one)."""
    outage = maintenance_windows(
        4, {1: [(0.0, 400.0)], 2: [(100.0, 300.0), (500.0, 650.0)]})
    w = make_stream_workload(JSCC_SYSTEMS, 35, arrival="poisson", rate=0.8,
                             seed=8, outage=outage)
    assert_bit_identical(w, make_policy("easy_backfill", k=0.1))
    assert_bit_identical(w, make_policy("easy_queue_aware", k=0.1))


def test_bit_identity_trace_replay():
    swf = "\n".join(
        f"{i+1} {i*15} 0 {200 + 61*i % 2400} {2 ** (2 + i % 7)} 100.0 0 "
        f"{2 ** (2 + i % 7)} 1000 0 1 1 1 1 1 1 -1 -1"
        for i in range(50)).splitlines()
    w = workload_from_trace(load_swf(swf), JSCC_SYSTEMS)
    assert_bit_identical(w, make_policy("easy_backfill", k=0.2))


def test_bit_identity_window_overflow_and_degenerate():
    """window=1 (every placement is the forced head) and an overflowing
    window=2 on a bursty stream: the FCFS-fallback edge must agree too."""
    w = _contended_stream(kind="bursty", rate=0.8, seed=5)
    for window in (1, 2):
        assert_bit_identical(
            w, make_policy("paper", k=0.1).with_params(
                queue="easy_backfill", window=window))


def test_bit_identity_totals_only():
    """Campaign-memory path: the masked Kahan accumulator sees the same
    per-step addends, so [*grid] aggregates are bit-identical as well."""
    w = _contended_stream(seed=13)
    assert_bit_identical(w, make_policy("easy_backfill", k=0.1),
                         totals_only=True)


@pytest.mark.parametrize("placer", ["sort", "pallas_interpret"])
def test_bit_identity_forced_placers(placer):
    """Explicit placer forcing routes the batched scoring through the
    broadcast batched kernel (not the shared-sort fast path) — still
    bit-identical."""
    assert_bit_identical(_contended_stream(n=25),
                         make_policy("easy_backfill", k=0.1), placer=placer)


def test_scheduler_validates_easy_eval():
    with pytest.raises(ValueError, match="easy_eval"):
        Scheduler("easy_backfill", easy_eval="vectorised")
