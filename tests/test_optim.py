"""Optimizer + gradient-compression tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import (AdamWConfig, adamw_init, adamw_update, lr_schedule,
                         global_norm, quantize_int8, dequantize_int8,
                         compress_with_feedback, compressed_psum,
                         init_error_state)


def test_adamw_converges_on_quadratic():
    ocfg = AdamWConfig(lr_peak=0.1, warmup_steps=5, total_steps=200,
                       weight_decay=0.0, clip_norm=100.0)
    target = jnp.asarray([1.0, -2.0, 3.0])
    params = {"w": jnp.zeros(3)}
    opt = adamw_init(params)

    @jax.jit
    def step(params, opt):
        g = jax.grad(lambda p: jnp.sum((p["w"] - target) ** 2))(params)
        return adamw_update(g, opt, ocfg, jnp.float32)

    for _ in range(200):
        params, opt, m = step(params, opt)
    np.testing.assert_allclose(params["w"], target, atol=1e-2)


def test_grad_clipping_bounds_update():
    ocfg = AdamWConfig(lr_peak=1.0, warmup_steps=0, total_steps=10,
                       clip_norm=1.0, weight_decay=0.0)
    params = {"w": jnp.zeros(4)}
    opt = adamw_init(params)
    huge = {"w": jnp.full(4, 1e6)}
    _, _, metrics = adamw_update(huge, opt, ocfg, jnp.float32)
    assert float(metrics["grad_norm"]) == pytest.approx(2e6, rel=1e-3)
    # effective gradient after clipping has norm 1 -> m is bounded
    assert np.isfinite(float(metrics["lr"]))


def test_lr_schedule_shape():
    ocfg = AdamWConfig(lr_peak=1e-3, warmup_steps=10, total_steps=100,
                       lr_min_ratio=0.1)
    lrs = [float(lr_schedule(ocfg, jnp.asarray(s))) for s in range(101)]
    assert lrs[0] == 0.0
    assert lrs[10] == pytest.approx(1e-3, rel=1e-5)
    assert lrs[100] == pytest.approx(1e-4, rel=1e-3)
    assert max(lrs) <= 1e-3 + 1e-9


def test_master_weights_stay_fp32():
    params = {"w": jnp.zeros(3, jnp.bfloat16)}
    opt = adamw_init(params)
    assert opt["master"]["w"].dtype == jnp.float32
    g = {"w": jnp.ones(3, jnp.bfloat16)}
    new_p, new_opt, _ = adamw_update(
        g, opt, AdamWConfig(lr_peak=0.01, warmup_steps=0, total_steps=10),
        jnp.bfloat16)
    assert new_p["w"].dtype == jnp.bfloat16
    assert new_opt["master"]["w"].dtype == jnp.float32


# ----------------------------------------------------- int8 compression

def test_quantize_roundtrip_error_bounded():
    x = jax.random.normal(jax.random.key(0), (1024,)) * 3
    q, s = quantize_int8(x)
    err = dequantize_int8(q, s) - x
    assert float(jnp.abs(err).max()) <= float(s) * 0.5 + 1e-6


def test_error_feedback_accumulates_residual():
    g = jnp.asarray([1e-4, 2e-4, 1.0])     # tiny entries vanish under int8
    err = jnp.zeros(3)
    q, s, err = compress_with_feedback(g, err)
    # residual carries what quantization dropped
    recon = dequantize_int8(q, s)
    np.testing.assert_allclose(recon + err, g, atol=1e-6)


def test_compressed_psum_mean_close_and_ef_converges():
    """DP all-reduce with int8 EF: mean close to true mean; EF-SGD on a
    least-squares problem converges like exact SGD."""
    n_dev = 4
    key = jax.random.key(1)
    grads = jax.random.normal(key, (n_dev, 64))

    def worker(g, e):
        out, new_e = compressed_psum({"g": g}, {"g": e}, "dp")
        return out["g"], new_e["g"]

    out, _ = jax.vmap(worker, axis_name="dp")(grads, jnp.zeros((n_dev, 64)))
    true_mean = grads.mean(0)
    np.testing.assert_allclose(out[0], true_mean, atol=0.05)

    # EF-SGD convergence: w -> target despite compression
    target = jnp.linspace(-1, 1, 16)
    w = jnp.zeros((n_dev, 16))
    err = jnp.zeros((n_dev, 16))

    @jax.jit
    def step(w, err, key):
        noise = jax.random.normal(key, w.shape) * 0.1

        def one(wi, ei, ni):
            g = 2 * (wi - target) + ni
            mg, new_e = compressed_psum({"g": g}, {"g": ei}, "dp")
            return wi - 0.05 * mg["g"], new_e["g"]

        return jax.vmap(one, axis_name="dp")(w, err, noise)

    for i in range(300):
        w, err = step(w, err, jax.random.key(i))
    np.testing.assert_allclose(w[0], target, atol=0.05)


def test_global_norm():
    tree = {"a": jnp.asarray([3.0]), "b": jnp.asarray([4.0])}
    assert float(global_norm(tree)) == pytest.approx(5.0)
