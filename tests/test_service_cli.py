"""Service CLI (launch/scheduler_service.py): the JSONL loop end to end.

Subprocess tests (slow tier): a real scheduling session scripted over
stdin/stdout, then the kill/restore round-trip the CI ``service-smoke``
step exercises — first process checkpoints mid-stream and dies, second
process ``--restore``s and finishes; the union of decisions must equal an
uninterrupted session's.
"""

import json
import pathlib
import subprocess
import sys

import pytest

pytestmark = pytest.mark.slow

ROOT = pathlib.Path(__file__).resolve().parent.parent
BASE = [sys.executable, "-m", "repro.launch.scheduler_service",
        "--queue", "easy_backfill:window=4", "--warm-start",
        "--capacity", "16"]


def run_cli(lines, *extra):
    env = {"PYTHONPATH": str(ROOT / "src"), "PATH": "/usr/bin:/bin",
           "JAX_PLATFORMS": "cpu", "HOME": "/tmp"}
    proc = subprocess.run(
        BASE + list(extra), input="\n".join(json.dumps(x) for x in lines),
        capture_output=True, text=True, timeout=600, env=env, cwd=ROOT)
    assert proc.returncode == 0, proc.stderr
    return [json.loads(line) for line in proc.stdout.splitlines() if line]


STREAM = [{"op": "submit", "prog": "BT", "arrival": 0.0},
          {"op": "submit", "prog": "LU", "arrival": 30.0},
          {"op": "submit", "prog": "SP", "arrival": 60.0},
          {"op": "submit", "prog": "EP", "arrival": 90.0}]


def test_session_loop():
    """One full session: submits, a what-if, a drain, metrics, totals —
    every response ok, errors surfaced without killing the loop."""
    out = run_cli(STREAM + [
        {"op": "whatif", "prog": "IS"},
        {"op": "submit", "prog": "nope"},           # error: loop survives
        {"op": "drain"},
        {"op": "metrics"},
        {"op": "result"},
    ])
    assert [r["ok"] for r in out] == [True] * 5 + [False] + [True] * 3
    assert "unknown program" in out[5]["error"]
    proj = out[4]
    assert proj["job"]["wait"] >= 0 and proj["peak_power"] > 0
    m = out[-2]["metrics"]
    assert m["n_submitted"] == 4 and m["n_finished"] == 4
    assert m["queue_depth"] == 0 and m["mean_latency_us"] > 0
    t = out[-1]["totals"]
    assert t["total_energy"] > 0 and t["makespan"] > 0
    assert out[-1]["n_jobs"] == 4


def test_kill_and_restore_matches_uninterrupted(tmp_path):
    """Checkpoint mid-stream, die, ``--restore`` in a new process, finish:
    decisions and totals match one uninterrupted session."""
    ck = ["--checkpoint-dir", str(tmp_path)]
    head, tail = STREAM[:2], STREAM[2:]
    finish = [{"op": "drain"}, {"op": "result"}]

    first = run_cli(head + [{"op": "drive", "until": 60.0},
                            {"op": "checkpoint"}], *ck)
    assert all(r["ok"] for r in first)
    assert first[-1]["step"] == 0

    second = run_cli(tail + finish, *ck, "--restore")
    assert all(r["ok"] for r in second)
    banner = second[0]
    assert banner["resumed"] and banner["n_submitted"] == 2

    solo = run_cli(STREAM + finish)
    assert solo[-1]["totals"] == second[-1]["totals"]
    assert solo[-1]["n_jobs"] == second[-1]["n_jobs"] == 4


# ------------------------------------------------------------ pool mode

POOL = ["--pool", "4"]
#: four distinct per-session program orders over one shared arrival grid
PROGS = [["BT", "LU", "SP", "EP"], ["LU", "BT", "EP", "SP"],
         ["SP", "EP", "BT", "LU"], ["EP", "SP", "LU", "BT"]]


def _psub(i, j):
    return {"op": "submit", "session": i,
            "prog": PROGS[i][j], "arrival": 30.0 * j}


def test_pool_kill_and_restore_per_session(tmp_path):
    """The pool smoke (ISSUE 9): 4 sessions multiplexed over one loop,
    checkpointed mid-stream into per-session namespaces, killed,
    ``--restore``d in a new process, finished — per-session totals
    bit-identical to an uninterrupted pool."""
    ck = ["--checkpoint-dir", str(tmp_path)]
    head = [_psub(i, j) for j in (0, 1) for i in range(4)]
    tail = [_psub(i, j) for j in (2, 3) for i in range(4)]
    finish = ([{"op": "drain"}]
              + [{"op": "result", "session": i} for i in range(4)]
              + [{"op": "metrics"}])

    first = run_cli(head + [{"op": "drive", "until": 60.0},
                            {"op": "checkpoint"}], *POOL, *ck)
    assert all(r["ok"] for r in first)
    assert first[-1]["steps"] == [0, 0, 0, 0]
    # per-session namespaces under one root
    assert sorted(p.name for p in tmp_path.iterdir()) == \
        ["s000", "s001", "s002", "s003"]

    second = run_cli(tail + finish, *POOL, *ck, "--restore")
    assert all(r["ok"] for r in second)
    banner = second[0]
    assert banner["resumed"] and banner["sessions"] == 4
    assert banner["n_submitted"] == [2, 2, 2, 2]

    solo = run_cli(head + tail + finish, *POOL)
    assert solo[-5:-1] == second[-5:-1]          # 4 per-session results
    for i in range(4):
        m = second[-1]["metrics"][str(i)]
        assert m["n_submitted"] == 4 and m["n_finished"] == 4
        assert m["queue_depth"] == 0
