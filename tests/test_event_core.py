"""Event-granular core (ISSUE 5): completion-event granularity,
conservative backfilling, SCC power-cap enforcement, mid-job failure
re-queue.

Acceptance pins:
- event-granular FCFS is BIT-IDENTICAL to the arrival-indexed scan for
  every registered fcfs-queue policy (the event clock only changes WHEN
  decisions are evaluated, never what they see);
- conservative reservations are never delayed by a backfill (the float64
  mirror asserts the invariant at every placement while the differential
  suite pins jax == mirror);
- cluster power never exceeds a binding cap — engine-reported
  ``peak_power`` and an independent numpy reconstruction of the power
  trace both stay under it, and cap grids leaf-batch in one compilation.
"""

import numpy as np
import pytest

from repro.core import (JSCC_SYSTEMS, FaultConfig, Scheduler, SimConfig,
                        make_npb_workload, make_policy, policy_names,
                        simulate_jax, simulate_py)
from repro.core.engine import _batched_run
from repro.data.scenarios import make_stream_workload, maintenance_windows

#: fields that must agree bit-exactly between the two FCFS cores
#: (power fields excluded: the arrival core reports peak_power = NaN)
FCFS_FIELDS = ("system", "start", "finish", "wait", "energy", "runtime",
               "nodes", "total_energy", "makespan", "total_wait",
               "max_wait", "slowdown_sum", "busy", "C_tab", "T_tab",
               "runs", "idle_energy")

FCFS_POLICIES = [n for n in policy_names() if make_policy(n).queue == "fcfs"]


def _stream(n=30, rate=0.8, kind="poisson", seed=3, **kw):
    return make_stream_workload(JSCC_SYSTEMS, n, arrival=kind, rate=rate,
                                seed=seed, pred_noise=0.05, **kw)


def assert_event_fcfs_bit_identical(w, name, *, warm=True, seeds=7,
                                    faults=None):
    kw = dict(warm_start=warm, seeds=seeds, faults=faults)
    ra = Scheduler(make_policy(name, k=0.1), **kw).run(w)
    re = Scheduler(make_policy(name, k=0.1), engine="events", **kw).run(w)
    for field in FCFS_FIELDS:
        np.testing.assert_array_equal(
            np.asarray(getattr(ra, field)), np.asarray(getattr(re, field)),
            err_msg=f"event-FCFS != arrival-FCFS on {field!r} ({name})")
    assert int(re.n_backfilled) == 0


# ----------------------------------------- event-FCFS bit-identity sweep

@pytest.mark.slow
@pytest.mark.parametrize("name", FCFS_POLICIES)
@pytest.mark.parametrize("warm", [True, False], ids=["warm", "cold"])
def test_event_fcfs_bit_identity_registry(name, warm):
    """Acceptance: the event core under fcfs reproduces the historical
    arrival-indexed scan bit for bit, for every registered policy."""
    assert_event_fcfs_bit_identical(_stream(), name, warm=warm)


@pytest.mark.parametrize("name", ["paper", "random", "queue_aware", "ucb"])
def test_event_fcfs_bit_identity_quick(name):
    assert_event_fcfs_bit_identical(_stream(), name)


def test_event_fcfs_bit_identity_stragglers_and_outages():
    """Straggler draws (keyed by job id) and outage pushes replay
    identically on the event clock; totals_only aggregates too (the
    event core applies the Kahan update only on placement steps, so the
    op sequence matches)."""
    outage = maintenance_windows(4, {1: [(0.0, 300.0)], 2: [(50.0, 200.0)]})
    w = _stream(n=25, outage=outage)
    faults = FaultConfig(straggler_prob=0.4, straggler_factor=2.5)
    assert_event_fcfs_bit_identical(w, "paper", faults=faults)
    kw = dict(warm_start=True, faults=faults)
    ta = Scheduler("paper", **kw).run(w, totals_only=True)
    te = Scheduler("paper", engine="events", **kw).run(w, totals_only=True)
    for field in ("total_energy", "total_wait", "slowdown_sum", "makespan",
                  "max_wait", "busy"):
        np.testing.assert_array_equal(np.asarray(getattr(ta, field)),
                                      np.asarray(getattr(te, field)),
                                      err_msg=field)


# ----------------------------------------------- differential (jax == py)

def assert_differential(w, cfg, check_reservations=False):
    rj = simulate_jax(w, cfg)
    rp = simulate_py(w, cfg, check_reservations=check_reservations)
    np.testing.assert_array_equal(np.asarray(rj["system"]), rp["system"])
    np.testing.assert_allclose(np.asarray(rj["start"]), rp["start"],
                               rtol=1e-5, atol=1e-3)
    np.testing.assert_array_equal(np.asarray(rj["backfilled"]),
                                  rp["backfilled"])
    np.testing.assert_allclose(float(rj["total_energy"]),
                               rp["total_energy"], rtol=1e-5)
    if not np.isnan(rp["peak_power"]):
        np.testing.assert_allclose(float(rj["peak_power"]),
                                   rp["peak_power"], rtol=1e-5)
        np.testing.assert_allclose(float(rj["capped_delay"]),
                                   rp["capped_delay"], rtol=1e-4, atol=1e-2)
    np.testing.assert_allclose(float(rj["idle_energy"]), rp["idle_energy"],
                               rtol=1e-4)
    return rj, rp


@pytest.mark.parametrize("warm", [True, False], ids=["warm", "cold"])
@pytest.mark.parametrize("window", [2, 8])
def test_differential_conservative(warm, window):
    w = _stream(n=40, rate=1.0)
    assert_differential(
        w, SimConfig(mode="conservative", k=0.1, warm_start=warm,
                     queue_window=window), check_reservations=True)


@pytest.mark.parametrize("mode", ["queue_aware", "fastest", "predictive"])
def test_differential_conservative_composes_with_selectors(mode):
    w = _stream(n=30, kind="bursty", seed=5)
    assert_differential(
        w, SimConfig(mode=mode, k=0.1, warm_start=True,
                     queue="conservative", queue_window=6),
        check_reservations=True)


def test_differential_conservative_with_outages():
    outage = maintenance_windows(4, {1: [(0.0, 400.0)], 3: [(50.0, 250.0)]})
    w = make_stream_workload(JSCC_SYSTEMS, 35, arrival="poisson", rate=0.8,
                             seed=8, outage=outage)
    assert_differential(w, SimConfig(mode="conservative", k=0.1,
                                     warm_start=True),
                        check_reservations=True)


@pytest.mark.parametrize("queue", ["", "easy_backfill", "conservative"])
def test_differential_power_capped(queue):
    w = _stream(n=35, rate=1.0)
    cfg = SimConfig(mode="paper", k=0.1, warm_start=True, queue=queue,
                    power_cap=45_000.0)
    rj, _ = assert_differential(w, cfg)
    assert float(rj["peak_power"]) <= 45_000.0 * (1 + 1e-6)
    assert float(rj["capped_delay"]) > 0.0          # the cap really bound


def test_differential_event_easy_and_fcfs():
    """engine="events" differentials for the re-used disciplines: the
    mirror replays the merged event stream step for step.  (The legacy
    ``SimConfig`` keeps its ``core`` field — only the ``Scheduler``
    facade grew the ``engine=`` spelling.)"""
    w = _stream(n=35, rate=1.0)
    assert_differential(w, SimConfig(mode="paper", k=0.1, warm_start=True,
                                     core="events"))
    assert_differential(w, SimConfig(mode="easy_backfill", k=0.1,
                                     warm_start=True, core="events"))


# -------------------------------------------------- conservative behavior

def _blocking_workload(n_ep=4):
    """Ten LUs saturate min-C KNL (9 run, the 10th reserves); EPs need
    the 2 idle nodes for ~8s — the hole under the reservation."""
    from dataclasses import replace
    order = ("LU",) * 10 + ("EP",) * n_ep
    w = make_npb_workload(JSCC_SYSTEMS, order=order,
                          arrivals=np.zeros(len(order), np.float32))
    return replace(w, k_job=np.full(len(order), 5.0, np.float32))


def test_conservative_fills_holes_without_delaying_reservations():
    w = _blocking_workload()
    cfg = SimConfig(mode="paper", warm_start=True, queue="conservative",
                    queue_window=16)
    assert_differential(w, cfg, check_reservations=True)
    fcfs = simulate_jax(w, SimConfig(mode="paper", warm_start=True))
    cons = simulate_jax(w, cfg)
    f_start = np.asarray(fcfs["start"])
    c_start = np.asarray(cons["start"])
    # the held 10th LU keeps exactly its FCFS start (reservation honored)
    np.testing.assert_allclose(c_start[9], f_start[9], rtol=1e-6)
    # nobody starts later than under FCFS; the EPs jumped into the hole
    assert (c_start <= f_start * (1 + 1e-6) + 1e-3).all()
    assert np.asarray(cons["backfilled"])[10:].all()
    assert float(cons["total_wait"]) < float(fcfs["total_wait"])


def test_conservative_beats_easy_on_contended_stream():
    """The interval reservation table exposes holes under EVERY pending
    job (EASY only sees the head's): on a contended stream conservative
    strictly improves mean wait over both FCFS and EASY."""
    w = _stream(n=60, rate=1.5, seed=11)
    waits = {}
    for queue in ("fcfs", "easy_backfill:window=16",
                  "conservative:window=16"):
        r = Scheduler("paper", warm_start=True, queue=queue).run(w)
        waits[queue.split(":")[0]] = float(r.total_wait)
    assert waits["conservative"] < waits["easy_backfill"]
    assert waits["conservative"] < waits["fcfs"]


def test_conservative_grid_single_compile():
    """power_cap and k are leaves: a (K x cap) grid under conservative is
    still ONE compilation."""
    w = _stream(n=20)
    kk = np.linspace(0.0, 0.3, 4).astype(np.float32)
    caps = np.asarray([40_000.0, 50_000.0, 60_000.0, 1e30], np.float32)
    pol = make_policy("conservative", k=kk, power_cap=caps)
    cache0 = _batched_run._cache_size()
    res = Scheduler(pol).run(w, totals_only=True)
    assert _batched_run._cache_size() - cache0 <= 1
    assert np.asarray(res.total_energy).shape == (4,)
    assert np.asarray(res.peak_power).shape == (4,)


# ------------------------------------------------------- power-cap rules

def reconstruct_peak_power(w, res):
    """Independent numpy reconstruction of the cluster power trace from
    per-job arrays: P sampled at every job start (the only instants power
    can rise)."""
    start = np.asarray(res.start)
    finish = np.asarray(res.finish)
    sel = np.asarray(res.system)
    pw = np.asarray(res.energy) / np.maximum(np.asarray(res.runtime), 1e-30)
    nodes = np.asarray(res.nodes)
    idle_w = np.asarray(w.idle_w)
    n_nodes = np.asarray(w.n_nodes)
    peak = float(np.sum(idle_w * n_nodes))
    for t in start:
        running = (start <= t) & (t < finish)
        busy_nodes = np.zeros(len(n_nodes))
        np.add.at(busy_nodes, sel[running], nodes[running])
        p = pw[running].sum() + float(
            np.sum(idle_w * (n_nodes - busy_nodes)))
        peak = max(peak, p)
    return peak


@pytest.mark.parametrize("queue", ["", "conservative"])
def test_peak_power_under_cap_and_reconstruction(queue):
    w = _stream(n=40, rate=1.0, seed=6)
    cap = 47_000.0
    res = Scheduler("paper", warm_start=True, queue=queue or None,
                    power_cap=cap).run(w)
    peak = float(res.peak_power)
    assert peak <= cap * (1 + 1e-6)
    # engine peak == trace reconstruction (capped starts are quantized to
    # events, so the sampled trace is exact)
    np.testing.assert_allclose(peak, reconstruct_peak_power(w, res),
                               rtol=1e-4)
    # uncapped run on the same stream actually exceeds the cap (binding)
    un = Scheduler("paper", warm_start=True, queue=queue or None,
                   engine="events").run(w)
    assert float(un.peak_power) > cap
    assert float(res.makespan) >= float(un.makespan) * (1 - 1e-6)
    assert float(res.capped_delay) > 0


@pytest.mark.parametrize("queue", ["", "conservative"])
def test_capped_starts_respect_outage_windows(queue):
    """Regression (review finding): a cap-deferred start quantizes to the
    current event — which must still respect the maintenance-window start
    gate.  Before the fix, power freeing up mid-window placed jobs with
    starts inside the window."""
    outage = maintenance_windows(4, {2: [(100.0, 700.0)],
                                     3: [(100.0, 700.0)]})
    w = _stream(n=40, rate=1.2, seed=1, outage=outage)
    cfg = SimConfig(mode="paper", k=0.1, warm_start=True, queue=queue,
                    power_cap=45_000.0)
    rj, _ = assert_differential(w, cfg)
    start = np.asarray(rj["start"])
    sel = np.asarray(rj["system"])
    for s, spans in ((2, [(100.0, 700.0)]), (3, [(100.0, 700.0)])):
        for o0, o1 in spans:
            inside = (sel == s) & (start >= o0) & (start < o1)
            assert not inside.any(), \
                f"jobs started inside outage window on system {s}: " \
                f"{start[inside]}"
    assert float(rj["peak_power"]) <= 45_000.0 * (1 + 1e-6)


def test_cap_below_idle_floor_forces_progress():
    """A cap under the all-idle draw is unsatisfiable: the stuck valve
    force-places rather than stalling, and the recorded peak honestly
    exceeds the cap."""
    w = _stream(n=10)
    idle_floor = float(np.sum(np.asarray(w.idle_w) * np.asarray(w.n_nodes)))
    res = Scheduler("paper", warm_start=True,
                    power_cap=idle_floor * 0.5).run(w)
    assert (np.asarray(res.runtime) > 0).all()      # every job placed
    assert float(res.peak_power) > idle_floor * 0.5


def test_power_cap_requires_event_core():
    with pytest.raises(ValueError, match="event-"):
        Scheduler("paper", power_cap=50_000.0, engine="arrival")
    with pytest.raises(ValueError, match="event-"):
        Scheduler("conservative", engine="arrival")


def test_trace_workloads_carry_idle_watts():
    """Regression (review finding): workload_from_trace must fill
    Workload.idle_w like the other builders — a power-capped SWF replay
    would otherwise ignore the ~33 kW JSCC idle floor entirely."""
    from repro.data.scenarios import load_swf, workload_from_trace
    swf = [f"{i+1} {i*20} 0 {300 + 40 * i} {8 + i} 100.0 0 {8 + i} "
           "0 0 1 1 1 1 1 1 -1 -1" for i in range(12)]
    w = workload_from_trace(load_swf(swf), JSCC_SYSTEMS)
    np.testing.assert_array_equal(
        np.asarray(w.idle_w),
        np.asarray([s.idle_w for s in JSCC_SYSTEMS], np.float32))
    idle_floor = float(np.sum(np.asarray(w.idle_w) * np.asarray(w.n_nodes)))
    res = Scheduler("paper", warm_start=True, engine="events").run(w)
    assert float(res.peak_power) >= idle_floor
    assert float(res.idle_energy) > 0


def test_arrival_core_reports_nan_peak_and_idle_energy():
    w = _stream(n=15)
    ra = Scheduler("paper", warm_start=True).run(w)
    assert np.isnan(float(ra.peak_power))
    assert float(ra.capped_delay) == 0.0
    # idle_energy == idle_w . (n_nodes * makespan - busy)
    idle = float(np.sum(np.asarray(w.idle_w)
                        * (np.asarray(w.n_nodes) * float(ra.makespan)
                           - np.asarray(ra.busy))))
    np.testing.assert_allclose(float(ra.idle_energy), idle, rtol=1e-5)
    d = ra.to_dict()
    for key in ("peak_power", "idle_energy", "capped_delay"):
        assert key in d


# ------------------------------------------------- mid-job failure retry

@pytest.mark.parametrize("queue", ["", "conservative"])
def test_failure_requeue_semantics(queue):
    """On the event core a failing job re-queues at its failure event:
    every job still completes, the failed work costs energy, and the
    per-job runtime carries both attempts (restart_overhead + full
    rerun when both attempts land on one system)."""
    w = _stream(n=20, rate=0.5, seed=9)
    kw = dict(warm_start=True, engine="events" if not queue else None,
              queue=queue or None)
    clean = Scheduler("paper", **kw).run(w)
    faulty = Scheduler(
        "paper", faults=FaultConfig(failure_prob=1.0, restart_overhead=0.5),
        **kw).run(w)
    assert (np.asarray(faulty.runtime) > 0).all()
    assert float(faulty.total_energy) > float(clean.total_energy) * 1.3
    # at least one job retried on its own system => runtime exactly
    # (1 + restart_overhead) x T_true there
    sel = np.asarray(faulty.system)
    T_base = np.asarray(w.T_true)[np.asarray(w.prog), sel]
    ratio = np.asarray(faulty.runtime) / T_base
    assert np.isclose(ratio, 1.5, rtol=1e-4).any()
    assert (ratio > 1.0 - 1e-5).all()       # failed work never free
    # learned tables absorb the inflated totals exactly once per job
    # (same update count as the clean run)
    np.testing.assert_array_equal(np.asarray(faulty.runs).sum(),
                                  np.asarray(clean.runs).sum())


def test_failure_requeue_seed_axis_varies():
    w = _stream(n=15, rate=0.5, seed=2)
    res = Scheduler("conservative", warm_start=True, seeds=range(3),
                    faults=FaultConfig(failure_prob=0.5,
                                       restart_overhead=0.5)).run(w)
    E = np.asarray(res.total_energy)
    assert len(np.unique(E)) > 1


# (hypothesis property sweeps over these invariants live in
# tests/test_property_events.py — the dev extra is optional there)
