"""Unit tests for the paper's selection algorithm (core/algorithm.py)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.algorithm import select_system, _paper_rule
from repro.core.profiles import ProfileStore, k_auto

BIG_T = 1e9


def sel(mode, c, t, runs=None, avail=None, k=0.0, c_pred=None, t_pred=None):
    c = jnp.asarray(c, jnp.float32)
    t = jnp.asarray(t, jnp.float32)
    runs = jnp.asarray(runs if runs is not None else [1] * len(c))
    avail = jnp.asarray(avail if avail is not None else [0.0] * len(c))
    return int(select_system(
        mode, c_row=c, t_row=t, runs_row=runs, avail_row=avail, k=k,
        c_pred_row=jnp.asarray(c_pred if c_pred is not None else c),
        t_pred_row=jnp.asarray(t_pred if t_pred is not None else t),
        key=jax.random.key(0)))


# ---- Table 5 of the paper: exact reproduction ----------------------------
# Columns: CC1, CC2, CC3.  K in percent.  Expected allocation from the paper.
TABLE5 = [
    # (C row,                 T row,            K,    expected CC index)
    ([0.0015, 0.002, 0.001], [550, 500, 700], 0.10, 0),   # Program 1 -> CC1
    ([0.0012, 0.0015, 0.0013], [500, 350, 650], 0.30, 1), # Program 2 -> CC2
    ([0.0013, 0.0019, 0.0011], [700, 500, 900], 0.90, 2), # Program 3 -> CC3
    ([0.0055, 0.0075, 0.006], [180, 100, 120], 0.50, 2),  # Program 4 -> CC3
    ([0.005, 0.0055, 0.0045], [5000, 4500, 6000], 0.0, 1),# Program 5 -> CC2
]


@pytest.mark.parametrize("c,t,k,expected", TABLE5)
def test_table5_exact(c, t, k, expected):
    assert sel("paper", c, t, k=k) == expected


def test_table5_program6_explores_first_released():
    # Program 6: ran only on CC3; CC1 and CC2 unexplored; CC1 released first
    idx = sel("paper", [0, 0, 0.005], [0, 0, 150], runs=[0, 0, 1],
              avail=[10.0, 20.0, 0.0], k=0.15)
    assert idx == 0      # paper: Program 6 -> CC1


def test_table5_program7_never_run():
    # Program 7: never run anywhere; first released wins (CC3 here)
    idx = sel("paper", [0, 0, 0], [0, 0, 0], runs=[0, 0, 0],
              avail=[5.0, 3.0, 1.0], k=0.25)
    assert idx == 2      # paper: Program 7 -> CC3


# ---- paper rule invariants ------------------------------------------------

def test_k_zero_selects_fastest_feasible():
    # K=0: only the T_min system is feasible
    assert sel("paper", [5.0, 1.0, 3.0], [100, 200, 300], k=0.0) == 0


def test_k_large_selects_greenest():
    assert sel("paper", [5.0, 1.0, 3.0], [100, 200, 300], k=10.0) == 1


def test_feasibility_respected():
    # system 1 is greener but 50% slower; K=0.2 excludes it
    assert sel("paper", [2.0, 1.0], [100, 150], k=0.2) == 0
    # K=0.5 admits it
    assert sel("paper", [2.0, 1.0], [100, 150], k=0.5) == 1


def test_tie_break_on_time():
    # equal C: pick the faster one
    assert sel("paper", [1.0, 1.0, 2.0], [200, 100, 50], k=10.0) == 1


def test_queue_aware_avoids_busy_system():
    # greener system is busy for 1000s; queue_aware counts the wait
    idx_paper = sel("paper", [1.0, 2.0], [100, 105],
                    avail=[1000.0, 0.0], k=0.10)
    idx_qa = sel("queue_aware", [1.0, 2.0], [100, 105],
                 avail=[1000.0, 0.0], k=0.10)
    assert idx_paper == 0          # paper ignores the queue
    assert idx_qa == 1             # queue-aware routes around it


def test_predictive_skips_exploration():
    # unexplored system with great predicted C is chosen directly
    idx = sel("predictive", [1.0, 0.0], [100.0, 0.0], runs=[1, 0],
              c_pred=[1.0, 0.2], t_pred=[100.0, 101.0], k=0.05)
    assert idx == 1


def test_modes_return_valid_index():
    for mode in ("paper", "queue_aware", "predictive", "ucb", "fastest",
                 "greenest", "first_free", "random", "oracle"):
        idx = sel(mode, [1.0, 2.0, 3.0], [30, 20, 10], k=0.1)
        assert 0 <= idx < 3, mode


# ---- profile store / k_auto ----------------------------------------------

def test_profile_store_updates_and_averages():
    ps = ProfileStore(2, 3)
    assert not ps.fully_explored()
    ps.update(0, 1, c=2.0, t=100.0)
    ps.update(0, 1, c=4.0, t=200.0)
    assert ps.C[0, 1] == pytest.approx(3.0)
    assert ps.T[0, 1] == pytest.approx(150.0)
    assert ps.runs[0, 1] == 2
    assert ps.known(0)[1] and not ps.known(0)[0]


def test_k_auto_matches_paper_formula():
    # paper: K = T_max / T  (as allowed-increase fraction: T_max/T - 1)
    assert k_auto(t_max=600.0, t_hist=500.0) == pytest.approx(0.2)
    assert k_auto(t_max=400.0, t_hist=500.0) == 0.0    # never negative
    assert k_auto(t_max=100.0, t_hist=0.0) == 0.0      # no history
