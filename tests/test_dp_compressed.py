"""Hierarchical compressed-DP trainer (subprocess: needs a (pod, data)
multi-device mesh)."""

import os
import subprocess
import sys

import pytest

pytestmark = pytest.mark.slow       # subprocess, 150-step training run

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.optim import AdamWConfig
from repro.train.dp import make_dp_train_step, init_dp_state
from repro.launch.mesh import _make_mesh

mesh = _make_mesh((2, 4), ("pod", "data"))
target = jnp.linspace(-1.0, 1.0, 32)

def loss_fn(params, batch):
    pred = batch["x"] @ params["w"]
    return jnp.mean((pred - batch["y"]) ** 2)

key = jax.random.key(0)
params = {"w": jnp.zeros((16, 32))}
w_true = jax.random.normal(key, (16, 32)) * 0.5
ocfg = AdamWConfig(lr_peak=3e-2, warmup_steps=5, total_steps=150,
                   weight_decay=0.0)

losses = {}
for compress in (False, True):
    p = {"w": jnp.zeros((16, 32))}
    opt, err = init_dp_state(p)
    step = make_dp_train_step(loss_fn, mesh, ocfg, compress_cross_pod=compress)
    for i in range(150):
        k = jax.random.fold_in(key, i)
        x = jax.random.normal(k, (64, 16))
        y = x @ w_true + 0.01 * jax.random.normal(k, (64, 32))
        p, opt, err, loss, gn = step(p, opt, err, {"x": x, "y": y})
    losses[compress] = float(loss)
    print(f"compress={compress}: final loss {float(loss):.5f}")

assert losses[True] < 0.01, losses
assert abs(losses[True] - losses[False]) < 0.01, losses
print("DP COMPRESSED OK")
"""


def test_hierarchical_compressed_dp():
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "DP COMPRESSED OK" in out.stdout
