"""CI benchmark-regression gate (ISSUE 4; calibration + new rows ISSUE 5).

PR 4 bought a >= 5x warm wall-clock win on the EASY scan (batched
candidate evaluation); this guard keeps the next refactor from silently
giving it back.  It re-measures the small queue-discipline benchmark and
fails when the warm ``us_per_call`` for ``queue_swf_easy_backfill`` (or
the event-granular ``queue_swf_conservative`` scan, ISSUE 5) regresses
more than 2x past the committed ``BENCH_scheduler.json`` row.

Machine normalization: CI runners and dev boxes are not the machine that
produced the committed row, so the raw 2x ratio would flag hardware, not
code.  The FCFS row on the same stream is the anchor — its scan shares
the kernels and workload shape but none of the window machinery — and
the gate compares against ``2x * committed * speed_factor``.  The anchor
is the MEDIAN of three independent warm measurements (each itself
best-of-3): a single flukey-slow FCFS sample on a noisy GitHub runner
would inflate the allowance (masking real regressions) or — when the
fresh EASY sample flukes instead — trip the gate spuriously; the median
of three keeps one outlier from steering the bound (ROADMAP bench-gate
calibration item).

Tier-1 (``pytest -x -q`` runs it) but ``slow``-marked, so the quick loop
skips it; the dedicated ``bench-smoke`` CI job runs it on every PR.
"""

import json
import pathlib
import statistics
import sys

import pytest

ROOT = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT / "benchmarks"))

pytestmark = pytest.mark.slow

GATE = 2.0                      # allowed warm wall-clock regression factor


def _committed_rows() -> dict:
    payload = json.loads((ROOT / "BENCH_scheduler.json").read_text())
    return {r["name"]: r for r in payload["rows"]}


def _median_fcfs_us(w, repeats: int = 3) -> float:
    """Median of ``repeats`` independent warm FCFS measurements — the
    noise-calibrated machine-speed anchor."""
    from scheduler_ablation import _warm_us
    from repro.core import Scheduler, make_policy

    pol = make_policy("paper", k=0.10)
    sched = Scheduler(pol, warm_start=True)
    return statistics.median(_warm_us(sched, w)[0] for _ in range(repeats))


def test_committed_rows_carry_timed_flag():
    """Every committed row says whether its us_per_call is a measurement.
    Timed rows carry a positive ``us_per_call``; derived-only rows (e.g.
    ``queue_swf_delta``) are ``timed: false`` and OMIT the key entirely —
    a phantom 0.0 reads like "this took no time" to averaging tools, so
    the writer no longer emits one (and this guard skips untimed rows
    explicitly rather than special-casing zeros)."""
    rows = _committed_rows()
    assert rows, "BENCH_scheduler.json has no rows"
    for name, row in rows.items():
        assert "timed" in row, f"row {name!r} lacks the timed flag"
        if row["timed"]:
            assert row.get("us_per_call", 0) > 0, \
                f"timed row {name!r} lacks a positive us_per_call"
        else:
            assert "us_per_call" not in row, \
                f"untimed row {name!r} carries a phantom us_per_call"
    # the rows the gate leans on must be real measurements
    assert rows["queue_swf_easy_backfill"]["timed"]
    assert rows["queue_swf_conservative"]["timed"]
    assert rows["queue_swf_fcfs"]["timed"]
    assert rows["service_decision_latency"]["timed"]
    assert rows["pool_decision_latency"]["timed"]
    assert rows["dvfs_pareto_grid"]["timed"]
    assert rows["campaign_jobs_per_sec"]["timed"]
    assert rows["campaign_shard_scaling"]["timed"]


def test_power_cap_rows_committed():
    """The ISSUE 5 power-cap sweep rows are part of the committed
    artifact: a binding cap's peak must be recorded at or under its cap
    (the derived string is the record the trend tooling reads)."""
    rows = _committed_rows()
    assert rows["power_cap_sweep"]["timed"]
    for name in ("power_cap_45kW", "power_cap_52kW", "power_cap_60kW",
                 "power_cap_uncapped"):
        assert name in rows, f"missing committed power-cap row {name!r}"
        assert "peak=" in rows[name]["derived"]
    for name, cap_kw in (("power_cap_45kW", 45.0), ("power_cap_52kW", 52.0),
                         ("power_cap_60kW", 60.0)):
        peak = float(rows[name]["derived"].split("peak=")[1].split("kW")[0])
        assert peak <= cap_kw * (1 + 1e-3), \
            f"committed {name} peak {peak}kW exceeds its cap"


def test_dvfs_pareto_rows_committed():
    """The ISSUE 8 DVFS Pareto lattice rows are part of the committed
    artifact: the timed grid row records a single compilation for the
    whole cap x phi-weight x K lattice, and the frontier row records a
    non-trivial front that dominates the selection-only baseline."""
    rows = _committed_rows()
    grid = rows["dvfs_pareto_grid"]
    assert grid["timed"]
    assert "compiles=1" in grid["derived"], \
        "committed lattice row must record a single jit compilation"
    points = int(grid["derived"].split("points=")[1].split(";")[0])
    assert points >= 24, f"lattice too small: {points} points (>= 24)"
    front = rows["dvfs_pareto_frontier"]
    assert "dominates_baseline=True" in front["derived"]
    size = int(front["derived"].split("size=")[1].split("/")[0])
    assert size >= 2, f"degenerate committed frontier (size {size})"


def test_dvfs_pareto_wallclock_gate():
    """Fresh warm per-grid-point wall-clock of the one-jit DVFS lattice
    must stay within GATE x of the committed ``dvfs_pareto_grid`` row,
    machine-normalized through the median-of-3 FCFS anchor.  Running the
    suite also re-asserts the single-compilation and baseline-domination
    acceptance criteria (they are asserts inside the benchmark)."""
    from scheduler_ablation import (machine_speed_factor, queue_streams,
                                    run_dvfs_pareto)

    rows = _committed_rows()
    committed = rows["dvfs_pareto_grid"]["us_per_call"]
    committed_fcfs = rows["queue_swf_fcfs"]["us_per_call"]

    fresh_fcfs = _median_fcfs_us(queue_streams()["swf"])
    fresh_rows = {name: (us, derived)
                  for name, us, derived in run_dvfs_pareto()}
    fresh = fresh_rows["dvfs_pareto_grid"][0]
    assert "dominates_baseline=True" in fresh_rows["dvfs_pareto_frontier"][1]

    speed = machine_speed_factor(fresh_fcfs, committed_fcfs)
    bound = GATE * committed * speed
    assert fresh <= bound, (
        f"DVFS lattice warm wall-clock regressed: fresh {fresh:.0f}us/point "
        f"> {GATE}x committed {committed:.0f}us (speed factor {speed:.2f}) "
        f"— if intentional, regenerate BENCH_scheduler.json via "
        f"`python benchmarks/scheduler_ablation.py --suites dvfs_pareto`")


def test_million_campaign_rows_committed():
    """The ISSUE 10 million-job rows are part of the committed artifact:
    the throughput row records the full J=10^6 chunked totals_only
    campaign as a rate, and the shard-scaling row records the
    8-virtual-device shard_map within GATE x of the single-device vmap."""
    rows = _committed_rows()
    thr = rows["campaign_jobs_per_sec"]
    assert thr["timed"]
    assert int(thr["derived"].split("jobs=")[1].split(";")[0]) == 1_000_000
    assert "totals_only=True" in thr["derived"]
    assert float(thr["derived"].split("jobs_per_sec=")[1].split(";")[0]) > 0
    sc = rows["campaign_shard_scaling"]
    assert sc["timed"]
    assert int(sc["derived"].split("devices=")[1].split(";")[0]) == 8
    ratio = float(sc["derived"].split("ratio_vs_single=")[1].split(";")[0])
    assert ratio <= GATE, \
        f"committed shard_map overhead ratio {ratio:.2f} exceeds {GATE}x"


def test_million_campaign_throughput_gate():
    """Fresh warm campaign throughput (jobs/sec over the whole grid) must
    stay within GATE x of the committed million-job rate, normalized
    through the median-of-3 FCFS anchor.  The re-measurement uses a
    reduced-J stream (``SCHED_BENCH_MILLION_J``, default 60k here) — the
    row's rate form is what makes that comparable to the committed
    J=10^6 number.  The fresh shard-scaling ratio is gated directly (no
    normalization: both sides of the ratio ran on the same box)."""
    import os

    from scheduler_ablation import (machine_speed_factor, queue_streams,
                                    run_million_jobs)

    rows = _committed_rows()
    committed_rate = float(rows["campaign_jobs_per_sec"]["derived"]
                           .split("jobs_per_sec=")[1].split(";")[0])
    committed_fcfs = rows["queue_swf_fcfs"]["us_per_call"]

    fresh_fcfs = _median_fcfs_us(queue_streams()["swf"])
    J = int(os.environ.get("SCHED_BENCH_MILLION_J", "60000"))
    fresh_rows = {name: derived
                  for name, _, derived in run_million_jobs(J=J)}
    fresh_rate = float(fresh_rows["campaign_jobs_per_sec"]
                       .split("jobs_per_sec=")[1].split(";")[0])
    ratio = float(fresh_rows["campaign_shard_scaling"]
                  .split("ratio_vs_single=")[1].split(";")[0])
    # 8 virtual devices on fewer physical cores SERIALIZE the shards, so
    # the ratio measures pure shard_map overhead there (~1.9x on a 1-core
    # box) — keep the strict bound for machines that can actually run the
    # shards concurrently and a catastrophic-only bound elsewhere
    ratio_bound = GATE if (os.cpu_count() or 1) >= 8 else 2 * GATE
    assert ratio <= ratio_bound, (
        f"shard_map now costs {ratio:.2f}x the single-device vmap "
        f"(> {ratio_bound}x on {os.cpu_count()} cores)")

    speed = machine_speed_factor(fresh_fcfs, committed_fcfs)
    floor = committed_rate / (GATE * speed)
    assert fresh_rate >= floor, (
        f"campaign throughput regressed: fresh {fresh_rate:.0f} jobs/s at "
        f"J={J} < committed {committed_rate:.0f}/{GATE}x (speed factor "
        f"{speed:.2f}) — if intentional, regenerate BENCH_scheduler.json "
        f"via `python benchmarks/scheduler_ablation.py --suites "
        f"million_jobs`")


@pytest.mark.parametrize("row,queue", [
    ("queue_swf_easy_backfill", "easy_backfill:window=16"),
    ("queue_swf_conservative", "conservative:window=16"),
])
def test_backfill_warm_wallclock_gate(row, queue):
    """Fresh warm wall-clock for the W=16 backfill scans on the SWF
    stream must stay within GATE x of the committed rows
    (machine-normalized through the median-of-3 FCFS anchor)."""
    from scheduler_ablation import _warm_us, machine_speed_factor, \
        queue_streams
    from repro.core import Scheduler, make_policy

    rows = _committed_rows()
    committed = rows[row]["us_per_call"]
    committed_fcfs = rows["queue_swf_fcfs"]["us_per_call"]

    w = queue_streams()["swf"]
    pol = make_policy("paper", k=0.10)
    fresh_fcfs = _median_fcfs_us(w)
    fresh, _ = _warm_us(Scheduler(pol, warm_start=True, queue=queue), w)

    speed = machine_speed_factor(fresh_fcfs, committed_fcfs)
    bound = GATE * committed * speed
    assert fresh <= bound, (
        f"{row} warm wall-clock regressed: fresh {fresh:.0f}us > "
        f"{GATE}x committed {committed:.0f}us (machine speed factor "
        f"{speed:.2f} from median FCFS {fresh_fcfs:.0f}us vs committed "
        f"{committed_fcfs:.0f}us) — if the regression is intentional, "
        f"regenerate BENCH_scheduler.json via "
        f"`python benchmarks/scheduler_ablation.py` and commit it")


def test_service_decision_latency_gate():
    """ISSUE 7: warm per-decision latency of the live dispatcher on the
    SWF stream (same jitted step as the batch scan, called per event)
    must stay within GATE x of the committed ``service_decision_latency``
    row, machine-normalized through the same FCFS anchor.  The suite
    itself also re-asserts live-vs-batch bit-identity, so this one test
    is the whole service acceptance smoke on CI."""
    from scheduler_ablation import (machine_speed_factor, queue_streams,
                                    run_service)

    rows = _committed_rows()
    committed = rows["service_decision_latency"]["us_per_call"]
    committed_fcfs = rows["queue_swf_fcfs"]["us_per_call"]

    fresh_fcfs = _median_fcfs_us(queue_streams()["swf"])
    (_, fresh, derived), = run_service()
    assert "bit_identical=True" in derived

    speed = machine_speed_factor(fresh_fcfs, committed_fcfs)
    bound = GATE * committed * speed
    assert fresh <= bound, (
        f"service decision latency regressed: fresh {fresh:.0f}us/step > "
        f"{GATE}x committed {committed:.0f}us (speed factor {speed:.2f}) "
        f"— if intentional, regenerate BENCH_scheduler.json via "
        f"`python benchmarks/scheduler_ablation.py --suites service`")


def test_pool_decision_latency_gate():
    """ISSUE 9: warm per-decision latency of the 8-session vmapped pool
    on the SWF stream must stay within GATE x of the committed
    ``pool_decision_latency`` row (machine-normalized through the FCFS
    anchor), and the suite's own asserts re-check per-lane bit-identity
    plus SUB-linear per-decision scaling in N — one pool step must be
    cheaper than N independent steps."""
    from scheduler_ablation import (machine_speed_factor, queue_streams,
                                    run_pool)

    rows = _committed_rows()
    committed = rows["pool_decision_latency"]["us_per_call"]
    committed_fcfs = rows["queue_swf_fcfs"]["us_per_call"]

    fresh_fcfs = _median_fcfs_us(queue_streams()["swf"])
    (_, fresh, derived), = run_pool()
    assert "bit_identical=True" in derived
    scaling = float(derived.split("scaling_x8=")[1].split(";")[0])
    assert scaling < 1.0, (
        f"pool per-decision cost no longer sub-linear in N "
        f"(x8 scaling {scaling:.2f})")

    speed = machine_speed_factor(fresh_fcfs, committed_fcfs)
    bound = GATE * committed * speed
    assert fresh <= bound, (
        f"pool decision latency regressed: fresh {fresh:.0f}us/decision > "
        f"{GATE}x committed {committed:.0f}us (speed factor {speed:.2f}) "
        f"— if intentional, regenerate BENCH_scheduler.json via "
        f"`python benchmarks/scheduler_ablation.py --suites pool`")
