"""CI benchmark-regression gate (ISSUE 4).

PR 4 bought a >= 5x warm wall-clock win on the EASY scan (batched
candidate evaluation); this guard keeps the next refactor from silently
giving it back.  It re-measures the small queue-discipline benchmark and
fails when the warm ``us_per_call`` for ``queue_swf_easy_backfill``
regresses more than 2x past the committed ``BENCH_scheduler.json`` row.

Machine normalization: CI runners and dev boxes are not the machine that
produced the committed row, so the raw 2x ratio would flag hardware, not
code.  The FCFS row on the same stream is the anchor — its scan shares
the kernels and workload shape but none of the EASY window machinery —
and the gate compares against ``2x * committed * max(fresh_fcfs /
committed_fcfs, 1)``.

Tier-1 (``pytest -x -q`` runs it) but ``slow``-marked, so the quick loop
skips it; the dedicated ``bench-smoke`` CI job runs it on every PR.
"""

import json
import pathlib
import sys

import pytest

ROOT = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT / "benchmarks"))

pytestmark = pytest.mark.slow

GATE = 2.0                      # allowed warm wall-clock regression factor


def _committed_rows() -> dict:
    payload = json.loads((ROOT / "BENCH_scheduler.json").read_text())
    return {r["name"]: r for r in payload["rows"]}


def test_committed_rows_carry_timed_flag():
    """Every committed row says whether its us_per_call is a measurement;
    derived-only rows (e.g. ``queue_swf_delta``) must be ``timed: false``
    so no tool ever averages their phantom zeros."""
    rows = _committed_rows()
    assert rows, "BENCH_scheduler.json has no rows"
    for name, row in rows.items():
        assert "timed" in row, f"row {name!r} lacks the timed flag"
        assert row["timed"] == (row["us_per_call"] > 0), \
            f"row {name!r}: timed flag inconsistent with us_per_call"
    # the two rows the gate leans on must be real measurements
    assert rows["queue_swf_easy_backfill"]["timed"]
    assert rows["queue_swf_fcfs"]["timed"]


def test_easy_backfill_warm_wallclock_gate():
    """Fresh warm wall-clock for the W=16 EASY scan on the SWF stream
    must stay within GATE x of the committed row (machine-normalized)."""
    from scheduler_ablation import _warm_us, machine_speed_factor, \
        queue_streams
    from repro.core import Scheduler, make_policy

    rows = _committed_rows()
    committed_easy = rows["queue_swf_easy_backfill"]["us_per_call"]
    committed_fcfs = rows["queue_swf_fcfs"]["us_per_call"]

    w = queue_streams()["swf"]
    pol = make_policy("paper", k=0.10)
    fresh_fcfs, _ = _warm_us(Scheduler(pol, warm_start=True), w)
    fresh_easy, _ = _warm_us(
        Scheduler(pol, warm_start=True, queue="easy_backfill:window=16"), w)

    speed = machine_speed_factor(fresh_fcfs, committed_fcfs)
    bound = GATE * committed_easy * speed
    assert fresh_easy <= bound, (
        f"EASY warm wall-clock regressed: fresh {fresh_easy:.0f}us > "
        f"{GATE}x committed {committed_easy:.0f}us (machine speed factor "
        f"{speed:.2f} from FCFS {fresh_fcfs:.0f}us vs committed "
        f"{committed_fcfs:.0f}us) — if the regression is intentional, "
        f"regenerate BENCH_scheduler.json via "
        f"`python benchmarks/scheduler_ablation.py` and commit it")
