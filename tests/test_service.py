"""Online scheduler service (ISSUE 7): the event core as live state.

The acceptance criteria pinned here:

  - a live ``Dispatcher`` session fed a stream event-by-event (submit
    each job before driving past its arrival) reproduces the batch
    ``Scheduler.run`` placements AND totals bit-identically — including
    the swf ablation stream across all three queue disciplines;
  - a session killed mid-stream and restored from its checkpoint
    finishes with decisions/totals bit-identical to uninterrupted;
  - a what-if query answers from a forked rollout without mutating the
    live carry (carry snapshot equality);
  - ``engine="events"`` routes the default EASY path onto the event
    core (``core=`` survives only as a deprecation shim, PR 9); the
    divergence from the arrival-indexed EASY scan is real and
    documented below.
"""

import pathlib
import sys

import numpy as np
import pytest
import jax

from repro.core import (JSCC_SYSTEMS, Scheduler, make_npb_workload,
                        make_policy)
from repro.service import Dispatcher, ServiceMetrics, whatif

ROOT = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT / "benchmarks"))

#: every total/per-job/table field a SimResult carries (bit-compared)
FIELDS = ("system", "start", "finish", "wait", "energy", "runtime",
          "backfilled", "total_energy", "makespan", "total_wait",
          "slowdown_sum", "max_wait", "n_backfilled", "peak_power",
          "idle_energy", "capped_delay", "busy", "C_tab", "T_tab", "runs")


def small_stream():
    return make_npb_workload(
        JSCC_SYSTEMS, order=("BT", "EP", "IS", "LU", "SP"), repeats=2,
        arrivals=np.arange(10, dtype=np.float32) * 30.0)


def replay(w, disp):
    """The live protocol: submit each job before driving past its
    arrival, then drain."""
    for j in range(len(w.prog)):
        disp.drive(until=float(w.arrival[j]))
        disp.submit(int(w.prog[j]), float(w.arrival[j]))
    disp.drain()
    return disp


def assert_bit_identical(batch, live):
    for f in FIELDS:
        a = np.asarray(getattr(batch, f))
        b = np.asarray(getattr(live, f))
        assert a.tobytes() == b.tobytes(), \
            f"{f}: batch {a} != live {b}"


# ------------------------------------------------------ live bit-identity

@pytest.mark.parametrize("queue", ["fcfs", "easy_backfill:window=4"])
def test_live_replay_matches_batch(queue):
    """Event-by-event dispatch reproduces the batch scan bitwise (the
    extra quiescent steps a live session sees are carry no-ops)."""
    w = small_stream()
    pol = make_policy("paper", k=0.1)
    batch = Scheduler(pol, warm_start=True, queue=queue,
                      engine="events").run(w)
    live = replay(w, Dispatcher(w, pol, warm_start=True, queue=queue))
    assert_bit_identical(batch, live.result())
    assert len(live.decisions) == len(w.prog)


@pytest.mark.slow
@pytest.mark.parametrize("queue", ["fcfs", "easy_backfill:window=16",
                                   "conservative:window=16"])
def test_live_replay_swf_stream(queue):
    """The acceptance stream: the swf ablation workload, all three
    disciplines, placements and totals bit-identical to batch."""
    from scheduler_ablation import queue_streams
    w = queue_streams()["swf"]
    pol = make_policy("paper", k=0.10)
    batch = Scheduler(pol, warm_start=True, queue=queue,
                      engine="events").run(w)
    live = replay(w, Dispatcher(w, pol, warm_start=True, queue=queue))
    assert_bit_identical(batch, live.result())


def test_live_power_cap_session():
    """A capped live session enforces the cap exactly as the batch scan
    (deferral decisions ride the same step)."""
    w = small_stream()
    pol = make_policy("paper", k=0.1)
    kw = dict(warm_start=True, queue="easy_backfill:window=4",
              power_cap=45e3)
    batch = Scheduler(pol, engine="events", **kw).run(w)
    live = replay(w, Dispatcher(w, pol, **kw)).result()
    assert_bit_identical(batch, live)
    assert float(live.peak_power) <= 45e3 * (1 + 1e-6)


# ------------------------------------------------------------ checkpoint

def test_checkpoint_roundtrip_bit_identical(tmp_path):
    """Save mid-stream, restore into a FRESH dispatcher, finish the
    stream: decisions and totals match the uninterrupted session."""
    w = small_stream()
    pol = make_policy("paper", k=0.1)

    def mk():
        return Dispatcher(w, pol, warm_start=True,
                          queue="easy_backfill:window=4",
                          checkpoint_dir=str(tmp_path))

    def feed(d, jobs):
        for j in jobs:
            d.drive(until=float(w.arrival[j]))
            d.submit(int(w.prog[j]), float(w.arrival[j]))

    d1 = mk()
    feed(d1, range(6))
    d1.save()
    feed(d1, range(6, 10))
    d1.drain()

    d2 = mk()                      # fresh process-style restore path
    assert d2.restore()
    assert d2.n_submitted == 6
    feed(d2, range(6, 10))
    d2.drain()

    assert d1.decisions == d2.decisions
    assert_bit_identical(d1.result(), d2.result())


def test_restore_empty_dir_is_noop(tmp_path):
    d = Dispatcher(small_stream(), make_policy("paper", k=0.1),
                   checkpoint_dir=str(tmp_path))
    assert not d.restore()
    assert d.n_submitted == 0


# --------------------------------------------------------------- what-if

def test_whatif_does_not_mutate_live_carry():
    """The rollout is a pure fork: the live carry, job arrays, and
    counters are bitwise unchanged by a query."""
    w = small_stream()
    d = Dispatcher(w, make_policy("paper", k=0.1), warm_start=True,
                   queue="easy_backfill:window=4", capacity=12)
    for j in range(6):
        d.drive(until=float(w.arrival[j]))
        d.submit(int(w.prog[j]), float(w.arrival[j]))
    before = d.carry_snapshot()
    jobs_before = jax.device_get(
        {k: d._arrs[k] for k in ("prog", "arrival", "k_job")})
    n_before = d.n_submitted

    proj = whatif(d, prog=2)

    after = d.carry_snapshot()
    for a, b in zip(jax.tree.leaves(before), jax.tree.leaves(after)):
        assert np.array_equal(a, b, equal_nan=True)
    jobs_after = jax.device_get(
        {k: d._arrs[k] for k in ("prog", "arrival", "k_job")})
    for k in jobs_before:
        assert np.array_equal(jobs_before[k], jobs_after[k],
                              equal_nan=True)
    assert d.n_submitted == n_before
    assert proj["job"]["wait"] >= 0 and proj["makespan"] > 0


def test_whatif_projects_the_actual_submission():
    """Submitting the queried job realizes exactly the projection (no
    later arrivals intervene in this stream, so the rollout is exact)."""
    w = small_stream()
    d = Dispatcher(w, make_policy("paper", k=0.1), warm_start=True,
                   capacity=12)
    for j in range(10):
        d.drive(until=float(w.arrival[j]))
        d.submit(int(w.prog[j]), float(w.arrival[j]))
    d.drain()
    proj = whatif(d, prog=3)
    j = d.submit(3)
    d.drain()
    dec = [x for x in d.decisions if x["job"] == j]
    assert len(dec) == 1
    assert dec[0]["system"] == proj["job"]["system"]
    assert dec[0]["start"] == pytest.approx(proj["job"]["start"])
    assert dec[0]["finish"] == pytest.approx(proj["job"]["finish"])


def test_whatif_reports_cap_headroom():
    w = small_stream()
    d = Dispatcher(w, make_policy("paper", k=0.1), warm_start=True,
                   power_cap=60e3, capacity=12)
    proj = whatif(d, prog=0, arrival=0.0)
    assert np.isfinite(proj["cap_headroom"])
    assert proj["peak_power"] + proj["cap_headroom"] == pytest.approx(60e3)


# -------------------------------------------- engine= / core= shim / EASY

def test_core_deprecation_shim_matches_engine():
    """``core=`` still routes (bit-identically) but warns: the PR 9
    migration keeps every old call site working while naming the one
    supported spelling (``engine=``)."""
    w = small_stream()
    pol = make_policy("paper", k=0.1)
    with pytest.warns(DeprecationWarning, match="core=.*deprecated"):
        sched = Scheduler(pol, warm_start=True, core="events")
    assert sched.engine == "events"
    ra = sched.run(w)
    rb = Scheduler(pol, warm_start=True, engine="events").run(w)
    assert_bit_identical(ra, rb)


def test_engine_keyword_does_not_warn():
    import warnings as _warnings
    with _warnings.catch_warnings():
        _warnings.simplefilter("error", DeprecationWarning)
        sched = Scheduler("paper", engine="events")
    assert sched.engine == sched.core == "events"


def test_engine_alias_conflict_raises():
    with pytest.warns(DeprecationWarning):
        with pytest.raises(ValueError, match="conflicts"):
            Scheduler("paper", core="arrival", engine="events")


@pytest.mark.slow
def test_easy_events_vs_arrival_divergence_documented():
    """DOCUMENTED DIVERGENCE: the arrival-indexed EASY scan evaluates
    backfills once per arrival step and may grant a backfill a FUTURE
    start; the event core re-evaluates at every completion event and
    only starts backfills at the current event.  On a contended stream
    the event core therefore finds strictly more backfill opportunities
    (it looks again whenever nodes free up) — placements are NOT
    bit-identical, while the FCFS path (no backfill axis) is (asserted
    per policy in tests/test_event_core.py)."""
    from scheduler_ablation import queue_streams
    w = queue_streams()["swf"]
    pol = make_policy("paper", k=0.10)
    qs = "easy_backfill:window=16"
    ra = Scheduler(pol, warm_start=True, queue=qs).run(w)
    re = Scheduler(pol, warm_start=True, queue=qs, engine="events").run(w)
    # the divergence is real...
    assert int(re.n_backfilled) != int(ra.n_backfilled)
    # ...directional (the event core backfills at least as much, and no
    # later than arrival-indexed EASY on total wait)...
    assert int(re.n_backfilled) >= int(ra.n_backfilled)
    assert float(re.total_wait) <= float(ra.total_wait) * 1.05
    # ...and bounded: same jobs, same systems universe, close makespans
    assert float(re.makespan) == pytest.approx(float(ra.makespan),
                                               rel=0.10)


# ------------------------------------------------------- intake / clock

def test_submit_validation():
    w = small_stream()
    d = Dispatcher(w, make_policy("paper", k=0.1), capacity=2)
    d.submit(0, 0.0)
    with pytest.raises(ValueError, match="catalog"):
        d.submit(99, 1.0)
    d.submit(1, 1.0)
    with pytest.raises(RuntimeError, match="full"):
        d.submit(0, 2.0)


def test_submit_in_the_past_rejected():
    w = small_stream()
    d = Dispatcher(w, make_policy("paper", k=0.1), warm_start=True)
    d.submit(0, 50.0)
    d.drive(until=60.0)
    assert d.now >= 50.0
    with pytest.raises(ValueError, match="past"):
        d.submit(1, 10.0)


def test_drive_horizon_gates_clock():
    """The clock never runs past the horizon — a live session cannot
    decide ahead of arrivals it has not been told about."""
    w = small_stream()
    d = Dispatcher(w, make_policy("paper", k=0.1), warm_start=True)
    d.submit(0, 0.0)
    d.drive(until=10.0)
    assert d.now <= 10.0
    d.drive(until=1e4)
    assert d.now <= 1e4


def test_metrics_stream():
    w = small_stream()
    d = Dispatcher(w, make_policy("paper", k=0.1), warm_start=True)
    replay(w, d)
    m = d.metrics
    assert m.n_submitted == 10 and m.n_placed == 10 and m.n_finished == 10
    assert m.queue_depth == 0
    assert m.peak_power > 0 and m.latency_us_total > 0
    snap = m.snapshot()
    assert snap["mean_latency_us"] == pytest.approx(
        m.latency_us_total / m.n_steps)
    m2 = ServiceMetrics.from_snapshot(snap)
    assert m2 == m
