"""Per-architecture smoke tests (deliverable f).

Each assigned architecture is instantiated at a REDUCED same-family config
(small width/depth/experts/vocab) and runs one forward/train step and one
decode step on CPU, asserting output shapes and finiteness.  The FULL configs
are exercised only via the dry-run (ShapeDtypeStruct, no allocation).

Model builds/params are cached in a session-scoped fixture (each arch is
built once, not once per smoke test); compile-heavy smokes carry the
``slow`` marker — the quick loop (-m "not slow") keeps the config-dimension
checks only.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, smoke_reduce, SHAPES, shape_applicable
from repro.models import build_model

from conftest import make_lm_batch


@pytest.fixture(scope="session")
def built_arch():
    """arch -> (cfg, api, params), built once per session."""
    cache = {}

    def get(arch):
        if arch not in cache:
            cfg = smoke_reduce(get_config(arch))
            api = build_model(cfg)
            cache[arch] = (cfg, api, api.init_params(jax.random.key(0)))
        return cache[arch]

    return get


@pytest.mark.slow
@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_smoke(arch, built_arch):
    cfg, api, params = built_arch(arch)
    key = jax.random.key(0)
    batch = make_lm_batch(cfg, 2, 64, key)
    loss, metrics = jax.jit(api.train_loss)(params, batch)
    assert loss.shape == ()
    assert np.isfinite(float(loss)), (arch, loss)
    assert np.isfinite(float(metrics["loss"]))
    # gradients flow and are finite
    grads = jax.grad(lambda p: api.train_loss(p, batch)[0])(params)
    flat = jax.tree.leaves(grads)
    assert all(np.isfinite(np.asarray(g)).all() for g in flat), arch
    assert any(float(jnp.abs(g).max()) > 0 for g in flat), f"{arch}: all-zero grads"


@pytest.mark.slow
@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_step_smoke(arch, built_arch):
    cfg, api, params = built_arch(arch)
    b, max_seq = 2, 32
    cache = api.init_decode_cache(b, max_seq)
    tok = jnp.zeros((b, 1), jnp.int32)
    logits, cache2 = jax.jit(api.decode_step)(params, cache, tok, jnp.int32(5))
    assert logits.shape == (b, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits)).all(), arch
    # cache tree structure preserved
    assert jax.tree.structure(cache) == jax.tree.structure(cache2)


@pytest.mark.slow
@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_smoke(arch, built_arch):
    cfg, api, params = built_arch(arch)
    key = jax.random.key(0)
    batch = make_lm_batch(cfg, 2, 64, key)
    batch.pop("labels"), batch.pop("mask")
    logits = jax.jit(api.prefill)(params, batch)
    assert logits.shape == (2, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits)).all(), arch


def test_full_configs_exact_dims():
    """The FULL configs carry the exact assigned dimensions."""
    expect = {
        "llama4-scout-17b-a16e": (48, 5120, 40, 8, 8192, 202048),
        "moonshot-v1-16b-a3b": (48, 2048, 16, 16, 1408, 163840),
        "jamba-v0.1-52b": (32, 4096, 32, 8, 14336, 65536),
        "gemma-7b": (28, 3072, 16, 16, 24576, 256000),
        "qwen2-1.5b": (28, 1536, 12, 2, 8960, 151936),
        "internlm2-20b": (48, 6144, 48, 8, 16384, 92544),
        "tinyllama-1.1b": (22, 2048, 32, 4, 5632, 32000),
        "mamba2-780m": (48, 1536, 0, 0, 0, 50280),
        "whisper-medium": (24, 1024, 16, 16, 4096, 51865),
        "phi-3-vision-4.2b": (32, 3072, 32, 32, 8192, 32064),
    }
    for arch, (nl, d, h, kv, ff, v) in expect.items():
        cfg = get_config(arch)
        assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                cfg.d_ff, cfg.vocab_size) == (nl, d, h, kv, ff, v), arch
    # MoE / SSM specifics from the assignment
    assert get_config("llama4-scout-17b-a16e").moe.n_experts == 16
    assert get_config("llama4-scout-17b-a16e").moe.top_k == 1
    assert get_config("moonshot-v1-16b-a3b").moe.n_experts == 64
    assert get_config("moonshot-v1-16b-a3b").moe.top_k == 6
    assert get_config("jamba-v0.1-52b").moe.n_experts == 16
    assert get_config("jamba-v0.1-52b").moe.top_k == 2
    assert get_config("mamba2-780m").ssm.state == 128
    assert get_config("gemma-7b").resolved_head_dim() == 256
    assert get_config("qwen2-1.5b").qkv_bias


def test_shape_matrix_is_40_cells():
    cells = [(a, s) for a in ARCH_IDS for s in SHAPES]
    assert len(cells) == 40
    skipped = [(a, s) for a in ARCH_IDS for s, sh in SHAPES.items()
               if not shape_applicable(get_config(a), sh)[0]]
    # long_500k runs only for ssm/hybrid per DESIGN.md §5
    assert {(a, s) for a, s in skipped} == {
        (a, "long_500k") for a in ARCH_IDS
        if a not in ("mamba2-780m", "jamba-v0.1-52b")}
