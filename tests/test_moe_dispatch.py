"""MoE per-shard dispatch correctness (the §Perf iteration-1 change)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, smoke_reduce
from repro.models.moe import init_moe, apply_moe, moe_capacity
from repro.sharding.ctx import use_rules


def dense_ref(p, x, cfg):
    """Full top-k mixture, no capacity drops — the semantic ground truth."""
    b, s, d = x.shape
    xf = x.reshape(-1, d)
    logits = xf @ p["router"]
    probs = jax.nn.softmax(logits, -1)
    gates, ids = jax.lax.top_k(probs, cfg.moe.top_k)
    gates = gates / gates.sum(-1, keepdims=True)
    out = jnp.zeros_like(xf)
    for kk in range(cfg.moe.top_k):
        for ei in range(cfg.moe.n_experts):
            mask = (ids[:, kk] == ei).astype(jnp.float32) * gates[:, kk]
            h = jax.nn.silu(xf @ p["wi"][ei]) * (xf @ p["wu"][ei])
            out += (h @ p["wo"][ei]) * mask[:, None]
    return out.reshape(b, s, d)


@pytest.fixture(scope="module")
def setup():
    cfg = smoke_reduce(get_config("moonshot-v1-16b-a3b"))
    cfg = cfg.with_overrides(moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    key = jax.random.key(0)
    p = init_moe(cfg, key, jnp.float32)
    x = jax.random.normal(key, (4, 16, cfg.d_model), jnp.float32)
    return cfg, p, x


def test_sharded_matches_dense_when_no_drops(setup):
    cfg, p, x = setup
    out, aux = apply_moe(p, x, cfg)
    np.testing.assert_allclose(out, dense_ref(p, x, cfg), atol=2e-4)
    assert float(aux) > 0


def test_global_matches_dense_when_no_drops(setup):
    cfg, p, x = setup
    cfg_g = cfg.with_overrides(moe_dispatch="global")
    out, _ = apply_moe(p, x, cfg_g)
    np.testing.assert_allclose(out, dense_ref(p, x, cfg_g), atol=2e-4)


@pytest.mark.slow
def test_shard_count_invariance_no_drops(setup):
    """With ample capacity, the shard count is an implementation detail."""
    cfg, p, x = setup

    class _Mesh:  # dummy; annotate() needs a mesh only when rules installed
        axis_names = ()

        class devices:
            shape = ()

    out1, _ = apply_moe(p, x, cfg.with_overrides(moe_dispatch="global"))
    # dispatch_shards() reads rules; emulate S=2/S=4 via direct reshape check
    for s_count in (2, 4):
        from repro.sharding import ctx as sctx
        sctx._state.rules = {"dp_shards": s_count}
        sctx._state.mesh = None          # annotate() stays no-op
        try:
            out_s, _ = apply_moe(p, x, cfg)
        finally:
            sctx._state.rules = None
    np.testing.assert_allclose(out1, out_s, atol=2e-4)


def test_capacity_drops_are_bounded(setup):
    """At cf=1.0 with skewed routing, some tokens drop — output stays finite
    and within the convex hull scale of expert outputs."""
    cfg, p, x = setup
    cfg_small = cfg.with_overrides(
        moe=dataclasses.replace(cfg.moe, capacity_factor=1.0))
    out, aux = apply_moe(p, x, cfg_small)
    assert np.isfinite(np.asarray(out)).all()
    assert float(jnp.abs(out).max()) < 1e3


def test_capacity_rounding():
    cfg = smoke_reduce(get_config("moonshot-v1-16b-a3b"))
    c = moe_capacity(1000, cfg)
    assert c % 8 == 0 and c >= 8
