"""Sharding rules: divisibility fallbacks, no-duplicate-axis regression,
full-arch spec coverage (no device state touched — specs only)."""

import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCH_IDS, get_config, SHAPES
from repro.models import build_model
from repro.sharding.ctx import lm_rules
from repro.sharding.params import (param_partition_spec, tree_partition_specs,
                                   logical_axes_for)
from repro.utils.tree import flatten_with_names

AXIS_SIZES_1POD = {"data": 16, "model": 16}
AXIS_SIZES_2POD = {"pod": 2, "data": 16, "model": 16}


def _flat_axes(spec):
    out = []
    for part in spec:
        if part is None:
            continue
        out.extend([part] if isinstance(part, str) else list(part))
    return out


@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.parametrize("fsdp", [False, True])
def test_no_duplicate_mesh_axes_any_param(arch, fsdp):
    """Regression: MoE (experts, embed, ff) once produced duplicate 'model'."""
    cfg = get_config(arch)
    api = build_model(cfg)
    rules = lm_rules(multi_pod=True, fsdp=fsdp)
    for name, x in flatten_with_names(api.param_specs()):
        spec = param_partition_spec(name, tuple(x.shape), rules,
                                    AXIS_SIZES_2POD)
        axes = _flat_axes(spec)
        assert len(axes) == len(set(axes)), (arch, name, spec)
        assert len(spec) == len(x.shape), (arch, name, spec, x.shape)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_sharded_dims_divide(arch):
    cfg = get_config(arch)
    api = build_model(cfg)
    rules = lm_rules(multi_pod=False, fsdp=cfg.fsdp)
    for name, x in flatten_with_names(api.param_specs()):
        spec = param_partition_spec(name, tuple(x.shape), rules,
                                    AXIS_SIZES_1POD)
        for dim, part in zip(x.shape, spec):
            if part is None:
                continue
            size = np.prod([AXIS_SIZES_1POD[a] for a in
                            ([part] if isinstance(part, str) else part)])
            assert dim % size == 0, (arch, name, dim, part)


def test_llama4_heads_fall_back():
    """40 q-heads don't divide model=16 -> heads dim must stay unsharded."""
    spec = param_partition_spec(
        "groups/pos0/attn/wq", (48, 5120, 40, 128),
        lm_rules(False, True), AXIS_SIZES_1POD)
    assert spec[2] is None          # heads unsharded
    assert spec[1] == "data"        # FSDP fallback on embed dim


def test_qwen2_kv_heads_fall_back():
    spec = param_partition_spec(
        "groups/pos0/attn/wk", (28, 1536, 2, 128),
        lm_rules(False, False), AXIS_SIZES_1POD)
    assert spec[2] is None


def test_divisible_heads_are_sharded():
    spec = param_partition_spec(
        "groups/pos0/attn/wq", (48, 6144, 48, 128),
        lm_rules(False, False), AXIS_SIZES_1POD)
    assert spec[2] == "model"


def test_vocab_sharded_when_divisible():
    spec = param_partition_spec("embed/table", (202048, 5120),
                                lm_rules(False, False), AXIS_SIZES_1POD)
    assert spec[0] == "model"
    # mamba2 vocab 50280 is not divisible by 16 -> replicated
    spec = param_partition_spec("embed/table", (50280, 1536),
                                lm_rules(False, False), AXIS_SIZES_1POD)
    assert spec[0] is None


def test_moe_experts_on_model_axis():
    spec = param_partition_spec(
        "groups/pos0/moe/wi", (48, 64, 2048, 1408),
        lm_rules(False, True), AXIS_SIZES_1POD)
    assert spec == P(None, "model", "data", None)


def test_all_archs_tree_specs_build():
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        api = build_model(cfg)

        class _FakeMesh:
            axis_names = ("data", "model")

            class devices:
                shape = (16, 16)

        tree = tree_partition_specs(api.param_specs(),
                                    lm_rules(False, cfg.fsdp), _FakeMesh)
        n = len(flatten_with_names(tree))
        assert n == len(flatten_with_names(api.param_specs()))
