"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs pure-jnp oracle
(deliverable c: every kernel sweeps shapes/dtypes against ref.py)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention_bhsd, attention_ref
from repro.kernels.ep import ep_pairs_pallas, ep_pairs_ref
from repro.kernels.is_hist import key_histogram_pallas, key_histogram_ref
from repro.kernels.stencil3d import stencil7_pallas, stencil7_ref


# ----------------------------------------------------------- flash attention

@pytest.mark.parametrize("b,sq,sk,h,kv,hd,bq,bk", [
    (2, 256, 256, 8, 2, 64, 128, 128),
    (1, 256, 256, 4, 4, 128, 64, 128),
    (2, 128, 384, 4, 1, 64, 128, 128),     # MQA, rectangular
    (1, 512, 512, 2, 2, 32, 128, 256),
])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_sweep(b, sq, sk, h, kv, hd, bq, bk, causal):
    ks = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(ks[0], (b, sq, h, hd), jnp.float32)
    k = jax.random.normal(ks[1], (b, sk, kv, hd), jnp.float32)
    v = jax.random.normal(ks[2], (b, sk, kv, hd), jnp.float32)
    out = flash_attention_bhsd(q, k, v, causal=causal, block_q=bq, block_k=bk,
                               interpret=True)
    ref = attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(out, ref, atol=3e-5)


@pytest.mark.parametrize("dtype,atol", [(jnp.float32, 3e-5), (jnp.bfloat16, 3e-2)])
def test_flash_attention_dtypes(dtype, atol):
    ks = jax.random.split(jax.random.key(1), 3)
    q = jax.random.normal(ks[0], (1, 256, 4, 64), dtype)
    k = jax.random.normal(ks[1], (1, 256, 2, 64), dtype)
    v = jax.random.normal(ks[2], (1, 256, 2, 64), dtype)
    out = flash_attention_bhsd(q, k, v, causal=True, interpret=True)
    ref = attention_ref(q, k, v, causal=True)
    assert out.dtype == dtype
    np.testing.assert_allclose(out.astype(jnp.float32),
                               ref.astype(jnp.float32), atol=atol)


# ----------------------------------------------------------------------- EP

@pytest.mark.parametrize("n,block", [(4096, 1024), (8192, 2048), (2048, 2048)])
def test_ep_kernel_sweep(n, block):
    u = jax.random.uniform(jax.random.key(2), (2, n), minval=-1.0, maxval=1.0)
    h1, s1 = ep_pairs_pallas(u, block_n=block, interpret=True)
    h2, s2 = ep_pairs_ref(u)
    np.testing.assert_array_equal(np.asarray(h1), np.asarray(h2))
    np.testing.assert_allclose(s1, s2, rtol=1e-4)
    # acceptance ratio sanity (pi/4 for uniform pairs on the square)
    assert abs(float(h1.sum()) / n - np.pi / 4) < 0.05


# ----------------------------------------------------------------------- IS

@pytest.mark.parametrize("n,buckets,shift,block", [
    (8192, 64, 8, 2048),
    (16384, 256, 6, 4096),
    (4096, 16, 10, 4096),
])
def test_is_histogram_sweep(n, buckets, shift, block):
    keys = jax.random.randint(jax.random.key(3), (n,), 0,
                              buckets << shift, jnp.int32)
    h1 = key_histogram_pallas(keys, n_buckets=buckets, bucket_shift=shift,
                              block_n=block, interpret=True)
    h2 = key_histogram_ref(keys, n_buckets=buckets, bucket_shift=shift)
    np.testing.assert_array_equal(np.asarray(h1), np.asarray(h2))
    assert int(h1.sum()) == n


# ------------------------------------------------------------------ stencil

@pytest.mark.parametrize("nx,ny,nz,bx", [
    (32, 16, 16, 8), (64, 32, 32, 16), (16, 16, 16, 16), (48, 8, 8, 8),
])
def test_stencil_sweep(nx, ny, nz, bx):
    u = jax.random.normal(jax.random.key(4), (nx, ny, nz), jnp.float32)
    o1 = stencil7_pallas(u, bx=bx, interpret=True)
    o2 = stencil7_ref(u)
    np.testing.assert_allclose(o1, o2, atol=2e-5)


def test_stencil_boundary_is_dirichlet_zero():
    """Global-edge neighbours must contribute zero (not wrap / clamp)."""
    u = jnp.ones((16, 8, 8), jnp.float32)
    out = stencil7_pallas(u, bx=8, interpret=True)
    ref = stencil7_ref(u)
    np.testing.assert_allclose(out, ref, atol=1e-6)
    # interior point: -6 + 6 = 0; corner point: -6 + 3 = -3
    assert float(out[8, 4, 4]) == pytest.approx(0.0, abs=1e-5)
    assert float(out[0, 0, 0]) == pytest.approx(-3.0, abs=1e-5)


# ---------------------------------------------------------------- kth free

from repro.kernels.kth_free import (kth_free_ref, kth_free_pallas,  # noqa: E402
                                    kth_free_batched_ref,
                                    kth_free_pallas_batched,
                                    kth_free_time, kth_free_time_batched,
                                    kth_free_time_shared,
                                    radix_select_kth,
                                    radix_select_kth_batched)


@pytest.mark.parametrize("s,n,seed", [
    (4, 136, 0),      # the JSCC node matrix
    (2, 8, 1),
    (7, 200, 2),
    (3, 129, 3),      # non-multiple-of-lane width
])
def test_kth_free_sweep(s, n, seed):
    rng = np.random.default_rng(seed)
    free = rng.uniform(0, 1e6, (s, n)).astype(np.float32)
    free[rng.random((s, n)) < 0.3] = 1e30
    free[rng.random((s, n)) < 0.3] = 0.0
    nreq = rng.integers(1, n + 1, s).astype(np.int32)
    ref = np.asarray(kth_free_ref(jnp.asarray(free), jnp.asarray(nreq)))
    pal = np.asarray(kth_free_pallas(jnp.asarray(free), jnp.asarray(nreq),
                                     interpret=True))
    sel = np.asarray(radix_select_kth(jnp.asarray(free), jnp.asarray(nreq)))
    np.testing.assert_array_equal(ref, pal)
    np.testing.assert_array_equal(ref, sel)


def test_kth_free_clips_out_of_range_requests():
    free = jnp.asarray(np.arange(12, dtype=np.float32).reshape(2, 6))
    nreq = jnp.asarray(np.array([0, 99], np.int32))   # clipped to [1, N]
    out = np.asarray(radix_select_kth(free, nreq))
    np.testing.assert_array_equal(out, [0.0, 11.0])


def _batched_case(wn, s, n, seed, sentinel_row=True):
    """Random [W, S, maxN] free-time stack with BIG sentinels, idle ties,
    and (optionally) one all-sentinel padding row."""
    rng = np.random.default_rng(seed)
    free = rng.uniform(0, 1e6, (wn, s, n)).astype(np.float32)
    free[rng.random((wn, s, n)) < 0.3] = 1e30
    free[rng.random((wn, s, n)) < 0.3] = 0.0
    if sentinel_row:
        free[0, 0, :] = 1e30               # a fully-padded (nonexistent) row
    nreq = rng.integers(1, n + 1, (wn, s)).astype(np.int32)
    return jnp.asarray(free), jnp.asarray(nreq)


@pytest.mark.parametrize("wn,s,n,seed", [
    (1, 4, 136, 0),       # W=1 degenerate (window=0 candidate batch)
    (9, 4, 136, 1),       # the JSCC node matrix, default window + head
    (17, 3, 129, 2),      # W=16 window, non-multiple-of-lane width
    (5, 2, 8, 3),
    (33, 7, 200, 4),      # W=32 window, wide stack
])
def test_kth_free_batched_sweep(wn, s, n, seed):
    """Batched radix + batched Pallas vs the vmapped jnp.sort oracle,
    bit for bit, across candidate-count/system/node shapes."""
    free, nreq = _batched_case(wn, s, n, seed)
    ref = np.asarray(kth_free_batched_ref(free, nreq))
    sel = np.asarray(radix_select_kth_batched(free, nreq))
    pal = np.asarray(kth_free_pallas_batched(free, nreq, interpret=True))
    np.testing.assert_array_equal(ref, sel)
    np.testing.assert_array_equal(ref, pal)


def test_kth_free_batched_matches_unbatched_per_slice():
    """The batched entry point is exactly W unbatched calls."""
    free, nreq = _batched_case(6, 4, 64, 5)
    out = np.asarray(kth_free_time_batched(free, nreq, force="jnp"))
    for wi in range(6):
        np.testing.assert_array_equal(
            out[wi], np.asarray(kth_free_time(free[wi], nreq[wi],
                                              force="jnp")))


@pytest.mark.parametrize("force", ["jnp", "sort", "pallas_interpret"])
def test_kth_free_batched_dispatch_modes_agree(force):
    free, nreq = _batched_case(8, 4, 136, 6)
    ref = np.asarray(kth_free_batched_ref(free, nreq))
    np.testing.assert_array_equal(
        ref, np.asarray(kth_free_time_batched(free, nreq, force=force)))


@pytest.mark.parametrize("wn", [1, 8, 17])
@pytest.mark.parametrize("force", [None, "jnp", "sort", "pallas_interpret"])
def test_kth_free_shared_bit_exact(wn, force):
    """Shared-table entry (one sort serves all W candidates) vs the
    broadcast batched oracle, every dispatch mode, including the W=1
    degenerate batch and an all-sentinel padding row."""
    rng = np.random.default_rng(40 + wn)
    free = rng.uniform(0, 1e6, (4, 136)).astype(np.float32)
    free[rng.random((4, 136)) < 0.3] = 1e30
    free[rng.random((4, 136)) < 0.3] = 0.0
    free[2, :] = 1e30                      # all-sentinel system row
    nreq = rng.integers(1, 137, (wn, 4)).astype(np.int32)
    free, nreq = jnp.asarray(free), jnp.asarray(nreq)
    ref = np.asarray(kth_free_batched_ref(
        jnp.broadcast_to(free, (wn,) + free.shape), nreq))
    np.testing.assert_array_equal(
        ref, np.asarray(kth_free_time_shared(free, nreq, force=force)))


def test_kth_free_shared_clips_out_of_range_requests():
    free = jnp.asarray(np.arange(12, dtype=np.float32).reshape(2, 6))
    nreq = jnp.asarray(np.array([[0, 99], [1, 6]], np.int32))
    out = np.asarray(kth_free_time_shared(free, nreq))
    np.testing.assert_array_equal(out, [[0.0, 11.0], [0.0, 11.0]])


from repro.kernels.kth_free import kth_free_time_rows  # noqa: E402


def _rows_oracle(table, sels, nreq):
    """Reservation recheck the slow way: per reservation, one scalar
    sort-and-index of its reserved system's row."""
    out = np.zeros(len(sels), np.float32)
    for e in range(len(sels)):
        row = np.sort(np.asarray(table[int(sels[e])]))
        out[e] = row[int(np.clip(nreq[e] - 1, 0, row.size - 1))]
    return out


@pytest.mark.parametrize("wn,s,n,seed", [
    (2, 4, 136, 0),       # W=1 conservative window (head + 1 slot)
    (9, 4, 136, 1),       # the JSCC node matrix, default window
    (17, 3, 129, 2),      # W=16, non-multiple-of-lane width
])
@pytest.mark.parametrize("force", [None, "sort", "jnp", "pallas_interpret"])
def test_kth_free_rows_bit_exact(wn, s, n, seed, force):
    """The [W] reservation recheck (ISSUE 5: one shared sort serves every
    pending reservation) vs the scalar sort-per-slot oracle, every
    dispatch mode, bit for bit — including repeated reserved systems,
    BIG sentinels and idle ties."""
    rng = np.random.default_rng(seed)
    free = rng.uniform(0, 1e6, (s, n)).astype(np.float32)
    free[rng.random((s, n)) < 0.3] = 1e30
    free[rng.random((s, n)) < 0.3] = 0.0
    free[0, :] = 1e30                      # an all-sentinel system row
    sels = rng.integers(0, s, wn).astype(np.int32)
    nreq = rng.integers(1, n + 1, wn).astype(np.int32)
    ref = _rows_oracle(free, sels, nreq)
    out = np.asarray(kth_free_time_rows(
        jnp.asarray(free), jnp.asarray(sels), jnp.asarray(nreq),
        force=force))
    np.testing.assert_array_equal(ref, out)


def test_kth_free_rows_clips_out_of_range_requests():
    table = jnp.asarray(np.arange(12, dtype=np.float32).reshape(2, 6))
    sels = jnp.asarray(np.array([0, 1, 0], np.int32))
    nreq = jnp.asarray(np.array([0, 99, 3], np.int32))
    out = np.asarray(kth_free_time_rows(table, sels, nreq))
    np.testing.assert_array_equal(out, [0.0, 11.0, 2.0])


# ---------------------------------------------------------------- SSD scan

from repro.kernels.ssd_scan import ssd_scan_pallas, ssd_scan_ref  # noqa: E402


@pytest.mark.parametrize("bh,l,p,n,rep,chunk", [
    (4, 128, 16, 8, 2, 32),
    (2, 64, 8, 16, 1, 16),
    (6, 96, 32, 8, 3, 32),
])
def test_ssd_scan_sweep(bh, l, p, n, rep, chunk):
    ks = jax.random.split(jax.random.key(5), 5)
    bg = bh // rep
    x = jax.random.normal(ks[0], (bh, l, p), jnp.float32) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (bh, l)))
    A = -jnp.exp(jax.random.normal(ks[2], (bh,)) * 0.3)
    dA = dt * A[:, None]
    B = jax.random.normal(ks[3], (bg, l, n), jnp.float32) * 0.5
    C = jax.random.normal(ks[4], (bg, l, n), jnp.float32) * 0.5
    y1, s1 = ssd_scan_pallas(x, dt, dA, B, C, chunk=chunk, interpret=True)
    y2, s2 = ssd_scan_ref(x, dt, dA, B, C, chunk=chunk)
    np.testing.assert_allclose(y1, y2, atol=2e-4)
    np.testing.assert_allclose(s1, s2, atol=2e-4)


def test_ssd_scan_chunk_invariance():
    ks = jax.random.split(jax.random.key(6), 5)
    bh, l, p, n = 2, 128, 8, 8
    x = jax.random.normal(ks[0], (bh, l, p), jnp.float32) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (bh, l)))
    dA = dt * -0.5
    B = jax.random.normal(ks[3], (bh, l, n), jnp.float32) * 0.5
    C = jax.random.normal(ks[4], (bh, l, n), jnp.float32) * 0.5
    y1, s1 = ssd_scan_pallas(x, dt, dA, B, C, chunk=16, interpret=True)
    y2, s2 = ssd_scan_pallas(x, dt, dA, B, C, chunk=64, interpret=True)
    np.testing.assert_allclose(y1, y2, atol=2e-4)
    np.testing.assert_allclose(s1, s2, atol=2e-4)
