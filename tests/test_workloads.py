"""NPB-analogue workload tests: verification + op counters + Thomas solver."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.workloads import run_benchmark, BENCHMARKS, thomas_tridiag
from repro.workloads.ep import run_ep, verify_ep, ep_flops
from repro.workloads.is_sort import run_is, verify_is


@pytest.mark.parametrize("name", BENCHMARKS)
def test_benchmark_verifies(name):
    res, ok, flops = run_benchmark(name, scale="smoke")
    assert ok, name
    assert flops > 0


def test_ep_acceptance_ratio_approaches_pi_over_4():
    res = run_ep(m=18)
    ratio = float(res["accepted"]) / res["n_pairs"]
    assert abs(ratio - np.pi / 4) < 0.01
    assert verify_ep(res)
    assert ep_flops(18) == (1 << 18) * 100.0


def test_ep_hist_sums_to_accepted():
    res = run_ep(m=16)
    assert float(res["hist"].sum()) == pytest.approx(float(res["accepted"]))


def test_is_ranks_are_a_valid_bucket_order():
    res = run_is(n_pow=14)
    assert verify_is(res)


def test_thomas_solves_tridiagonal_system():
    n = 64
    key = jax.random.key(0)
    ks = jax.random.split(key, 4)
    a = jax.random.uniform(ks[0], (n,), minval=-0.3, maxval=0.0)
    b = jax.random.uniform(ks[1], (n,), minval=2.0, maxval=3.0)
    c = jax.random.uniform(ks[2], (n,), minval=-0.3, maxval=0.0)
    x_true = jax.random.normal(ks[3], (n,))
    a = a.at[0].set(0.0)
    c = c.at[-1].set(0.0)
    # build rhs = A @ x
    d = b * x_true
    d = d.at[1:].add(a[1:] * x_true[:-1])
    d = d.at[:-1].add(c[:-1] * x_true[1:])
    x = thomas_tridiag(a[None], b[None], c[None], d[None])[0]
    np.testing.assert_allclose(x, x_true, atol=1e-4)


def test_thomas_batched_over_grid():
    shape = (4, 8, 32)
    ones = jnp.ones(shape)
    x = thomas_tridiag(0 * ones, 2 * ones, 0 * ones, ones)
    np.testing.assert_allclose(x, 0.5 * np.ones(shape), atol=1e-6)
