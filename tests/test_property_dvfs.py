"""Hypothesis property sweeps for the DVFS power model (ISSUE 8):
arbitrary phi grids satisfy the tier-model monotonicities (downclocking
stretches the compute phase and lowers its power draw, with unit tiers
bit-exactly free), ``pareto_mask`` returns exactly the non-dominated
points on arbitrary clouds, and ``peak_power <= cap`` stays EXACT (no
tolerance) when the tier axis and a binding SCC cap compose on the
event-granular core.  Hypothesis is a dev extra: the suite skips cleanly
where it isn't installed (see requirements-dev.txt);
tests/test_dvfs.py carries the non-hypothesis coverage of the same
invariants."""

import pytest

pytest.importorskip("hypothesis")

import numpy as np  # noqa: E402
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import JSCC_SYSTEMS, Scheduler, make_npb_workload, \
    make_policy  # noqa: E402
from test_dvfs import (  # noqa: E402
    _tier_stream, assert_front_nondominated, assert_tier_monotone)
from test_event_core import reconstruct_peak_power  # noqa: E402

#: Shared NPB workload (exact predict_phases split) for the grid sweeps.
W_NPB = make_npb_workload(JSCC_SYSTEMS)


@st.composite
def phi_grids(draw):
    """A valid ``freq_tiers`` grid: leading unit anchor, then strictly
    descending phis on a 0.01 lattice in [0.05, 0.99] (the lattice keeps
    adjacent grids >= 0.01 apart, so the strict monotonicity assertions
    are float64-robust rather than fighting 1-ulp-apart draws)."""
    lo = draw(st.lists(st.integers(5, 99), min_size=1, max_size=4,
                       unique=True))
    return (1.0,) + tuple(sorted((i / 100 for i in lo), reverse=True))


@settings(max_examples=40, deadline=None)
@given(phi_grids())
def test_property_tier_model_monotone_npb(grid):
    """phi down => compute-phase runtime up AND compute-phase power down,
    for every (program, system) with a compute phase, on the exact NPB
    phase split; unit tiers reproduce the base tables bit for bit."""
    assert_tier_monotone(W_NPB, grid)


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 10_000), phi_grids())
def test_property_tier_model_monotone_trace_defaults(seed, grid):
    """Same monotonicities under the trace-workload default phase split
    (all-compute, all-dynamic) on arbitrary generated streams."""
    assert_tier_monotone(_tier_stream(n=12, seed=seed), grid)


@settings(max_examples=50, deadline=None)
@given(st.integers(0, 10_000), st.integers(1, 80), st.booleans())
def test_property_pareto_mask_exact(seed, n, quantize):
    """``pareto_mask`` == the brute-force non-dominance predicate on
    arbitrary point clouds; quantized clouds exercise the tie rule
    (equal points survive together)."""
    rng = np.random.default_rng(seed)
    e, m = rng.uniform(1.0, 10.0, (2, n))
    if quantize:
        e, m = np.round(e), np.round(m)
    mask = assert_front_nondominated(e, m)
    # the frontier's energy-sorted makespans are non-increasing
    order = np.argsort(e[mask], kind="stable")
    assert (np.diff(m[mask][order]) <= 1e-12).all()


@settings(max_examples=12, deadline=None)
@given(st.integers(0, 10_000), st.sampled_from([40_000.0, 50_000.0]))
def test_property_cap_exact_with_dvfs(seed, cap):
    """DVFS x finite cap compose: the engine's peak_power respects the
    cap EXACTLY (the admission gate and the recorded trace share one f32
    accounting), the independent float64 trace reconstruction agrees,
    and the tier axis is genuinely in play (not vacuously capped at the
    unit tier)."""
    w = _tier_stream(n=16, rate=1.2, seed=seed)
    res = Scheduler(make_policy("dvfs_paper", k=0.6, power_cap=cap),
                    warm_start=True).run(w)
    assert float(res.peak_power) <= cap
    assert reconstruct_peak_power(w, res) <= cap * (1 + 1e-4)
    assert (np.asarray(res.tier) > 0).any()
