"""Paper Figs 3-4: per-NPB-benchmark energy and runtime vs K."""

from __future__ import annotations

import time

import numpy as np

from repro.core import JSCC_SYSTEMS, SimConfig, make_npb_workload, sweep_k

KS = np.array([0.0, 0.05, 0.10, 0.20, 0.50, 0.85])


def run():
    w = make_npb_workload(JSCC_SYSTEMS)
    t0 = time.perf_counter()
    res = sweep_k(w, SimConfig(mode="paper", warm_start=True), KS)
    us = (time.perf_counter() - t0) * 1e6 / len(KS)
    E = np.asarray(res["energy"])        # [K, J]
    T = np.asarray(res["runtime"])       # [K, J]
    names = [w.programs[p] for p in w.prog]
    rows = [("fig3_4_sweep", us, f"programs={','.join(names)}")]
    for j, name in enumerate(names):
        dE = 100 * (E[:, j] - E[0, j]) / E[0, j]
        dT = 100 * (T[:, j] - T[0, j]) / T[0, j]
        rows.append((
            f"fig3_4_{name}", 0.0,
            "dE%=" + "/".join(f"{v:+.0f}" for v in dE)
            + ";dT%=" + "/".join(f"{v:+.0f}" for v in dT)))
    return rows
