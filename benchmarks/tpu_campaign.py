"""Production-half benchmark: energy-aware placement of LM training jobs
across heterogeneous TPU pod tiers (DESIGN.md §2).

Jobs = assigned-architecture train_4k cells; per-(job, tier) C/T come from
the roofline model over the compiled dry-run stats scaled by tier peak
specs — the same J/op quantity the paper's C represents (here J/Gflop).
The EcoSched algorithm trades runtime for energy exactly as on the CPU
systems; reported against fastest-first placement.
"""

from __future__ import annotations

import glob
import json
import os
import time

import numpy as np

from repro.core import TPU_SYSTEMS, SimConfig, simulate_jax
from repro.core.simulator import Workload

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..",
                          "experiments", "dryrun")


def _lm_jobs():
    """Training-cell jobs from dry-run records (fall back to analytic
    estimates when records are absent)."""
    jobs = []
    for path in sorted(glob.glob(os.path.join(
            DRYRUN_DIR, "*__train_4k__pod16x16.json"))):
        rec = json.load(open(path))
        if "hlo_walk" not in rec:
            continue
        w = rec["hlo_walk"]
        jobs.append((rec["arch"],
                     w["flops_per_device"] * 256,
                     w["mem_bytes_per_device"] * 256,
                     w["coll_link_bytes_per_device"] * 256))
    return jobs


def _tables(jobs, steps=100):
    """Per-(job, tier) T and E via the tier roofline + power model."""
    P, S = len(jobs), len(TPU_SYSTEMS)
    T = np.zeros((P, S))
    E = np.zeros((P, S))
    C = np.zeros((P, S))
    N = np.zeros((P, S), np.int32)
    for i, (_, flops, mem, coll) in enumerate(jobs):
        for j, sys in enumerate(TPU_SYSTEMS):
            n = sys.n_nodes
            t_c = flops / (n * sys.peak_flops_node * sys.efficiency)
            t_m = mem / (n * sys.mem_bw_node)
            t_x = coll / (n * sys.net_bw_node)
            step_t = max(t_c, t_m, t_x)
            util = t_c / step_t
            T[i, j] = step_t * steps
            power = n * (sys.idle_w + sys.cpu_w * util
                         + sys.net_w * (t_x / step_t))
            E[i, j] = power * T[i, j]
            C[i, j] = E[i, j] / (flops * steps / 1e9)   # J/Gflop
            N[i, j] = n
    return T, E, C, N


def run():
    jobs = _lm_jobs()
    if not jobs:
        return [("tpu_campaign", 0.0, "no dryrun records; run dryrun first")]
    T, E, C, N = _tables(jobs)
    J = len(jobs)
    w = Workload(
        prog=np.arange(J, dtype=np.int32),
        arrival=np.zeros(J, np.float32),
        k_job=np.full(J, np.nan, np.float32),
        n_req=N, T_true=T, C_true=C, E_true=E,
        T_pred=T, C_pred=C,
        n_nodes=np.array([s.n_nodes for s in TPU_SYSTEMS], np.int32),
        programs=tuple(j[0] for j in jobs),
        systems=tuple(s.name for s in TPU_SYSTEMS))
    rows = []
    base = None
    for mode, k in [("fastest", 0.0), ("paper", 0.10), ("paper", 0.30),
                    ("greenest", 0.0)]:
        t0 = time.perf_counter()
        r = simulate_jax(w, SimConfig(mode=mode, k=k, warm_start=True))
        us = (time.perf_counter() - t0) * 1e6
        e = float(r["total_energy"])
        m = float(r["makespan"])
        if base is None:
            base = (e, m)
        rows.append((f"tpu_{mode}_k{int(k*100)}", us,
                     f"dE={100*(e-base[0])/base[0]:+.1f}%;"
                     f"dT={100*(m-base[1])/base[1]:+.1f}%"))
    return rows
