"""Beyond-paper scheduler ablation on campaign-scale scenario streams.

Every registered policy on a bursty mixed-class job stream via the
``Scheduler`` facade (each policy's whole K x seed grid is ONE jitted
call), reporting the energy / makespan / wait Pareto — the paper's
algorithm is the tunable middle; predictive cold-start removes exploration
waste (DESIGN.md §9).  The fault-tolerance sweep drives the same stream
through a FaultConfig grid in a single call.

``run_policy_grid`` is the hyperparameter-grid demonstration: because
Policy hyperparameters (K, ucb_scale) are PyTree leaves, a 32-point
K x ucb-scale mesh is ONE leaf-batched Policy — a single jitted
``Scheduler.run`` vmaps the whole grid without re-tracing per point
(asserted on the jit cache).

``run_queue_disciplines`` is the queue-discipline ablation (ISSUE 3):
FCFS vs EASY backfilling on the contended SWF-replay and diurnal streams
the classic HPC literature evaluates with backfill; EASY must strictly
improve mean wait on at least one of them (asserted).

Run as a module (``python benchmarks/scheduler_ablation.py``) to also
write ``BENCH_scheduler.json`` (every row + per-point wall-clock) at the
repo root, so the scheduler perf trajectory is tracked across commits.
"""

from __future__ import annotations

import json
import pathlib
import time

import numpy as np

from repro.core import (JSCC_SYSTEMS, FaultConfig, Scheduler, make_policy,
                        policy_names)
from repro.core.engine import _batched_run
from repro.data.scenarios import (load_swf, make_stream_workload,
                                  workload_from_trace)

KS = (0.05, 0.10, 0.20)
SEEDS = (0, 1)


def _stream(n_jobs=200, seed=0):
    return make_stream_workload(JSCC_SYSTEMS, n_jobs, arrival="bursty",
                                rate=0.125, seed=seed, pred_noise=0.10)


def run():
    w = _stream()
    rows = []
    for name in policy_names():
        if name == "oracle":
            continue                   # identical to paper on clean tables
        pol = make_policy(name, k=np.asarray(KS, np.float32))
        t0 = time.perf_counter()
        res = Scheduler(pol, seeds=SEEDS).run(w)   # cold start: tables empty
        e = float(np.asarray(res.total_energy).mean())
        m = float(np.asarray(res.makespan).mean())
        wsum = float(np.asarray(res.total_wait).mean())
        us = (time.perf_counter() - t0) * 1e6
        rows.append((f"ablate_{name}", us,
                     f"E={e/1e3:.0f}kJ;makespan={m:.0f}s;wait={wsum:.0f}s"
                     f";grid={len(KS)}Kx{len(SEEDS)}seed"))
    return rows


def run_policy_grid():
    """One jitted ``Scheduler.run`` over a 32-point K x ucb-scale
    hyperparameter mesh (leaf-batched Policy): no re-trace per point."""
    w = _stream(n_jobs=150, seed=2)
    kk, uu = np.meshgrid(np.linspace(0.0, 0.35, 8).astype(np.float32),
                         np.asarray([0.25, 0.5, 0.75, 1.0], np.float32))
    pol = make_policy("ucb", k=kk.ravel(), ucb_scale=uu.ravel())
    cache0 = _batched_run._cache_size()
    t0 = time.perf_counter()
    res = Scheduler(pol, seeds=0).run(w, totals_only=True)
    E = np.asarray(res.total_energy)                        # [32]
    us = (time.perf_counter() - t0) * 1e6
    traced = _batched_run._cache_size() - cache0
    assert traced <= 1, f"grid re-traced: {traced} compilations"
    best = int(E.argmin())
    return [("policy_grid_32pt", us,
             f"points={E.size};compiles={traced};best_E={E[best]/1e3:.0f}kJ"
             f"@K={kk.ravel()[best]:.2f},ucb={uu.ravel()[best]:.2f}")]


def _synthetic_swf(n=250, seed=11):
    """A contended SWF-style trace: heavy-tailed runtimes and node counts
    with clustered submits — the workload shape EASY backfilling was made
    for (long wide head jobs blocking short narrow ones)."""
    rng = np.random.default_rng(seed)
    submit = np.cumsum(rng.exponential(15.0, n)).astype(int)
    runtime = np.where(rng.random(n) < 0.25,
                       rng.integers(1500, 5000, n),      # long tail
                       rng.integers(60, 400, n))         # short majority
    procs = np.where(rng.random(n) < 0.3,
                     rng.integers(96, 257, n),           # wide
                     rng.integers(4, 33, n))             # narrow
    lines = [f"{i + 1} {submit[i]} 0 {runtime[i]} {procs[i]} 100.0 0 "
             f"{procs[i]} 0 0 1 1 1 1 1 1 -1 -1" for i in range(n)]
    return load_swf(lines)


def queue_streams():
    """The two contended scenario streams of the queue ablation."""
    return {
        "swf": workload_from_trace(_synthetic_swf(), JSCC_SYSTEMS),
        "diurnal": make_stream_workload(JSCC_SYSTEMS, 300, arrival="diurnal",
                                        rate=0.8, seed=3, pred_noise=0.05),
    }


def run_queue_disciplines():
    """FCFS vs EASY backfilling (paper selection rule, warm tables) on
    SWF-replay and diurnal streams; every (stream, discipline) point is
    timed individually.  EASY must strictly improve mean wait on at least
    one stream (the ISSUE 3 acceptance criterion)."""
    rows = []
    improved = []
    for tag, w in queue_streams().items():
        waits = {}
        for queue in ("fcfs", "easy_backfill:window=16"):
            qname = queue.split(":")[0]
            sched = Scheduler(make_policy("paper", k=0.10), warm_start=True,
                              queue=queue)
            sched.run(w)                 # warm the jit cache: time the scan,
            t0 = time.perf_counter()     # not XLA compilation
            res = sched.run(w)
            mw = float(np.asarray(res.mean_wait))
            us = (time.perf_counter() - t0) * 1e6
            waits[qname] = mw
            rows.append((
                f"queue_{tag}_{qname}", us,
                f"mean_wait={mw:.1f}s;max_wait={float(res.max_wait):.0f}s"
                f";makespan={float(res.makespan):.0f}s"
                f";backfill_rate={float(res.backfill_rate):.2f}"
                f";util={float(np.asarray(res.utilization).mean()):.2f}"))
        improved.append(waits["easy_backfill"] < waits["fcfs"])
        rows.append((f"queue_{tag}_delta", 0.0,
                     f"dwait={100 * (waits['easy_backfill'] / waits['fcfs'] - 1):+.1f}%"))
    assert any(improved), \
        "EASY backfilling improved mean wait on no stream (acceptance)"
    return rows


def run_fault_tolerance():
    """Same stream under a straggler/failure grid: the history mechanism
    routes around degraded systems (fault tolerance, DESIGN.md §7).  The
    whole fault grid is one ``Scheduler.run``."""
    w = _stream(seed=1)
    grid = [
        ("clean", FaultConfig()),
        ("stragglers", FaultConfig(straggler_prob=0.15, straggler_factor=2.5)),
        ("failures", FaultConfig(failure_prob=0.10, restart_overhead=0.5)),
    ]
    pol = make_policy("paper", k=np.asarray([0.10], np.float32))
    t0 = time.perf_counter()
    res = Scheduler(pol, seeds=SEEDS, faults=[f for _, f in grid]).run(w)
    us = (time.perf_counter() - t0) * 1e6
    E = np.asarray(res.total_energy)          # [F, K, R]
    M = np.asarray(res.makespan)
    # the grid is ONE jitted call — time it once; per-config rows carry
    # metrics only (a per-config split of the shared call would be fiction)
    rows = [("fault_grid", us,
             f"configs={len(grid)};seeds={len(SEEDS)};one_jit_call")]
    for i, (tag, _) in enumerate(grid):
        rows.append((f"fault_{tag}", 0.0,
                     f"E={E[i].mean()/1e3:.0f}kJ;makespan={M[i].mean():.0f}s"))
    return rows


#: The module's suite registry — the single source for both harnesses
#: (benchmarks/run.py spreads it into its suite list; main() below writes
#: the same rows to BENCH_scheduler.json).
SUITES = (("ablation", run),
          ("policy_grid", run_policy_grid),
          ("fault_tolerance", run_fault_tolerance),
          ("queue_disciplines", run_queue_disciplines))


def main():
    """Run every ablation suite, print the CSV, and persist the rows (with
    per-point wall-clock) to BENCH_scheduler.json at the repo root."""
    rows = []
    print("name,us_per_call,derived")
    for _, fn in SUITES:
        for row in fn():
            rows.append(row)
            print(f"{row[0]},{row[1]:.1f},{row[2]}")
    payload = {
        "bench": "scheduler",
        "generated_unix": time.time(),
        "rows": [{"name": n, "us_per_call": round(us, 1), "derived": d}
                 for n, us, d in rows],
    }
    out = pathlib.Path(__file__).resolve().parent.parent / "BENCH_scheduler.json"
    out.write_text(json.dumps(payload, indent=2) + "\n")
    print(f"wrote {out}")


if __name__ == "__main__":
    main()
