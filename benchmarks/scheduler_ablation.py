"""Beyond-paper scheduler ablation on campaign-scale scenario streams.

All selector modes on a bursty mixed-class job stream via ``run_campaign``
(each mode's whole K x seed grid is ONE jitted call), reporting the
energy / makespan / wait Pareto — the paper's algorithm is the tunable
middle; predictive cold-start removes exploration waste (DESIGN.md §9).
The fault-tolerance sweep drives the same stream through a FaultConfig
grid in a single call."""

from __future__ import annotations

import time

import numpy as np

from repro.core import JSCC_SYSTEMS, SimConfig, FaultConfig, run_campaign
from repro.data.scenarios import make_stream_workload

MODES = ("paper", "queue_aware", "predictive", "ucb", "fastest",
         "greenest", "first_free", "random")

KS = (0.05, 0.10, 0.20)
SEEDS = (0, 1)


def _stream(n_jobs=200, seed=0):
    return make_stream_workload(JSCC_SYSTEMS, n_jobs, arrival="bursty",
                                rate=0.125, seed=seed, pred_noise=0.10)


def run():
    w = _stream()
    rows = []
    for mode in MODES:
        cfg = SimConfig(mode=mode)             # cold start: tables empty
        t0 = time.perf_counter()
        res = run_campaign(w, cfg, ks=KS, seeds=SEEDS)
        e = float(np.asarray(res["total_energy"]).mean())
        m = float(np.asarray(res["makespan"]).mean())
        wsum = float(np.asarray(res["total_wait"]).mean())
        us = (time.perf_counter() - t0) * 1e6
        rows.append((f"ablate_{mode}", us,
                     f"E={e/1e3:.0f}kJ;makespan={m:.0f}s;wait={wsum:.0f}s"
                     f";grid={len(KS)}Kx{len(SEEDS)}seed"))
    return rows


def run_fault_tolerance():
    """Same stream under a straggler/failure grid: the history mechanism
    routes around degraded systems (fault tolerance, DESIGN.md §7).  The
    whole fault grid is one run_campaign call."""
    w = _stream(seed=1)
    grid = [
        ("clean", FaultConfig()),
        ("stragglers", FaultConfig(straggler_prob=0.15, straggler_factor=2.5)),
        ("failures", FaultConfig(failure_prob=0.10, restart_overhead=0.5)),
    ]
    cfg = SimConfig(mode="paper", k=0.10)
    t0 = time.perf_counter()
    res = run_campaign(w, cfg, ks=[0.10], seeds=SEEDS,
                       faults=[f for _, f in grid])
    us = (time.perf_counter() - t0) * 1e6
    E = np.asarray(res["total_energy"])       # [F, K, R]
    M = np.asarray(res["makespan"])
    # the grid is ONE jitted call — time it once; per-config rows carry
    # metrics only (a per-config split of the shared call would be fiction)
    rows = [("fault_grid", us,
             f"configs={len(grid)};seeds={len(SEEDS)};one_jit_call")]
    for i, (tag, _) in enumerate(grid):
        rows.append((f"fault_{tag}", 0.0,
                     f"E={E[i].mean()/1e3:.0f}kJ;makespan={M[i].mean():.0f}s"))
    return rows
