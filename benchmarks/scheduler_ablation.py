"""Beyond-paper scheduler ablation: all modes on a realistic Poisson job
stream (repeated NPB programs, staggered arrivals, auto-K), reporting the
energy / makespan / wait Pareto — the paper's algorithm is the tunable
middle; predictive cold-start removes exploration waste (DESIGN.md §9)."""

from __future__ import annotations

import time

import numpy as np

from repro.core import JSCC_SYSTEMS, SimConfig, make_npb_workload, simulate_jax

MODES = ("paper", "queue_aware", "predictive", "ucb", "fastest",
         "greenest", "first_free", "random")


def _stream(n_jobs=40, seed=0):
    rng = np.random.default_rng(seed)
    order = rng.choice(["BT", "EP", "IS", "LU", "SP"], size=n_jobs)
    arrivals = np.cumsum(rng.exponential(8.0, size=n_jobs)).astype(np.float32)
    return make_npb_workload(JSCC_SYSTEMS, order=tuple(order),
                             arrivals=arrivals, pred_noise=0.10)


def run():
    w = _stream()
    rows = []
    base_e = base_m = None
    for mode in MODES:
        cfg = SimConfig(mode=mode, k=0.10)      # cold start: tables empty
        t0 = time.perf_counter()
        r = simulate_jax(w, cfg)
        e = float(r["total_energy"])
        m = float(r["makespan"])
        wsum = float(r["total_wait"])
        us = (time.perf_counter() - t0) * 1e6
        if mode == "fastest":
            base_e, base_m = e, m
        rows.append((f"ablate_{mode}", us,
                     f"E={e/1e3:.0f}kJ;makespan={m:.0f}s;wait={wsum:.0f}s"))
    # derived: paper & predictive vs fastest
    return rows


def run_fault_tolerance():
    """Same stream under stragglers/failures: the history mechanism routes
    around degraded systems (fault-tolerance benchmark, DESIGN.md §7)."""
    w = _stream(seed=1)
    rows = []
    for tag, scfg in [
        ("clean", SimConfig(mode="paper", k=0.10)),
        ("stragglers", SimConfig(mode="paper", k=0.10,
                                 straggler_prob=0.15, straggler_factor=2.5)),
        ("failures", SimConfig(mode="paper", k=0.10,
                               failure_prob=0.10, restart_overhead=0.5)),
    ]:
        t0 = time.perf_counter()
        r = simulate_jax(w, scfg)
        us = (time.perf_counter() - t0) * 1e6
        rows.append((f"fault_{tag}", us,
                     f"E={float(r['total_energy'])/1e3:.0f}kJ;"
                     f"makespan={float(r['makespan']):.0f}s"))
    return rows
