"""Beyond-paper scheduler ablation on campaign-scale scenario streams.

Every registered policy on a bursty mixed-class job stream via the
``Scheduler`` facade (each policy's whole K x seed grid is ONE jitted
call), reporting the energy / makespan / wait Pareto — the paper's
algorithm is the tunable middle; predictive cold-start removes exploration
waste (DESIGN.md §9).  The fault-tolerance sweep drives the same stream
through a FaultConfig grid in a single call.

``run_policy_grid`` is the hyperparameter-grid demonstration: because
Policy hyperparameters (K, ucb_scale) are PyTree leaves, a 32-point
K x ucb-scale mesh is ONE leaf-batched Policy — a single jitted
``Scheduler.run`` vmaps the whole grid without re-tracing per point
(asserted on the jit cache).

``run_queue_disciplines`` is the queue-discipline ablation (ISSUE 3):
FCFS vs EASY backfilling on the contended SWF-replay and diurnal streams
the classic HPC literature evaluates with backfill; EASY must strictly
improve mean wait on at least one of them (asserted).

``run_window_scaling`` is the batched-candidate-evaluation proof
(ISSUE 4): the EASY warm wall-clock across W in {4, 8, 16, 32} with the
FCFS baseline, asserted >= 5x faster than the PR 3 unrolled loop's
committed W=16 row (machine-speed-normalized via the FCFS baseline) and
sub-linear in W.

``run_million_jobs`` is the campaign-scale throughput suite (ISSUE 10):
a J=10^6 synthetic-SWF stream through the chunked ``totals_only``
campaign path, recorded as a jobs/sec RATE so the CI smoke re-run at
reduced J (``SCHED_BENCH_MILLION_J``) gates against the committed
million-job number, plus an 8-virtual-device shard_map-vs-vmap ratio.

Run as a module (``python benchmarks/scheduler_ablation.py``) to also
write ``BENCH_scheduler.json`` (every row + per-point wall-clock; rows
that only carry derived metrics are marked ``"timed": false``) at the
repo root, so the scheduler perf trajectory is tracked across commits —
``tests/test_bench_guard.py`` gates regressions against the committed
rows in CI.
"""

from __future__ import annotations

import json
import os
import pathlib
import statistics
import subprocess
import sys
import time

import jax
import numpy as np

from repro.core import (JSCC_SYSTEMS, FaultConfig, Scheduler, make_policy,
                        policy_names)
from repro.core.engine import _batched_run
from repro.core.systems import ComputeSystem
from repro.data.scenarios import (load_swf, make_stream_workload,
                                  swf_lines, synthetic_swf_arrays,
                                  workload_from_arrays, workload_from_trace)

KS = (0.05, 0.10, 0.20)
SEEDS = (0, 1)

#: PR 3's committed warm wall-clock (BENCH_scheduler.json @ 9d6f3dd) for
#: the python-unrolled EASY scan at W=16 on the SWF stream, with its FCFS
#: row as the machine-speed anchor.  The batched candidate evaluation
#: (ISSUE 4) must beat the unrolled number by >= 5x; the anchor converts
#: that bar to the machine actually running the benchmark.
PR3_EASY_W16_US = 1_357_624.3
PR3_FCFS_US = 31_567.4


def _warm_us(sched, w, repeats: int = 3):
    """Warm wall-clock of one ``Scheduler.run``: first call compiles, then
    best-of-``repeats`` timed calls (device-synced) — the scan, not XLA
    compilation or scheduler noise.  Returns ``(microseconds, result)``
    with the last run's result, so callers read metrics without paying
    for yet another simulation."""
    jax.block_until_ready(sched.run(w).total_energy)
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        res = sched.run(w)
        jax.block_until_ready(res.total_energy)
        best = min(best, time.perf_counter() - t0)
    return best * 1e6, res


def machine_speed_factor(fresh_fcfs_us: float, anchor_us: float) -> float:
    """How much slower this machine is than the one that produced the
    anchor FCFS measurement.  Unclamped on purpose: scaling a bound by
    this ratio makes it machine-invariant in both directions (a faster
    machine shrinks the absolute bound proportionally), and best-of-N
    warm timing cannot fluke *below* the hardware's real speed, so a
    ratio < 1 always means genuinely faster hardware."""
    return fresh_fcfs_us / anchor_us


def _stream(n_jobs=200, seed=0):
    return make_stream_workload(JSCC_SYSTEMS, n_jobs, arrival="bursty",
                                rate=0.125, seed=seed, pred_noise=0.10)


def run():
    w = _stream()
    rows = []
    for name in policy_names():
        if name == "oracle":
            continue                   # identical to paper on clean tables
        pol = make_policy(name, k=np.asarray(KS, np.float32))
        t0 = time.perf_counter()
        res = Scheduler(pol, seeds=SEEDS).run(w)   # cold start: tables empty
        e = float(np.asarray(res.total_energy).mean())
        m = float(np.asarray(res.makespan).mean())
        wsum = float(np.asarray(res.total_wait).mean())
        us = (time.perf_counter() - t0) * 1e6
        rows.append((f"ablate_{name}", us,
                     f"E={e/1e3:.0f}kJ;makespan={m:.0f}s;wait={wsum:.0f}s"
                     f";grid={len(KS)}Kx{len(SEEDS)}seed"))
    return rows


def run_policy_grid():
    """One jitted ``Scheduler.run`` over a 32-point K x ucb-scale
    hyperparameter mesh (leaf-batched Policy): no re-trace per point."""
    w = _stream(n_jobs=150, seed=2)
    kk, uu = np.meshgrid(np.linspace(0.0, 0.35, 8).astype(np.float32),
                         np.asarray([0.25, 0.5, 0.75, 1.0], np.float32))
    pol = make_policy("ucb", k=kk.ravel(), ucb_scale=uu.ravel())
    cache0 = _batched_run._cache_size()
    t0 = time.perf_counter()
    res = Scheduler(pol, seeds=0).run(w, totals_only=True)
    E = np.asarray(res.total_energy)                        # [32]
    us = (time.perf_counter() - t0) * 1e6
    traced = _batched_run._cache_size() - cache0
    assert traced <= 1, f"grid re-traced: {traced} compilations"
    best = int(E.argmin())
    return [("policy_grid_32pt", us,
             f"points={E.size};compiles={traced};best_E={E[best]/1e3:.0f}kJ"
             f"@K={kk.ravel()[best]:.2f},ucb={uu.ravel()[best]:.2f}")]


def _synthetic_swf(n=250, seed=11):
    """A contended SWF-style trace: heavy-tailed runtimes and node counts
    with clustered submits — the workload shape EASY backfilling was made
    for (long wide head jobs blocking short narrow ones).  Round-trips
    the scenario library's column generator through the SWF text format
    (the loader is part of what the queue bench exercises)."""
    return load_swf(swf_lines(*synthetic_swf_arrays(n, seed)))


def queue_streams():
    """The two contended scenario streams of the queue ablation."""
    return {
        "swf": workload_from_trace(_synthetic_swf(), JSCC_SYSTEMS),
        "diurnal": make_stream_workload(JSCC_SYSTEMS, 300, arrival="diurnal",
                                        rate=0.8, seed=3, pred_noise=0.05),
    }


def run_queue_disciplines():
    """FCFS vs EASY vs conservative backfilling (paper selection rule,
    warm tables) on SWF-replay and diurnal streams; every (stream,
    discipline) point is timed individually.  Asserted acceptance
    criteria: EASY strictly improves mean wait over FCFS on at least one
    stream (ISSUE 3), and conservative — hole-aware reservations on the
    event-granular core — strictly improves mean wait over EASY on BOTH
    streams (ISSUE 5: the interval reservation table exposes the idle
    gaps under every pending job, where EASY only sees the head's)."""
    rows = []
    improved = []
    cons_beats_easy = []
    for tag, w in queue_streams().items():
        waits = {}
        for queue in ("fcfs", "easy_backfill:window=16",
                      "conservative:window=16"):
            qname = queue.split(":")[0]
            sched = Scheduler(make_policy("paper", k=0.10), warm_start=True,
                              queue=queue)
            us, res = _warm_us(sched, w)
            mw = float(np.asarray(res.mean_wait))
            waits[qname] = mw
            rows.append((
                f"queue_{tag}_{qname}", us,
                f"mean_wait={mw:.1f}s;max_wait={float(res.max_wait):.0f}s"
                f";makespan={float(res.makespan):.0f}s"
                f";backfill_rate={float(res.backfill_rate):.2f}"
                f";util={float(np.asarray(res.utilization).mean()):.2f}"))
        improved.append(waits["easy_backfill"] < waits["fcfs"])
        cons_beats_easy.append(waits["conservative"] < waits["easy_backfill"])
        rows.append((f"queue_{tag}_delta", 0.0,
                     f"dwait={100 * (waits['easy_backfill'] / waits['fcfs'] - 1):+.1f}%"))
        rows.append((
            f"queue_{tag}_cons_delta", 0.0,
            f"dwait_vs_easy="
            f"{100 * (waits['conservative'] / waits['easy_backfill'] - 1):+.1f}%"))
    assert any(improved), \
        "EASY backfilling improved mean wait on no stream (acceptance)"
    assert all(cons_beats_easy), \
        "conservative backfilling must strictly improve mean wait over " \
        "EASY on every ablation stream (ISSUE 5 acceptance)"
    return rows


#: Power-cap sweep grid (Watts).  The JSCC model's all-idle floor is
#: ~32.8 kW and the uncapped peak on the diurnal stream is ~66 kW, so the
#: grid spans comfortably-binding to effectively-uncapped.
POWER_CAPS = (45_000.0, 52_000.0, 60_000.0, 1e30)


def run_power_caps():
    """SCC power-cap sweep (ISSUE 5): the whole cap grid is ONE
    leaf-batched policy simulated in a single jitted call (power_cap is a
    Policy leaf, like k/ucb_scale).  Asserted: every binding cap yields
    peak_power <= cap, and tightening the cap never reduces makespan
    (the runtime side of the paper's power/performance trade-off)."""
    w = queue_streams()["diurnal"]
    caps = np.asarray(POWER_CAPS, np.float32)
    pol = make_policy("paper", k=0.10, power_cap=caps)
    sched = Scheduler(pol, warm_start=True)
    us, res = _warm_us(sched, w)
    peak = np.asarray(res.peak_power)
    mk = np.asarray(res.makespan)
    cdel = np.asarray(res.capped_delay)
    idle = np.asarray(res.idle_energy)
    energy = np.asarray(res.total_energy)
    rows = [("power_cap_sweep", us,
             f"grid={len(caps)}caps;one_jit_call;uncapped_peak="
             f"{peak[-1] / 1e3:.1f}kW")]
    for i, cap in enumerate(caps):
        tag = "uncapped" if cap >= 1e29 else f"{int(cap / 1000)}kW"
        rows.append((
            f"power_cap_{tag}", 0.0,
            f"peak={peak[i] / 1e3:.1f}kW;makespan={mk[i]:.0f}s"
            f";capped_delay={cdel[i]:.0f}s;energy={energy[i] / 1e6:.2f}MJ"
            f";idle_energy={idle[i] / 1e6:.2f}MJ"))
        if cap < 1e29:
            assert peak[i] <= cap * (1 + 1e-5), \
                f"peak_power {peak[i]:.0f} exceeds cap {cap:.0f} (acceptance)"
    # tightening the cap never reduces makespan (monotone trade-off,
    # small tolerance for f32 scheduling ties)
    assert (mk[:-1] >= mk[1:] * (1 - 1e-4)).all(), \
        f"makespan not monotone under tightening caps: {mk}"
    return rows


def run_window_scaling():
    """EASY window-scaling sweep on the contended SWF stream: warm
    wall-clock for W in {4, 8, 16, 32} with the (W-independent) FCFS
    baseline.  Two asserted properties of the batched candidate
    evaluation (ISSUE 4):

    - >= 5x faster at W=16 than the PR 3 unrolled loop's committed row
      (the hard-coded ``PR3_*`` anchors, normalized to this machine's
      speed through the FCFS baseline);
    - sub-linear cost growth in W: the 8x window increase 4 -> 32 must
      cost well under 8x (one shared sort + one [W, maxN] row query per
      step, so the per-step kernel work barely scales with W).
    """
    w = queue_streams()["swf"]
    pol = make_policy("paper", k=0.10)
    fcfs_us, _ = _warm_us(Scheduler(pol, warm_start=True), w)
    rows = [("queue_window_fcfs", fcfs_us, "baseline;window-independent")]
    by_w = {}
    for window in (4, 8, 16, 32):
        sched = Scheduler(pol, warm_start=True,
                          queue=f"easy_backfill:window={window}")
        us, res = _warm_us(sched, w)
        by_w[window] = us
        rows.append((
            f"queue_window_w{window}", us,
            f"mean_wait={float(res.mean_wait):.1f}s"
            f";backfill_rate={float(res.backfill_rate):.2f}"
            f";x_fcfs={us / fcfs_us:.1f}"))
    speed = machine_speed_factor(fcfs_us, PR3_FCFS_US)
    gain = PR3_EASY_W16_US * speed / by_w[16]
    rows.append(("queue_window_gain_vs_pr3", 0.0,
                 f"gain={gain:.1f}x;speed_factor={speed:.2f}"
                 f";w32_over_w4={by_w[32] / by_w[4]:.2f}"))
    assert gain >= 5.0, (
        f"batched EASY at W=16 is only {gain:.1f}x faster than the PR 3 "
        f"committed row (>= 5x required): {by_w[16]:.0f}us vs "
        f"{PR3_EASY_W16_US:.0f}us @ speed factor {speed:.2f}")
    assert by_w[32] < 8.0 * by_w[4], (
        f"window cost not sub-linear: W=32 {by_w[32]:.0f}us vs "
        f"W=4 {by_w[4]:.0f}us (8x window must cost < 8x)")
    return rows


def run_fault_tolerance():
    """Same stream under a straggler/failure grid: the history mechanism
    routes around degraded systems (fault tolerance, DESIGN.md §7).  The
    whole fault grid is one ``Scheduler.run``."""
    w = _stream(seed=1)
    grid = [
        ("clean", FaultConfig()),
        ("stragglers", FaultConfig(straggler_prob=0.15, straggler_factor=2.5)),
        ("failures", FaultConfig(failure_prob=0.10, restart_overhead=0.5)),
    ]
    pol = make_policy("paper", k=np.asarray([0.10], np.float32))
    t0 = time.perf_counter()
    res = Scheduler(pol, seeds=SEEDS, faults=[f for _, f in grid]).run(w)
    us = (time.perf_counter() - t0) * 1e6
    E = np.asarray(res.total_energy)          # [F, K, R]
    M = np.asarray(res.makespan)
    # the grid is ONE jitted call — time it once; per-config rows carry
    # metrics only (a per-config split of the shared call would be fiction)
    rows = [("fault_grid", us,
             f"configs={len(grid)};seeds={len(SEEDS)};one_jit_call")]
    for i, (tag, _) in enumerate(grid):
        rows.append((f"fault_{tag}", 0.0,
                     f"E={E[i].mean()/1e3:.0f}kJ;makespan={M[i].mean():.0f}s"))
    return rows


def run_service():
    """Online service decision latency (ISSUE 7): a live ``Dispatcher``
    replays the contended SWF stream event-by-event — each job submitted
    before the clock is driven past its arrival — through the SAME jitted
    step the batch scan folds.  Bit-identity of the realized totals
    against the batch ``Scheduler.run`` is asserted (the service
    acceptance criterion); the row records the warm per-decision latency
    (the one compile-paying step is excluded as the latency maximum)."""
    from repro.service import Dispatcher

    w = queue_streams()["swf"]
    pol = make_policy("paper", k=0.10)
    qs = "easy_backfill:window=16"
    batch = Scheduler(pol, warm_start=True, queue=qs, engine="events").run(w)
    disp = Dispatcher(w, pol, warm_start=True, queue=qs)
    for j in range(len(w.prog)):
        disp.drive(until=float(w.arrival[j]))
        disp.submit(int(w.prog[j]), float(w.arrival[j]))
    disp.drain()
    res = disp.result()
    for f in ("total_energy", "makespan", "total_wait", "max_wait",
              "peak_power", "idle_energy", "n_backfilled"):
        a, b = np.asarray(getattr(batch, f)), np.asarray(getattr(res, f))
        assert a.tobytes() == b.tobytes(), \
            f"live session diverged from batch on {f}: {b} != {a}"
    m = disp.metrics
    warm_us = (m.latency_us_total - m.latency_us_max) / max(m.n_steps - 1, 1)
    return [("service_decision_latency", warm_us,
             f"steps={m.n_steps};jobs={m.n_finished}"
             f";compile_us={m.latency_us_max:.0f}"
             f";peak={m.peak_power / 1e3:.1f}kW;bit_identical=True")]


def run_pool():
    """Pooled decision latency (ISSUE 9): N sessions replay the
    contended SWF stream concurrently through ONE jitted vmapped step
    (repro.service.SessionPool).  Every lane's totals are asserted
    bit-identical to the batch run, and the per-decision cost (warm
    pool-step wall / N) must scale SUB-linearly in N — the vmapped step
    amortizes dispatch and device traffic across the whole pool."""
    from repro.service import SessionPool

    w = queue_streams()["swf"]
    pol = make_policy("paper", k=0.10)
    qs = "easy_backfill:window=16"
    batch = Scheduler(pol, warm_start=True, queue=qs, engine="events").run(w)
    per_dec = {}
    for n in (1, 4, 8):
        pool = SessionPool.replicate(
            Scheduler(pol, warm_start=True, queue=qs), n, w)
        for j in range(len(w.prog)):
            t = float(w.arrival[j])
            pool.drive(t)
            for i in range(n):
                pool.submit(i, int(w.prog[j]), t)
        pool.drain()
        for i in range(n):
            res = pool.result(i)
            for f in ("total_energy", "makespan", "total_wait"):
                a = np.asarray(getattr(batch, f))
                b = np.asarray(getattr(res, f))
                assert a.tobytes() == b.tobytes(), \
                    f"pool lane {i}/{n} diverged from batch on {f}: {b} != {a}"
        warm = ((pool.wall_us_total - pool.wall_us_max)
                / max(pool.n_pool_steps - 1, 1))
        per_dec[n] = warm / n
        pool.close()
    assert per_dec[8] < per_dec[1], \
        f"pool per-decision cost scaled super-linearly: {per_dec}"
    return [("pool_decision_latency", per_dec[8],
             f"n1={per_dec[1]:.0f}us;n4={per_dec[4]:.0f}us"
             f";n8={per_dec[8]:.0f}us"
             f";scaling_x8={per_dec[8] / per_dec[1]:.2f}"
             f";bit_identical=True")]


def run_dvfs_pareto():
    """DVFS x selection Pareto lattice (ISSUE 8): one leaf-batched
    ``Scheduler.run`` over a (power_cap x freq_weight x K) grid of the
    ``dvfs_paper`` policy; frontier extraction, single-compilation and
    baseline-domination assertions live in benchmarks/dvfs_pareto.py."""
    import dvfs_pareto
    return dvfs_pareto.run()


#: Million-job campaign suite (ISSUE 10).  ``SCHED_BENCH_MILLION_J``
#: shrinks the trace for CI smoke runs; the committed row is the full
#: J=10^6.  The throughput row records a RATE (simulated job-decisions
#: per second across the whole grid), so reduced-J re-measurements stay
#: comparable to the committed million-job number.
MILLION_J = int(os.environ.get("SCHED_BENCH_MILLION_J", "1000000"))
MILLION_CHUNK = 65_536

#: A deliberately small two-system cluster for the million-job rows: the
#: per-step cost scales with max nodes/system, and the point of the suite
#: is job-stream THROUGHPUT, not cluster size.
SMALL_CAMPAIGN = (
    ComputeSystem(name="alpha", n_nodes=8, cores_per_node=64,
                  peak_flops_node=2e12, mem_bw_node=200e9, net_bw_node=10e9,
                  disk_bw_node=2e9, idle_w=100.0, cpu_w=200.0, net_w=20.0,
                  disk_w=10.0, efficiency=0.5),
    ComputeSystem(name="beta", n_nodes=12, cores_per_node=48,
                  peak_flops_node=1.2e12, mem_bw_node=150e9, net_bw_node=8e9,
                  disk_bw_node=1.5e9, idle_w=80.0, cpu_w=160.0, net_w=15.0,
                  disk_w=8.0, efficiency=0.55),
)


def million_workload(J):
    """Synthetic-SWF million-job stream on the small campaign cluster."""
    return workload_from_arrays(*synthetic_swf_arrays(int(J), seed=11),
                                SMALL_CAMPAIGN)


def _median_campaign_sec(sched, w, repeats: int = 3) -> float:
    """Warm median-of-``repeats`` wall-clock of one totals_only campaign
    call (first call pays compilation and is discarded)."""
    jax.block_until_ready(sched.run(w, totals_only=True).total_energy)
    samples = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        res = sched.run(w, totals_only=True)
        jax.block_until_ready(res.total_energy)
        samples.append(time.perf_counter() - t0)
    return statistics.median(samples)


def _shard_scaling_row(J):
    """Sharded-vs-single-device wall-clock ratio on an 8-virtual-device
    CPU mesh (subprocess: the XLA device-count flag must be set before
    jax initializes).  The ratio is machine-invariant — both sides run on
    the same box in the same process — so it is gated directly: sharding
    the grid must never cost more than GATE x the single-device vmap
    (on a multi-core runner it should win; 8 virtual devices on one
    physical core merely round-trip through shard_map)."""
    Js = min(int(J), 200_000)
    script = f"""
import json, statistics, time
import jax
import numpy as np
from scheduler_ablation import (MILLION_CHUNK, SEEDS, _median_campaign_sec,
                                million_workload)
from repro.core import Scheduler, make_policy

w = million_workload({Js})
ks = np.linspace(0.0, 0.3, 4).astype(np.float32)
def med(**kw):
    s = Scheduler(make_policy("paper", k=ks), warm_start=True, seeds=SEEDS,
                  chunk=MILLION_CHUNK, **kw)
    return _median_campaign_sec(s, w)
single = med()
sharded = med(shards="auto")
print(json.dumps({{"devices": len(jax.devices()),
                   "single_us": single * 1e6,
                   "sharded_us": sharded * 1e6}}))
"""
    here = pathlib.Path(__file__).resolve().parent
    env = dict(os.environ)
    env["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                        + env.get("XLA_FLAGS", "")).strip()
    env["PYTHONPATH"] = f"{here.parent / 'src'}:{here}"
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=1200)
    assert out.returncode == 0, out.stderr[-2000:]
    rep = json.loads(out.stdout.splitlines()[-1])
    ratio = rep["sharded_us"] / rep["single_us"]
    return [("campaign_shard_scaling", rep["sharded_us"],
             f"devices={rep['devices']};jobs={Js};lanes=8"
             f";single_us={rep['single_us']:.0f}"
             f";ratio_vs_single={ratio:.2f}")]


def run_million_jobs(J=None):
    """Million-job campaign throughput (ISSUE 10): an 8-lane (K x seed)
    grid over a J=10^6 synthetic-SWF stream, chunked (``chunk=65536``) and
    ``totals_only`` so no [grid, J] array is ever materialized.  The
    timed row is the warm median-of-3 campaign call; its derived
    ``jobs_per_sec`` rate (grid lanes x J / seconds) is what the CI gate
    compares, so reduced-J smoke runs measure the same quantity as the
    committed million-job row.  The companion ``campaign_shard_scaling``
    row measures the 8-virtual-device shard_map against the single-device
    vmap in a subprocess."""
    J = int(J or MILLION_J)
    w = million_workload(J)
    ks = np.linspace(0.0, 0.3, 4).astype(np.float32)
    lanes = len(ks) * len(SEEDS)
    sched = Scheduler(make_policy("paper", k=ks), warm_start=True,
                      seeds=SEEDS, chunk=MILLION_CHUNK)
    sec = _median_campaign_sec(sched, w)
    rate = lanes * J / sec
    rows = [("campaign_jobs_per_sec", sec * 1e6,
             f"jobs={J};lanes={lanes};chunk={MILLION_CHUNK}"
             f";jobs_per_sec={rate:.0f};totals_only=True")]
    rows += _shard_scaling_row(J)
    return rows


#: The module's suite registry — the single source for both harnesses
#: (benchmarks/run.py spreads it into its suite list; main() below writes
#: the same rows to BENCH_scheduler.json).
SUITES = (("ablation", run),
          ("policy_grid", run_policy_grid),
          ("fault_tolerance", run_fault_tolerance),
          ("queue_disciplines", run_queue_disciplines),
          ("window_scaling", run_window_scaling),
          ("power_caps", run_power_caps),
          ("service", run_service),
          ("pool", run_pool),
          ("dvfs_pareto", run_dvfs_pareto),
          ("million_jobs", run_million_jobs))


def main(argv=None):
    """Run the ablation suites (all by default; ``--suites a,b`` for a
    subset — the bench-smoke PR job runs only the queue suites), print
    the CSV, and persist the rows (with per-point wall-clock) to
    BENCH_scheduler.json at the repo root.  Rows that only carry derived
    metrics (no wall-clock of their own) are marked ``"timed": false``
    so the regression gate and averaging tools never mistake their 0.0
    for a measurement."""
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--suites", default="",
                    help="comma-separated subset of: "
                         + ",".join(n for n, _ in SUITES))
    args = ap.parse_args(argv)
    wanted = set(args.suites.split(",")) if args.suites else None
    if wanted is not None:
        unknown = wanted - {n for n, _ in SUITES}
        if unknown:
            ap.error(f"unknown suites {sorted(unknown)}")
    rows = []
    print("name,us_per_call,derived")
    for name, fn in SUITES:
        if wanted is not None and name not in wanted:
            continue
        for row in fn():
            rows.append(row)
            print(f"{row[0]},{row[1]:.1f},{row[2]}")
    fresh = []
    for n, us, d in rows:
        row = {"name": n, "timed": us > 0, "derived": d}
        if us > 0:
            # derived-only rows OMIT us_per_call entirely — a phantom 0.0
            # reads like "this took no time" to averaging tools
            row = {"name": n, "us_per_call": round(us, 1), "timed": True,
                   "derived": d}
        fresh.append(row)
    out = pathlib.Path(__file__).resolve().parent.parent / "BENCH_scheduler.json"
    if wanted is not None and out.exists():
        # subset runs refresh their own rows IN the existing file — never
        # drop the other suites' committed rows from the artifact
        by_name = {r["name"]: r for r in fresh}
        old = json.loads(out.read_text())["rows"]
        fresh = [by_name.pop(r["name"], r) for r in old] + list(by_name.values())
    payload = {
        "bench": "scheduler",
        "generated_unix": time.time(),
        "rows": fresh,
    }
    out.write_text(json.dumps(payload, indent=2) + "\n")
    print(f"wrote {out}")


if __name__ == "__main__":
    main()
