"""Beyond-paper scheduler ablation on campaign-scale scenario streams.

Every registered policy on a bursty mixed-class job stream via the
``Scheduler`` facade (each policy's whole K x seed grid is ONE jitted
call), reporting the energy / makespan / wait Pareto — the paper's
algorithm is the tunable middle; predictive cold-start removes exploration
waste (DESIGN.md §9).  The fault-tolerance sweep drives the same stream
through a FaultConfig grid in a single call.

``run_policy_grid`` is the hyperparameter-grid demonstration: because
Policy hyperparameters (K, ucb_scale) are PyTree leaves, a 32-point
K x ucb-scale mesh is ONE leaf-batched Policy — a single jitted
``Scheduler.run`` vmaps the whole grid without re-tracing per point
(asserted on the jit cache).
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import (JSCC_SYSTEMS, FaultConfig, Scheduler, make_policy,
                        policy_names)
from repro.core.engine import _batched_run
from repro.data.scenarios import make_stream_workload

KS = (0.05, 0.10, 0.20)
SEEDS = (0, 1)


def _stream(n_jobs=200, seed=0):
    return make_stream_workload(JSCC_SYSTEMS, n_jobs, arrival="bursty",
                                rate=0.125, seed=seed, pred_noise=0.10)


def run():
    w = _stream()
    rows = []
    for name in policy_names():
        if name == "oracle":
            continue                   # identical to paper on clean tables
        pol = make_policy(name, k=np.asarray(KS, np.float32))
        t0 = time.perf_counter()
        res = Scheduler(pol, seeds=SEEDS).run(w)   # cold start: tables empty
        e = float(np.asarray(res.total_energy).mean())
        m = float(np.asarray(res.makespan).mean())
        wsum = float(np.asarray(res.total_wait).mean())
        us = (time.perf_counter() - t0) * 1e6
        rows.append((f"ablate_{name}", us,
                     f"E={e/1e3:.0f}kJ;makespan={m:.0f}s;wait={wsum:.0f}s"
                     f";grid={len(KS)}Kx{len(SEEDS)}seed"))
    return rows


def run_policy_grid():
    """One jitted ``Scheduler.run`` over a 32-point K x ucb-scale
    hyperparameter mesh (leaf-batched Policy): no re-trace per point."""
    w = _stream(n_jobs=150, seed=2)
    kk, uu = np.meshgrid(np.linspace(0.0, 0.35, 8).astype(np.float32),
                         np.asarray([0.25, 0.5, 0.75, 1.0], np.float32))
    pol = make_policy("ucb", k=kk.ravel(), ucb_scale=uu.ravel())
    cache0 = _batched_run._cache_size()
    t0 = time.perf_counter()
    res = Scheduler(pol, seeds=0).run(w, totals_only=True)
    E = np.asarray(res.total_energy)                        # [32]
    us = (time.perf_counter() - t0) * 1e6
    traced = _batched_run._cache_size() - cache0
    assert traced <= 1, f"grid re-traced: {traced} compilations"
    best = int(E.argmin())
    return [("policy_grid_32pt", us,
             f"points={E.size};compiles={traced};best_E={E[best]/1e3:.0f}kJ"
             f"@K={kk.ravel()[best]:.2f},ucb={uu.ravel()[best]:.2f}")]


def run_fault_tolerance():
    """Same stream under a straggler/failure grid: the history mechanism
    routes around degraded systems (fault tolerance, DESIGN.md §7).  The
    whole fault grid is one ``Scheduler.run``."""
    w = _stream(seed=1)
    grid = [
        ("clean", FaultConfig()),
        ("stragglers", FaultConfig(straggler_prob=0.15, straggler_factor=2.5)),
        ("failures", FaultConfig(failure_prob=0.10, restart_overhead=0.5)),
    ]
    pol = make_policy("paper", k=np.asarray([0.10], np.float32))
    t0 = time.perf_counter()
    res = Scheduler(pol, seeds=SEEDS, faults=[f for _, f in grid]).run(w)
    us = (time.perf_counter() - t0) * 1e6
    E = np.asarray(res.total_energy)          # [F, K, R]
    M = np.asarray(res.makespan)
    # the grid is ONE jitted call — time it once; per-config rows carry
    # metrics only (a per-config split of the shared call would be fiction)
    rows = [("fault_grid", us,
             f"configs={len(grid)};seeds={len(SEEDS)};one_jit_call")]
    for i, (tag, _) in enumerate(grid):
        rows.append((f"fault_{tag}", 0.0,
                     f"E={E[i].mean()/1e3:.0f}kJ;makespan={M[i].mean():.0f}s"))
    return rows
