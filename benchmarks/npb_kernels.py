"""NPB-analogue workload benchmarks: wall time + verification per program
(the jobs the paper schedules, deliverable b/d) — jnp path on CPU; the
Pallas kernels are timed per-op in interpret mode for reference."""

from __future__ import annotations

import time

import jax

from repro.workloads import run_benchmark, BENCHMARKS


def run():
    rows = []
    for name in BENCHMARKS:
        # warmup + compile
        res, ok, flops = run_benchmark(name, scale="smoke")
        jax.block_until_ready(res)
        t0 = time.perf_counter()
        res, ok, flops = run_benchmark(name, scale="smoke")
        jax.block_until_ready(res)
        us = (time.perf_counter() - t0) * 1e6
        mflops = flops / max(us / 1e6, 1e-9) / 1e6
        rows.append((f"npb_{name}", us,
                     f"verified={ok};Mop/s={mflops:.0f}"))
    return rows
