"""Roofline summary benchmark: per-(arch x shape) dominant terms from the
dry-run records (deliverable g); prints the three terms + dominant."""

from __future__ import annotations

import os
import time

from repro.launch.roofline import load_records, roofline_row

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..",
                          "experiments", "dryrun")


def run():
    t0 = time.perf_counter()
    recs = load_records(DRYRUN_DIR, "pod16x16")
    rows_out = []
    n_skip = n_err = 0
    for (arch, shape), rec in sorted(recs.items()):
        r = roofline_row(rec)
        if "skip" in r:
            n_skip += 1
            continue
        if "error" in r:
            n_err += 1
            continue
        rows_out.append((
            f"roofline_{arch}_{shape}", 0.0,
            f"tc={r['t_compute']:.2f};tm={r['t_memory_adj']:.2f};"
            f"tx={r['t_collective']:.2f};dom={r['dominant']};"
            f"frac={r['roofline_frac']:.3f}"))
    us = (time.perf_counter() - t0) * 1e6
    head = [("roofline_summary", us,
             f"cells={len(rows_out)};skipped={n_skip};errors={n_err}")]
    return head + rows_out
