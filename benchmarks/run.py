"""Benchmark orchestrator — one module per paper table/figure + extensions.

Prints ``name,us_per_call,derived`` CSV (one line per measurement).

  table5               paper Table 5 (selection decisions)
  table6               paper Table 6 (NPB run parameters)
  fig1_2_suite_vs_k    paper Figs 1-2 (suite energy/runtime vs K)
  fig3_4_per_benchmark paper Figs 3-4 (per-benchmark energy/runtime vs K)
  scheduler_ablation   beyond-paper modes + fault-tolerance sweeps
  npb_kernels          the NPB-analogue workloads (verified, Mop/s)
  tpu_campaign         energy-aware placement of LM jobs on TPU tiers
  roofline_bench       per-cell roofline terms from the dry-run records
"""

from __future__ import annotations

import sys
import traceback


def main() -> None:
    from benchmarks import (table5, table6, fig1_2_suite_vs_k,
                            fig3_4_per_benchmark, scheduler_ablation,
                            npb_kernels, tpu_campaign, roofline_bench,
                            dvfs_pareto)
    suites = [
        ("table5", table5.run),
        ("table6", table6.run),
        ("fig1_2", fig1_2_suite_vs_k.run),
        ("fig3_4", fig3_4_per_benchmark.run),
        # the scheduler-ablation suites come from the module's own registry
        # (single source — scheduler_ablation.main() writes the same rows
        # to BENCH_scheduler.json)
        *scheduler_ablation.SUITES,
        ("npb", npb_kernels.run),
        ("tpu_campaign", tpu_campaign.run),
        ("roofline", roofline_bench.run),
        ("dvfs_pareto", dvfs_pareto.run),
    ]
    print("name,us_per_call,derived")
    failures = 0
    for name, fn in suites:
        try:
            for row in fn():
                n, us, derived = row
                print(f"{n},{us:.1f},{derived}")
        except Exception as e:   # noqa: BLE001
            failures += 1
            print(f"{name},0.0,ERROR:{e!r}")
            traceback.print_exc(file=sys.stderr)
    if failures:
        raise SystemExit(f"{failures} benchmark suites failed")


if __name__ == "__main__":
    main()
