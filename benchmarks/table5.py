"""Paper Table 5: per-program system selection given (C, T, K).

Replays the paper's worked example through repro.core.algorithm and checks
every allocation; also times the (jitted) selector.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core.algorithm import select_system

ROWS = [
    # name,  C per CC,                 T per CC,        K,    paper's answer
    ("P1", [0.0015, 0.002, 0.001], [550, 500, 700], 0.10, 0),
    ("P2", [0.0012, 0.0015, 0.0013], [500, 350, 650], 0.30, 1),
    ("P3", [0.0013, 0.0019, 0.0011], [700, 500, 900], 0.90, 2),
    ("P4", [0.0055, 0.0075, 0.006], [180, 100, 120], 0.50, 2),
    ("P5", [0.005, 0.0055, 0.0045], [5000, 4500, 6000], 0.0, 1),
]


def run():
    sel = jax.jit(lambda c, t, k: select_system(
        "paper", c_row=c, t_row=t, runs_row=jnp.ones(3, jnp.int32),
        avail_row=jnp.zeros(3), k=k, c_pred_row=c, t_pred_row=t,
        key=jax.random.key(0)), static_argnames=())

    correct = 0
    for name, c, t, k, want in ROWS:
        got = int(sel(jnp.asarray(c, jnp.float32), jnp.asarray(t, jnp.float32),
                      jnp.float32(k)))
        correct += got == want

    c0 = jnp.asarray(ROWS[0][1], jnp.float32)
    t0 = jnp.asarray(ROWS[0][2], jnp.float32)
    n, reps = 0, 200
    sel(c0, t0, jnp.float32(0.1)).block_until_ready()
    t_start = time.perf_counter()
    for _ in range(reps):
        sel(c0, t0, jnp.float32(0.1)).block_until_ready()
    us = (time.perf_counter() - t_start) / reps * 1e6
    return [("table5_selector", us, f"correct={correct}/5")]
