"""Paper Figs 1-2: suite energy consumption and runtime vs the K parameter
(Alg(0) .. Alg(85)) for the simultaneously-submitted NPB suite."""

from __future__ import annotations

import time

import numpy as np

from repro.core import JSCC_SYSTEMS, SimConfig, make_npb_workload, sweep_k

KS = np.array([0.0, 0.05, 0.10, 0.15, 0.20, 0.30, 0.50, 0.85])


def run():
    w = make_npb_workload(JSCC_SYSTEMS)
    t0 = time.perf_counter()
    res = sweep_k(w, SimConfig(mode="paper", warm_start=True), KS)
    E = np.asarray(res["total_energy"])
    M = np.asarray(res["makespan"])
    us = (time.perf_counter() - t0) * 1e6 / len(KS)
    rows = [("fig1_2_sweep", us,
             f"E0={E[0]/1e3:.1f}kJ;M0={M[0]:.1f}s")]
    for i, k in enumerate(KS):
        rows.append((
            f"fig1_2_K{int(k*100):02d}", 0.0,
            f"dE={100*(E[i]-E[0])/E[0]:+.1f}%;dT={100*(M[i]-M[0])/M[0]:+.1f}%"))
    return rows
