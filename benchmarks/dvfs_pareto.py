"""DVFS x selection unified Pareto front (docs/API.md "Frequency axis").

One leaf-batched ``Scheduler.run`` sweeps a (power_cap x freq_weight x K)
lattice of the ``dvfs_paper`` policy over the NPB suite — per-job
frequency selection folded into the paper's selection rule, every grid
point sharing ONE jitted compilation (asserted on the jit cache) — and
``pareto_mask`` extracts the non-dominated (energy, makespan) rows: the
unified frontier of system choice, frequency tier, K-guard slack and SCC
power capping.

Asserted acceptance (ISSUE 8): the frontier strictly dominates the
selection-only baseline (plain ``paper`` at the tightest lattice K) —
some frontier point spends less energy at no more than a
``MAKESPAN_TOL`` makespan increase.

Replaces the PR 1 ``sweep_k`` shim that baked each (system, phi) pair
into a virtual ``ComputeSystem`` via ``expand_with_dvfs`` — migration
notes in docs/API.md "Frequency axis".
"""

from __future__ import annotations

import numpy as np

from repro.core import JSCC_SYSTEMS, Scheduler, make_npb_workload, make_policy
from repro.core.dvfs import pareto_mask
from repro.core.engine import _batched_run
from scheduler_ablation import _warm_us

#: Power-cap axis (Watts): two binding caps bracketing the NPB suite's
#: uncapped peak draw on the JSCC machines, plus effectively-uncapped.
CAPS = (45_000.0, 55_000.0, 1e30)
#: K-guard axis: the paper's relative-slowdown slack; 0.10 keeps every
#: candidate (tier included) within 10% of the fastest, 0.50 admits the
#: deep-downclock candidates.
KS = (0.10, 0.50)
#: freq_weight axis, in units of the workload's median C/T scale (the
#: leaf's native unit is cost-per-second): 0 takes the lowest-energy
#: eligible tier outright, larger weights buy the runtime back.
FW_STEPS = (0.0, 0.25, 1.0, 4.0)
#: "Minor makespan increase" bound for the domination assertion.
MAKESPAN_TOL = 1.05


def _lattice(w):
    """Flat (cap, freq_weight, K) coordinate vectors for the leaf batch."""
    scale = float(np.median(np.asarray(w.C_true))
                  / np.median(np.asarray(w.T_true)))
    caps, fws, ks = np.meshgrid(np.asarray(CAPS, np.float32),
                                scale * np.asarray(FW_STEPS, np.float32),
                                np.asarray(KS, np.float32), indexing="ij")
    return caps.ravel(), fws.ravel(), ks.ravel()


def run():
    w = make_npb_workload(JSCC_SYSTEMS, repeats=4)
    capb, fwb, kb = _lattice(w)
    B = capb.size

    base = Scheduler(make_policy("paper", k=float(min(KS))),
                     warm_start=True).run(w)
    E0 = float(np.asarray(base.total_energy))
    M0 = float(np.asarray(base.makespan))

    pol = make_policy("dvfs_paper", k=kb, freq_weight=fwb, power_cap=capb)
    sched = Scheduler(pol, warm_start=True)
    cache0 = _batched_run._cache_size()
    us, res = _warm_us(sched, w)
    traced = _batched_run._cache_size() - cache0
    assert traced <= 1, \
        f"cap x phi-weight x K lattice re-traced: {traced} compilations"

    E = np.asarray(res.total_energy, np.float64)        # [B]
    M = np.asarray(res.makespan, np.float64)
    front = pareto_mask(E, M)
    tiers = np.asarray(res.tier_counts)                 # [B, F]

    # acceptance: some frontier point beats selection-only on energy while
    # staying within the minor-makespan-increase budget
    wins = front & (E < E0) & (M <= M0 * MAKESPAN_TOL)
    assert wins.any(), (
        f"DVFS frontier does not dominate the selection-only baseline: "
        f"no frontier point with E < {E0:.0f}J and makespan <= "
        f"{MAKESPAN_TOL}x {M0:.0f}s (frontier E={E[front]}, M={M[front]})")
    best = int(np.flatnonzero(wins)[E[wins].argmin()])

    rows = [("dvfs_pareto_grid", us / B,
             f"points={B};compiles={traced};one_jit_call"
             f";total_us={us:.0f};jobs={res.n_jobs}"),
            ("dvfs_pareto_frontier", 0.0,
             f"size={int(front.sum())}/{B};dominates_baseline=True"
             f";base_E={E0 / 1e3:.0f}kJ;base_makespan={M0:.0f}s"),
            ("dvfs_pareto_best", 0.0,
             f"dE={100 * (E[best] - E0) / E0:+.1f}%"
             f";dT={100 * (M[best] - M0) / M0:+.1f}%"
             f";cap={'inf' if capb[best] >= 1e29 else int(capb[best])}"
             f";K={kb[best]:.2f};fw={fwb[best]:.3g}"
             f";tiers={tiers[best].tolist()}")]
    order = np.flatnonzero(front)[np.argsort(E[front])]
    for rank, i in enumerate(order):
        cap = "inf" if capb[i] >= 1e29 else f"{int(capb[i] / 1000)}kW"
        rows.append((
            f"dvfs_front_{rank:02d}", 0.0,
            f"E={E[i] / 1e3:.0f}kJ;makespan={M[i]:.0f}s;cap={cap}"
            f";K={kb[i]:.2f};fw={fwb[i]:.3g};tiers={tiers[i].tolist()}"))
    return rows
