"""Beyond-paper: DVFS x selection unified Pareto (DESIGN.md §9.4-9.5).

Sweeps K over the DVFS-expanded system list (4 systems x 3 frequency
levels = 12 virtual systems) and reports the energy/makespan frontier
against selection-only scheduling."""

from __future__ import annotations

import time

import numpy as np

from repro.core import JSCC_SYSTEMS, SimConfig, make_npb_workload, sweep_k
from repro.core.dvfs import dvfs_npb_workload

KS = np.array([0.0, 0.05, 0.10, 0.20, 0.50])


def run():
    w_plain = make_npb_workload(JSCC_SYSTEMS)
    w_dvfs = dvfs_npb_workload(JSCC_SYSTEMS, phis=(1.0, 0.8, 0.6))
    t0 = time.perf_counter()
    r_plain = sweep_k(w_plain, SimConfig(mode="paper", warm_start=True), KS)
    r_dvfs = sweep_k(w_dvfs, SimConfig(mode="paper", warm_start=True), KS)
    us = (time.perf_counter() - t0) * 1e6 / (2 * len(KS))
    Ep = np.asarray(r_plain["total_energy"])
    Ed = np.asarray(r_dvfs["total_energy"])
    Mp = np.asarray(r_plain["makespan"])
    Md = np.asarray(r_dvfs["makespan"])
    rows = [("dvfs_sweep", us, f"systems=4x3phi;E0={Ep[0]/1e3:.0f}kJ")]
    for i, k in enumerate(KS):
        rows.append((
            f"dvfs_K{int(k*100):02d}", 0.0,
            f"sel_only:dE={100*(Ep[i]-Ep[0])/Ep[0]:+.1f}%,dT={100*(Mp[i]-Mp[0])/Mp[0]:+.1f}%;"
            f"with_dvfs:dE={100*(Ed[i]-Ep[0])/Ep[0]:+.1f}%,dT={100*(Md[i]-Mp[0])/Mp[0]:+.1f}%"))
    return rows
