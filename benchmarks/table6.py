"""Paper Table 6: NPB run parameters (cores and allocated CNs per system).

Checks the node-count arithmetic against the paper's exact Table 6 and
reports the phase-model's predicted runtimes for the allocations.
"""

from __future__ import annotations

import time

from repro.core.systems import JSCC_SYSTEMS
from repro.core.workload_model import NPB_NODES, NPB_CORES, npb_tables

PAPER_TABLE6 = {
    "BT": {"Broadwell": 5, "CascadeLake": 3, "KNL": 2, "Skylake": 4},
    "EP": {"Broadwell": 5, "CascadeLake": 3, "KNL": 2, "Skylake": 4},
    "IS": {"Broadwell": 8, "CascadeLake": 6, "KNL": 4, "Skylake": 8},
    "LU": {"Broadwell": 8, "CascadeLake": 6, "KNL": 4, "Skylake": 8},
    "SP": {"Broadwell": 8, "CascadeLake": 6, "KNL": 4, "Skylake": 8},
}


def run():
    t0 = time.perf_counter()
    ok = NPB_NODES == PAPER_TABLE6
    # node counts must cover the requested cores
    cover = all(
        NPB_NODES[p][s.name] * s.cores_per_node >= NPB_CORES[p]
        for p in NPB_NODES for s in JSCC_SYSTEMS)
    C, T, N = npb_tables(JSCC_SYSTEMS)
    us = (time.perf_counter() - t0) * 1e6
    return [("table6_run_params", us,
             f"matches_paper={ok};cores_covered={cover};"
             f"T_range=[{T.min():.1f},{T.max():.1f}]s")]
