"""Fault-tolerant training loop.

Production concerns handled here:
  - checkpoint/restart (atomic async saves via CheckpointManager; resume
    restores params, optimizer state AND the data stream position — batches
    are a pure function of step);
  - straggler detection: per-step wall time vs. running median; slow steps
    are logged as events (at fleet scale the scheduler consumes these via
    the repro.core T-tables — see DESIGN.md §7);
  - crash injection hook for fault-tolerance tests.
"""

from __future__ import annotations

import json
import os
import statistics
import time
from dataclasses import dataclass, field

import jax
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.data import SyntheticStream, DataConfig
from repro.models import ModelApi, build_model
from repro.optim import AdamWConfig, adamw_init
from repro.train.step import make_train_step


@dataclass
class LoopConfig:
    steps: int = 100
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_every: int = 50
    keep_n: int = 3
    log_every: int = 10
    straggler_factor: float = 3.0
    microbatches: int = 1
    seed: int = 0


@dataclass
class LoopResult:
    losses: list = field(default_factory=list)
    step_times: list = field(default_factory=list)
    straggler_events: list = field(default_factory=list)
    final_step: int = 0
    resumed_from: int | None = None


def run_training(api: ModelApi, shape, ocfg: AdamWConfig, lcfg: LoopConfig,
                 crash_at_step: int | None = None,
                 metrics_path: str | None = None) -> LoopResult:
    """Single-process training with checkpoint/resume. Returns LoopResult."""
    cfg = api.cfg
    mgr = CheckpointManager(lcfg.ckpt_dir, keep_n=lcfg.keep_n)
    res = LoopResult()

    params = api.init_params(jax.random.key(lcfg.seed))
    opt_state = adamw_init(params)
    start_step = 0
    state_tmpl = {"params": params, "opt": opt_state}
    restored, ck_step, _meta = mgr.restore(state_tmpl)
    if restored is not None:
        params, opt_state = restored["params"], restored["opt"]
        start_step = ck_step
        res.resumed_from = ck_step

    step_fn = jax.jit(make_train_step(api, ocfg, lcfg.microbatches))
    stream = SyntheticStream(cfg, shape, start_step=start_step,
                             dcfg=DataConfig(seed=lcfg.seed))
    mfile = open(metrics_path, "a") if metrics_path else None

    for step in range(start_step, lcfg.steps):
        if crash_at_step is not None and step == crash_at_step:
            mgr.wait()
            raise RuntimeError(f"injected crash at step {step}")
        batch = next(stream)
        t0 = time.perf_counter()
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        loss = float(metrics["loss"])          # blocks: includes device time
        dt = time.perf_counter() - t0
        res.losses.append(loss)
        res.step_times.append(dt)
        if len(res.step_times) >= 5:
            med = statistics.median(res.step_times[-50:])
            if dt > lcfg.straggler_factor * med:
                res.straggler_events.append(
                    {"step": step, "dt": dt, "median": med})
        if mfile and step % lcfg.log_every == 0:
            mfile.write(json.dumps({"step": step, "loss": loss, "dt": dt,
                                    "lr": float(metrics["lr"])}) + "\n")
            mfile.flush()
        if (step + 1) % lcfg.ckpt_every == 0 or step + 1 == lcfg.steps:
            mgr.save(step + 1, {"params": params, "opt": opt_state},
                     metadata={"loss": loss, "arch": cfg.name})
        res.final_step = step + 1

    mgr.wait()
    if mfile:
        mfile.close()
    assert np.isfinite(res.losses[-1]) if res.losses else True
    return res
