from repro.train.step import (
    make_train_step,
    make_eval_step,
    make_prefill_step,
    make_decode_step,
)
from repro.train.loop import LoopConfig, LoopResult, run_training
