"""Manual hierarchical data-parallel trainer with compressed cross-pod
gradients (DESIGN.md §7 distributed-optimization tricks).

pjit handles single-program SPMD; this driver makes the cross-pod boundary
EXPLICIT with shard_map so the DCN hop can be compressed:

  - grads are psum'd over the intra-pod 'data' axis in full precision
    (ICI is cheap);
  - the cross-pod reduction runs through int8 error-feedback compression
    (repro.optim.compression) — DCN bytes halve vs bf16, and the EF
    residual keeps convergence;
  - the optimizer step runs replicated (params identical on all shards).

Used by tests/test_dp_compressed.py on a (pod, data) host-device mesh; on
real multi-pod TPU fleets the same code runs with the pod axis mapped over
DCN-connected slices.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.optim import (AdamWConfig, adamw_update, compressed_psum,
                         init_error_state)

# jax >= 0.5 exposes shard_map at top level with check_vma; older jaxlibs
# keep it in jax.experimental with the check_rep spelling.
if hasattr(jax, "shard_map"):
    _shard_map = partial(jax.shard_map, check_vma=False)
else:
    from jax.experimental.shard_map import shard_map as _shard_map_experimental
    _shard_map = partial(_shard_map_experimental, check_rep=False)


def make_dp_train_step(loss_fn, mesh, ocfg: AdamWConfig,
                       compress_cross_pod: bool = True):
    """loss_fn(params, batch) -> scalar.  Returns
    train_step(params, opt_state, err_state, batch) with batch sharded
    over ('pod', 'data') on dim 0 and params/opt replicated."""

    def shard_fn(params, opt_state, err_state, batch):
        # per-shard gradient on the local microbatch
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        # intra-pod reduction: full precision over ICI
        grads = jax.tree.map(lambda g: jax.lax.pmean(g, "data"), grads)
        loss = jax.lax.pmean(loss, "data")
        # cross-pod reduction: int8 error-feedback over DCN
        if compress_cross_pod:
            grads, err_state = compressed_psum(grads, err_state, "pod")
        else:
            grads = jax.tree.map(lambda g: jax.lax.pmean(g, "pod"), grads)
        loss = jax.lax.pmean(loss, "pod")
        new_params, new_opt, metrics = adamw_update(
            grads, opt_state, ocfg, jax.tree.leaves(params)[0].dtype)
        return new_params, new_opt, err_state, loss, metrics["grad_norm"]

    rep = P()            # params/opt/err replicated across the mesh
    mapped = _shard_map(
        shard_fn, mesh=mesh,
        in_specs=(rep, rep, rep, P(("pod", "data"))),
        out_specs=(rep, rep, rep, rep, rep))
    return jax.jit(mapped)


def init_dp_state(params):
    from repro.optim import adamw_init
    return adamw_init(params), init_error_state(params)
