"""jit/pjit-ready train and serve step factories."""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.models import ModelApi
from repro.optim import AdamWConfig, adamw_update


def make_train_step(api: ModelApi, ocfg: AdamWConfig, microbatches: int = 1):
    """Returns train_step(params, opt_state, batch) -> (params, opt, metrics).

    ``microbatches > 1`` runs gradient accumulation via lax.scan over
    batch-dim splits (grads accumulated in fp32) — the standard way to trade
    HBM for throughput at large global batch.
    """
    cfg = api.cfg
    model_dtype = jnp.dtype(cfg.dtype)

    def loss_fn(p, mb):
        return api.train_loss(p, mb)

    def compute_grads(params, batch):
        if microbatches == 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
            return loss, metrics, grads

        def split(x):
            b = x.shape[0]
            assert b % microbatches == 0, (b, microbatches)
            return x.reshape(microbatches, b // microbatches, *x.shape[1:])

        mbs = jax.tree.map(split, batch)
        g0 = jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), params)

        def body(carry, mb):
            gacc, lacc, aacc = carry
            (loss, metrics), g = jax.value_and_grad(
                loss_fn, has_aux=True)(params, mb)
            gacc = jax.tree.map(lambda a, b_: a + b_.astype(jnp.float32), gacc, g)
            return (gacc, lacc + loss, aacc + metrics["aux"]), None

        (gsum, lsum, asum), _ = jax.lax.scan(
            body, (g0, jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)), mbs)
        inv = 1.0 / microbatches
        grads = jax.tree.map(lambda g: g * inv, gsum)
        return lsum * inv, {"loss": lsum * inv, "aux": asum * inv,
                            "tokens": jnp.float32(0)}, grads

    def train_step(params, opt_state, batch):
        loss, metrics, grads = compute_grads(params, batch)
        new_params, new_opt, om = adamw_update(grads, opt_state, ocfg, model_dtype)
        out_metrics = {"loss": metrics["loss"], "aux": metrics["aux"],
                       "lr": om["lr"], "grad_norm": om["grad_norm"]}
        return new_params, new_opt, out_metrics

    return train_step


def make_eval_step(api: ModelApi):
    def eval_step(params, batch):
        loss, metrics = api.train_loss(params, batch)
        return metrics
    return eval_step


def make_prefill_step(api: ModelApi):
    return api.prefill


def make_decode_step(api: ModelApi):
    return api.decode_step
