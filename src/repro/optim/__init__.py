from repro.optim.adamw import (
    AdamWConfig,
    adamw_init,
    adamw_init_specs,
    adamw_update,
    lr_schedule,
    global_norm,
)
from repro.optim.compression import (
    quantize_int8,
    dequantize_int8,
    compress_with_feedback,
    compressed_psum,
    init_error_state,
)
