"""Int8 error-feedback gradient compression for cross-pod (DCN) reduction.

At multi-pod scale the only inter-pod training traffic is the data-parallel
gradient all-reduce.  Compressing it bf16->int8 halves DCN bytes; error
feedback (residual accumulation) keeps SGD convergence (1-bit Adam lineage).

``compressed_psum`` is designed for use inside ``jax.shard_map`` over the
``pod`` axis (see repro.train.dp for a manual-DP driver and tests for a
convergence demonstration); per-tensor symmetric quantization.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_int8(x):
    """Per-tensor symmetric int8 quantization. Returns (q, scale)."""
    amax = jnp.max(jnp.abs(x))
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q, scale):
    return q.astype(jnp.float32) * scale


def compress_with_feedback(g, err):
    """Quantize (g + err); return (q, scale, new_err)."""
    target = g.astype(jnp.float32) + err
    q, scale = quantize_int8(target)
    recon = dequantize_int8(q, scale)
    return q, scale, target - recon


def compressed_psum(grads, err_state, axis_name: str):
    """All-reduce (mean) a gradient pytree over ``axis_name`` with int8
    error-feedback compression.  Must be called inside shard_map/ vmap with
    the named axis bound.  Returns (mean_grads_f32, new_err_state)."""
    n = jax.lax.psum(1, axis_name)

    def one(g, e):
        q, scale, new_e = compress_with_feedback(g, e)
        # int8 tensors cross the wire; scales are scalar fp32 (negligible)
        summed = jax.lax.psum(dequantize_int8(q, scale), axis_name)
        return summed / n, new_e

    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(err_state)
    out, new_e = zip(*[one(g, e) for g, e in zip(flat_g, flat_e)])
    return jax.tree.unflatten(tdef, out), jax.tree.unflatten(tdef, new_e)


def init_error_state(params):
    return jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), params)
