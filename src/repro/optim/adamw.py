"""AdamW with fp32 master weights, global-norm clipping and LR schedules.

Built from scratch (no optax in this environment).  The optimizer state keeps
fp32 master params + moments regardless of the model compute dtype (bf16
models train on fp32 masters, cast on apply) — standard mixed-precision
production setup.
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr_peak: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    lr_min_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def lr_schedule(cfg: AdamWConfig, step):
    """Linear warmup + cosine decay to lr_min_ratio (all fp32, jit-safe)."""
    step = step.astype(jnp.float32) if hasattr(step, "astype") else jnp.float32(step)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps) /
                    jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    frac = cfg.lr_min_ratio + (1 - cfg.lr_min_ratio) * cos
    return cfg.lr_peak * warm * frac


def global_norm(tree):
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_init(params):
    f32 = partial(jax.tree.map, lambda x: x.astype(jnp.float32))
    zeros = partial(jax.tree.map, lambda x: jnp.zeros(x.shape, jnp.float32))
    return {
        "master": f32(params),
        "m": zeros(params),
        "v": zeros(params),
        "step": jnp.zeros((), jnp.int32),
    }


def adamw_init_specs(param_specs):
    """ShapeDtypeStruct version for the dry-run (no allocation)."""
    sds = jax.ShapeDtypeStruct
    f32 = jax.tree.map(lambda x: sds(x.shape, jnp.float32), param_specs)
    return {"master": f32, "m": f32, "v": f32, "step": sds((), jnp.int32)}


def adamw_update(grads, opt_state, ocfg: AdamWConfig, model_dtype):
    """Returns (new_params_in_model_dtype, new_opt_state, metrics)."""
    step = opt_state["step"] + 1
    lr = lr_schedule(ocfg, step)

    g32 = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
    gnorm = global_norm(g32)
    scale = jnp.minimum(1.0, ocfg.clip_norm / jnp.maximum(gnorm, 1e-12))
    g32 = jax.tree.map(lambda g: g * scale, g32)

    b1, b2 = ocfg.b1, ocfg.b2
    m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g, opt_state["m"], g32)
    v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * g * g, opt_state["v"], g32)
    t = step.astype(jnp.float32)
    bc1 = 1 - b1 ** t
    bc2 = 1 - b2 ** t

    def upd(master, m_, v_):
        mhat = m_ / bc1
        vhat = v_ / bc2
        return master - lr * (mhat / (jnp.sqrt(vhat) + ocfg.eps)
                              + ocfg.weight_decay * master)

    master = jax.tree.map(upd, opt_state["master"], m, v)
    new_params = jax.tree.map(lambda x: x.astype(model_dtype), master)
    new_state = {"master": master, "m": m, "v": v, "step": step}
    return new_params, new_state, {"lr": lr, "grad_norm": gnorm}
