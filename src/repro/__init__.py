"""EcoSched: energy-efficient scheduling for shared-facility compute centers
(Kiselev/Telegin/Shabanov 2021) on a multi-pod JAX substrate.

Primary contribution lives in repro.core (profiles, algorithm, simulator,
energy formalism); substrates in sibling subpackages.  See DESIGN.md.
"""

__version__ = "0.1.0"
