from repro.data.synthetic import (
    DataConfig,
    SyntheticStream,
    host_batch,
    device_batch,
    EOS,
    PAD,
)
from repro.data.scenarios import (
    poisson_arrivals, diurnal_arrivals, bursty_arrivals, make_arrivals,
    sample_programs, maintenance_windows, make_stream_workload,
    TraceJob, load_swf, workload_from_trace,
    NPB_SMALL, NPB_LARGE, ARRIVAL_KINDS,
)
