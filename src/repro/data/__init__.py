from repro.data.synthetic import (
    DataConfig,
    SyntheticStream,
    host_batch,
    device_batch,
    EOS,
    PAD,
)
