"""Scenario library: reusable job-stream generators for scheduler campaigns.

The paper's experiment is a single simultaneous 5-program suite; campaign
evaluation (Garg et al.'s long heterogeneous traces; accasim's reusable
workload library) needs arrival processes, job-mix classes, maintenance
windows, and replay of real logs.  Everything here builds plain numpy
inputs for ``repro.core.simulator.Workload`` so the whole scenario grid
stays jit/vmap-friendly downstream.

Arrival processes (all return [n] f32 submit times, sorted):
  poisson_arrivals   — homogeneous rate
  diurnal_arrivals   — inhomogeneous sinusoidal day/night rate (thinning)
  bursty_arrivals    — Poisson bursts of correlated submissions (campaigns,
                       array jobs)

Job mixes: ``sample_programs`` draws program names from weighted classes
(e.g. small/large NPB job-size classes — BT/EP run on few nodes, IS/LU/SP
on many, per the paper's Table 6 allocations).

Maintenance: ``maintenance_windows`` builds the [S, W, 2] outage tensor the
simulator consumes (sorted, non-overlapping, per system).

Trace replay: ``load_swf`` parses the Standard Workload Format (Feitelson's
archive; whitespace-separated fields, ';' comments, gzipped files ok) and
``workload_from_arrays`` / ``workload_from_trace`` map (submit, runtime,
procs) onto the multi-system Workload by binning jobs into program classes
and extrapolating each class across systems — with the relative
node-throughput model, or (``calibrate=True`` / ``workload_from_swf``) the
paper's phase model via ``workload_model.predict_phases``.
``synthetic_swf_arrays`` generates SWF-shaped campaigns at arbitrary scale
(the million-job benchmarks build on it).
"""

from __future__ import annotations

import gzip
import os
from dataclasses import dataclass

import numpy as np

from repro.core.simulator import Workload, make_npb_workload

NPB_SMALL = ("BT", "EP")          # 144-core class (2-5 nodes per system)
NPB_LARGE = ("IS", "LU", "SP")    # 256-core class (4-8 nodes per system)


# ------------------------------------------------------------------ arrivals

def poisson_arrivals(n: int, rate: float, seed: int = 0,
                     start: float = 0.0) -> np.ndarray:
    """Homogeneous Poisson process: n submit times at ``rate`` jobs/sec."""
    rng = np.random.default_rng(seed)
    return (start + np.cumsum(rng.exponential(1.0 / rate, n))).astype(np.float32)


def diurnal_arrivals(n: int, base_rate: float, peak_rate: float,
                     period: float = 86_400.0, seed: int = 0) -> np.ndarray:
    """Inhomogeneous Poisson with sinusoidal rate in [base, peak] (day/night
    load), sampled by thinning against the peak rate."""
    assert peak_rate >= base_rate > 0
    rng = np.random.default_rng(seed)
    out = np.empty(n, np.float64)
    t, i = 0.0, 0
    while i < n:
        t += rng.exponential(1.0 / peak_rate)
        lam = base_rate + 0.5 * (peak_rate - base_rate) * (
            1.0 + np.sin(2.0 * np.pi * t / period))
        if rng.uniform() * peak_rate <= lam:
            out[i] = t
            i += 1
    return out.astype(np.float32)


def bursty_arrivals(n: int, burst_rate: float, burst_size_mean: float = 8.0,
                    burst_spread: float = 5.0, seed: int = 0) -> np.ndarray:
    """Bursts arrive as a Poisson process at ``burst_rate`` bursts/sec; each
    burst submits a geometric number of jobs within ``burst_spread`` seconds
    (array jobs / parameter-sweep campaigns)."""
    rng = np.random.default_rng(seed)
    times = []
    t = 0.0
    while len(times) < n:
        t += rng.exponential(1.0 / burst_rate)
        size = rng.geometric(1.0 / burst_size_mean)
        times.extend(t + rng.uniform(0.0, burst_spread, size))
    return np.sort(np.asarray(times[:n], np.float32))


ARRIVAL_KINDS = ("simultaneous", "poisson", "diurnal", "bursty")


def make_arrivals(kind: str, n: int, rate: float, seed: int = 0) -> np.ndarray | None:
    """Uniform entry point for the CLI/benchmarks; None = all at t=0."""
    if kind == "simultaneous" or rate <= 0:
        return None
    if kind == "poisson":
        return poisson_arrivals(n, rate, seed)
    if kind == "diurnal":
        return diurnal_arrivals(n, base_rate=rate * 0.2, peak_rate=rate * 1.8,
                                seed=seed)
    if kind == "bursty":
        return bursty_arrivals(n, burst_rate=rate / 8.0, seed=seed)
    raise ValueError(f"unknown arrival kind {kind!r}; known: {ARRIVAL_KINDS}")


# ------------------------------------------------------------------ job mix

def sample_programs(n: int, mix: dict | None = None, seed: int = 0) -> tuple:
    """Draw n program names from weighted size classes.

    ``mix`` maps a class (tuple of program names) or a single name to a
    weight; default: small and large NPB classes equally weighted."""
    rng = np.random.default_rng(seed)
    mix = mix or {NPB_SMALL: 0.5, NPB_LARGE: 0.5}
    classes = [(c,) if isinstance(c, str) else tuple(c) for c in mix]
    w = np.asarray([mix[c] for c in mix], np.float64)
    w = w / w.sum()
    picks = rng.choice(len(classes), size=n, p=w)
    return tuple(str(rng.choice(classes[c])) for c in picks)


# -------------------------------------------------------------- maintenance

def maintenance_windows(n_systems: int, windows: dict) -> np.ndarray:
    """Build the simulator's [S, W, 2] outage tensor.

    ``windows`` maps system index -> list of (start, end).  Pads with empty
    (0, 0) windows so every system has the same count; sorts per system.
    """
    W = max((len(v) for v in windows.values()), default=0)
    out = np.zeros((n_systems, W, 2), np.float32)
    for s, spans in windows.items():
        for i, (a, b) in enumerate(sorted(spans)):
            assert b >= a, (s, a, b)
            out[s, i] = (a, b)
    return out


# -------------------------------------------------------------- NPB streams

def make_stream_workload(systems, n_jobs: int, arrival: str = "poisson",
                         rate: float = 0.1, mix: dict | None = None,
                         seed: int = 0, pred_noise: float = 0.0,
                         outage: np.ndarray | None = None,
                         k_job: np.ndarray | None = None) -> Workload:
    """Campaign-scale NPB job stream: weighted job-size mix + an arrival
    process + optional maintenance windows, as one Workload."""
    order = sample_programs(n_jobs, mix, seed)
    arrivals = make_arrivals(arrival, n_jobs, rate, seed)
    return make_npb_workload(systems, order=order, arrivals=arrivals,
                             k_job=k_job, pred_noise=pred_noise,
                             noise_seed=seed, outage=outage)


# ------------------------------------------------------------- trace replay

@dataclass(frozen=True)
class TraceJob:
    """One SWF record (the fields the scheduler consumes)."""
    job_id: int
    submit: float       # seconds since log start
    runtime: float      # wall-clock seconds
    procs: int          # allocated (or requested) processors


def load_swf(source) -> list:
    """Parse SWF text into TraceJob records.

    ``source``: path (``.gz`` transparently gunzipped — the Feitelson
    archive ships gzipped logs), or iterable of lines.  SWF: 18
    whitespace-separated numeric fields per job; ';' starts a comment.
    Field 2 is submit time, 4 is runtime, 5 allocated processors (field 8,
    requested, is the fallback when allocation is missing).  Jobs with
    unknown runtime or zero processors are dropped; submit times are
    rebased to the first job.
    """
    if isinstance(source, (str, bytes, os.PathLike)):
        path = os.fsdecode(source)
        opener = gzip.open if path.endswith(".gz") else open
        with opener(path, "rt") as f:
            lines = f.readlines()
    else:
        lines = list(source)
    jobs = []
    for line in lines:
        line = line.strip()
        if not line or line.startswith(";"):
            continue
        f = line.split()
        if len(f) < 8:
            continue
        runtime = float(f[3])
        procs = int(float(f[4]))
        if procs <= 0:
            procs = int(float(f[7]))
        if runtime <= 0 or procs <= 0:
            continue
        jobs.append(TraceJob(job_id=int(float(f[0])), submit=float(f[1]),
                             runtime=runtime, procs=procs))
    jobs.sort(key=lambda j: j.submit)
    if jobs:
        t0 = jobs[0].submit
        jobs = [TraceJob(j.job_id, j.submit - t0, j.runtime, j.procs)
                for j in jobs]
    return jobs


#: default (compute, net, disk) runtime shares assumed when calibrating a
#: trace job's phase behaviour (SWF logs carry no phase decomposition)
SWF_PHASE_FRACTIONS = (0.7, 0.2, 0.1)


def workload_from_arrays(submit, runtime, procs, systems,
                         n_size_bins: int = 4, n_time_bins: int = 4,
                         active_w: float = 250.0, calibrate: bool = False,
                         phase_fractions=SWF_PHASE_FRACTIONS) -> Workload:
    """Map raw (submit, runtime, procs) trace columns onto the
    multi-system simulator — the vectorized core of the SWF replay path
    (million-job traces never materialize per-job python objects).

    Jobs are binned into program classes by (procs, runtime) quantiles —
    the trace's analogue of "program p" whose (C, T) the scheduler learns.
    Each class's reference runtime is its median; per-system ground truth
    extrapolates by relative node throughput (peak_flops x efficiency),
    with node counts from ceil(procs / cores_per_node) and a first-order
    energy model E = n_nodes x (idle_w + active_w-ish) x T.  Coarse by
    construction — the scheduler only ever consumes relative (C, T).

    ``calibrate=True`` replaces the first-order energy model with the
    paper's phase model: each class's observed median runtime is split
    into (compute, net, disk) shares per ``phase_fractions``, a
    ``JobProfile`` is inverted from those shares on the reference system,
    and per-system (T, E) plus the DVFS phase split (``T_comp``/
    ``E_comp``) come from ``workload_model.predict_phases`` /
    ``predict_energy`` — so replayed jobs scale across systems with the
    same net/disk behaviour the NPB workloads carry, instead of pure
    flops throughput."""
    submit = np.asarray(submit, np.float64)
    runt = np.asarray(runtime, np.float64)
    procs = np.asarray(procs, np.float64)
    assert submit.size, "empty trace"
    S = len(systems)

    def _bin(x, nb):
        qs = np.quantile(x, np.linspace(0, 1, nb + 1)[1:-1])
        return np.searchsorted(qs, x, side="right")

    cls = _bin(procs, n_size_bins) * n_time_bins + _bin(runt, n_time_bins)
    uniq, prog = np.unique(cls, return_inverse=True)
    P = len(uniq)

    theta = np.asarray([s.peak_flops_node * s.efficiency for s in systems])
    cores = np.asarray([s.cores_per_node for s in systems], np.float64)
    nn = np.asarray([s.n_nodes for s in systems], np.float64)
    ref = int(np.argmax(theta * cores))   # most capable node type anchors T

    p_med = np.empty(P)
    t_med = np.empty(P)
    for pi in range(P):                   # <= n_size_bins * n_time_bins
        m = prog == pi
        p_med[pi] = np.median(procs[m])
        t_med[pi] = np.median(runt[m])

    n_req = np.minimum(np.maximum(np.ceil(p_med[:, None] / cores[None, :]),
                                  1.0), nn[None, :])             # [P, S]
    T_comp = E_comp = None
    if calibrate:
        T_true, E_true, C_true, T_comp, E_comp = _calibrated_tables(
            uniq, p_med, t_med, n_req, systems, ref, phase_fractions)
    else:
        flops_est = t_med * theta[ref] * np.maximum(
            np.ceil(p_med / cores[ref]), 1.0)
        T_true = flops_est[:, None] / (theta[None, :] * n_req)
        watts = np.asarray([s.idle_w + active_w for s in systems])
        E_true = n_req * watts[None, :] * T_true
        mops = np.maximum(T_true[:, [ref]] * theta[ref] * n_req[:, [ref]],
                          1.0) / 1e6
        C_true = E_true / mops

    J = len(submit)
    return Workload(
        prog=prog.astype(np.int32),
        arrival=submit.astype(np.float32),
        k_job=np.full(J, np.nan, np.float32),
        n_req=n_req.astype(np.int32),
        T_true=T_true, C_true=C_true, E_true=E_true,
        T_pred=T_true.copy(), C_pred=C_true.copy(),
        n_nodes=np.asarray([s.n_nodes for s in systems], np.int32),
        programs=tuple(f"class{int(u)}" for u in uniq),
        systems=tuple(s.name for s in systems),
        idle_w=np.asarray([s.idle_w for s in systems], np.float32),
        T_comp=T_comp, E_comp=E_comp,
    )


def _calibrated_tables(uniq, p_med, t_med, n_req, systems, ref,
                       phase_fractions):
    """Per-class phase-model tables: invert a ``JobProfile`` from the
    observed median runtime on the reference system (each phase is linear
    in its volume, so a unit-volume probe gives the exact scale), then
    predict every system from that one profile."""
    from repro.core.workload_model import (JobProfile, predict_energy,
                                           predict_phases)
    fc, fn, fd = (float(f) for f in phase_fractions)
    assert abs(fc + fn + fd - 1.0) < 1e-6, phase_fractions
    P, S = n_req.shape
    T_true = np.zeros((P, S))
    E_true = np.zeros((P, S))
    C_true = np.zeros((P, S))
    T_comp = np.zeros((P, S))
    E_comp = np.zeros((P, S))
    for pi in range(P):
        name = f"class{int(uniq[pi])}"
        nr = int(n_req[pi, ref])
        probe = JobProfile(name, flops=1.0, net_bytes=1.0, disk_bytes=1.0)
        tc1, tn1, td1 = predict_phases(probe, systems[ref], nr)
        prof = JobProfile(name,
                          flops=fc * t_med[pi] / tc1,
                          net_bytes=fn * t_med[pi] / tn1,
                          disk_bytes=fd * t_med[pi] / td1)
        for s, sysm in enumerate(systems):
            n = int(n_req[pi, s])
            tc, _, _ = predict_phases(prof, sysm, n)
            E, _, T = predict_energy(prof, sysm, n)
            T_true[pi, s] = T
            E_true[pi, s] = E
            C_true[pi, s] = E / (prof.flops / 1e6)
            T_comp[pi, s] = tc
            E_comp[pi, s] = n * sysm.cpu_w * tc   # dynamic compute joules
    return T_true, E_true, C_true, T_comp, E_comp


def workload_from_trace(jobs, systems, n_size_bins: int = 4,
                        n_time_bins: int = 4, active_w: float = 250.0,
                        calibrate: bool = False,
                        phase_fractions=SWF_PHASE_FRACTIONS) -> Workload:
    """``TraceJob`` records -> Workload (see ``workload_from_arrays`` —
    this wrapper just extracts the columns)."""
    jobs = list(jobs)
    assert jobs, "empty trace"
    return workload_from_arrays(
        np.asarray([j.submit for j in jobs], np.float64),
        np.asarray([j.runtime for j in jobs], np.float64),
        np.asarray([j.procs for j in jobs], np.float64),
        systems, n_size_bins=n_size_bins, n_time_bins=n_time_bins,
        active_w=active_w, calibrate=calibrate,
        phase_fractions=phase_fractions)


def workload_from_swf(source, systems, *, calibrate: bool = True,
                      **kw) -> Workload:
    """One-call SWF replay: parse (gzipped ok) + build the Workload.
    Calibrates against the phase model by default — the archive path is
    for studies, not for the legacy first-order pin."""
    return workload_from_trace(load_swf(source), systems,
                               calibrate=calibrate, **kw)


# ------------------------------------------------- synthetic SWF campaigns

def synthetic_swf_arrays(n: int, seed: int = 11, mean_gap: float = 15.0):
    """A contended SWF-shaped column set at arbitrary scale: heavy-tailed
    runtimes and node counts with clustered submits (long wide head jobs
    blocking short narrow ones — the shape backfilling was made for).
    Returns (submit, runtime, procs) integer arrays, ready for
    ``workload_from_arrays`` or ``swf_lines``."""
    rng = np.random.default_rng(seed)
    submit = np.cumsum(rng.exponential(mean_gap, n)).astype(np.int64)
    runtime = np.where(rng.random(n) < 0.25,
                       rng.integers(1500, 5000, n),      # long tail
                       rng.integers(60, 400, n))         # short majority
    procs = np.where(rng.random(n) < 0.3,
                     rng.integers(96, 257, n),           # wide
                     rng.integers(4, 33, n))             # narrow
    return submit, runtime, procs


def swf_lines(submit, runtime, procs):
    """Serialize trace columns as SWF records (18 fields, the subset the
    loader consumes populated) — fixture generation and loader
    round-trip tests."""
    return [f"{i + 1} {int(s)} 0 {int(r)} {int(p)} 100.0 0 {int(p)} "
            "0 0 1 1 1 1 1 1 -1 -1"
            for i, (s, r, p) in enumerate(zip(submit, runtime, procs))]
