"""Deterministic synthetic token pipeline.

Production data loading is host-side and deterministic-by-step so that
checkpoint/restart resumes the exact stream (fault tolerance requirement):
batch(step) is a pure function of (seed, step) — no iterator state to
persist.  Documents are Zipf-ish token sequences with EOS-delimited packing
and a loss mask that ignores padding, mimicking a packed LM pipeline.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig

EOS = 1
PAD = 0


@dataclass(frozen=True)
class DataConfig:
    seed: int = 1234
    mean_doc_len: int = 512
    zipf_a: float = 1.2           # token distribution skew


def _doc_lengths(rng: np.random.Generator, total: int, mean_len: int):
    lens = []
    left = total
    while left > 0:
        l = int(np.clip(rng.geometric(1.0 / mean_len), 8, left))
        lens.append(l)
        left -= l
    return lens


def host_batch(cfg: ModelConfig, shape: ShapeConfig, step: int,
               dcfg: DataConfig = DataConfig()) -> dict:
    """Build one packed global batch as numpy arrays (pure fn of step)."""
    rng = np.random.default_rng(np.random.SeedSequence([dcfg.seed, step]))
    b, s = shape.global_batch, shape.seq_len
    tokens = np.empty((b, s), np.int32)
    for i in range(b):
        row = []
        for l in _doc_lengths(rng, s, dcfg.mean_doc_len):
            doc = rng.zipf(dcfg.zipf_a, size=l - 1).astype(np.int64)
            doc = (doc % (cfg.vocab_size - 2)) + 2      # reserve PAD/EOS
            row.extend(doc.tolist())
            row.append(EOS)
        tokens[i] = np.asarray(row[:s], np.int32)
    labels = np.roll(tokens, -1, axis=1)
    labels[:, -1] = EOS
    mask = (tokens != PAD).astype(np.int32)
    batch = {"tokens": tokens, "labels": labels, "mask": mask}
    if cfg.is_encoder_decoder:
        batch["frame_embeds"] = rng.standard_normal(
            (b, cfg.encoder_seq, cfg.d_model), dtype=np.float32)
    if cfg.frontend == "vision":
        batch["patch_embeds"] = rng.standard_normal(
            (b, cfg.n_patches, cfg.d_model), dtype=np.float32)
    return batch


def device_batch(cfg, shape, step, shardings=None, dcfg: DataConfig = DataConfig()):
    """Host batch -> device arrays; with ``shardings`` (a pytree of
    NamedSharding matching the batch) the arrays are laid out for the mesh —
    the multi-host analogue of per-host data loading."""
    hb = host_batch(cfg, shape, step, dcfg)
    if shardings is None:
        return jax.tree.map(jnp.asarray, hb)
    return jax.tree.map(
        lambda x, sh: jax.make_array_from_callback(
            x.shape, sh, lambda idx: x[idx]),
        hb, shardings)


class SyntheticStream:
    """Step-indexed iterator facade (resume = construct with start_step)."""

    def __init__(self, cfg, shape, start_step: int = 0,
                 dcfg: DataConfig = DataConfig(), shardings=None):
        self.cfg, self.shape, self.dcfg = cfg, shape, dcfg
        self.step = start_step
        self.shardings = shardings

    def __iter__(self):
        return self

    def __next__(self):
        b = device_batch(self.cfg, self.shape, self.step, self.shardings, self.dcfg)
        self.step += 1
        return b
