"""Uniform model API over decoder-only and encoder-decoder families.

``build_model(cfg)`` returns a ``ModelApi`` whose methods are plain functions
of (params, batch/cache) — jit/pjit-ready, no hidden state.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import transformer as T
from repro.models import encdec as E


@dataclass(frozen=True)
class ModelApi:
    cfg: ModelConfig
    init_params: Callable
    param_specs: Callable
    train_loss: Callable            # (params, batch) -> (loss, metrics)
    prefill: Callable               # (params, batch) -> logits [b, V]
    decode_step: Callable           # (params, cache, tokens, pos) -> (logits, cache)
    decode_cache_specs: Callable    # (batch, max_seq) -> pytree of SDS
    init_decode_cache: Callable

    def input_specs(self, shape: ShapeConfig, batch_override: int | None = None):
        """ShapeDtypeStruct stand-ins for every model input of this shape cell
        (global logical shapes; the launcher attaches shardings)."""
        cfg = self.cfg
        b = batch_override or shape.global_batch
        s = shape.seq_len
        i32 = jnp.int32
        dt = jnp.dtype(cfg.dtype)
        sds = jax.ShapeDtypeStruct
        if shape.kind == "train":
            batch = {"tokens": sds((b, s), i32),
                     "labels": sds((b, s), i32),
                     "mask": sds((b, s), i32)}
            if cfg.is_encoder_decoder:
                batch["frame_embeds"] = sds((b, cfg.encoder_seq, cfg.d_model), dt)
            if cfg.frontend == "vision":
                batch["patch_embeds"] = sds((b, cfg.n_patches, cfg.d_model), dt)
            return {"batch": batch}
        if shape.kind == "prefill":
            batch = {"tokens": sds((b, s), i32)}
            if cfg.is_encoder_decoder:
                batch["frame_embeds"] = sds((b, cfg.encoder_seq, cfg.d_model), dt)
            if cfg.frontend == "vision":
                batch["patch_embeds"] = sds((b, cfg.n_patches, cfg.d_model), dt)
            return {"batch": batch}
        # decode: one new token against a seq_len cache
        return {
            "cache": self.decode_cache_specs(b, s),
            "tokens": sds((b, 1), i32),
            "pos": sds((), i32),
        }


def build_model(cfg: ModelConfig) -> ModelApi:
    if cfg.is_encoder_decoder:
        return ModelApi(
            cfg=cfg,
            init_params=lambda key: E.init_params(cfg, key),
            param_specs=lambda: E.param_specs(cfg),
            train_loss=lambda p, b: E.train_loss(cfg, p, b),
            prefill=lambda p, b: E.prefill(cfg, p, b),
            decode_step=lambda p, c, t, pos: E.decode_step(cfg, p, c, t, pos),
            decode_cache_specs=lambda b, s: E.decode_cache_specs(cfg, b, s),
            init_decode_cache=lambda b, s: E.init_decode_cache(cfg, b, s),
        )
    return ModelApi(
        cfg=cfg,
        init_params=lambda key: T.init_params(cfg, key),
        param_specs=lambda: T.param_specs(cfg),
        train_loss=lambda p, b: T.train_loss(cfg, p, b),
        prefill=lambda p, b: T.prefill(cfg, p, b),
        decode_step=lambda p, c, t, pos: T.decode_step(cfg, p, c, t, pos),
        decode_cache_specs=lambda b, s: T.decode_cache_specs(cfg, b, s),
        init_decode_cache=lambda b, s: T.init_decode_cache(cfg, b, s),
    )
