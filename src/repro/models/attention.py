"""GQA attention: blocked (flash-style) prefill/train path + decode path.

Three implementations, numerically equivalent:
  - ``plain_attention``  : einsum + causal mask, for short sequences (smoke).
  - ``blocked_attention``: nested-scan online-softmax (flash algorithm in pure
    jnp).  Never materializes [Sq, Sk]; working set is [bq, bk].  This is the
    CPU/compile-path twin of the Pallas TPU kernel in
    ``repro.kernels.flash_attention`` (ops.py dispatches between them).
  - ``decode_attention`` : one query token vs a KV cache (logits are [b,h,1,S],
    cheap; the cache may be sequence-sharded — XLA inserts the partial-softmax
    collectives).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init, apply_rope, rope_angles

NEG_INF = -1e30


def init_attn(cfg: ModelConfig, key, dtype, cross: bool = False):
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim()
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = {
        "wq": dense_init(k1, d, (h, hd), dtype),
        "wk": dense_init(k2, d, (kv, hd), dtype),
        "wv": dense_init(k3, d, (kv, hd), dtype),
        "wo": dense_init(k4, h * hd, (d,), dtype).reshape(h, hd, d),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h, hd), dtype)
        p["bk"] = jnp.zeros((kv, hd), dtype)
        p["bv"] = jnp.zeros((kv, hd), dtype)
    return p


def _project_q(p, x, cfg):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"], preferred_element_type=jnp.float32)
    if "bq" in p:
        q = q + p["bq"].astype(jnp.float32)
    return q.astype(x.dtype)


def _project_kv(p, x, cfg):
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"], preferred_element_type=jnp.float32)
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"], preferred_element_type=jnp.float32)
    if "bk" in p:
        k = k + p["bk"].astype(jnp.float32)
        v = v + p["bv"].astype(jnp.float32)
    return k.astype(x.dtype), v.astype(x.dtype)


def _out_proj(p, o, x_dtype):
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"],
                      preferred_element_type=jnp.float32).astype(x_dtype)


# ------------------------------------------------------------------ cores

def plain_attention(q, k, v, *, causal: bool, q_positions=None, k_positions=None):
    """q: [b,sq,h,hd]; k,v: [b,sk,kv,hd]. fp32 softmax. Returns [b,sq,h,hd]."""
    b, sq, h, hd = q.shape
    _, sk, kv, _ = k.shape
    rep = h // kv
    scale = hd ** -0.5
    qr = q.reshape(b, sq, kv, rep, hd).astype(jnp.float32) * scale
    s = jnp.einsum("bqgrd,bpgd->bgrqp", qr, k.astype(jnp.float32))
    if causal:
        qp = jnp.arange(sq) if q_positions is None else q_positions
        kp = jnp.arange(sk) if k_positions is None else k_positions
        mask = qp[:, None] >= kp[None, :]
        s = jnp.where(mask[None, None, None], s, NEG_INF)
    p_attn = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bgrqp,bpgd->bqgrd", p_attn, v.astype(jnp.float32))
    return o.reshape(b, sq, h, hd).astype(q.dtype)


def blocked_attention(q, k, v, *, causal: bool, block_q: int = 512,
                      block_k: int = 512, q_offset: int = 0,
                      shard_blocks: bool = False):
    """Flash-style online-softmax attention; O(bq*bk) working set.

    q: [b,sq,h,hd]; k,v: [b,sk,kv,hd].  ``q_offset`` shifts query positions
    (prefill continuation).  Requires sq % block_q == sk % block_k == 0.

    ``shard_blocks``: shard the q-block row dim over the 'model' mesh axis —
    sequence-sharded attention for archs whose head counts do not divide the
    model axis (llama4's 40, qwen2's 12); k/v are replicated over 'model'
    there anyway, so this buys /model_par attention parallelism with no
    extra collectives (§Perf iteration 3).
    """
    from repro.sharding import annotate
    b, sq, h, hd = q.shape
    _, sk, kv, _ = k.shape
    rep = h // kv
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    assert sq % block_q == 0 and sk % block_k == 0, (sq, block_q, sk, block_k)
    nq, nk = sq // block_q, sk // block_k
    scale = hd ** -0.5

    qb = q.reshape(b, nq, block_q, h, hd).transpose(1, 0, 2, 3, 4)
    kb = k.reshape(b, nk, block_k, kv, hd).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(b, nk, block_k, kv, hd).transpose(1, 0, 2, 3, 4)
    if shard_blocks:
        qb = annotate(qb, (None, "batch", "seq_sp", None, None))
        kb = annotate(kb, (None, "batch", None, None, None))
        vb = annotate(vb, (None, "batch", None, None, None))
    qpos = (jnp.arange(sq) + q_offset).reshape(nq, block_q)
    kpos = jnp.arange(sk).reshape(nk, block_k)

    @jax.named_scope("flash_attn_interior")
    def q_step(_, qi):
        q_blk, q_pos = qi                      # [b,bq,h,hd], [bq]
        qr = q_blk.reshape(b, block_q, kv, rep, hd).astype(jnp.float32) * scale

        def k_step(carry, ki):
            m, l, acc = carry                  # [b,h,bq], [b,h,bq], [b,h,bq,hd]
            k_blk, v_blk, k_pos = ki
            s = jnp.einsum("bqgrd,bpgd->bgrqp", qr, k_blk.astype(jnp.float32))
            s = s.reshape(b, h, block_q, block_k)
            if causal:
                mask = q_pos[:, None] >= k_pos[None, :]
                s = jnp.where(mask[None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p_blk = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + p_blk.sum(axis=-1)
            pv = jnp.einsum("bgrqp,bpgd->bgrqd",
                            p_blk.reshape(b, kv, rep, block_q, block_k),
                            v_blk.astype(jnp.float32)).reshape(b, h, block_q, hd)
            acc_new = acc * alpha[..., None] + pv
            return (m_new, l_new, acc_new), None

        init = (jnp.full((b, h, block_q), NEG_INF, jnp.float32),
                jnp.zeros((b, h, block_q), jnp.float32),
                jnp.zeros((b, h, block_q, hd), jnp.float32))
        (m, l, acc), _ = jax.lax.scan(k_step, init, (kb, vb, kpos))
        o = acc / jnp.maximum(l, 1e-30)[..., None]          # [b,h,bq,hd]
        return None, o.transpose(0, 2, 1, 3).astype(q.dtype)  # [b,bq,h,hd]

    _, ob = jax.lax.scan(q_step, None, (qb, qpos))           # [nq,b,bq,h,hd]
    return ob.transpose(1, 0, 2, 3, 4).reshape(b, sq, h, hd)


def blocked_attention_tri(q, k, v, *, block_q: int = 512, block_k: int = 512,
                          q_offset: int = 0):
    """Causal blocked attention on a TRIANGULAR schedule: only the
    nq(nq+1)/2 not-fully-masked (qi, ki<=qi) block pairs are computed
    (§Perf iteration 2) — ~2x fewer tiles than the rectangular schedule.
    Requires sq == sk and q_offset == 0 (the training/prefill case)."""
    b, sq, h, hd = q.shape
    _, sk, kv, _ = k.shape
    assert sq == sk and q_offset == 0, "triangular schedule: self-causal only"
    rep = h // kv
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    assert sq % block_q == 0 and sk % block_k == 0
    assert block_q == block_k, "triangular schedule assumes square blocks"
    nq = sq // block_q
    scale = hd ** -0.5

    qb = q.reshape(b, nq, block_q, h, hd).transpose(1, 0, 2, 3, 4)
    kb = k.reshape(b, nq, block_k, kv, hd).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(b, nq, block_k, kv, hd).transpose(1, 0, 2, 3, 4)

    pairs = [(qi, ki) for qi in range(nq) for ki in range(qi + 1)]
    qi_arr = jnp.asarray([p[0] for p in pairs], jnp.int32)
    ki_arr = jnp.asarray([p[1] for p in pairs], jnp.int32)

    @jax.named_scope("flash_attn_interior")
    def step(carry, inp):
        m, l, acc = carry            # [nq,b,h,bq], [nq,b,h,bq], [nq,b,h,bq,hd]
        qi, ki = inp
        q_blk = jax.lax.dynamic_index_in_dim(qb, qi, 0, keepdims=False)
        k_blk = jax.lax.dynamic_index_in_dim(kb, ki, 0, keepdims=False)
        v_blk = jax.lax.dynamic_index_in_dim(vb, ki, 0, keepdims=False)
        qr = q_blk.reshape(b, block_q, kv, rep, hd).astype(jnp.float32) * scale
        s = jnp.einsum("bqgrd,bpgd->bgrqp", qr, k_blk.astype(jnp.float32))
        s = s.reshape(b, h, block_q, block_k)
        q_pos = qi * block_q + jnp.arange(block_q)
        k_pos = ki * block_k + jnp.arange(block_k)
        s = jnp.where((q_pos[:, None] >= k_pos[None, :])[None, None], s, NEG_INF)

        m_i = jax.lax.dynamic_index_in_dim(m, qi, 0, keepdims=False)
        l_i = jax.lax.dynamic_index_in_dim(l, qi, 0, keepdims=False)
        a_i = jax.lax.dynamic_index_in_dim(acc, qi, 0, keepdims=False)
        m_new = jnp.maximum(m_i, s.max(axis=-1))
        p_blk = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m_i - m_new)
        l_new = l_i * alpha + p_blk.sum(axis=-1)
        pv = jnp.einsum("bgrqp,bpgd->bgrqd",
                        p_blk.reshape(b, kv, rep, block_q, block_k),
                        v_blk.astype(jnp.float32)).reshape(b, h, block_q, hd)
        a_new = a_i * alpha[..., None] + pv
        m = jax.lax.dynamic_update_index_in_dim(m, m_new, qi, 0)
        l = jax.lax.dynamic_update_index_in_dim(l, l_new, qi, 0)
        acc = jax.lax.dynamic_update_index_in_dim(acc, a_new, qi, 0)
        return (m, l, acc), None

    init = (jnp.full((nq, b, h, block_q), NEG_INF, jnp.float32),
            jnp.zeros((nq, b, h, block_q), jnp.float32),
            jnp.zeros((nq, b, h, block_q, hd), jnp.float32))
    (m, l, acc), _ = jax.lax.scan(step, init, (qi_arr, ki_arr))
    o = acc / jnp.maximum(l, 1e-30)[..., None]           # [nq,b,h,bq,hd]
    return (o.transpose(1, 0, 3, 2, 4)                   # [b,nq,bq,h,hd]
            .reshape(b, sq, h, hd).astype(q.dtype))


def decode_attention(q, cache_k, cache_v, *, length=None):
    """q: [b,1,h,hd]; cache: [b,S,kv,hd]. Attends over positions < length
    (length=None => whole cache)."""
    b, _, h, hd = q.shape
    _, S, kv, _ = cache_k.shape
    rep = h // kv
    scale = hd ** -0.5
    qr = q.reshape(b, kv, rep, hd).astype(jnp.float32) * scale
    s = jnp.einsum("bgrd,bpgd->bgrp", qr, cache_k.astype(jnp.float32))
    if length is not None:
        valid = jnp.arange(S) < length
        s = jnp.where(valid[None, None, None], s, NEG_INF)
    p_attn = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bgrp,bpgd->bgrd", p_attn, cache_v.astype(jnp.float32))
    return o.reshape(b, 1, h, hd).astype(q.dtype)


# ------------------------------------------------------------- full layers

def attn_forward(p, x, cfg: ModelConfig, *, causal=True, use_rope=True,
                 positions=None, kv_x=None, return_kv=False):
    """Training / prefill self- (or cross-) attention.

    x: [b,s,d]; kv_x: source for K/V (cross-attention) or None (self).
    Returns out [b,s,d]  (and (k,v) if return_kv).
    """
    b, s, _ = x.shape
    q = _project_q(p, x, cfg)
    k, v = _project_kv(p, kv_x if kv_x is not None else x, cfg)
    if use_rope:
        pos = jnp.arange(s) if positions is None else positions
        sin, cos = rope_angles(pos, cfg.resolved_head_dim(), cfg.rope_theta)
        q = apply_rope(q, sin, cos)
        k = apply_rope(k, sin, cos)
    sk = k.shape[1]
    if cfg.use_flash != "never" and s >= 2048 and s % 512 == 0 and sk % 512 == 0:
        if causal and cfg.attn_schedule == "tri" and s == sk:
            o = blocked_attention_tri(q, k, v)
        else:
            o = blocked_attention(q, k, v, causal=causal,
                                  shard_blocks=cfg.attn_seq_shard)
    else:
        o = plain_attention(q, k, v, causal=causal)
    out = _out_proj(p, o, x.dtype)
    if return_kv:
        return out, (k, v)
    return out


def attn_decode(p, x, cfg: ModelConfig, cache_k, cache_v, pos, *,
                use_rope=True, update_cache=True):
    """One-token decode. x: [b,1,d]; cache: [b,S,kv,hd]; pos: scalar int.
    Returns (out, new_cache_k, new_cache_v). Attends over positions <= pos."""
    q = _project_q(p, x, cfg)
    k_new, v_new = _project_kv(p, x, cfg)
    if use_rope:
        posv = jnp.asarray(pos)[None]
        sin, cos = rope_angles(posv, cfg.resolved_head_dim(), cfg.rope_theta)
        q = apply_rope(q, sin, cos)
        k_new = apply_rope(k_new, sin, cos)
    if update_cache:
        cache_k = jax.lax.dynamic_update_slice_in_dim(cache_k, k_new, pos, axis=1)
        cache_v = jax.lax.dynamic_update_slice_in_dim(cache_v, v_new, pos, axis=1)
    o = decode_attention(q, cache_k, cache_v, length=pos + 1)
    return _out_proj(p, o, x.dtype), cache_k, cache_v


def attn_cross_decode(p, x, cfg: ModelConfig, mem_k, mem_v):
    """Cross-attention decode against precomputed encoder K/V (no rope)."""
    q = _project_q(p, x, cfg)
    o = decode_attention(q, mem_k, mem_v, length=None)
    return _out_proj(p, o, x.dtype)
