"""Mamba-2 mixer (SSD — state-space duality, arXiv:2405.21060), pure JAX.

Chunked SSD prefill: within-chunk quadratic term + inter-chunk state
recurrence (lax.scan over chunks) — O(L·Q) work, O(L/Q) sequential steps.
Decode: O(1) per token state update.  All state math in fp32.

Layout conventions:
  u  : [b, l, d_model]
  x  : [b, l, h, p]     (h = d_inner/head_dim SSD heads, p = head_dim)
  B,C: [b, l, g, n]     (g groups, n = ssm state)
  dt : [b, l, h]
  state (decode): [b, h, p, n]
  conv buffer   : [b, K-1, conv_dim]  with conv_dim = d_inner + 2*g*n
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init


def _dims(cfg: ModelConfig):
    ssm = cfg.ssm
    d_inner = ssm.expand * cfg.d_model
    h = d_inner // ssm.head_dim
    conv_dim = d_inner + 2 * ssm.n_groups * ssm.state
    return d_inner, h, conv_dim


def init_mamba(cfg: ModelConfig, key, dtype):
    ssm = cfg.ssm
    d = cfg.d_model
    d_inner, h, conv_dim = _dims(cfg)
    proj_out = 2 * d_inner + 2 * ssm.n_groups * ssm.state + h
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "in_proj": dense_init(k1, d, (proj_out,), dtype),
        "conv_w": (jax.random.normal(k2, (ssm.conv_kernel, conv_dim), jnp.float32)
                   * (ssm.conv_kernel ** -0.5)).astype(dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "A_log": jnp.zeros((h,), jnp.float32),        # A = -exp(A_log) = -1 init
        "D": jnp.ones((h,), jnp.float32),
        "norm_scale": jnp.ones((d_inner,), dtype),
        "out_proj": dense_init(k4, d_inner, (d,), dtype),
    }


def _split_proj(cfg, zxbcdt):
    ssm = cfg.ssm
    d_inner, h, _ = _dims(cfg)
    gn = ssm.n_groups * ssm.state
    z, x, bc, dt = jnp.split(zxbcdt, [d_inner, 2 * d_inner, 2 * d_inner + 2 * gn], axis=-1)
    return z, x, bc, dt


def _causal_conv(xbc, w, b, prev=None):
    """Depthwise causal conv1d. xbc: [b,l,c]; w: [K,c]; prev: [b,K-1,c] or None.
    Returns (out [b,l,c], tail [b,K-1,c])."""
    k = w.shape[0]
    if prev is None:
        prev = jnp.zeros((xbc.shape[0], k - 1, xbc.shape[-1]), xbc.dtype)
    xp = jnp.concatenate([prev, xbc], axis=1)                   # [b, l+K-1, c]
    out = jnp.zeros_like(xbc, shape=xbc.shape).astype(jnp.float32)
    for i in range(k):
        out = out + xp[:, i:i + xbc.shape[1]].astype(jnp.float32) * w[i].astype(jnp.float32)
    out = out + b.astype(jnp.float32)
    tail = xp[:, xp.shape[1] - (k - 1):]
    return jax.nn.silu(out).astype(xbc.dtype), tail


def _gated_norm(y, z, scale, eps):
    """RMSNormGated(y * silu(z)) over the channel dim."""
    yf = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    var = (yf * yf).mean(-1, keepdims=True)
    return (yf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32))


def ssd_chunked(x, dt, A, B, C, chunk: int):
    """Chunked SSD scan.  x:[b,l,h,p] dt:[b,l,h] A:[h] B,C:[b,l,g,n].
    Returns (y [b,l,h,p] fp32, final_state [b,h,p,n] fp32)."""
    b, l, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    assert l % chunk == 0, (l, chunk)
    nc, q = l // chunk, chunk
    rep = h // g

    xf = x.astype(jnp.float32).reshape(b, nc, q, h, p)
    dtf = dt.astype(jnp.float32).reshape(b, nc, q, h)
    Bf = B.astype(jnp.float32).reshape(b, nc, q, g, n)
    Cf = C.astype(jnp.float32).reshape(b, nc, q, g, n)

    dA = dtf * A                                    # [b,nc,q,h]  (A negative)
    cum = jnp.cumsum(dA, axis=2)                    # inclusive cumsum within chunk

    # --- intra-chunk (diagonal block) term
    # decay L[i,j] = exp(cum_i - cum_j) for i >= j
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]        # [b,nc,i,j,h]
    tri = jnp.tril(jnp.ones((q, q), bool))
    L = jnp.where(tri[None, None, :, :, None], jnp.exp(diff), 0.0)
    # scores S[i,j] per head = C_i . B_j  (group-broadcast over heads)
    S = jnp.einsum("bcign,bcjgn->bcijg", Cf, Bf)                # [b,nc,i,j,g]
    S = jnp.repeat(S, rep, axis=-1)                             # [b,nc,i,j,h]
    M = S * L * dtf[:, :, None, :, :]                           # weight dt_j
    y_diag = jnp.einsum("bcijh,bcjhp->bcihp", M, xf)

    # --- chunk summary states: state_c = sum_j exp(cum_last - cum_j) dt_j B_j x_j
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)             # [b,nc,q,h]
    Bh = jnp.repeat(Bf, rep, axis=3)                            # [b,nc,q,h,n]
    states = jnp.einsum("bcqh,bcqhn,bcqhp->bchpn",
                        decay_to_end * dtf, Bh, xf)             # [b,nc,h,p,n]
    chunk_decay = jnp.exp(cum[:, :, -1, :])                     # [b,nc,h]

    # --- inter-chunk recurrence (sequential scan over chunks)
    def step(prev, inp):
        dec, st_chunk = inp                                     # [b,h], [b,h,p,n]
        new = prev * dec[:, :, None, None] + st_chunk
        return new, prev                                        # emit state *entering* the chunk

    init = jnp.zeros((b, h, p, n), jnp.float32)
    final_state, prev_states = jax.lax.scan(
        step, init,
        (jnp.moveaxis(chunk_decay, 1, 0), jnp.moveaxis(states, 1, 0)))
    prev_states = jnp.moveaxis(prev_states, 0, 1)               # [b,nc,h,p,n]

    # --- off-diagonal term: y_off[i] = exp(cum_i) * C_i . prev_state
    Ch = jnp.repeat(Cf, rep, axis=3)                            # [b,nc,q,h,n]
    y_off = jnp.einsum("bcqhn,bchpn->bcqhp", Ch, prev_states) * \
        jnp.exp(cum)[..., None]

    y = (y_diag + y_off).reshape(b, l, h, p)
    return y, final_state


def mamba_forward(p, u, cfg: ModelConfig, *, return_state: bool = False):
    """Full mixer forward (train / prefill). u: [b,l,d]. Returns out [b,l,d]
    (and (conv_tail, ssd_state) if return_state)."""
    ssm = cfg.ssm
    d_inner, h, conv_dim = _dims(cfg)
    zxbcdt = jnp.einsum("bld,dk->blk", u, p["in_proj"],
                        preferred_element_type=jnp.float32).astype(u.dtype)
    z, x, bc, dt = _split_proj(cfg, zxbcdt)
    xbc = jnp.concatenate([x, bc], axis=-1)
    xbc, conv_tail = _causal_conv(xbc, p["conv_w"], p["conv_b"])
    x, bc = xbc[..., :d_inner], xbc[..., d_inner:]
    gn = ssm.n_groups * ssm.state
    B = bc[..., :gn].reshape(*bc.shape[:2], ssm.n_groups, ssm.state)
    C = bc[..., gn:].reshape(*bc.shape[:2], ssm.n_groups, ssm.state)
    xh = x.reshape(*x.shape[:2], h, ssm.head_dim)
    dtv = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    y, state = ssd_chunked(xh, dtv, A, B, C, ssm.chunk)
    y = y + p["D"][None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(*y.shape[:2], d_inner)
    y = _gated_norm(y, z, p["norm_scale"], cfg.norm_eps).astype(u.dtype)
    out = jnp.einsum("blk,kd->bld", y, p["out_proj"],
                     preferred_element_type=jnp.float32).astype(u.dtype)
    if return_state:
        return out, (conv_tail, state)
    return out


def mamba_decode(p, u, cfg: ModelConfig, conv_buf, state):
    """One-token decode. u: [b,1,d]; conv_buf: [b,K-1,conv_dim];
    state: [b,h,p,n] fp32. Returns (out [b,1,d], conv_buf, state)."""
    ssm = cfg.ssm
    d_inner, h, conv_dim = _dims(cfg)
    zxbcdt = jnp.einsum("bld,dk->blk", u, p["in_proj"],
                        preferred_element_type=jnp.float32).astype(u.dtype)
    z, x, bc, dt = _split_proj(cfg, zxbcdt)
    xbc = jnp.concatenate([x, bc], axis=-1)                    # [b,1,c]
    xbc, conv_buf = _causal_conv(xbc, p["conv_w"], p["conv_b"], prev=conv_buf)
    x, bc = xbc[..., :d_inner], xbc[..., d_inner:]
    gn = ssm.n_groups * ssm.state
    B = bc[:, 0, :gn].reshape(-1, ssm.n_groups, ssm.state)     # [b,g,n]
    C = bc[:, 0, gn:].reshape(-1, ssm.n_groups, ssm.state)
    xh = x[:, 0].reshape(-1, h, ssm.head_dim).astype(jnp.float32)   # [b,h,p]
    dtv = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])  # [b,h]
    A = -jnp.exp(p["A_log"])
    rep = h // ssm.n_groups
    Bh = jnp.repeat(B.astype(jnp.float32), rep, axis=1)        # [b,h,n]
    Ch = jnp.repeat(C.astype(jnp.float32), rep, axis=1)
    dA = jnp.exp(dtv * A)                                      # [b,h]
    state = state * dA[..., None, None] + \
        jnp.einsum("bh,bhp,bhn->bhpn", dtv, xh, Bh)
    y = jnp.einsum("bhn,bhpn->bhp", Ch, state) + p["D"][None, :, None] * xh
    y = y.reshape(-1, 1, d_inner)
    y = _gated_norm(y, z, p["norm_scale"], cfg.norm_eps).astype(u.dtype)
    out = jnp.einsum("blk,kd->bld", y, p["out_proj"],
                     preferred_element_type=jnp.float32).astype(u.dtype)
    return out, conv_buf, state


def mamba_decode_cache_specs(cfg: ModelConfig, batch: int):
    """ShapeDtypeStructs for one mamba layer's decode cache."""
    ssm = cfg.ssm
    d_inner, h, conv_dim = _dims(cfg)
    return (
        jax.ShapeDtypeStruct((batch, ssm.conv_kernel - 1, conv_dim),
                             jnp.dtype(cfg.dtype)),
        jax.ShapeDtypeStruct((batch, h, ssm.head_dim, ssm.state), jnp.float32),
    )
