"""Decoder-only LM covering dense / MoE / hybrid (Jamba) / pure-SSM (Mamba2)
/ VLM (stub frontend) families.

Layer organisation: layers are grouped into identical *groups* of size
``lcm(attn_layer_period, moe.layer_period)`` (1 for uniform models, 8 for
Jamba).  Group params are stacked on a leading axis and the model scans over
groups — one traced group body regardless of depth, which keeps 48-layer
compiles tractable and is the standard production pattern (MaxText-style).

Remat: each group body is wrapped in ``jax.checkpoint`` with a configurable
policy; with ``nothing_saveable`` only group inputs are stored.

Cross-entropy is computed *chunked over the sequence* so the [b, s, V] fp32
logits tensor is never materialized (vocabularies here reach 256k).
"""

from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import attention as A
from repro.models import moe as M
from repro.models import mamba as S
from repro.sharding import annotate

AUX_LOSS_COEF = 0.01
XENT_CHUNK = 512


def group_size(cfg: ModelConfig) -> int:
    a = cfg.attn_layer_period if (cfg.ssm is not None and cfg.attn_layer_period > 1) else 1
    m = cfg.moe.layer_period if cfg.moe.n_experts else 1
    g = math.lcm(max(a, 1), m)
    assert cfg.n_layers % g == 0, (cfg.name, cfg.n_layers, g)
    return g


def n_groups(cfg: ModelConfig) -> int:
    return cfg.n_layers // group_size(cfg)


def _layer_kind(cfg: ModelConfig, j: int) -> str:
    return "attn" if cfg.layer_is_attn(j) else "mamba"


def _has_ffn(cfg: ModelConfig) -> bool:
    return cfg.d_ff > 0


# ------------------------------------------------------------------- init

def init_group(cfg: ModelConfig, key, dtype):
    g = group_size(cfg)
    keys = jax.random.split(key, 2 * g)
    gp = {}
    for j in range(g):
        lk, fk = keys[2 * j], keys[2 * j + 1]
        lp = {"norm1": L.init_norm(cfg, dtype)}
        if _layer_kind(cfg, j) == "attn":
            lp["attn"] = A.init_attn(cfg, lk, dtype)
        else:
            lp["mamba"] = S.init_mamba(cfg, lk, dtype)
        if _has_ffn(cfg):
            lp["norm2"] = L.init_norm(cfg, dtype)
            if cfg.layer_is_moe(j):
                lp["moe"] = M.init_moe(cfg, fk, dtype)
            else:
                lp["mlp"] = L.init_mlp(cfg, fk, dtype)
        gp[f"pos{j}"] = lp
    return gp


def init_params(cfg: ModelConfig, key):
    dtype = jnp.dtype(cfg.dtype)
    ke, kh, kg = jax.random.split(key, 3)
    groups = jax.vmap(lambda k: init_group(cfg, k, dtype))(
        jax.random.split(kg, n_groups(cfg)))
    return {
        "embed": L.init_embed(cfg, ke, dtype),
        "head": L.init_lm_head(cfg, kh, dtype),
        "final_norm": L.init_norm(cfg, dtype),
        "groups": groups,
    }


def param_specs(cfg: ModelConfig):
    """ShapeDtypeStruct tree (no allocation)."""
    return jax.eval_shape(lambda: init_params(cfg, jax.random.key(0)))


# ---------------------------------------------------------------- forward

def _residual_annotate(cfg, x):
    if cfg.seq_parallel:
        return annotate(x, ("batch", "seq_sp", None))
    return annotate(x, ("batch", None, None))


def _apply_group(cfg: ModelConfig, gp, x):
    """One group of layers (train/prefill). Returns (x, aux)."""
    g = group_size(cfg)
    aux = jnp.zeros((), jnp.float32)
    for j in range(g):
        lp = gp[f"pos{j}"]
        h = L.apply_norm(lp["norm1"], x, cfg)
        if _layer_kind(cfg, j) == "attn":
            h = A.attn_forward(lp["attn"], h, cfg,
                               causal=True, use_rope=cfg.norm_type == "rmsnorm")
        else:
            h = S.mamba_forward(lp["mamba"], h, cfg)
        x = _residual_annotate(cfg, x + h)
        if _has_ffn(cfg):
            h2 = L.apply_norm(lp["norm2"], x, cfg)
            if cfg.layer_is_moe(j):
                h2, aux_j = M.apply_moe(lp["moe"], h2, cfg)
                aux = aux + aux_j
            else:
                h2 = L.apply_mlp(lp["mlp"], h2, cfg)
            x = _residual_annotate(cfg, x + h2)
    return x, aux


_REMAT_POLICIES = {
    "nothing_saveable": jax.checkpoint_policies.nothing_saveable,
    "dots": jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
}


def backbone(cfg: ModelConfig, params, x):
    """Scan groups over a [b, s, d] stream. Returns (x, aux)."""
    body = partial(_apply_group, cfg)
    if cfg.remat_policy != "none":
        body = jax.checkpoint(body, policy=_REMAT_POLICIES[cfg.remat_policy])

    def scan_fn(carry, gp):
        x, aux = carry
        x, aux_g = body(gp, x)
        return (x, aux + aux_g), None

    (x, aux), _ = jax.lax.scan(scan_fn, (x, jnp.zeros((), jnp.float32)),
                               params["groups"])
    x = L.apply_norm(params["final_norm"], x, cfg)
    return x, aux


def embed_inputs(cfg: ModelConfig, params, batch):
    """tokens [+ patch_embeds] -> [b, s(+P), d]; returns (x, n_prefix)."""
    x = L.embed_tokens(params["embed"], batch["tokens"], cfg)
    n_prefix = 0
    if cfg.frontend == "vision" and "patch_embeds" in batch:
        pe = batch["patch_embeds"].astype(x.dtype)     # [b, P, d] (stub frontend)
        x = jnp.concatenate([pe, x], axis=1)
        n_prefix = pe.shape[1]
    return _residual_annotate(cfg, x), n_prefix


def chunked_xent(cfg: ModelConfig, params, x, labels, mask, chunk=XENT_CHUNK):
    """Sequence-chunked softmax cross-entropy; never materializes [b,s,V].
    x: [b,s,d]; labels/mask: [b,s]. Returns (sum_nll, sum_cnt)."""
    b, s, _ = x.shape
    chunk = min(chunk, s)
    if s % chunk:
        chunk = s  # fallback: single chunk
    nc = s // chunk

    def body(carry, inp):
        xs, ls, ms = inp                               # [nc-major] slices
        logits = L.lm_logits(params["embed"], params["head"], xs, cfg)
        logits = annotate(logits, ("batch", None, "vocab"))
        lf = logits - jax.lax.stop_gradient(logits.max(-1, keepdims=True))
        logz = jnp.log(jnp.exp(lf).sum(-1))
        gold = jnp.take_along_axis(lf, ls[..., None], axis=-1)[..., 0]
        nll = ((logz - gold) * ms).sum()
        return (carry[0] + nll, carry[1] + ms.sum()), None

    xs = x.reshape(b, nc, chunk, -1).transpose(1, 0, 2, 3)
    ls = labels.reshape(b, nc, chunk).transpose(1, 0, 2)
    ms = mask.reshape(b, nc, chunk).transpose(1, 0, 2).astype(jnp.float32)
    (nll, cnt), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        (xs, ls, ms))
    return nll, cnt


def train_loss(cfg: ModelConfig, params, batch):
    """batch: tokens [b,s], labels [b,s], mask [b,s] (+patch_embeds for vlm).
    Returns (loss, metrics)."""
    x, n_prefix = embed_inputs(cfg, params, batch)
    x, aux = backbone(cfg, params, x)
    if n_prefix:
        x = x[:, n_prefix:]
    nll, cnt = chunked_xent(cfg, params, x, batch["labels"], batch["mask"])
    loss = nll / jnp.maximum(cnt, 1.0)
    total = loss + AUX_LOSS_COEF * aux
    return total, {"loss": loss, "aux": aux, "tokens": cnt}


# ---------------------------------------------------------------- serving

def prefill(cfg: ModelConfig, params, batch):
    """Prefill forward -> last-position logits [b, V] (cache omitted: the
    dry-run prefill cell measures the forward; cache writes are decode-path)."""
    x, _ = embed_inputs(cfg, params, batch)
    x, _ = backbone(cfg, params, x)
    logits = L.lm_logits(params["embed"], params["head"], x[:, -1:], cfg)
    return logits[:, 0]


def decode_cache_specs(cfg: ModelConfig, batch: int, max_seq: int):
    """Pytree of ShapeDtypeStructs for the decode cache (grouped layout)."""
    g, ng = group_size(cfg), n_groups(cfg)
    dtype = jnp.dtype(cfg.dtype)
    hd, kv = cfg.resolved_head_dim(), cfg.n_kv_heads
    cache = {}
    for j in range(g):
        if _layer_kind(cfg, j) == "attn":
            cache[f"pos{j}"] = {
                "k": jax.ShapeDtypeStruct((ng, batch, max_seq, kv, hd), dtype),
                "v": jax.ShapeDtypeStruct((ng, batch, max_seq, kv, hd), dtype),
            }
        else:
            conv, state = S.mamba_decode_cache_specs(cfg, batch)
            cache[f"pos{j}"] = {
                "conv": jax.ShapeDtypeStruct((ng, *conv.shape), conv.dtype),
                "state": jax.ShapeDtypeStruct((ng, *state.shape), state.dtype),
            }
    return cache


def init_decode_cache(cfg: ModelConfig, batch: int, max_seq: int):
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        decode_cache_specs(cfg, batch, max_seq))


def decode_step(cfg: ModelConfig, params, cache, tokens, pos):
    """One decode step for all sequences (synchronized position ``pos``).
    tokens: [b, 1] int32; pos: scalar int32. Returns (logits [b,V], cache)."""
    x = L.embed_tokens(params["embed"], tokens, cfg)
    x = annotate(x, ("batch", None, None))
    g = group_size(cfg)

    def scan_fn(x, inp):
        gp, gc = inp
        new_gc = {}
        for j in range(g):
            lp, cj = gp[f"pos{j}"], gc[f"pos{j}"]
            h = L.apply_norm(lp["norm1"], x, cfg)
            if _layer_kind(cfg, j) == "attn":
                h, ck, cv = A.attn_decode(
                    lp["attn"], h, cfg, cj["k"], cj["v"], pos,
                    use_rope=cfg.norm_type == "rmsnorm")
                new_gc[f"pos{j}"] = {"k": ck, "v": cv}
            else:
                h, conv, state = S.mamba_decode(
                    lp["mamba"], h, cfg, cj["conv"], cj["state"])
                new_gc[f"pos{j}"] = {"conv": conv, "state": state}
            x = x + h
            if _has_ffn(cfg):
                h2 = L.apply_norm(lp["norm2"], x, cfg)
                if cfg.layer_is_moe(j):
                    h2, _ = M.apply_moe(lp["moe"], h2, cfg)
                else:
                    h2 = L.apply_mlp(lp["mlp"], h2, cfg)
                x = x + h2
        return x, new_gc

    x, new_cache = jax.lax.scan(scan_fn, x, (params["groups"], cache))
    x = L.apply_norm(params["final_norm"], x, cfg)
    logits = L.lm_logits(params["embed"], params["head"], x, cfg)
    return logits[:, 0], new_cache
