"""Mixture-of-Experts FFN: sort-based grouped matmul with static capacity.

Design (see DESIGN.md §6 EP and EXPERIMENTS.md §Perf iteration 1):
  - top-k routing in fp32, gates renormalized over the selected experts;
  - **per-data-shard dispatch**: tokens are argsorted and capacity-bucketed
    within their data shard (leading ``S`` dim matching the batch sharding),
    never globally — a global argsort would force XLA to all-gather the
    entire token stream per layer (measured: 617 s collective term on
    moonshot train_4k).  With local dispatch the only cross-device movement
    is the true expert all-to-all of the dispatched activations;
  - scatter into a static [S, E, C_loc, d] capacity buffer
    (C_loc = ceil(T_loc*k/E * cf) rounded to a multiple of 8), grouped
    matmuls [S,E,C,d]x[E,d,f] — FLOPs ≈ T*k*cf * 3*d*f, no dense-dispatch
    blowup;
  - capacity overflow tokens are dropped per shard (standard GShard
    behaviour); the residual path still flows;
  - experts live on the 'model' mesh axis (EP), the shard dim on the data
    axes, annotated via ``annotate``.

Returns (out, aux) with the switch-transformer load-balance loss in aux.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init
from repro.sharding import annotate
from repro.sharding.ctx import dispatch_shards


def moe_capacity(tokens_per_shard: int, cfg: ModelConfig) -> int:
    moe = cfg.moe
    c = int(tokens_per_shard * moe.top_k * moe.capacity_factor / moe.n_experts)
    return max(8, -(-c // 8) * 8)  # round up to multiple of 8


def init_moe(cfg: ModelConfig, key, dtype):
    d, f, e = cfg.d_model, cfg.d_ff, cfg.moe.n_experts
    kr, k1, k2, k3 = jax.random.split(key, 4)
    return {
        "router": dense_init(kr, d, (e,), jnp.float32),
        "wi": dense_init(k1, d, (e, f), dtype).transpose(1, 0, 2),  # [E,d,f]
        "wu": dense_init(k2, d, (e, f), dtype).transpose(1, 0, 2),  # [E,d,f]
        "wo": dense_init(k3, f, (e, d), dtype).transpose(1, 0, 2),  # [E,f,d]
    }


def apply_moe(p, x, cfg: ModelConfig):
    """x: [b, s, d]. Returns (out [b,s,d], aux scalar)."""
    b, s, d = x.shape
    moe = cfg.moe
    e, k = moe.n_experts, moe.top_k

    n_shards = dispatch_shards() if cfg.moe_dispatch == "shard" else 1
    if b % n_shards != 0:
        n_shards = 1                    # e.g. global_batch=1 long-decode
    t_loc = (b // n_shards) * s
    cap = moe_capacity(t_loc, cfg)

    xs = x.reshape(n_shards, t_loc, d)                  # S-major == batch shards
    xs = annotate(xs, ("batch", None, None))

    router_logits = jnp.einsum("std,de->ste", xs.astype(jnp.float32),
                               p["router"])
    probs = jax.nn.softmax(router_logits, axis=-1)      # [S,T,E] fp32
    gates, ids = jax.lax.top_k(probs, k)                # [S,T,k]
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    # load-balance aux loss (Switch), averaged over shards
    me = probs.mean(axis=1)                             # [S,E]
    ce = jax.vmap(lambda f_: jnp.zeros((e,), jnp.float32).at[f_].add(1.0))(
        ids.reshape(n_shards, -1)) / (t_loc * k)
    aux = (e * jnp.sum(me * ce, axis=-1)).mean()

    flat_ids = ids.reshape(n_shards, t_loc * k).astype(jnp.int32)
    order = jnp.argsort(flat_ids, axis=-1, stable=True)           # per-shard sort
    sorted_ids = jnp.take_along_axis(flat_ids, order, axis=-1)
    token_idx = order // k                                        # [S,T*k]

    counts = jax.vmap(lambda f_: jnp.zeros((e,), jnp.int32).at[f_].add(1))(
        flat_ids)                                                 # [S,E]
    starts = jnp.cumsum(counts, axis=-1) - counts
    pos_in_expert = (jnp.arange(t_loc * k, dtype=jnp.int32)[None]
                     - jnp.take_along_axis(starts, sorted_ids, axis=-1))
    keep = pos_in_expert < cap                                    # [S,T*k]
    dest = sorted_ids * cap + jnp.where(keep, pos_in_expert, 0)

    gathered = jnp.take_along_axis(xs, token_idx[..., None], axis=1)  # [S,T*k,d]
    contrib = jnp.where(keep[..., None], gathered, jnp.zeros_like(gathered))
    buf = jax.vmap(lambda de, co: jnp.zeros((e * cap, d), x.dtype)
                   .at[de].add(co))(dest, contrib)
    buf = annotate(buf.reshape(n_shards, e, cap, d),
                   ("batch", "experts", None, None))

    h_g = jnp.einsum("secd,edf->secf", buf, p["wi"],
                     preferred_element_type=jnp.float32)
    h_u = jnp.einsum("secd,edf->secf", buf, p["wu"],
                     preferred_element_type=jnp.float32)
    h = (jax.nn.silu(h_g) * h_u).astype(x.dtype)
    h = annotate(h, ("batch", "experts", None, None))
    out_e = jnp.einsum("secf,efd->secd", h, p["wo"],
                       preferred_element_type=jnp.float32).astype(x.dtype)
    out_e = annotate(out_e, ("batch", "experts", None, None))

    back = jnp.take_along_axis(out_e.reshape(n_shards, e * cap, d),
                               dest[..., None], axis=1)           # [S,T*k,d]
    w = (jnp.take_along_axis(gates.reshape(n_shards, -1), order, axis=-1)
         * keep).astype(jnp.float32)                              # [S,T*k]
    # combine in model dtype (bf16): halves the cross-model psum volume
    # (§Perf iteration 3); top-k<=8 partial sums are bf16-safe here, and the
    # residual-stream addition outside stays exact in its own dtype.
    back = (back.astype(jnp.float32) * w[..., None]).astype(x.dtype)
    out = jax.vmap(lambda ti, bk: jnp.zeros((t_loc, d), x.dtype)
                   .at[ti].add(bk))(token_idx, back)
    out = annotate(out, ("batch", None, None))
    return out.reshape(b, s, d), aux
