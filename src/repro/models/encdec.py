"""Encoder-decoder transformer (Whisper-medium backbone).

The conv audio frontend is a STUB per the assignment: inputs carry
precomputed frame embeddings [b, enc_seq, d_model].  Whisper specifics:
LayerNorm (not RMSNorm), GELU MLPs with biases, learned absolute positions,
no RoPE, pre-LN blocks, tied decoder embedding/output.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import attention as A
from repro.sharding import annotate

MAX_DEC_POSITIONS = 32_768


def _init_layer(cfg: ModelConfig, key, dtype, cross: bool):
    k1, k2, k3 = jax.random.split(key, 3)
    lp = {
        "norm1": L.init_norm(cfg, dtype),
        "attn": A.init_attn(cfg, k1, dtype),
        "norm_mlp": L.init_norm(cfg, dtype),
        "mlp": L.init_mlp(cfg, k2, dtype),
    }
    if cross:
        lp["norm_x"] = L.init_norm(cfg, dtype)
        lp["xattn"] = A.init_attn(cfg, k3, dtype, cross=True)
    return lp


def init_params(cfg: ModelConfig, key):
    dtype = jnp.dtype(cfg.dtype)
    ke, kp1, kp2, kenc, kdec = jax.random.split(key, 5)
    enc_layers = jax.vmap(lambda k: _init_layer(cfg, k, dtype, cross=False))(
        jax.random.split(kenc, cfg.n_encoder_layers))
    dec_layers = jax.vmap(lambda k: _init_layer(cfg, k, dtype, cross=True))(
        jax.random.split(kdec, cfg.n_layers))
    return {
        "embed": L.init_embed(cfg, ke, dtype),
        "head": L.init_lm_head(cfg, ke, dtype),
        "enc_pos": (jax.random.normal(kp1, (cfg.encoder_seq, cfg.d_model),
                                      jnp.float32) * 0.02).astype(dtype),
        "dec_pos": (jax.random.normal(kp2, (MAX_DEC_POSITIONS, cfg.d_model),
                                      jnp.float32) * 0.02).astype(dtype),
        "enc_final_norm": L.init_norm(cfg, dtype),
        "dec_final_norm": L.init_norm(cfg, dtype),
        "enc_layers": enc_layers,
        "dec_layers": dec_layers,
    }


def param_specs(cfg: ModelConfig):
    return jax.eval_shape(lambda: init_params(cfg, jax.random.key(0)))


def _enc_layer(cfg, lp, x):
    h = L.apply_norm(lp["norm1"], x, cfg)
    x = x + A.attn_forward(lp["attn"], h, cfg, causal=False, use_rope=False)
    h = L.apply_norm(lp["norm_mlp"], x, cfg)
    return x + L.apply_mlp(lp["mlp"], h, cfg)


def _dec_layer(cfg, lp, x, enc_out):
    h = L.apply_norm(lp["norm1"], x, cfg)
    x = x + A.attn_forward(lp["attn"], h, cfg, causal=True, use_rope=False)
    h = L.apply_norm(lp["norm_x"], x, cfg)
    x = x + A.attn_forward(lp["xattn"], h, cfg, causal=False, use_rope=False,
                           kv_x=enc_out)
    h = L.apply_norm(lp["norm_mlp"], x, cfg)
    return x + L.apply_mlp(lp["mlp"], h, cfg)


def _scan_layers(cfg, layer_fn, stacked, x):
    body = layer_fn
    if cfg.remat_policy != "none":
        body = jax.checkpoint(body)

    def f(x, lp):
        return body(lp, x), None

    x, _ = jax.lax.scan(f, x, stacked)
    return x


def encode(cfg: ModelConfig, params, frame_embeds):
    x = frame_embeds.astype(jnp.dtype(cfg.dtype)) + params["enc_pos"]
    x = annotate(x, ("batch", None, None))
    x = _scan_layers(cfg, partial(_enc_layer, cfg), params["enc_layers"], x)
    return L.apply_norm(params["enc_final_norm"], x, cfg)


def decode_forward(cfg: ModelConfig, params, tokens, enc_out):
    x = L.embed_tokens(params["embed"], tokens, cfg)
    s = tokens.shape[1]
    x = x + params["dec_pos"][:s]
    x = annotate(x, ("batch", None, None))
    x = _scan_layers(cfg, lambda lp, h: _dec_layer(cfg, lp, h, enc_out),
                     params["dec_layers"], x)
    return L.apply_norm(params["dec_final_norm"], x, cfg)


def train_loss(cfg: ModelConfig, params, batch):
    """batch: frame_embeds [b,F,d], tokens [b,s], labels [b,s], mask [b,s]."""
    enc_out = encode(cfg, params, batch["frame_embeds"])
    x = decode_forward(cfg, params, batch["tokens"], enc_out)
    from repro.models.transformer import chunked_xent
    nll, cnt = chunked_xent(cfg, params, x, batch["labels"], batch["mask"])
    loss = nll / jnp.maximum(cnt, 1.0)
    return loss, {"loss": loss, "aux": jnp.zeros((), jnp.float32), "tokens": cnt}


def prefill(cfg: ModelConfig, params, batch):
    enc_out = encode(cfg, params, batch["frame_embeds"])
    x = decode_forward(cfg, params, batch["tokens"], enc_out)
    logits = L.lm_logits(params["embed"], params["head"], x[:, -1:], cfg)
    return logits[:, 0]


def decode_cache_specs(cfg: ModelConfig, batch: int, max_seq: int):
    dtype = jnp.dtype(cfg.dtype)
    kv, hd = cfg.n_kv_heads, cfg.resolved_head_dim()
    nl = cfg.n_layers
    return {
        "self_k": jax.ShapeDtypeStruct((nl, batch, max_seq, kv, hd), dtype),
        "self_v": jax.ShapeDtypeStruct((nl, batch, max_seq, kv, hd), dtype),
        # cross-attention memory (precomputed at prefill from encoder output)
        "mem_k": jax.ShapeDtypeStruct((nl, batch, cfg.encoder_seq, kv, hd), dtype),
        "mem_v": jax.ShapeDtypeStruct((nl, batch, cfg.encoder_seq, kv, hd), dtype),
    }


def init_decode_cache(cfg: ModelConfig, batch: int, max_seq: int):
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        decode_cache_specs(cfg, batch, max_seq))


def decode_step(cfg: ModelConfig, params, cache, tokens, pos):
    """One decoder token step. tokens: [b,1]. Returns (logits [b,V], cache)."""
    x = L.embed_tokens(params["embed"], tokens, cfg)
    x = x + jax.lax.dynamic_slice_in_dim(params["dec_pos"], pos, 1, axis=0)
    x = annotate(x, ("batch", None, None))

    def scan_fn(x, inp):
        lp, sk, sv, mk, mv = inp
        h = L.apply_norm(lp["norm1"], x, cfg)
        h, sk, sv = A.attn_decode(lp["attn"], h, cfg, sk, sv, pos, use_rope=False)
        x = x + h
        h = L.apply_norm(lp["norm_x"], x, cfg)
        x = x + A.attn_cross_decode(lp["xattn"], h, cfg, mk, mv)
        h = L.apply_norm(lp["norm_mlp"], x, cfg)
        x = x + L.apply_mlp(lp["mlp"], h, cfg)
        return x, (sk, sv)

    x, (new_sk, new_sv) = jax.lax.scan(
        scan_fn, x,
        (params["dec_layers"], cache["self_k"], cache["self_v"],
         cache["mem_k"], cache["mem_v"]))
    x = L.apply_norm(params["dec_final_norm"], x, cfg)
    logits = L.lm_logits(params["embed"], params["head"], x, cfg)
    new_cache = dict(cache, self_k=new_sk, self_v=new_sv)
    return logits[:, 0], new_cache
