"""Shared NN building blocks (pure-JAX pytrees, no flax).

Conventions
-----------
- Params are nested dicts of jnp arrays; init fns are ``jax.eval_shape``-safe
  (used by the dry-run to build ShapeDtypeStruct trees with no allocation).
- Matmuls accumulate in fp32 (``preferred_element_type``); norms, softmax and
  router math run in fp32 and cast back.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


def dense_init(key, in_dim, out_shape, dtype, scale=None):
    """Truncated-normal fan-in init, eval_shape-safe."""
    if scale is None:
        scale = in_dim ** -0.5
    return (jax.random.truncated_normal(key, -2.0, 2.0, (in_dim, *out_shape), jnp.float32)
            * scale).astype(dtype)


# ----------------------------------------------------------------- norms

def init_norm(cfg: ModelConfig, dtype):
    p = {"scale": jnp.ones((cfg.d_model,), dtype)}
    if cfg.norm_type == "layernorm":
        p["bias"] = jnp.zeros((cfg.d_model,), dtype)
    return p


def apply_norm(p, x, cfg: ModelConfig):
    xf = x.astype(jnp.float32)
    if cfg.norm_type == "layernorm":
        mu = xf.mean(-1, keepdims=True)
        xf = xf - mu
        var = (xf * xf).mean(-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + cfg.norm_eps)
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    else:  # rmsnorm
        var = (xf * xf).mean(-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + cfg.norm_eps)
        y = y * p["scale"].astype(jnp.float32)
    return y.astype(x.dtype)


# ----------------------------------------------------------------- RoPE

def rope_angles(positions, head_dim: int, theta: float):
    """positions: int array [...]. Returns (sin, cos) of shape [..., head_dim//2]."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs  # [..., half]
    return jnp.sin(ang), jnp.cos(ang)


def apply_rope(x, sin, cos):
    """x: [..., seq, heads, head_dim]; sin/cos: [seq, head_dim//2] (or broadcastable)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    # broadcast sin/cos over head axis: [seq, 1, half]
    s = sin[..., :, None, :]
    c = cos[..., :, None, :]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    o1 = xf1 * c - xf2 * s
    o2 = xf2 * c + xf1 * s
    return jnp.concatenate([o1, o2], axis=-1).astype(x.dtype)


# ----------------------------------------------------------------- MLPs

def init_mlp(cfg: ModelConfig, key, dtype, d_ff: int | None = None):
    d, f = cfg.d_model, (d_ff or cfg.d_ff)
    k1, k2, k3 = jax.random.split(key, 3)
    if cfg.mlp_type in ("swiglu", "geglu"):
        return {
            "wi": dense_init(k1, d, (f,), dtype),          # gate proj
            "wu": dense_init(k2, d, (f,), dtype),          # up proj
            "wo": dense_init(k3, f, (d,), dtype),
        }
    # plain gelu MLP (whisper)
    return {
        "wi": dense_init(k1, d, (f,), dtype),
        "bi": jnp.zeros((f,), dtype),
        "wo": dense_init(k3, f, (d,), dtype),
        "bo": jnp.zeros((d,), dtype),
    }


def apply_mlp(p, x, cfg: ModelConfig):
    if cfg.mlp_type in ("swiglu", "geglu"):
        g = jnp.einsum("...d,df->...f", x, p["wi"], preferred_element_type=jnp.float32)
        u = jnp.einsum("...d,df->...f", x, p["wu"], preferred_element_type=jnp.float32)
        act = jax.nn.silu(g) if cfg.mlp_type == "swiglu" else jax.nn.gelu(g, approximate=True)
        h = (act * u).astype(x.dtype)
        return jnp.einsum("...f,fd->...d", h, p["wo"],
                          preferred_element_type=jnp.float32).astype(x.dtype)
    h = jnp.einsum("...d,df->...f", x, p["wi"], preferred_element_type=jnp.float32)
    h = jax.nn.gelu(h + p["bi"].astype(jnp.float32), approximate=True).astype(x.dtype)
    o = jnp.einsum("...f,fd->...d", h, p["wo"], preferred_element_type=jnp.float32)
    return (o + p["bo"].astype(jnp.float32)).astype(x.dtype)


# ------------------------------------------------------------ embeddings

def init_embed(cfg: ModelConfig, key, dtype):
    p = {"table": dense_init(key, cfg.d_model, (cfg.vocab_size,), jnp.float32).T.astype(dtype)}
    # table: [V, d]
    return p


def embed_tokens(p, tokens, cfg: ModelConfig):
    out = jnp.take(p["table"], tokens, axis=0)
    if cfg.name.startswith("gemma"):
        out = out * jnp.asarray(cfg.d_model ** 0.5, out.dtype)
    return out


def lm_logits(embed_params, head_params, x, cfg: ModelConfig):
    """Final projection to vocab. Tied => reuse the embedding table."""
    table = embed_params["table"] if cfg.tie_embeddings else head_params["w"]
    return jnp.einsum("...d,vd->...v", x, table, preferred_element_type=jnp.float32)


def init_lm_head(cfg: ModelConfig, key, dtype):
    if cfg.tie_embeddings:
        return {}
    return {"w": dense_init(key, cfg.d_model, (cfg.vocab_size,), dtype).T}  # [V, d]


# ----------------------------------------------------------------- loss

def softmax_xent(logits_f32, labels, mask):
    """logits: [..., V] fp32; labels int; mask 0/1 same shape as labels.
    Returns (mean_loss, token_count)."""
    logits_f32 = logits_f32 - jax.lax.stop_gradient(
        logits_f32.max(axis=-1, keepdims=True))
    logz = jnp.log(jnp.exp(logits_f32).sum(axis=-1))
    gold = jnp.take_along_axis(logits_f32, labels[..., None], axis=-1)[..., 0]
    nll = (logz - gold) * mask
    cnt = jnp.maximum(mask.sum(), 1.0)
    return nll.sum() / cnt, cnt
