"""Parse post-optimization HLO text for roofline accounting.

``compiled.as_text()`` (after SPMD partitioning) contains the materialized
collective ops.  We sum *operand* bytes of every collective, which is the
amount of data each participating device contributes per invocation — the
quantity that crosses links under a bandwidth-optimal algorithm (up to the
standard 2(n-1)/n ring factor, which we fold into the reported term).
"""

from __future__ import annotations

import re
from collections import defaultdict

# f32[128,256]{1,0} / bf16[4096]{0} / u32[] / pred[8,1]{...}
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1,
    "u64": 8, "u32": 4, "u16": 2, "u8": 1,
    "pred": 1, "c64": 8, "c128": 16,
}

COLLECTIVE_OPS = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

# matches e.g.:  %ag = bf16[16,512]{1,0} all-gather(bf16[1,512]{1,0} %x), ...
_OP_LINE_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\]\S*)\s+"
    r"([a-z0-9\-]+)(?:-start|-done)?\("
)


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def parse_hlo_op_bytes(hlo_text: str, op_names=COLLECTIVE_OPS):
    """Sum output bytes of the listed HLO ops.

    Returns {op_name: {"bytes": int, "count": int}}.

    Collective output shape ~= the per-device data volume involved:
      all-gather: output = full gathered buffer (input * group)  — per-device
        traffic under ring is (g-1)/g of this;
      all-reduce: output = reduced buffer; ring traffic ~2x this;
      reduce-scatter: output = scattered shard; traffic ~(g-1) shards;
      all-to-all / collective-permute: output ~= bytes sent per device.
    We record raw output bytes and let the roofline layer apply the
    algorithm factor per op kind.
    """
    out = defaultdict(lambda: {"bytes": 0, "count": 0})
    for line in hlo_text.splitlines():
        m = _OP_LINE_RE.match(line)
        if not m:
            continue
        shape_str, opcode = m.group(1), m.group(2)
        # normalize async forms: all-gather-start / all-reduce-done etc.
        base = None
        for name in op_names:
            if opcode == name or opcode.startswith(name):
                base = name
                break
        if base is None:
            continue
        if opcode.endswith("-done"):
            continue  # avoid double counting start/done pairs
        out[base]["bytes"] += _shape_bytes(shape_str)
        out[base]["count"] += 1
    return dict(out)


# Per-op multiplier converting *output bytes* into approximate bytes that
# cross each device's links (bandwidth-optimal ring algorithms; group factor
# (g-1)/g ~ 1 for the 16-256 way groups we use).
_LINK_FACTOR = {
    "all-gather": 1.0,        # each device receives (g-1)/g of output
    "all-reduce": 2.0,        # reduce-scatter + all-gather
    "reduce-scatter": 1.0,    # output is the shard; each device sends (g-1) shards ~ input
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}


def parse_collective_bytes(hlo_text: str) -> dict:
    """Return {"per_op": {...}, "link_bytes": float, "total_output_bytes": int}."""
    per_op = parse_hlo_op_bytes(hlo_text)
    link_bytes = 0.0
    total = 0
    for name, rec in per_op.items():
        link_bytes += rec["bytes"] * _LINK_FACTOR[name]
        total += rec["bytes"]
    return {"per_op": per_op, "link_bytes": link_bytes, "total_output_bytes": total}
