"""Pytree helpers used across the framework (no flax dependency)."""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp


def _name_of(entry) -> str:
    """Human/path name of a single KeyEntry."""
    if isinstance(entry, jax.tree_util.DictKey):
        return str(entry.key)
    if isinstance(entry, jax.tree_util.SequenceKey):
        return str(entry.idx)
    if isinstance(entry, jax.tree_util.GetAttrKey):
        return str(entry.name)
    return str(entry)


def path_str(path) -> str:
    return "/".join(_name_of(p) for p in path)


def tree_path_map(fn, tree):
    """Map ``fn(path_str, leaf) -> new_leaf`` over a pytree."""
    return jax.tree_util.tree_map_with_path(
        lambda p, x: fn(path_str(p), x), tree
    )


def flatten_with_names(tree):
    """Return [(path_str, leaf)] for all leaves."""
    leaves, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [(path_str(p), x) for p, x in leaves]


def tree_size_bytes(tree) -> int:
    return int(
        sum(
            np.prod(x.shape) * jnp.dtype(x.dtype).itemsize
            for x in jax.tree.leaves(tree)
        )
    )


def tree_num_params(tree) -> int:
    return int(sum(np.prod(x.shape) for x in jax.tree.leaves(tree)))
