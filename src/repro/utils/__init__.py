from repro.utils.tree import (
    tree_path_map,
    tree_size_bytes,
    tree_num_params,
    flatten_with_names,
)
from repro.utils.hlo import parse_collective_bytes, parse_hlo_op_bytes
