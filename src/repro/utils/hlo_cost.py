"""Trip-count-aware cost analysis over optimized HLO text.

Why: ``compiled.cost_analysis()`` counts while-loop bodies ONCE, so any
scan-over-layers module under-reports FLOPs/bytes/collectives by ~n_layers
(verified empirically; see EXPERIMENTS.md §Dry-run).  XLA's optimized HLO
carries ``known_trip_count`` on while ops, so we walk the call graph and
multiply loop bodies out.

Model:
  flops:
    dot            2 * numel(out) * prod(contracting dims of lhs)
    elementwise    numel(out)          (transcendentals weighted x4)
    reduce(+window) numel(input)
    fusion         recurse (interior dots etc.)
  memory bytes (HBM traffic approximation):
    at materialization boundaries (top-level instructions of non-fusion
    computations): sum of operand + output bytes for memory-touching ops;
    fusion interiors are free (that is what fusion means).  bitcast /
    get-tuple-element / tuple / parameter are free.
  collectives:
    output bytes summed per op kind, x ring-algorithm link factor
    (all-reduce 2x, others 1x), multiplied by enclosing trip counts.

This is a first-order model: it ignores cache reuse between consumers and
pads, and counts both operands of every fusion — good to ~2x, which is the
fidelity a roofline argument needs.
"""

from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "s4": 1,
    "u64": 8, "u32": 4, "u16": 2, "u8": 1, "u4": 1,
    "pred": 1, "c64": 8, "c128": 16, "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")

_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "and",
    "or", "xor", "not", "negate", "abs", "select", "compare", "clamp",
    "sign", "floor", "ceil", "round-nearest-afz", "round-nearest-even",
    "shift-left", "shift-right-logical", "shift-right-arithmetic",
    "remainder", "is-finite",
}
_TRANSCENDENTAL = {"exponential", "log", "rsqrt", "sqrt", "tanh", "power",
                   "sine", "cosine", "erf", "expm1", "log1p", "logistic",
                   "atan2", "cbrt"}
_FREE = {"bitcast", "get-tuple-element", "tuple", "parameter", "constant",
         "after-all", "add-dependency", "partition-id", "replica-id", "iota"}
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_MEMORY_OPS = {"copy", "transpose", "slice", "dynamic-slice",
               "dynamic-update-slice", "concatenate", "broadcast", "gather",
               "pad", "reverse", "reshape", "copy-start", "copy-done"}
_LINK_FACTOR = {"all-gather": 1.0, "all-reduce": 2.0, "reduce-scatter": 1.0,
                "all-to-all": 1.0, "collective-permute": 1.0}


def _shape_numel_bytes(shape_str: str):
    numel = 0
    nbytes = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        numel += n
        nbytes += n * _DTYPE_BYTES[dtype]
    return numel, nbytes


@dataclass
class Instr:
    name: str
    shape: str
    opcode: str
    operands: list
    attrs: str
    is_root: bool = False


@dataclass
class Computation:
    name: str
    instrs: list = field(default_factory=list)
    symbols: dict = field(default_factory=dict)   # %name -> shape str


_COMP_HDR = re.compile(r"^(?:ENTRY )?(%[\w\.\-]+|[\w\.\-]+) \(.*\)(?: -> .*)? {")
_INST_HEAD = re.compile(r"^\s+(ROOT\s+)?(%[\w\.\-]+) = ")


def _parse_instr_line(line: str):
    """Parse '  %name = SHAPE opcode(operands), attrs' robustly (tuple
    shapes may contain spaces and '=' inside /*index=N*/ comments)."""
    m = _INST_HEAD.match(line)
    if not m:
        return None
    is_root = m.group(1) is not None
    name = m.group(2)
    rest = line[m.end():]
    if rest.startswith("("):              # tuple shape: balanced-paren scan
        depth = 0
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
        shape, rest = rest[:i + 1], rest[i + 1:].lstrip()
    else:
        sp = rest.find(" ")
        if sp < 0:
            return None
        shape, rest = rest[:sp], rest[sp + 1:]
    par = rest.find("(")
    if par < 0:
        return None
    opcode = rest[:par]
    argstr = rest[par + 1:]
    return name, shape, opcode, argstr, is_root


def _operand_name(token: str):
    """'%name' from an operand token — either bare ('%Arg_0.1') or typed
    ('f32[8,16]{1,0} %Arg_0.1', the form newer XLA emits)."""
    for part in token.split():
        if part.startswith("%"):
            return part
    return None


def _top_level_operands(argstr: str):
    """Extract top-level operand names from 'a, b, c), attrs...'."""
    out, depth = [], 0
    token = ""
    for ch in argstr:
        if ch in "({[":
            depth += 1
        elif ch in ")}]":
            if depth == 0:
                break
            depth -= 1
        if ch == "," and depth == 0:
            name = _operand_name(token)
            if name:
                out.append(name)
            token = ""
        else:
            token += ch
    name = _operand_name(token)
    if name:
        out.append(name)
    return out


def parse_hlo_module(text: str):
    comps = {}
    cur = None
    for line in text.splitlines():
        if cur is None:
            m = _COMP_HDR.match(line)
            if m:
                name = m.group(1)
                if not name.startswith("%"):
                    name = "%" + name
                cur = Computation(name=name)
            continue
        if line.startswith("}"):
            comps[cur.name] = cur
            cur = None
            continue
        parsed = _parse_instr_line(line)
        if parsed is None:
            continue
        name, shape, opcode, rest, is_root = parsed
        inst = Instr(name=name, shape=shape, opcode=opcode,
                     operands=_top_level_operands(rest), attrs=rest,
                     is_root=is_root)
        cur.instrs.append(inst)
        cur.symbols[name] = shape
    if cur is not None:
        comps[cur.name] = cur
    return comps


_CALLS = re.compile(r"(?:calls|to_apply|body|condition|branch_computations)="
                    r"(%[\w\.\-]+|\{[^}]*\})")
_ATTN_SCOPE = "flash_attn_interior"
_TRIP = re.compile(r'known_trip_count"?:\s*{"?n"?:\s*"?(\d+)')
_DOT_LHS_C = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")


def _dot_flops(inst: Instr, symbols: dict) -> float:
    out_numel, _ = _shape_numel_bytes(inst.shape)
    if not inst.operands:
        return 0.0
    lhs_shape = symbols.get(inst.operands[0], "")
    m = _DOT_LHS_C.search(inst.attrs)
    dims_m = _SHAPE_RE.search(lhs_shape)
    if not (m and dims_m):
        return 2.0 * out_numel
    lhs_dims = [int(d) for d in dims_m.group(2).split(",") if d]
    k = 1
    for i in (int(x) for x in m.group(1).split(",") if x):
        if i < len(lhs_dims):
            k *= lhs_dims[i]
    return 2.0 * out_numel * k


def _mem_traffic(op: str, out_bytes: int, opnd_bytes: list) -> float:
    """Per-op HBM traffic model (in-place aware).

    XLA aliases dynamic-update-slice and loop carries in place: traffic is
    the touched REGION, not the carried buffer.  Slices/gathers read only
    the sliced region.  Reductions read their full inputs."""
    if op in ("dynamic-slice", "slice", "gather"):
        return 2.0 * out_bytes
    if op == "dynamic-update-slice":
        upd = opnd_bytes[1] if len(opnd_bytes) > 1 else out_bytes
        return 2.0 * upd
    if op in ("broadcast", "iota"):
        return float(out_bytes)
    if op in ("reduce", "reduce-window", "sort", "scatter",
              "select-and-scatter"):
        return float(sum(opnd_bytes) + out_bytes)
    if op in ("fusion", "dot", "convolution", "custom-call", "call"):
        return float(sum(opnd_bytes) + out_bytes)
    # elementwise / copies / transposes: same-size streams
    return float(out_bytes + sum(min(b, out_bytes) for b in opnd_bytes))


_PARAM_IDX = re.compile(r"^(\d+)\)")


def _fusion_label(fused: "Computation") -> str:
    ops = {i.opcode for i in fused.instrs}
    for marker in ("dot", "dynamic-update-slice", "gather", "scatter",
                   "reduce", "transpose", "exponential"):
        if marker in ops:
            return f"fusion[{marker}]"
    return "fusion"


def _fusion_input_traffic(fused: "Computation", opnd_list: list) -> float:
    """Bytes actually READ by a fusion:
    - a parameter consumed only by (dynamic-)slice/gather ops touches just
      the sliced region (the XLA scan idiom carries whole buffers but reads
      one slice per trip);
    - a parameter that is only the TARGET (operand 0) of dynamic-update-slice
      ops is aliased in place — 0 read bytes."""
    total = 0.0
    for inst in fused.instrs:
        if inst.opcode != "parameter":
            continue
        m = _PARAM_IDX.match(inst.attrs)
        idx = int(m.group(1)) if m else -1
        full = opnd_list[idx] if 0 <= idx < len(opnd_list) else 0
        consumers = [i for i in fused.instrs if inst.name in i.operands]
        if consumers and all(c.opcode in ("dynamic-slice", "slice", "gather")
                             for c in consumers):
            touched = sum(_shape_numel_bytes(c.shape)[1] for c in consumers)
            total += min(touched, full)
        elif consumers and all(
                c.opcode == "dynamic-update-slice" and c.operands
                and c.operands[0] == inst.name for c in consumers):
            total += 0.0
        else:
            total += full
    return total


def _resolve_through_bitcast(fused: "Computation", name: str) -> "Instr | None":
    inst = next((i for i in fused.instrs if i.name == name), None)
    seen = 0
    while inst is not None and inst.opcode in ("bitcast", "copy") and seen < 8:
        if not inst.operands:
            break
        inst = next((i for i in fused.instrs if i.name == inst.operands[0]), None)
        seen += 1
    return inst


def _fusion_output_traffic(fused: "Computation", out_bytes: int) -> float:
    """Bytes actually WRITTEN by a fusion: dynamic-update-slice roots are
    in-place — only the update region is written."""
    root = next((i for i in fused.instrs if i.is_root), None)
    if root is None:
        return float(out_bytes)

    def written(inst) -> float:
        inst = _resolve_through_bitcast(fused, inst.name)
        if inst is None:
            return 0.0
        if inst.opcode == "dynamic-update-slice" and len(inst.operands) > 1:
            upd = _resolve_through_bitcast(fused, inst.operands[1])
            if upd is not None:
                return float(_shape_numel_bytes(upd.shape)[1])
            return float(_shape_numel_bytes(inst.shape)[1])
        return float(_shape_numel_bytes(inst.shape)[1])

    if root.opcode == "tuple":
        return sum(written(next((i for i in fused.instrs if i.name == o),
                                root))
                   for o in root.operands if o.startswith("%"))
    return min(written(root), float(out_bytes))


@dataclass
class Cost:
    flops: float = 0.0
    mem_bytes: float = 0.0
    coll_link_bytes: float = 0.0
    coll_ops: dict = field(default_factory=lambda: defaultdict(float))
    mem_by_op: dict = field(default_factory=lambda: defaultdict(float))

    def scaled(self, k: float):
        c = Cost(self.flops * k, self.mem_bytes * k, self.coll_link_bytes * k)
        c.coll_ops = defaultdict(float, {n: v * k for n, v in self.coll_ops.items()})
        c.mem_by_op = defaultdict(float, {n: v * k for n, v in self.mem_by_op.items()})
        c.attn_interior_bytes = self.attn_interior_bytes * k
        return c

    def add(self, o: "Cost"):
        self.flops += o.flops
        self.mem_bytes += o.mem_bytes
        self.coll_link_bytes += o.coll_link_bytes
        for n, v in o.coll_ops.items():
            self.coll_ops[n] += v
        for n, v in o.mem_by_op.items():
            self.mem_by_op[n] += v
        self.attn_interior_bytes += o.attn_interior_bytes

    attn_interior_bytes: float = 0.0

    def mem_add(self, op: str, v: float, attn: bool = False):
        self.mem_bytes += v
        self.mem_by_op["attn_interior" if attn else op] += v
        if attn:
            self.attn_interior_bytes += v


def analyze_hlo(text: str, entry: str | None = None,
                transcendental_weight: float = 4.0) -> dict:
    comps = parse_hlo_module(text)
    memo: dict[str, Cost] = {}

    # ENTRY computation: the one referenced by none / or marked ENTRY in text
    entry_name = entry
    if entry_name is None:
        m = re.search(r"^ENTRY (%?[\w\.\-]+)", text, re.M)
        if m:
            entry_name = m.group(1)
            if not entry_name.startswith("%"):
                entry_name = "%" + entry_name
        else:
            entry_name = next(iter(comps))

    def comp_cost(name: str, inside_fusion: bool = False) -> Cost:
        if name in memo:
            return memo[name]
        comp = comps.get(name)
        total = Cost()
        if comp is None:
            return total
        memo[name] = total      # guard cycles
        for inst in comp.instrs:
            op = inst.opcode
            out_numel, out_bytes = _shape_numel_bytes(inst.shape)
            opnd_list = [_shape_numel_bytes(comp.symbols.get(o, ""))[1]
                         for o in inst.operands]
            in_attn = _ATTN_SCOPE in inst.attrs

            if op in _FREE:
                continue

            coll = next((c for c in _COLLECTIVES
                         if op == c or (op.startswith(c) and not op.endswith("-done"))), None)
            if coll:
                total.coll_link_bytes += out_bytes * _LINK_FACTOR[coll]
                total.coll_ops[coll] += out_bytes
                if not inside_fusion:
                    total.mem_add(coll, out_bytes + sum(opnd_list))
                continue

            if op == "while":
                trips = 1.0
                m = _TRIP.search(inst.attrs)
                if m:
                    trips = float(m.group(1))
                body = cond = None
                mb = re.search(r"body=(%[\w\.\-]+)", inst.attrs)
                mc = re.search(r"condition=(%[\w\.\-]+)", inst.attrs)
                if mb:
                    total.add(comp_cost(mb.group(1)).scaled(trips))
                if mc:
                    total.add(comp_cost(mc.group(1)).scaled(trips))
                continue

            if op in ("call", "custom-call", "fusion", "map", "conditional",
                      "sort", "reduce", "reduce-window", "scatter",
                      "select-and-scatter"):
                # recurse into called computations (fusion interiors count
                # flops only; memory is boundary-level)
                fused_comp = None
                for m in _CALLS.finditer(inst.attrs):
                    tgt = m.group(1)
                    tgts = ([tgt] if tgt.startswith("%")
                            else re.findall(r"%[\w\.\-]+", tgt))
                    for t in tgts:
                        sub = comp_cost(t, inside_fusion=True)
                        if op in ("fusion", "call", "conditional", "custom-call"):
                            total.flops += sub.flops
                            total.coll_link_bytes += sub.coll_link_bytes
                            for n, v in sub.coll_ops.items():
                                total.coll_ops[n] += v
                            fused_comp = comps.get(t)
                        # map/reduce/scatter sub-computations are per-element
                        # scalar lambdas: folded into the elementwise estimate
                if op in ("reduce", "reduce-window"):
                    total.flops += float(
                        sum(_shape_numel_bytes(comp.symbols.get(o, ""))[0]
                            for o in inst.operands) / max(len(inst.operands), 1))
                if not inside_fusion:
                    if op == "fusion" and fused_comp is not None:
                        label = _fusion_label(fused_comp)
                        fused_attn = in_attn or any(
                            _ATTN_SCOPE in i.attrs for i in fused_comp.instrs)
                        total.mem_add(label, (
                            _fusion_output_traffic(fused_comp, out_bytes)
                            + _fusion_input_traffic(fused_comp, opnd_list)),
                            attn=fused_attn)
                    else:
                        total.mem_add(op, _mem_traffic(op, out_bytes, opnd_list),
                                      attn=in_attn)
                continue

            if op == "dot":
                total.flops += _dot_flops(inst, comp.symbols)
                if not inside_fusion:
                    total.mem_add("dot", _mem_traffic(op, out_bytes, opnd_list),
                                  attn=in_attn)
                continue
            if op == "convolution":
                total.flops += 2.0 * out_numel * 32  # rough; unused by our models
                if not inside_fusion:
                    total.mem_add("convolution", _mem_traffic(op, out_bytes, opnd_list))
                continue

            if op in _TRANSCENDENTAL:
                total.flops += out_numel * transcendental_weight
            elif op in _ELEMENTWISE or op == "convert":
                total.flops += out_numel
            # memory-touching ops at materialization boundaries
            if not inside_fusion and (
                    op in _MEMORY_OPS or op in _ELEMENTWISE
                    or op in _TRANSCENDENTAL or op == "convert"):
                total.mem_add(op, _mem_traffic(op, out_bytes, opnd_list),
                              attn=in_attn)
        return total

    c = comp_cost(entry_name)
    top_mem = dict(sorted(c.mem_by_op.items(), key=lambda kv: -kv[1])[:12])
    return {
        "flops": c.flops,
        "mem_bytes": c.mem_bytes,
        "coll_link_bytes": c.coll_link_bytes,
        "coll_output_bytes_per_op": dict(c.coll_ops),
        "mem_bytes_by_op": top_mem,
        "attn_interior_bytes": c.attn_interior_bytes,
        "entry": entry_name,
        "n_computations": len(comps),
    }
