"""Fault-tolerant checkpointing (no orbax in this environment).

Guarantees:
  - ATOMIC: a checkpoint directory appears only when complete (tmp dir +
    os.replace); a crash mid-save never corrupts the latest checkpoint.
  - ASYNC: saves run on a background thread; ``wait()`` joins before exit.
  - ELASTIC RESTORE: tensors are stored as full logical arrays; restore
    accepts target ShapeDtypeStructs/shardings, so a run may resume on a
    different mesh shape (re-sharding happens on first use under jit).
  - GC: keeps the most recent ``keep_n`` checkpoints.

Format: one ``arrays.npz`` (flat name -> ndarray) + ``manifest.msgpack``
(tree structure, shapes, dtypes, step, user metadata).
"""

from __future__ import annotations

import os
import re
import shutil
import threading
from concurrent.futures import ThreadPoolExecutor

import msgpack
import numpy as np
import jax

from repro.utils.tree import flatten_with_names

_STEP_DIR = re.compile(r"^step_(\d+)$")


def _tree_to_flat(tree):
    flat = flatten_with_names(tree)
    names = [n for n, _ in flat]
    arrays = {n: np.asarray(jax.device_get(x)) for n, x in flat}
    treedef = jax.tree.structure(tree)
    return names, arrays, treedef


class CheckpointManager:
    """``namespace`` scopes a manager to a subdirectory of ``directory``
    — the session pool gives every member session its own namespace
    (``s000``, ``s001``, ...) so per-session checkpoints never collide
    while sharing one ``--checkpoint-dir`` root (docs/SERVICE.md)."""

    def __init__(self, directory: str, keep_n: int = 3,
                 namespace: str | None = None):
        if namespace is not None:
            if os.sep in namespace or namespace.startswith("."):
                raise ValueError(f"bad checkpoint namespace {namespace!r}")
            directory = os.path.join(directory, namespace)
        self.dir = directory
        self.keep_n = keep_n
        os.makedirs(directory, exist_ok=True)
        self._pool = ThreadPoolExecutor(max_workers=1)
        self._pending = None
        self._lock = threading.Lock()

    # ------------------------------------------------------------- save
    def save(self, step: int, tree, metadata: dict | None = None,
             blocking: bool = False):
        names, arrays, _ = _tree_to_flat(tree)
        manifest = {
            "step": int(step),
            "names": names,
            "shapes": {n: list(arrays[n].shape) for n in names},
            "dtypes": {n: str(arrays[n].dtype) for n in names},
            "metadata": metadata or {},
        }

        def _write():
            tmp = os.path.join(self.dir, f".tmp_step_{step}")
            final = os.path.join(self.dir, f"step_{step}")
            if os.path.exists(tmp):
                shutil.rmtree(tmp)
            os.makedirs(tmp)
            np.savez(os.path.join(tmp, "arrays.npz"),
                     **{n.replace("/", "|"): a for n, a in arrays.items()})
            with open(os.path.join(tmp, "manifest.msgpack"), "wb") as f:
                f.write(msgpack.packb(manifest))
            if os.path.exists(final):
                shutil.rmtree(final)
            os.replace(tmp, final)          # atomic publish
            self._gc()
            return final

        with self._lock:
            self.wait()
            if blocking:
                return _write()
            self._pending = self._pool.submit(_write)
            return None

    def wait(self):
        if self._pending is not None:
            self._pending.result()
            self._pending = None

    def _gc(self):
        steps = sorted(self.all_steps())
        for s in steps[:-self.keep_n] if self.keep_n else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"), ignore_errors=True)

    # ---------------------------------------------------------- restore
    def all_steps(self):
        out = []
        for name in os.listdir(self.dir):
            m = _STEP_DIR.match(name)
            if m and os.path.exists(os.path.join(self.dir, name, "manifest.msgpack")):
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self):
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, template, step: int | None = None):
        """template: a pytree (arrays or ShapeDtypeStructs) giving structure.
        Returns (tree, step, metadata) or (None, None, None) if empty."""
        self.wait()
        if step is None:
            step = self.latest_step()
        if step is None:
            return None, None, None
        path = os.path.join(self.dir, f"step_{step}")
        with open(os.path.join(path, "manifest.msgpack"), "rb") as f:
            manifest = msgpack.unpackb(f.read())
        npz = np.load(os.path.join(path, "arrays.npz"))
        by_name = {n: npz[n.replace("/", "|")] for n in manifest["names"]}

        tmpl_flat = flatten_with_names(template)
        leaves = []
        for name, t in tmpl_flat:
            if name not in by_name:
                raise KeyError(f"checkpoint {path} missing tensor {name!r}")
            a = by_name[name]
            want = tuple(t.shape)
            if tuple(a.shape) != want:
                raise ValueError(
                    f"{name}: checkpoint shape {a.shape} != template {want}")
            leaves.append(a.astype(t.dtype))
        tree = jax.tree.unflatten(jax.tree.structure(template), leaves)
        return tree, manifest["step"], manifest["metadata"]
