"""What-if queries: fork the live carry into a jitted rollout.

An operator deciding whether (or when) to submit a job wants the
projected consequences — wait, system, cap headroom — WITHOUT committing
the submission.  ``whatif`` copies the dispatcher's context, writes the
hypothetical job into the next free slot of the (functionally-updated)
job arrays, and folds the SAME factored step the live session runs
through a fixed-length ``lax.scan`` from the CURRENT carry.  Everything
is functional: the live carry and job arrays are never written
(tests/test_service.py pins snapshot equality), and the projection is
exactly what the session would realize if the job were submitted now and
no other job arrived after it.

The rollout is jitted once per dispatcher (fixed scan length from the
session capacity), so repeated queries cost microseconds, not a
recompile.
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.engine import BIG, UNCAPPED, _event_results, event_context


def _rollout_fn(disp):
    """Build (once) and cache the dispatcher's jitted what-if rollout."""
    fn = getattr(disp, "_whatif_rollout", None)
    if fn is None:
        step = disp._step_fn
        mult = (9 if disp._retries else 5) \
            if disp.policy.queue == "conservative" \
            else (7 if disp._retries else 4)
        T = mult * disp.capacity + disp._n_out + 4
        hor = jnp.float32(BIG)

        @jax.jit
        def fn(ctx, carry):
            return jax.lax.scan(lambda c, _: step(ctx, c, hor), carry,
                                None, length=T)
        disp._whatif_rollout = fn
    return fn


def whatif(disp, prog: int, arrival: float | None = None,
           k: float | None = None) -> dict:
    """Project submitting ``prog`` at ``arrival`` (default: now) into the
    live session, without mutating it.  Returns the hypothetical job's
    projected placement (system, start, wait, finish), the session-level
    projections (mean wait over all submitted + hypothetical jobs,
    makespan, peak power), and the cap headroom at the projected peak
    (``inf`` when uncapped)."""
    if disp.n_submitted >= disp.capacity:
        raise RuntimeError("session full: no free slot for a what-if job")
    if not 0 <= int(prog) < disp.w.T_true.shape[0]:
        raise ValueError(f"prog {prog} not in the facility catalog")
    t = float(disp.now if arrival is None else arrival)
    if t < disp.now:
        raise ValueError(f"arrival {t} is in the past (now={disp.now})")

    j = disp.n_submitted
    arrs = dict(disp._arrs)
    arrs["prog"] = arrs["prog"].at[j].set(int(prog))
    arrs["arrival"] = arrs["arrival"].at[j].set(t)
    arrs["k_job"] = arrs["k_job"].at[j].set(
        np.nan if k is None else float(k))
    ctx = event_context(arrs, disp.policy, disp.seed, disp._fvec)

    carry_f, ys = _rollout_fn(disp)(ctx, disp._carry)
    proj = _event_results(arrs, False, ys, carry_f)
    proj = jax.device_get(proj)

    # decided channels of already-finished jobs are zeros in the rollout's
    # scatter (their steps pre-date the fork) — splice the realized values
    n = j + 1
    wait = np.asarray(disp._wait[:n], np.float32).copy()
    fin = np.asarray(disp._fin[:n], np.float32).copy()
    live_done = fin > 0
    wait[~live_done] = proj["wait"][:n][~live_done]
    fin[~live_done] = proj["finish"][:n][~live_done]

    cap = float(np.asarray(disp.policy.power_cap).reshape(-1)[0])
    peak = float(proj["peak_power"])
    return {
        "job": {"prog": int(prog), "arrival": t,
                "system": int(proj["system"][j]),
                "start": float(proj["start"][j]),
                "wait": float(proj["wait"][j]),
                "finish": float(proj["finish"][j]),
                "backfilled": bool(proj["backfilled"][j])},
        "mean_wait": float(wait.mean()) if n else 0.0,
        "makespan": float(fin.max()) if n else 0.0,
        "peak_power": peak,
        "cap_headroom": float("inf") if cap >= UNCAPPED else cap - peak,
    }
