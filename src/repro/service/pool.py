"""Multi-session scale-out: N facility sessions, ONE vmapped step.

``SessionPool`` holds N concurrent ``Dispatcher`` sessions as one
stacked carry pytree and advances all of them with a single jitted
``jax.vmap`` of the factored event step — one compile serves the whole
pool because sessions may differ only in policy LEAVES (K, power cap,
frequency weight, per-session seed streams), never in static
composition (queue discipline, window, tier grid, placer, retry mode).
The jit cache is asserted after every drive: a retrace means a session
broke that contract.

Intake is BATCHED: ``submit`` buffers per session and the buffer is
flushed in one scatter into the stacked job arrays when that session is
next driven (``drive``/``drain``) or read (``result``/``whatif``/
``save``).  Lanes that are not being driven hold their last horizon and
their job arrays untouched, so their steps are carry no-ops — each
session's decision sequence stays bit-identical to an independent
``Dispatcher`` fed the same stream (tests/test_service_pool.py).

Decision records and non-blocking checkpoints flow through one
``AsyncWriter`` thread (bounded queue, drain-on-close), so intake never
blocks on disk.  Checkpoints are namespaced per session (``s000``,
``s001``, ...) under one ``checkpoint_dir`` root; ``restore`` brings
any or all sessions back bit-identically.  See docs/SERVICE.md.
"""

from __future__ import annotations

import json
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.engine import (BIG, Scheduler, Workload, event_context,
                               index_session, stack_sessions)
from repro.service.dispatcher import Dispatcher
from repro.service.whatif import whatif as _whatif
from repro.service.writer import AsyncWriter


class SessionPool:
    """N live scheduling sessions advanced by one jitted vmapped step.

    ``scheds`` is one batch ``Scheduler`` per session (the same unified
    spec ``Dispatcher.from_scheduler`` adopts); all must share one
    static composition — same queue discipline, window, placer, tier
    grid, fault/retry mode and capacity — while leaves (K, power cap,
    freq weight) and seeds may differ per session.  ``decision_log``
    arms an append-only JSONL sink (``{"session": i, ...decision}`` per
    line) written by the async writer thread.
    """

    def __init__(self, scheds, w: Workload, *, capacity: int | None = None,
                 checkpoint_dir: str | None = None, keep_n: int = 3,
                 decision_log: str | None = None, writer_queue: int = 256):
        scheds = list(scheds)
        if not scheds:
            raise ValueError("a pool needs at least one session")
        self.w = w
        self.sessions = [
            Dispatcher.from_scheduler(
                s, w, capacity=capacity, checkpoint_dir=checkpoint_dir,
                keep_n=keep_n, checkpoint_namespace=f"s{i:03d}")
            for i, s in enumerate(scheds)]
        self.n = len(self.sessions)

        d0 = self.sessions[0]
        ref = jax.tree.structure(d0.policy)
        for i, d in enumerate(self.sessions[1:], start=1):
            if jax.tree.structure(d.policy) != ref:
                raise ValueError(
                    f"session {i} breaks the pool's static composition: "
                    f"policy metadata (queue/window/tiers/...) must match "
                    f"session 0 — only leaves (k, power_cap, freq_weight, "
                    f"ucb_scale) may differ")
            if (d.placer != d0.placer or d._retries != d0._retries
                    or d.capacity != d0.capacity
                    or d.warm_start != d0.warm_start):
                raise ValueError(
                    f"session {i} differs from session 0 in placer/retry/"
                    f"capacity/warm-start — those are static, one compile "
                    f"covers one composition")
        self.capacity = d0.capacity
        self._n_out = d0._n_out

        # ONE step for the whole pool: vmap over (policy leaves, ctx,
        # carry, horizon); the builder re-runs under trace with the
        # leaf-batched policy, metadata stays static -> one compile.
        build, placer, retries = d0._build_step, d0.placer, d0._retries

        def _lane(pol, ctx, carry, hor):
            return build(pol, placer, totals_only=False,
                         retries=retries)(ctx, carry, hor)

        self._step = jax.jit(jax.vmap(_lane, in_axes=(0, 0, 0, 0)))

        self._restack()
        self._horizons = np.zeros(self.n, np.float32)
        self._buffers: list[list] = [[] for _ in range(self.n)]
        self.n_pool_steps = 0
        self.wall_us_total = 0.0
        self.wall_us_max = 0.0
        self._writer = AsyncWriter(maxsize=writer_queue)
        self._log_f = open(decision_log, "a") if decision_log else None

    @classmethod
    def replicate(cls, sched: Scheduler, n: int, w: Workload,
                  **kw) -> "SessionPool":
        """N sessions of one configuration (the ``--pool N`` CLI path)."""
        return cls([sched] * int(n), w, **kw)

    # ----------------------------------------------------- stacked state
    def _restack(self):
        """Rebuild the pool's stacked pytrees from the member sessions
        (construction and restore; members are authoritative there)."""
        ds = self.sessions
        self._pol = stack_sessions([d.policy for d in ds])
        self._ctx = stack_sessions([d._ctx for d in ds])
        self._carry = stack_sessions([d._carry for d in ds])

    def _flush(self, idxs) -> int:
        """Scatter the buffered submissions of the given sessions into
        the stacked job arrays — ONE scatter per channel regardless of
        how many jobs or sessions flush — then sync those members.
        Un-flushed lanes' arrays are untouched, so their steps stay
        no-ops."""
        si, ji, progv, tv, kv, touched = [], [], [], [], [], []
        for i in idxs:
            buf = self._buffers[i]
            if not buf:
                continue
            touched.append(i)
            base = self.sessions[i].n_submitted
            for off, (p, t, k) in enumerate(buf):
                si.append(i)
                ji.append(base + off)
                progv.append(p)
                tv.append(t)
                kv.append(np.nan if k is None else float(k))
        if not touched:
            return 0
        si = np.asarray(si, np.int32)
        ji = np.asarray(ji, np.int32)
        arrs = self._ctx["arrs"]
        prog = arrs["prog"].at[si, ji].set(np.asarray(progv, np.int32))
        arrival = arrs["arrival"].at[si, ji].set(np.asarray(tv, np.float32))
        k_job = arrs["k_job"].at[si, ji].set(np.asarray(kv, np.float32))
        # the stacked twin of event_context's kvec (same elementwise
        # where, so each lane matches its member's own rebuild bitwise)
        kvec = jnp.where(jnp.isnan(k_job),
                         jnp.asarray(self._pol.k, jnp.float32)[:, None],
                         k_job)
        self._ctx = {**self._ctx, "kvec": kvec,
                     "arrs": {**arrs, "prog": prog, "arrival": arrival,
                              "k_job": k_job}}
        for i in touched:
            d = self.sessions[i]
            d._arrs["prog"] = prog[i]
            d._arrs["arrival"] = arrival[i]
            d._arrs["k_job"] = k_job[i]
            d._ctx = event_context(d._arrs, d.policy, d.seed, d._fvec)
            d.n_submitted += len(self._buffers[i])
            self._buffers[i].clear()
        return len(si)

    # ------------------------------------------------------------ intake
    def submit(self, session: int, prog: int, arrival: float | None = None,
               k: float | None = None) -> int:
        """Buffer one submission for ``session`` (validated now, flushed
        in one scatter at that session's next drive/read).  Returns the
        job id — assigned immediately, intake never waits on the pool."""
        i = int(session)
        d = self.sessions[i]
        buf = self._buffers[i]
        t = float(d.now if arrival is None else arrival)
        last = float(buf[-1][1]) if buf else None
        d._validate_intake(prog, t, queued=len(buf), last=last)
        j = d.n_submitted + len(buf)
        buf.append((int(prog), t, k))
        d.metrics.observe_submit()
        return j

    # ------------------------------------------------------------- drive
    def _run(self):
        """Step the whole pool until globally quiescent under the
        per-session horizon vector, folding each lane's decision channels
        into its member session."""
        hor = jnp.asarray(self._horizons)
        limit = 16 * self.capacity + self._n_out + 64
        ds = self.sessions
        for _ in range(limit):
            t0 = time.perf_counter()
            carry, out = self._step(self._pol, self._ctx, self._carry, hor)
            out = jax.device_get(out)
            dt_us = (time.perf_counter() - t0) * 1e6
            self._carry = carry
            self.n_pool_steps += 1
            self.wall_us_total += dt_us
            self.wall_us_max = max(self.wall_us_max, dt_us)
            share = dt_us / self.n       # amortized per-session step cost
            progress = False
            for i, d in enumerate(ds):
                oi = {key: val[i] for key, val in out.items()}
                d._record(oi)
                d.metrics.observe_step(oi, share)
                progress = (progress or bool(oi["pushed"])
                            or bool(oi["placed"]) or bool(oi["advanced"]))
            if not progress:
                break
        else:
            raise RuntimeError("pool drive exceeded its step budget — a "
                               "lane's carry is diverging (engine bug)")
        for i, d in enumerate(ds):
            d._carry = index_session(self._carry, i)
        size = getattr(self._step, "_cache_size", lambda: 1)()
        if size > 1:
            raise RuntimeError(
                f"pool step retraced ({size} compiles): sessions were "
                f"promised to share one static composition")

    def drive(self, until: float = BIG, session: int | None = None):
        """Advance sessions to ``until``: all of them (returns
        ``{session: [decisions]}``) or one (returns its decisions).
        Other lanes hold their last horizon — no-op steps, no state
        drift."""
        idxs = list(range(self.n)) if session is None else [int(session)]
        self._flush(idxs)
        for i in idxs:
            self._horizons[i] = np.float32(until)
        n0 = [len(d.decisions) for d in self.sessions]
        self._run()
        new = {i: list(self.sessions[i].decisions[n0[i]:])
               for i in range(self.n)}
        self._log_decisions(new)
        return new[int(session)] if session is not None else new

    def drain(self, session: int | None = None):
        """Run sessions to completion (open horizon)."""
        return self.drive(BIG, session)

    def _log_decisions(self, new: dict):
        if self._log_f is None:
            return
        for i in sorted(new):
            for dec in new[i]:
                line = json.dumps({"session": i, **dec}) + "\n"
                self._writer.submit(self._log_f.write, line)

    # ----------------------------------------------------------- queries
    def now(self, session: int) -> float:
        return self.sessions[int(session)].now

    def metrics(self, session: int) -> dict:
        return self.sessions[int(session)].metrics.snapshot()

    def result(self, session: int):
        """The realized ``SimResult`` of one session (buffer flushed
        first — a submitted job is part of the session even before its
        lane is driven)."""
        i = int(session)
        self._flush([i])
        return self.sessions[i].result()

    def whatif(self, session: int, prog: int, arrival: float | None = None,
               k: float | None = None) -> dict:
        """Project a hypothetical submission into one session — served
        from that member's cached jitted fork, the pool never stalls."""
        i = int(session)
        self._flush([i])
        return _whatif(self.sessions[i], prog, arrival, k)

    @property
    def mean_step_us(self) -> float:
        """Mean wall-clock of one pool step (all N lanes advance)."""
        return self.wall_us_total / max(self.n_pool_steps, 1)

    # -------------------------------------------------------- checkpoint
    def save(self, session: int | None = None, blocking: bool = True):
        """Checkpoint one session (returns its step id) or all (list of
        ids).  ``blocking=False`` snapshots state now and hands the disk
        write to the async writer thread."""
        idxs = list(range(self.n)) if session is None else [int(session)]
        self._flush(idxs)
        steps = []
        for i in idxs:
            d = self.sessions[i]
            if blocking:
                steps.append(d.save(blocking=True))
            else:
                if d._mgr is None:
                    raise RuntimeError("no checkpoint_dir configured")
                step = d._save_step
                d._save_step = step + 1
                tree = jax.device_get(d._tree())     # snapshot NOW
                meta = {"n_submitted": d.n_submitted,
                        "decisions": list(d.decisions),
                        "metrics": d.metrics.snapshot()}
                self._writer.submit(d._mgr.save, step, tree,
                                    metadata=meta, blocking=True)
                steps.append(step)
        return steps if session is None else steps[0]

    def restore(self, session: int | None = None,
                step: int | None = None):
        """Restore one session (or all) from its namespaced checkpoints;
        the lane resumes bit-identically (tests/test_service_pool.py).
        Returns per-call success (all-True for the pool form)."""
        idxs = list(range(self.n)) if session is None else [int(session)]
        if any(self._buffers[i] for i in idxs):
            raise RuntimeError("restore with buffered submissions pending "
                               "— drive or drop them first")
        self._writer.flush()             # pending async saves land first
        ok = [self.sessions[i].restore(step) for i in idxs]
        for i in idxs:
            self._horizons[i] = np.float32(self.sessions[i].now)
        self._restack()
        return all(ok) if session is None else ok[0]

    # ----------------------------------------------------------- closing
    def close(self):
        """Drain the writer (decision log + async checkpoints) and close
        the log sink.  Idempotent."""
        if self._log_f is not None:
            self._writer.submit(self._log_f.flush)
        self._writer.close()
        if self._log_f is not None:
            self._log_f.close()
            self._log_f = None
        for d in self.sessions:
            if d._mgr is not None:
                d._mgr.wait()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False

    def __repr__(self):
        return (f"SessionPool(n={self.n}, "
                f"queue={self.sessions[0].policy.queue or 'fcfs'!r}, "
                f"capacity={self.capacity}, steps={self.n_pool_steps})")
