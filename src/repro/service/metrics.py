"""Streaming service counters: one cheap fold per dispatcher step.

Everything here is plain python scalars — the metrics stream must stay
readable mid-session without touching device state, and a snapshot must
round-trip through the checkpoint metadata (msgpack) unchanged.
"""

from __future__ import annotations

from dataclasses import asdict, dataclass, fields


@dataclass
class ServiceMetrics:
    """Counters over one dispatcher session.

    Schema (docs/SERVICE.md): step/submission/placement/finish/backfill
    counts, the current queue depth and clock, the running peak cluster
    draw and the draw at the last step, and decision latency (wall-clock
    of one ``step_once``, jit dispatch + device transfer included) as
    last / total / max — mean is derived, never stored.
    """
    n_steps: int = 0
    n_submitted: int = 0
    n_placed: int = 0
    n_finished: int = 0
    n_backfilled: int = 0
    queue_depth: int = 0
    now: float = 0.0
    peak_power: float = 0.0
    cluster_power: float = 0.0
    latency_us_last: float = 0.0
    latency_us_total: float = 0.0
    latency_us_max: float = 0.0

    def observe_submit(self):
        self.n_submitted += 1

    def observe_step(self, out: dict, dt_us: float):
        """Fold one step's decision record (numpy scalars) in."""
        self.n_steps += 1
        self.n_placed += int(out["placed"])
        self.n_finished += int(out["final"])
        self.n_backfilled += int(out["bf"]) if bool(out["final"]) else 0
        self.queue_depth = int(out["qlen"])
        self.now = float(out["now"])
        self.cluster_power = float(out["power"])
        self.peak_power = max(self.peak_power, self.cluster_power)
        self.latency_us_last = dt_us
        self.latency_us_total += dt_us
        self.latency_us_max = max(self.latency_us_max, dt_us)

    @property
    def mean_latency_us(self) -> float:
        return self.latency_us_total / max(self.n_steps, 1)

    def snapshot(self) -> dict:
        """All fields plus the derived mean — the record the CLI emits
        and the checkpoint stores."""
        return {**asdict(self), "mean_latency_us": self.mean_latency_us}

    @classmethod
    def from_snapshot(cls, d: dict) -> "ServiceMetrics":
        keep = {f.name for f in fields(cls)}
        return cls(**{k: v for k, v in d.items() if k in keep})
