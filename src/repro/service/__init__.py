"""Online scheduler service: the event core as a live decision engine.

``Dispatcher`` holds the event-granular scan's carry (node-free / power
tables, pending buffer, reservations) as long-lived state: jobs are
submitted one at a time, the clock is driven through bounded horizons,
and every step emits the placement decision the batch scan would have
made — bit-identically (tests/test_service.py).  ``SessionPool`` scales
that out: N sessions as one stacked carry advanced by a single jitted
vmapped step, with batched intake and an async writer for decision
records and checkpoints (tests/test_service_pool.py).  ``whatif`` forks
the live carry into a jitted rollout for operator queries;
``ServiceMetrics`` streams queue / power / latency counters;
``repro.launch.scheduler_service`` is the JSONL CLI loop (single
session or ``--pool N``).  See docs/SERVICE.md.
"""

from repro.service.dispatcher import Dispatcher
from repro.service.metrics import ServiceMetrics
from repro.service.pool import SessionPool
from repro.service.whatif import whatif
from repro.service.writer import AsyncWriter

__all__ = ["AsyncWriter", "Dispatcher", "ServiceMetrics", "SessionPool",
           "whatif"]
