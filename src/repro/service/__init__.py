"""Online scheduler service: the event core as a live decision engine.

``Dispatcher`` holds the event-granular scan's carry (node-free / power
tables, pending buffer, reservations) as long-lived state: jobs are
submitted one at a time, the clock is driven through bounded horizons,
and every step emits the placement decision the batch scan would have
made — bit-identically (tests/test_service.py).  ``whatif`` forks the
live carry into a jitted rollout for operator queries; ``ServiceMetrics``
streams queue / power / latency counters; ``repro.launch
.scheduler_service`` is the JSONL CLI loop.  See docs/SERVICE.md.
"""

from repro.service.dispatcher import Dispatcher
from repro.service.metrics import ServiceMetrics
from repro.service.whatif import whatif

__all__ = ["Dispatcher", "ServiceMetrics", "whatif"]
