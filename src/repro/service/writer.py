"""Async session I/O: one bounded-queue writer thread.

The session pool must never stall intake on disk — decision records
(JSONL sink) and checkpoint writes are enqueued here and performed by a
single background thread, in submission order.  The queue is BOUNDED:
when the writer falls behind by ``maxsize`` items, ``submit`` blocks —
backpressure, not unbounded memory.  ``close`` drains the queue, joins
the thread, and re-raises the first exception the worker hit (an I/O
error must not be silently swallowed by the background thread).
"""

from __future__ import annotations

import queue
import threading

_STOP = object()


class AsyncWriter:
    """A single worker thread draining a bounded callable queue.

    ``submit(fn, *args, **kwargs)`` enqueues one unit of I/O;
    ``flush()`` blocks until everything enqueued so far has run;
    ``close()`` drains and joins.  The first exception raised by any
    enqueued callable is re-raised at the next ``submit``/``flush``/
    ``close`` call — callers observe failures at the API boundary, in
    order, never lose them.  Context-manager use closes on exit.
    """

    def __init__(self, maxsize: int = 256):
        self._q: queue.Queue = queue.Queue(maxsize=maxsize)
        self._exc: BaseException | None = None
        self._closed = False
        self._thread = threading.Thread(target=self._run, daemon=True,
                                        name="service-writer")
        self._thread.start()

    def _run(self):
        while True:
            item = self._q.get()
            try:
                if item is _STOP:
                    return
                fn, args, kwargs = item
                if self._exc is None:       # fail-stop: skip after error
                    try:
                        fn(*args, **kwargs)
                    except BaseException as e:
                        self._exc = e
            finally:
                self._q.task_done()

    def _check(self):
        if self._exc is not None:
            exc, self._exc = self._exc, None
            raise exc

    def submit(self, fn, *args, **kwargs):
        """Enqueue ``fn(*args, **kwargs)``; blocks when the queue is
        full (bounded backpressure)."""
        if self._closed:
            raise RuntimeError("writer is closed")
        self._check()
        self._q.put((fn, args, kwargs))

    def flush(self):
        """Block until every enqueued callable has run."""
        self._q.join()
        self._check()

    @property
    def depth(self) -> int:
        """Items currently enqueued (approximate; for tests/metrics)."""
        return self._q.qsize()

    def close(self):
        """Drain, stop the worker, join, and surface any pending error.
        Idempotent."""
        if self._closed:
            self._thread.join()
            self._check()
            return
        self._closed = True
        self._q.put(_STOP)
        self._q.join()
        self._thread.join()
        self._check()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False
