"""Long-lived dispatcher: the event core's carry as live service state.

The batch engine folds ``step(ctx, carry, horizon)`` through
``lax.scan``; the dispatcher jits the SAME step once and calls it per
event, holding the carry between calls.  Three operations drive a
session:

  submit(prog, arrival)   register a job (fills the next slot of the
                          capacity-padded job arrays; fixed shapes, so
                          the jitted step never retraces);
  drive(until)            advance the clock through pushes / placements
                          / event hops, never past ``until`` (the step's
                          horizon gate) — returns the decisions emitted;
  drain()                 drive with an open horizon until quiescent.

Fed a workload's stream submit-before-drive-past (each job submitted
before the clock is driven past its arrival), the decision sequence and
final totals are bit-identical to the batch ``Scheduler.run`` — the
extra quiescent steps a live session sees are no-ops on the carry
(asserted in tests/test_service.py).  ``save``/``restore`` persist the
carry + job arrays + realized decisions through ``CheckpointManager``
(atomic npz + msgpack), so a killed session resumes mid-stream with
identical remaining decisions.
"""

from __future__ import annotations

import time
from functools import partial

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.engine import (
    BIG, FaultConfig, Scheduler, Workload, _fault_vec, _power_totals,
    _workload_arrays, cons_carry0, event_carry0, event_context,
    make_cons_step, make_event_step,
)
from repro.core.policy import Policy
from repro.core.result import SimResult
from repro.checkpoint.manager import CheckpointManager
from repro.service.metrics import ServiceMetrics


class Dispatcher:
    """A stateful scheduling session over one facility description.

    ``w`` supplies the program x system tables (runtimes, energies, node
    counts, idle watts, outages); its job stream is only a catalog — the
    session's jobs are whatever ``submit`` registers, up to ``capacity``
    (default: the catalog's length).

    Construction: ``Dispatcher.from_scheduler(sched, w, ...)`` is the
    one path — a configured batch ``Scheduler`` IS the session spec
    (policy with queue/cap/tier knobs applied, placer, fault model,
    seed, warm start), so the live session re-declares nothing.  The
    legacy keyword signature survives as a thin shim that builds the
    ``Scheduler`` for you.  Policy leaves must be scalars (a grid has no
    live interpretation).  ``checkpoint_dir`` arms save/restore
    (``checkpoint_namespace`` sub-scopes it — the pool gives every
    session its own).
    """

    def __init__(self, w: Workload, policy: str | Policy = "paper", *,
                 capacity: int | None = None, seed: int = 0,
                 fault: FaultConfig | None = None, placer: str | None = None,
                 warm_start: bool = False, queue: str | None = None,
                 power_cap=None, checkpoint_dir: str | None = None,
                 keep_n: int = 3):
        # thin forwarding shim: every session knob a Scheduler already
        # declares is declared THERE (ISSUE 9 api_redesign)
        sched = Scheduler(
            policy, placer=placer, faults=fault, seeds=int(seed),
            warm_start=warm_start, queue=queue, power_cap=power_cap)
        self._setup(sched, w, capacity=capacity,
                    checkpoint_dir=checkpoint_dir, keep_n=keep_n)

    @classmethod
    def from_scheduler(cls, sched: Scheduler, w: Workload, *,
                       capacity: int | None = None,
                       seed: int | None = None,
                       checkpoint_dir: str | None = None,
                       keep_n: int = 3,
                       checkpoint_namespace: str | None = None
                       ) -> "Dispatcher":
        """The single construction path (CLI, ``SessionPool``, tests):
        adopt a batch ``Scheduler``'s full configuration as the live
        session spec.  ``seed`` overrides the scheduler's scalar seed;
        grid-valued schedulers (seed/fault axes, leaf-batched policies)
        are rejected — a live session is one point."""
        self = cls.__new__(cls)
        self._setup(sched, w, capacity=capacity, seed=seed,
                    checkpoint_dir=checkpoint_dir, keep_n=keep_n,
                    checkpoint_namespace=checkpoint_namespace)
        return self

    def _setup(self, sched: Scheduler, w: Workload, *,
               capacity=None, seed=None, checkpoint_dir=None, keep_n=3,
               checkpoint_namespace=None):
        if isinstance(sched.faults, tuple):
            raise ValueError("live sessions take one FaultConfig, not a "
                             "fault grid")
        if not isinstance(sched.seeds, (int, np.integer)):
            raise ValueError("live sessions take one seed, not a grid")
        pol = sched.policy
        for leaf in ("k", "ucb_scale", "power_cap", "freq_weight"):
            if np.asarray(getattr(pol, leaf)).ndim:
                raise ValueError(f"live policy leaf {leaf!r} must be a "
                                 "scalar, got a grid")
        self.scheduler = sched
        self.policy = pol
        self.seed = int(sched.seeds if seed is None else seed)
        self.fault = sched.faults
        self.placer = sched.placer
        self.capacity = int(capacity) if capacity else max(len(w.prog), 1)
        self.w = w

        fault = self.fault
        self._fvec = _fault_vec(fault or FaultConfig())
        self._retries = bool(fault and fault.failure_prob > 0)
        arrs = _workload_arrays(w)
        C = self.capacity
        arrs["prog"] = jnp.zeros(C, jnp.int32)
        arrs["arrival"] = jnp.full(C, BIG, jnp.float32)
        arrs["k_job"] = jnp.full(C, jnp.nan, jnp.float32)
        self._arrs = arrs
        self._n_out = (arrs["outage"][..., 1].size
                       if "outage" in arrs else 0)

        P, S = w.T_true.shape
        if sched.warm_start:
            tabs0 = (jnp.asarray(w.C_true), jnp.asarray(w.T_true),
                     jnp.ones((P, S), jnp.int32))
        else:
            tabs0 = (jnp.zeros((P, S)), jnp.zeros((P, S)),
                     jnp.zeros((P, S), jnp.int32))
        self.warm_start = bool(sched.warm_start)
        self._tabs0 = tabs0

        if pol.queue == "conservative":
            build, carry0 = make_cons_step, cons_carry0
        else:
            build, carry0 = make_event_step, event_carry0
        # the pool re-invokes the builder with a leaf-batched policy
        # under vmap — expose it alongside the concrete-leaf closure
        self._build_step = build
        step = build(pol, self.placer, totals_only=False,
                     retries=self._retries)
        self._step_fn = step
        self._step = jax.jit(step)
        # live sessions open at t=0 (the batch scan opens at the first
        # arrival; the extra advances to reach it are carry no-ops)
        self._carry = carry0(self._arrs, pol, tabs0, totals_only=False,
                             now0=0.0)
        self._ctx = event_context(self._arrs, pol, self.seed, self._fvec)

        self.n_submitted = 0
        self.metrics = ServiceMetrics()
        self.decisions: list[dict] = []
        # realized per-job channels, accumulated exactly as
        # ``_event_results`` scatters the scan's ys (f32 adds in step
        # order), so ``result()`` totals match the batch run bitwise
        self._E = np.zeros(C, np.float32)
        self._sys = np.zeros(C, np.int32)
        self._s0 = np.zeros(C, np.float32)
        self._fin = np.zeros(C, np.float32)
        self._wait = np.zeros(C, np.float32)
        self._T = np.ones(C, np.float32)
        self._bf = np.zeros(C, bool)
        self._tier = np.zeros(C, np.int32)

        self._mgr = (CheckpointManager(checkpoint_dir, keep_n=keep_n,
                                       namespace=checkpoint_namespace)
                     if checkpoint_dir else None)
        self._save_step = 0

    # ------------------------------------------------------------ intake
    def _validate_intake(self, prog: int, t: float, *, queued: int = 0,
                         last: float | None = None):
        """The submit-time checks, shared with the pool's buffered
        intake (``queued``/``last`` describe its not-yet-flushed
        buffer)."""
        if self.n_submitted + queued >= self.capacity:
            raise RuntimeError(f"session full: capacity {self.capacity}")
        if not 0 <= int(prog) < self.w.T_true.shape[0]:
            raise ValueError(f"prog {prog} not in the facility catalog "
                             f"(P={self.w.T_true.shape[0]})")
        if t < self.now:
            raise ValueError(f"arrival {t} is in the past (now={self.now})")
        if last is None and self.n_submitted:
            last = float(self._arrs["arrival"][self.n_submitted - 1])
        if last is not None and t < last:
            raise ValueError("submissions must be arrival-ordered")

    def submit(self, prog: int, arrival: float | None = None,
               k: float | None = None) -> int:
        """Register a job: program index, submit time (default: the
        current clock), optional per-job K override.  Returns the job id.
        Submitting an arrival earlier than the clock is an error — the
        past is already decided."""
        t = float(self.now if arrival is None else arrival)
        self._validate_intake(prog, t)
        j = self.n_submitted
        a = self._arrs
        a["prog"] = a["prog"].at[j].set(int(prog))
        a["arrival"] = a["arrival"].at[j].set(t)
        a["k_job"] = a["k_job"].at[j].set(
            np.nan if k is None else float(k))
        self._ctx = event_context(a, self.policy, self.seed, self._fvec)
        self.n_submitted += 1
        self.metrics.observe_submit()
        return j

    # ------------------------------------------------------------- clock
    @property
    def now(self) -> float:
        return float(self._carry.now)

    def step_once(self, horizon: float = BIG) -> dict:
        """One event step under ``horizon``; returns the decision record
        (numpy scalars) and folds it into the metrics stream."""
        t0 = time.perf_counter()
        carry, out = self._step(self._ctx, self._carry,
                                jnp.float32(horizon))
        out = jax.device_get(out)
        dt_us = (time.perf_counter() - t0) * 1e6
        self._carry = carry
        self._record(out)
        self.metrics.observe_step(out, dt_us)
        return out

    def _record(self, out: dict):
        """Fold one step's decision channels into the realized per-job
        arrays — the live twin of the ``_event_results`` scatter."""
        C = self.capacity
        if bool(out["placed"]):
            ja = int(out["j_add"])
            if ja < C:
                self._E[ja] += np.float32(out["E"])
        if bool(out["final"]):
            jf = int(out["j_fin"])
            if jf < C:
                self._sys[jf] = out["sys"]
                self._s0[jf] = out["s0"]
                self._fin[jf] = out["finish"]
                self._wait[jf] = out["wait"]
                self._T[jf] = out["T"]
                self._bf[jf] = out["bf"]
                self._tier[jf] = out["tier"]
                self.decisions.append({
                    "job": jf, "system": int(out["sys"]),
                    "start": float(out["s0"]), "finish": float(out["finish"]),
                    "wait": float(out["wait"]),
                    "backfilled": bool(out["bf"]),
                    "tier": int(out["tier"]),
                    "power": float(out["power"]), "now": float(out["now"]),
                })

    def drive(self, until: float = BIG) -> list[dict]:
        """Step until quiescent under ``until``: no push, no placement,
        no clock advance.  Returns the placement decisions emitted."""
        n0 = len(self.decisions)
        limit = 16 * self.capacity + self._n_out + 64
        for _ in range(limit):
            out = self.step_once(until)
            if not (bool(out["pushed"]) or bool(out["placed"])
                    or bool(out["advanced"])):
                break
        else:
            raise RuntimeError("drive() exceeded its step budget — the "
                               "carry is diverging (engine bug)")
        return self.decisions[n0:]

    def drain(self) -> list[dict]:
        """Run the session to completion (open horizon)."""
        return self.drive(BIG)

    # ------------------------------------------------------------ result
    def result(self) -> SimResult:
        """The realized session as a ``SimResult`` over the submitted
        jobs — totals computed with the batch epilogue's jnp expressions
        over the accumulated per-job channels, under one jit (the power
        totals' multiply-subtract must fuse exactly as it does inside
        the batch scan's graph), so a full session matches
        ``Scheduler.run`` bitwise (tests/test_service.py)."""
        n = self.n_submitted
        arrs, carry = self._arrs, self._carry

        @partial(jax.jit, static_argnames=("n",))
        def totals(E, wait, T_act, finish, busy, peak, cdel, n):
            makespan = finish.max() if n else jnp.float32(0.0)
            return dict(
                total_energy=E.sum(), makespan=makespan,
                total_wait=wait.sum(),
                slowdown_sum=((wait + T_act) / T_act).sum(),
                max_wait=wait.max() if n else jnp.float32(0.0),
                **_power_totals(arrs, makespan, busy, peak, cdel))

        E = jnp.asarray(self._E[:n])
        wait = jnp.asarray(self._wait[:n])
        T_act = jnp.asarray(self._T[:n])
        finish = jnp.asarray(self._fin[:n])
        sel = jnp.asarray(self._sys[:n])
        prog = arrs["prog"][:n]
        tot = totals(E, wait, T_act, finish, carry.busy, carry.peak,
                     carry.cdel, n)
        return SimResult(
            **tot,
            busy=carry.busy, C_tab=carry.C_tab, T_tab=carry.T_tab,
            runs=carry.runs,
            n_backfilled=carry.nbf,
            system=sel, start=jnp.asarray(self._s0[:n]), finish=finish,
            wait=wait, energy=E, runtime=T_act,
            nodes=arrs["n_req"][prog, sel],
            backfilled=jnp.asarray(self._bf[:n]),
            tier=jnp.asarray(self._tier[:n]),
            axes=(), n_jobs=n, n_nodes=np.asarray(self.w.n_nodes),
            programs=self.w.programs, systems=self.w.systems,
            freq_tiers=self.policy.freq_tiers)

    def carry_snapshot(self):
        """Host copy of the live carry (tests pin what-if purity on it)."""
        return jax.device_get(self._carry)

    # -------------------------------------------------------- checkpoint
    def _tree(self):
        return {
            "carry": self._carry,
            "jobs": {k: self._arrs[k]
                     for k in ("prog", "arrival", "k_job")},
            "perjob": {"E": self._E, "sys": self._sys, "s0": self._s0,
                       "fin": self._fin, "wait": self._wait, "T": self._T,
                       "bf": self._bf, "tier": self._tier},
        }

    def save(self, blocking: bool = True) -> int:
        """Checkpoint the session (atomic; see checkpoint/manager.py).
        Returns the checkpoint step id."""
        if self._mgr is None:
            raise RuntimeError("no checkpoint_dir configured")
        step = self._save_step
        self._mgr.save(step, self._tree(), metadata={
            "n_submitted": self.n_submitted,
            "decisions": self.decisions,
            "metrics": self.metrics.snapshot(),
        }, blocking=blocking)
        self._save_step = step + 1
        return step

    def restore(self, step: int | None = None) -> bool:
        """Restore the latest (or a specific) checkpoint into this
        session; returns False when the directory holds none.  The
        resumed session's remaining decisions are bit-identical to an
        uninterrupted run (tests/test_service.py)."""
        if self._mgr is None:
            raise RuntimeError("no checkpoint_dir configured")
        tree, step, meta = self._mgr.restore(self._tree(), step)
        if tree is None:
            return False
        self._carry = jax.tree.map(jnp.asarray, tree["carry"])
        for k in ("prog", "arrival", "k_job"):
            self._arrs[k] = jnp.asarray(tree["jobs"][k])
        self._ctx = event_context(self._arrs, self.policy, self.seed,
                                  self._fvec)
        pj = tree["perjob"]
        self._E, self._sys, self._s0 = pj["E"], pj["sys"], pj["s0"]
        self._fin, self._wait, self._T = pj["fin"], pj["wait"], pj["T"]
        self._bf, self._tier = pj["bf"], pj["tier"]
        self.n_submitted = int(meta["n_submitted"])
        self.decisions = list(meta["decisions"])
        self.metrics = ServiceMetrics.from_snapshot(meta["metrics"])
        self._save_step = step + 1
        return True

    def __repr__(self):
        return (f"Dispatcher(queue={self.policy.queue or 'fcfs'!r}, "
                f"jobs={self.n_submitted}/{self.capacity}, "
                f"now={self.now:.1f}, placed={len(self.decisions)})")
