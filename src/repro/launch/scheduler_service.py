"""Online scheduler service CLI: a JSONL decision loop on stdin/stdout.

Each input line is one JSON request; each response is one JSON line —
the shape a facility's submission portal (or the CI smoke) scripts
against.  Options come from the shared grammar (repro.core.cliargs):
``--policy name:key=val,...``, ``--queue DISC:window=W``,
``--power-cap``, fault probabilities.  Example single session::

    PYTHONPATH=src python -m repro.launch.scheduler_service \
        --queue easy_backfill:window=8 --power-cap 60000 \
        --checkpoint-dir /tmp/sched_ck <<'EOS'
    {"op": "submit", "prog": "BT", "arrival": 0.0}
    {"op": "submit", "prog": "LU", "arrival": 5.0}
    {"op": "drive", "until": 100.0}
    {"op": "whatif", "prog": "SP"}
    {"op": "checkpoint"}
    {"op": "drain"}
    {"op": "metrics"}
    {"op": "result"}
    EOS

Operations (all responses carry ``"ok"``; errors report ``"error"`` and
leave the session state untouched):

    submit   {"prog": name|index, "arrival"?: t, "k"?: f} -> {"job": id}
    drive    {"until": t} -> {"decisions": [...], "now": t'}
    drain    {} -> {"decisions": [...], "now": t'}   (open horizon)
    whatif   {"prog": ..., "arrival"?: t} -> projection (no state change)
    metrics  {} -> the streaming counters (docs/SERVICE.md schema)
    checkpoint {} -> {"step": n}          (needs --checkpoint-dir)
    restore  {} -> {"resumed": bool}      (latest checkpoint)
    result   {} -> realized totals so far

``--pool N`` multiplexes N sessions over the same loop: requests
address a session with a ``{"session": i, ...}`` envelope (default 0);
``drive``/``drain``/``metrics``/``checkpoint``/``restore`` WITHOUT a
session fan out to every session and key their response by session
index.  All N sessions advance through one jitted vmapped step and
intake is buffer-and-scatter batched (repro.service.SessionPool);
``--decision-log FILE`` streams every placement as one JSONL record
``{"session": i, ...}`` through the async writer thread.  Checkpoints
are per-session namespaced under ``--checkpoint-dir`` (``s000``, ...).

``--restore`` resumes the latest checkpoint(s) under
``--checkpoint-dir`` before reading any input — kill the process
mid-stream, restart with ``--restore``, replay the remaining lines, and
the decisions match the uninterrupted session bit for bit, per session
(the CI ``service-smoke`` step does exactly that, single and pooled).
"""

import argparse
import json
import sys

from repro.core import JSCC_SYSTEMS, Scheduler, make_npb_workload
from repro.core.cliargs import add_policy_options, build_fault, build_policy
from repro.service import Dispatcher, SessionPool, whatif


def _prog_index(w, prog):
    if isinstance(prog, str):
        if prog not in w.programs:
            raise ValueError(f"unknown program {prog!r}; "
                             f"catalog: {list(w.programs)}")
        return w.programs.index(prog)
    return int(prog)


def _scalar(v):
    """float(v) when v is scalar-like and finite, else None (strict-JSON
    safe: no Infinity/NaN literals on the wire)."""
    import math
    import numpy as np
    if np.ndim(v) != 0:
        return None
    f = float(v)
    return f if math.isfinite(f) else None


def _totals(r):
    totals = {k: _scalar(v) for k, v in r.to_dict(arrays=False).items()}
    return {"totals": {k: v for k, v in totals.items() if v is not None},
            "n_jobs": r.n_jobs}


def handle(disp, req: dict) -> dict:
    op = req.get("op")
    if op == "submit":
        j = disp.submit(_prog_index(disp.w, req["prog"]),
                        req.get("arrival"), req.get("k"))
        return {"ok": True, "job": j, "now": disp.now}
    if op in ("drive", "drain"):
        dec = (disp.drain() if op == "drain"
               else disp.drive(float(req["until"])))
        return {"ok": True, "decisions": dec, "now": disp.now}
    if op == "whatif":
        proj = whatif(disp, _prog_index(disp.w, req["prog"]),
                      req.get("arrival"), req.get("k"))
        proj["cap_headroom"] = _scalar(proj["cap_headroom"])
        return {"ok": True, **proj}
    if op == "metrics":
        return {"ok": True, "metrics": disp.metrics.snapshot()}
    if op == "checkpoint":
        return {"ok": True, "step": disp.save(blocking=True)}
    if op == "restore":
        return {"ok": True, "resumed": bool(disp.restore())}
    if op == "result":
        return {"ok": True, **_totals(disp.result())}
    return {"ok": False, "error": f"unknown op {op!r}"}


def handle_pool(pool, req: dict) -> dict:
    """The ``--pool N`` protocol: the ``{"session": i}`` envelope routes
    a request to one session; fan-out ops key their response by session
    index when the envelope is absent."""
    op = req.get("op")
    s = req.get("session")
    if s is not None:
        s = int(s)
        if not 0 <= s < pool.n:
            return {"ok": False,
                    "error": f"session {s} out of range (pool {pool.n})"}
    if op == "submit":
        i = s or 0
        j = pool.submit(i, _prog_index(pool.w, req["prog"]),
                        req.get("arrival"), req.get("k"))
        return {"ok": True, "session": i, "job": j, "now": pool.now(i)}
    if op in ("drive", "drain"):
        until = None if op == "drain" else float(req["until"])
        if s is None:
            dec = pool.drain() if until is None else pool.drive(until)
            return {"ok": True,
                    "decisions": {str(i): d for i, d in dec.items()},
                    "now": {str(i): pool.now(i) for i in range(pool.n)}}
        dec = (pool.drain(session=s) if until is None
               else pool.drive(until, session=s))
        return {"ok": True, "session": s, "decisions": dec,
                "now": pool.now(s)}
    if op == "whatif":
        i = s or 0
        proj = pool.whatif(i, _prog_index(pool.w, req["prog"]),
                           req.get("arrival"), req.get("k"))
        proj["cap_headroom"] = _scalar(proj["cap_headroom"])
        return {"ok": True, "session": i, **proj}
    if op == "metrics":
        if s is None:
            return {"ok": True,
                    "metrics": {str(i): pool.metrics(i)
                                for i in range(pool.n)}}
        return {"ok": True, "session": s, "metrics": pool.metrics(s)}
    if op == "checkpoint":
        if s is None:
            return {"ok": True, "steps": pool.save()}
        return {"ok": True, "session": s, "step": pool.save(session=s)}
    if op == "restore":
        if s is None:
            return {"ok": True, "resumed": bool(pool.restore())}
        return {"ok": True, "session": s,
                "resumed": bool(pool.restore(session=s))}
    if op == "result":
        i = s or 0
        return {"ok": True, "session": i, **_totals(pool.result(i))}
    return {"ok": False, "error": f"unknown op {op!r}"}


def main(argv=None):
    ap = argparse.ArgumentParser(
        description="online scheduler service (JSONL loop)")
    add_policy_options(ap)                  # the shared grammar (cliargs)
    ap.add_argument("--capacity", type=int, default=256,
                    help="max jobs per session (fixed shapes, one jit)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--warm-start", action="store_true",
                    help="profile tables pre-filled with ground truth")
    ap.add_argument("--pool", type=int, default=0, metavar="N",
                    help="serve N sessions through one vmapped step "
                         "(0 = single classic session)")
    ap.add_argument("--decision-log", default="", metavar="FILE",
                    help="pool mode: append every placement decision as "
                         "a JSONL record via the async writer")
    ap.add_argument("--checkpoint-dir", default="",
                    help="arm checkpoint/restore under this directory")
    ap.add_argument("--restore", action="store_true",
                    help="resume the latest checkpoint before reading input")
    args = ap.parse_args(argv)

    w = make_npb_workload(JSCC_SYSTEMS)
    sched = Scheduler(build_policy(args), faults=build_fault(args),
                      seeds=args.seed, warm_start=args.warm_start)

    if args.pool:
        pool = SessionPool.replicate(
            sched, args.pool, w, capacity=args.capacity,
            checkpoint_dir=args.checkpoint_dir or None,
            decision_log=args.decision_log or None)
        if args.restore:
            resumed = pool.restore()
            print(json.dumps({
                "ok": True, "resumed": bool(resumed), "sessions": pool.n,
                "n_submitted": [d.n_submitted for d in pool.sessions],
                "now": [pool.now(i) for i in range(pool.n)]}), flush=True)
        dispatch, target = handle_pool, pool
    else:
        disp = Dispatcher.from_scheduler(
            sched, w, capacity=args.capacity,
            checkpoint_dir=args.checkpoint_dir or None)
        if args.restore:
            resumed = disp.restore()
            print(json.dumps({"ok": True, "resumed": bool(resumed),
                              "n_submitted": disp.n_submitted,
                              "now": disp.now}), flush=True)
        dispatch, target = handle, disp

    try:
        for line in sys.stdin:
            line = line.strip()
            if not line:
                continue
            try:
                resp = dispatch(target, json.loads(line))
            except Exception as e:                  # state stays intact
                resp = {"ok": False, "error": str(e)}
            print(json.dumps(resp), flush=True)
    finally:
        if args.pool:
            pool.close()


if __name__ == "__main__":
    main()
