"""Online scheduler service CLI: a JSONL decision loop on stdin/stdout.

Each input line is one JSON request; each response is one JSON line —
the shape a facility's submission portal (or the CI smoke) scripts
against.  Example session::

    PYTHONPATH=src python -m repro.launch.scheduler_service \
        --queue easy_backfill:window=8 --power-cap 60000 \
        --checkpoint-dir /tmp/sched_ck <<'EOS'
    {"op": "submit", "prog": "BT", "arrival": 0.0}
    {"op": "submit", "prog": "LU", "arrival": 5.0}
    {"op": "drive", "until": 100.0}
    {"op": "whatif", "prog": "SP"}
    {"op": "checkpoint"}
    {"op": "drain"}
    {"op": "metrics"}
    {"op": "result"}
    EOS

Operations (all responses carry ``"ok"``; errors report ``"error"`` and
leave the session state untouched):

    submit   {"prog": name|index, "arrival"?: t, "k"?: f} -> {"job": id}
    drive    {"until": t} -> {"decisions": [...], "now": t'}
    drain    {} -> {"decisions": [...], "now": t'}   (open horizon)
    whatif   {"prog": ..., "arrival"?: t} -> projection (no state change)
    metrics  {} -> the streaming counters (docs/SERVICE.md schema)
    checkpoint {} -> {"step": n}          (needs --checkpoint-dir)
    result   {} -> realized totals so far

``--restore`` resumes the latest checkpoint under ``--checkpoint-dir``
before reading any input — kill the process mid-stream, restart with
``--restore``, replay the remaining lines, and the decisions match the
uninterrupted session bit for bit (the CI ``service-smoke`` step does
exactly that).
"""

import argparse
import json
import sys

from repro.core import (JSCC_SYSTEMS, FaultConfig, make_npb_workload,
                        make_policy, parse_policy_spec)
from repro.core.policy import apply_queue_spec
from repro.service import Dispatcher, whatif


def build_policy(args):
    if args.policy:
        pol = parse_policy_spec(args.policy, k=args.k)
    else:
        pol = make_policy(args.mode, k=args.k)
    if args.queue:
        pol = apply_queue_spec(pol, args.queue)
    return pol


def _prog_index(w, prog):
    if isinstance(prog, str):
        if prog not in w.programs:
            raise ValueError(f"unknown program {prog!r}; "
                             f"catalog: {list(w.programs)}")
        return w.programs.index(prog)
    return int(prog)


def _scalar(v):
    """float(v) when v is scalar-like and finite, else None (strict-JSON
    safe: no Infinity/NaN literals on the wire)."""
    import math
    import numpy as np
    if np.ndim(v) != 0:
        return None
    f = float(v)
    return f if math.isfinite(f) else None


def handle(disp, req: dict) -> dict:
    op = req.get("op")
    if op == "submit":
        j = disp.submit(_prog_index(disp.w, req["prog"]),
                        req.get("arrival"), req.get("k"))
        return {"ok": True, "job": j, "now": disp.now}
    if op in ("drive", "drain"):
        dec = (disp.drain() if op == "drain"
               else disp.drive(float(req["until"])))
        return {"ok": True, "decisions": dec, "now": disp.now}
    if op == "whatif":
        proj = whatif(disp, _prog_index(disp.w, req["prog"]),
                      req.get("arrival"), req.get("k"))
        proj["cap_headroom"] = _scalar(proj["cap_headroom"])
        return {"ok": True, **proj}
    if op == "metrics":
        return {"ok": True, "metrics": disp.metrics.snapshot()}
    if op == "checkpoint":
        return {"ok": True, "step": disp.save(blocking=True)}
    if op == "result":
        r = disp.result()
        totals = {k: _scalar(v) for k, v in
                  r.to_dict(arrays=False).items()}
        return {"ok": True,
                "totals": {k: v for k, v in totals.items()
                           if v is not None},
                "n_jobs": r.n_jobs}
    return {"ok": False, "error": f"unknown op {op!r}"}


def main(argv=None):
    ap = argparse.ArgumentParser(
        description="online scheduler service (JSONL loop)")
    ap.add_argument("--policy", default="", metavar="NAME[:k=v,...]")
    ap.add_argument("--mode", default="paper")
    ap.add_argument("--k", type=float, default=0.1)
    ap.add_argument("--queue", default="", metavar="DISC[:window=W]")
    ap.add_argument("--power-cap", type=float, default=0.0, metavar="WATTS")
    ap.add_argument("--capacity", type=int, default=256,
                    help="max jobs per session (fixed shapes, one jit)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--warm-start", action="store_true",
                    help="profile tables pre-filled with ground truth")
    ap.add_argument("--failures", type=float, default=0.0,
                    help="per-job failure probability (enables retries)")
    ap.add_argument("--stragglers", type=float, default=0.0)
    ap.add_argument("--checkpoint-dir", default="",
                    help="arm checkpoint/restore under this directory")
    ap.add_argument("--restore", action="store_true",
                    help="resume the latest checkpoint before reading input")
    args = ap.parse_args(argv)

    w = make_npb_workload(JSCC_SYSTEMS)
    fault = (FaultConfig(straggler_prob=args.stragglers,
                         failure_prob=args.failures)
             if (args.failures or args.stragglers) else None)
    disp = Dispatcher(
        w, build_policy(args), capacity=args.capacity, seed=args.seed,
        fault=fault, warm_start=args.warm_start,
        power_cap=args.power_cap or None,
        checkpoint_dir=args.checkpoint_dir or None)
    if args.restore:
        resumed = disp.restore()
        print(json.dumps({"ok": True, "resumed": bool(resumed),
                          "n_submitted": disp.n_submitted,
                          "now": disp.now}), flush=True)

    for line in sys.stdin:
        line = line.strip()
        if not line:
            continue
        try:
            resp = handle(disp, json.loads(line))
        except Exception as e:                      # state stays intact
            resp = {"ok": False, "error": str(e)}
        print(json.dumps(resp), flush=True)


if __name__ == "__main__":
    main()
