import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# --- the two lines above MUST run before any jax-importing module ---------
# (jax locks the device count at first init; smoke tests and benches must
#  NOT see 512 devices, so this override lives here and only here.)

import argparse      # noqa: E402
import json          # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402
import numpy as np   # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import SHAPES, ARCH_IDS, get_config, shape_applicable  # noqa: E402
from repro.models import build_model                        # noqa: E402
from repro.launch.mesh import make_production_mesh          # noqa: E402
from repro.launch.specs import build_all_specs, named       # noqa: E402
from repro.optim import AdamWConfig                         # noqa: E402
from repro.train import make_train_step                     # noqa: E402
from repro.sharding import use_rules                        # noqa: E402
from repro.utils.hlo import parse_collective_bytes          # noqa: E402
from repro.utils.hlo_cost import analyze_hlo                # noqa: E402
from repro.utils.tree import flatten_with_names             # noqa: E402

DEFAULT_OUT = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "experiments", "dryrun")


def _mem_dict(mem):
    return {
        "argument_bytes": mem.argument_size_in_bytes,
        "output_bytes": mem.output_size_in_bytes,
        "temp_bytes": mem.temp_size_in_bytes,
        "generated_code_bytes": mem.generated_code_size_in_bytes,
        "alias_bytes": mem.alias_size_in_bytes,
    }


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             overrides: dict | None = None) -> dict:
    """Lower + compile one (arch x shape x mesh) cell; return the record."""
    t_all = time.time()
    shape = SHAPES[shape_name]
    cfg = get_config(arch)
    if overrides:
        cfg = cfg.with_overrides(**overrides)
    ok, reason = shape_applicable(cfg, shape)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    rec = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "kind": shape.kind, "n_devices": 512 if multi_pod else 256,
        "applicable": ok,
    }
    if not ok:
        rec["skip_reason"] = reason
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    api = build_model(cfg)
    sp = build_all_specs(api, shape, mesh, multi_pod=multi_pod)
    n_params = int(sum(np.prod(x.shape) for _, x in
                       flatten_with_names(sp["param_specs"])))
    rec["n_params"] = n_params

    with mesh, use_rules(mesh, sp["rules"]):
        param_sh = named(mesh, sp["param_part"])
        t0 = time.time()
        if shape.kind == "train":
            step = make_train_step(api, AdamWConfig(),
                                   microbatches=cfg.microbatches)
            opt_sh = named(mesh, sp["opt_part"])
            batch_sh = named(mesh, sp["batch_part"])
            f = jax.jit(step,
                        in_shardings=(param_sh, opt_sh, batch_sh),
                        out_shardings=(param_sh, opt_sh, None),
                        donate_argnums=(0, 1))
            lowered = f.lower(sp["param_specs"], sp["opt_specs"],
                              sp["inputs"]["batch"])
        elif shape.kind == "prefill":
            batch_sh = named(mesh, sp["batch_part"])
            f = jax.jit(api.prefill, in_shardings=(param_sh, batch_sh),
                        out_shardings=None)
            lowered = f.lower(sp["param_specs"], sp["inputs"]["batch"])
        else:  # decode
            cache_sh = named(mesh, sp["cache_part"])
            bax = sp["rules"]["batch"] if shape.global_batch > 1 else None
            tok_sh = NamedSharding(mesh, P(bax, None))
            pos_sh = NamedSharding(mesh, P())
            f = jax.jit(api.decode_step,
                        in_shardings=(param_sh, cache_sh, tok_sh, pos_sh),
                        out_shardings=(None, cache_sh),
                        donate_argnums=(1,))
            lowered = f.lower(sp["param_specs"], sp["inputs"]["cache"],
                              sp["inputs"]["tokens"], sp["inputs"]["pos"])
        rec["lower_s"] = round(time.time() - t0, 2)

        t0 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t0, 2)

        rec["memory_analysis"] = _mem_dict(compiled.memory_analysis())
        ca = compiled.cost_analysis()
        ca = ca[0] if isinstance(ca, (list, tuple)) else ca
        rec["cost_analysis"] = {
            "flops_per_device": float(ca.get("flops", 0.0)),
            "bytes_per_device": float(ca.get("bytes accessed", 0.0)),
        }
        hlo = compiled.as_text()
        rec["hlo_chars"] = len(hlo)
        # raw single-body collective census (uncorrected, for reference)
        rec["collectives_raw"] = parse_collective_bytes(hlo)
        # trip-count-aware walk: corrected flops / HBM bytes / collective
        # bytes per device (see utils/hlo_cost.py docstring for the model)
        walk = analyze_hlo(hlo)
        rec["hlo_walk"] = {
            "mem_bytes_by_op": walk["mem_bytes_by_op"],
            "flops_per_device": walk["flops"],
            "mem_bytes_per_device": walk["mem_bytes"],
            "attn_interior_bytes": walk["attn_interior_bytes"],
            "coll_link_bytes_per_device": walk["coll_link_bytes"],
            "coll_output_bytes_per_op": walk["coll_output_bytes_per_op"],
        }
    rec["total_s"] = round(time.time() - t_all, 2)
    return rec


def main():
    ap = argparse.ArgumentParser(description="multi-pod dry-run")
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="every (arch x shape) on the selected mesh(es)")
    ap.add_argument("--out", default=os.path.normpath(DEFAULT_OUT))
    ap.add_argument("--override", action="append", default=[],
                    help="cfg overrides key=value (e.g. remat_policy=dots)")
    ap.add_argument("--tag", default="", help="suffix for output files")
    args = ap.parse_args()

    overrides = {}
    for kv in args.override:
        k, v = kv.split("=", 1)
        if v in ("true", "false"):
            v = v == "true"
        elif v.replace(".", "", 1).isdigit():
            v = float(v) if "." in v else int(v)
        overrides[k] = v

    cells = []
    archs = [args.arch] if args.arch else list(ARCH_IDS)
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    if args.all:
        archs, shapes = list(ARCH_IDS), list(SHAPES)
    for a in archs:
        for s in shapes:
            for mp in meshes:
                cells.append((a, s, mp))

    os.makedirs(args.out, exist_ok=True)
    failures = 0
    for a, s, mp in cells:
        mesh_name = "pod2x16x16" if mp else "pod16x16"
        tag = f"__{args.tag}" if args.tag else ""
        path = os.path.join(args.out, f"{a}__{s}__{mesh_name}{tag}.json")
        try:
            rec = run_cell(a, s, multi_pod=mp, overrides=overrides or None)
            status = ("SKIP" if not rec.get("applicable")
                      else f"ok lower={rec['lower_s']}s compile={rec['compile_s']}s")
        except Exception as e:   # noqa: BLE001 — record and continue
            rec = {"arch": a, "shape": s, "mesh": mesh_name,
                   "error": repr(e), "traceback": traceback.format_exc()}
            status = f"FAIL {e!r}"
            failures += 1
        with open(path, "w") as fh:
            json.dump(rec, fh, indent=1)
        print(f"[dryrun] {a:24s} {s:12s} {mesh_name:11s} {status}", flush=True)
    if failures:
        raise SystemExit(f"{failures} cells failed")


if __name__ == "__main__":
    main()
