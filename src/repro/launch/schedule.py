"""Scheduler CLI: run the EcoSched simulator on a job stream or campaign.

Policies come from the registry (``repro.core.policy``); pick one with
``--policy name`` or ``--policy name:key=val,...`` (hyperparameters parse
as floats), e.g.:

    PYTHONPATH=src python -m repro.launch.schedule --policy paper:k=0.1
    PYTHONPATH=src python -m repro.launch.schedule \
        --policy ucb:k=0.1,ucb_scale=0.25

(the legacy ``--mode NAME --k F`` spelling still works).

Queue discipline (``--queue``): placement order over the pending queue —
``fcfs`` (strict arrival order, the paper), EASY backfilling with a
bounded pending window, or conservative backfilling (every pending job's
reservation guarded, on the event-granular core)::

    PYTHONPATH=src python -m repro.launch.schedule --jobs 200 \
        --scenario diurnal --queue easy_backfill:window=16
    PYTHONPATH=src python -m repro.launch.schedule --jobs 200 \
        --scenario diurnal --queue conservative:window=16

SCC power cap (``--power-cap``, Watts): the paper's motivating grid
limit.  Placements are deferred while the cluster's instantaneous draw
(busy-job power + idle watts of unallocated nodes) would exceed the cap;
runs on the event-granular core and reports peak_power / capped_delay /
idle_energy::

    PYTHONPATH=src python -m repro.launch.schedule --jobs 200 \
        --scenario bursty --queue conservative --power-cap 60000

Single run / K sweep (the paper's Figs 1-4 regime):

    PYTHONPATH=src python -m repro.launch.schedule --policy paper:k=0.1
    PYTHONPATH=src python -m repro.launch.schedule --sweep-k 0,0.05,0.1,0.2

Campaign grid — ONE jitted ``Scheduler.run`` simulates the whole
(K grid x seed grid) over a scenario-generated job stream:

    PYTHONPATH=src python -m repro.launch.schedule \
        --jobs 10000 --scenario poisson --arrival-rate 0.5 \
        --campaign-k 0,0.05,0.1,0.2,0.3 --campaign-seeds 4 --totals-only

Trace replay (SWF; ``.gz`` ok, ``--calibrate-trace`` maps classes through
the phase model instead of raw node throughput):

    PYTHONPATH=src python -m repro.launch.schedule --trace my_log.swf.gz \
        --campaign-k 0,0.1,0.3 --campaign-seeds 2

Million-job scale-out: ``--shards auto|N`` spreads the campaign grid over
the local devices (shard_map on the ("grid",) mesh) and ``--chunk SIZE``
streams the event scan in fixed windows so a J=10^6 trace never
materializes a [grid, J] intermediate (pair with ``--totals-only`` for
O(1) per-job memory):

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
    PYTHONPATH=src python -m repro.launch.schedule \
        --jobs 1000000 --scenario poisson --arrival-rate 0.5 \
        --campaign-k 0,0.1 --campaign-seeds 4 --totals-only \
        --shards auto --chunk 65536

Facade (repro.core.Scheduler):
    Scheduler(policy, placer=..., faults=..., seeds=...).run(w,
    totals_only=...) -> SimResult / CampaignResult with named leading axes
    (fault, policy, seed), derived metrics (mean slowdown, per-system
    utilization), and ``.to_dict()``.  Everything runs in a single jit; the
    placement inner loop is the kth-free-time radix-select kernel
    (repro.kernels.kth_free), not a per-step sort.  ``--totals-only`` keeps
    per-job arrays out of memory on big grids (campaign memory).

Scenario formats (repro.data.scenarios):
    --scenario {simultaneous, poisson, diurnal, bursty}  — arrival process
      (diurnal: sinusoidal day/night rate; bursty: Poisson bursts of
      correlated array-job submissions), mixed NPB job-size classes drawn
      per --mix-small weight.
    --trace FILE — Standard Workload Format replay: 18 whitespace-separated
      fields per line, ';' comments; submit/runtime/procs are consumed and
      jobs are binned into learned program classes
      (repro.data.scenarios.workload_from_trace).
    --outage S:START:END (repeatable) — maintenance window on system index
      S; no new placements start inside [START, END).
"""

from __future__ import annotations

import argparse

import numpy as np

from repro.core import (JSCC_SYSTEMS, FaultConfig, Scheduler,
                        make_npb_workload)
from repro.core.cliargs import (add_policy_options, add_scale_options,
                                build_engine, build_policy, build_scale)
from repro.data.scenarios import (make_stream_workload, maintenance_windows,
                                  load_swf, workload_from_trace,
                                  NPB_SMALL, NPB_LARGE, ARRIVAL_KINDS)


def _parse_outages(specs, n_systems):
    if not specs:
        return None
    spans = {}
    for spec in specs:
        s, a, b = spec.split(":")
        spans.setdefault(int(s), []).append((float(a), float(b)))
    return maintenance_windows(n_systems, spans)


def build_workload(args):
    outage = _parse_outages(args.outage, len(JSCC_SYSTEMS))
    if args.trace:
        w = workload_from_trace(load_swf(args.trace), JSCC_SYSTEMS,
                                calibrate=args.calibrate_trace)
        if outage is not None:
            from dataclasses import replace
            w = replace(w, outage=outage)
        return w
    if args.jobs:
        mix = {NPB_SMALL: args.mix_small, NPB_LARGE: 1.0 - args.mix_small}
        return make_stream_workload(
            JSCC_SYSTEMS, args.jobs, arrival=args.scenario,
            rate=args.arrival_rate, mix=mix, seed=args.seed, outage=outage)
    return make_npb_workload(JSCC_SYSTEMS, outage=outage)


def main():
    ap = argparse.ArgumentParser()
    add_policy_options(ap, engine=True)     # the shared grammar (cliargs)
    add_scale_options(ap)                   # --shards / --chunk
    ap.add_argument("--easy-eval", default="batched",
                    choices=("batched", "unrolled"),
                    help="EASY candidate evaluation: batched (one [W, S] "
                         "kth-free call per step) or the historical "
                         "unrolled per-slot loop (bit-identical, ~W x "
                         "slower; debugging/A-B only)")
    ap.add_argument("--sweep-k", default="",
                    help="comma-separated K values (fractions)")
    ap.add_argument("--jobs", type=int, default=0,
                    help="stream length (default: the paper's 5-job suite)")
    ap.add_argument("--scenario", default="poisson", choices=ARRIVAL_KINDS,
                    help="arrival process for --jobs streams")
    ap.add_argument("--arrival-rate", type=float, default=0.125,
                    help="mean arrivals per second (0 = simultaneous)")
    ap.add_argument("--mix-small", type=float, default=0.5,
                    help="weight of the small NPB job-size class")
    ap.add_argument("--trace", default="",
                    help="SWF trace file to replay instead of synthetic "
                         "jobs (.gz transparently gunzipped)")
    ap.add_argument("--calibrate-trace", action="store_true",
                    help="calibrate replayed job classes against the "
                         "phase model (workload_model.predict_phases) "
                         "instead of raw node throughput")
    ap.add_argument("--outage", action="append", default=[],
                    metavar="S:T0:T1",
                    help="maintenance window on system S (repeatable)")
    ap.add_argument("--campaign-k", default="",
                    help="comma-separated K grid -> one-jit campaign")
    ap.add_argument("--campaign-seeds", type=int, default=0,
                    help="number of seeds in the campaign grid")
    ap.add_argument("--totals-only", action="store_true",
                    help="campaign memory: aggregate metrics only, no "
                         "per-job arrays (for huge job x grid products)")
    ap.add_argument("--cold", action="store_true",
                    help="empty profile tables (exploration phase)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    w = build_workload(args)
    pol = build_policy(args)
    engine = build_engine(args)
    scale = build_scale(args)
    faults = FaultConfig(straggler_prob=args.stragglers,
                         failure_prob=args.failures)

    if args.campaign_k:
        ks = np.array([float(x) for x in args.campaign_k.split(",")],
                      np.float32)
        seeds = [args.seed + i for i in range(max(args.campaign_seeds, 1))]
        res = Scheduler(pol.with_params(k=ks), faults=faults, seeds=seeds,
                        warm_start=not args.cold, engine=engine,
                        easy_eval=args.easy_eval, **scale).run(
            w, totals_only=args.totals_only)
        E = np.asarray(res.total_energy)            # [K, R]
        M = np.asarray(res.makespan)
        W = np.asarray(res.total_wait)
        print(f"campaign: jobs={res.n_jobs} grid={len(ks)}Kx{len(seeds)}seed "
              f"policy={pol.name} axes={res.axes}")
        print("K,energy_J(mean),energy_J(std),makespan_s(mean),wait_s(mean),dE%")
        for i, k in enumerate(ks):
            print(f"{k:.2f},{E[i].mean():.0f},{E[i].std():.0f},"
                  f"{M[i].mean():.1f},{W[i].mean():.1f},"
                  f"{100*(E[i].mean()-E[0].mean())/E[0].mean():+.1f}")
        return

    if args.sweep_k:
        ks = np.array([float(x) for x in args.sweep_k.split(",")], np.float32)
        res = Scheduler(pol.with_params(k=ks), faults=faults,
                        seeds=args.seed, warm_start=not args.cold,
                        engine=engine,
                        easy_eval=args.easy_eval, **scale).run(w)
        E = np.asarray(res.total_energy)
        M = np.asarray(res.makespan)
        print("K,energy_J,makespan_s,dE%,dT%")
        for i, k in enumerate(ks):
            print(f"{k:.2f},{E[i]:.0f},{M[i]:.1f},"
                  f"{100*(E[i]-E[0])/E[0]:+.1f},{100*(M[i]-M[0])/M[0]:+.1f}")
        return

    r = Scheduler(pol, faults=faults, seeds=args.seed,
                  warm_start=not args.cold, engine=engine,
                  easy_eval=args.easy_eval, **scale).run(w)
    sel = np.asarray(r.system)
    k_str = np.format_float_positional(float(np.asarray(pol.k)), trim="-")
    q_str = pol.queue if pol.queue == "fcfs" else \
        f"{pol.queue}(window={pol.window})"
    print(f"policy={pol.name} K={k_str} queue={q_str} jobs={r.n_jobs} "
          f"warm={not args.cold}")
    print(f"energy={float(r.total_energy)/1e3:.1f} kJ  "
          f"makespan={float(r.makespan):.1f} s  "
          f"total_wait={float(r.total_wait):.1f} s  "
          f"mean_slowdown={float(r.mean_slowdown):.2f}  "
          f"backfill_rate={float(r.backfill_rate):.1%}")
    peak = float(r.peak_power)
    if not np.isnan(peak):                 # event-granular core: SCC power
        cap_str = f"{args.power_cap:.0f} W" if args.power_cap else "none"
        print(f"peak_power={peak/1e3:.1f} kW (cap {cap_str})  "
              f"capped_delay={float(r.capped_delay):.1f} s  "
              f"idle_energy={float(r.idle_energy)/1e3:.1f} kJ")
    counts = np.bincount(sel, minlength=len(w.systems))
    print("placements:", {w.systems[i]: int(c) for i, c in enumerate(counts)})
    util = np.asarray(r.utilization)
    print("utilization:", {w.systems[i]: f"{u:.1%}" for i, u in enumerate(util)})


if __name__ == "__main__":
    main()
