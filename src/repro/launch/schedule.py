"""Scheduler CLI: run the EcoSched simulator on a job stream.

    PYTHONPATH=src python -m repro.launch.schedule --mode paper --k 0.1
    PYTHONPATH=src python -m repro.launch.schedule --sweep-k 0,0.05,0.1,0.2
    PYTHONPATH=src python -m repro.launch.schedule --mode predictive \
        --jobs 40 --arrival-rate 0.125 --stragglers 0.1
"""

from __future__ import annotations

import argparse

import numpy as np

from repro.core import (JSCC_SYSTEMS, SimConfig, make_npb_workload,
                        simulate_jax, sweep_k)
from repro.core.algorithm import MODES


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", default="paper", choices=MODES)
    ap.add_argument("--k", type=float, default=0.1)
    ap.add_argument("--sweep-k", default="",
                    help="comma-separated K values (fractions)")
    ap.add_argument("--jobs", type=int, default=0,
                    help="random stream length (default: the paper's suite)")
    ap.add_argument("--arrival-rate", type=float, default=0.0,
                    help="Poisson arrivals per second (0 = simultaneous)")
    ap.add_argument("--stragglers", type=float, default=0.0)
    ap.add_argument("--failures", type=float, default=0.0)
    ap.add_argument("--cold", action="store_true",
                    help="empty profile tables (exploration phase)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    rng = np.random.default_rng(args.seed)
    if args.jobs:
        order = tuple(rng.choice(["BT", "EP", "IS", "LU", "SP"], args.jobs))
        arrivals = (np.cumsum(rng.exponential(1 / args.arrival_rate, args.jobs))
                    .astype(np.float32) if args.arrival_rate else None)
    else:
        order, arrivals = ("BT", "EP", "IS", "LU", "SP"), None
    w = make_npb_workload(JSCC_SYSTEMS, order=order, arrivals=arrivals)
    scfg = SimConfig(mode=args.mode, k=args.k, warm_start=not args.cold,
                     straggler_prob=args.stragglers,
                     failure_prob=args.failures, seed=args.seed)

    if args.sweep_k:
        ks = np.array([float(x) for x in args.sweep_k.split(",")])
        res = sweep_k(w, scfg, ks)
        E = np.asarray(res["total_energy"])
        M = np.asarray(res["makespan"])
        print("K,energy_J,makespan_s,dE%,dT%")
        for i, k in enumerate(ks):
            print(f"{k:.2f},{E[i]:.0f},{M[i]:.1f},"
                  f"{100*(E[i]-E[0])/E[0]:+.1f},{100*(M[i]-M[0])/M[0]:+.1f}")
        return

    r = simulate_jax(w, scfg)
    sel = np.asarray(r["system"])
    print(f"mode={args.mode} K={args.k:.0%} jobs={len(w.prog)} "
          f"warm={not args.cold}")
    print(f"energy={float(r['total_energy'])/1e3:.1f} kJ  "
          f"makespan={float(r['makespan']):.1f} s  "
          f"total_wait={float(r['total_wait']):.1f} s")
    counts = np.bincount(sel, minlength=len(w.systems))
    print("placements:", {w.systems[i]: int(c) for i, c in enumerate(counts)})


if __name__ == "__main__":
    main()
