"""Production mesh construction.

A FUNCTION, not a module-level constant — importing this module never
touches jax device state (required by the dry-run contract: only dryrun.py
sets the 512-device XLA flag before jax initializes).
"""

from __future__ import annotations

import jax


def _make_mesh(shape, axes):
    """jax.make_mesh across jax versions: ``axis_types`` (and the AxisType
    enum) only exist from jax 0.5; older jaxlibs default every axis to Auto
    already, so omit the argument there."""
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(
            shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) over 256 chips.
    Multi-pod:  (pod=2, data=16, model=16) over 512 chips — the 'pod' axis
    carries only data parallelism (hierarchical gradient reduction over DCN).
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_grid_mesh(shards="auto"):
    """1-D ``("grid",)`` mesh over the local devices for campaign-grid
    sharding (``Scheduler(shards=...)``): the flat (fault x policy x seed)
    batch axis of a campaign spreads across its devices via shard_map
    (repro.sharding.grid).  ``shards``: "auto"/None = every local device,
    or an explicit count <= the local device count."""
    n_local = len(jax.devices())
    n = n_local if shards in (None, "auto") else int(shards)
    if not 1 <= n <= n_local:
        raise ValueError(f"shards={shards!r} not in 1..{n_local} "
                         f"(local devices)")
    return _make_mesh((n,), ("grid",))


def make_elastic_mesh(n_devices: int, model_parallel: int = 16):
    """Rebuild a (data, model) mesh from however many devices survive —
    the elastic-restart path (data dim shrinks, model dim is preserved so
    checkpoints reshard without repartitioning logic)."""
    assert n_devices % model_parallel == 0
    return _make_mesh((n_devices // model_parallel, model_parallel),
                      ("data", "model"))
