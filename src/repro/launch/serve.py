"""Production serving launcher: batched prefill + decode loop.

On this CPU container use ``--reduced``; full-scale serving paths are
exercised via the dry-run (prefill_32k / decode_32k / long_500k cells).

    PYTHONPATH=src python -m repro.launch.serve --arch mamba2-780m \
        --reduced --batch 4 --tokens 32
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config, smoke_reduce
from repro.models import build_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=ARCH_IDS)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--max-seq", type=int, default=128)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = smoke_reduce(cfg)
    api = build_model(cfg)
    key = jax.random.key(0)
    params = api.init_params(key)
    cache = api.init_decode_cache(args.batch, args.max_seq)
    step = jax.jit(api.decode_step, donate_argnums=(1,))

    tok = jax.random.randint(key, (args.batch, 1), 2, cfg.vocab_size, jnp.int32)
    logits, cache = step(params, cache, tok, jnp.int32(0))   # compile
    t0 = time.perf_counter()
    for pos in range(1, args.tokens):
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        logits, cache = step(params, cache, tok, jnp.int32(pos))
    jax.block_until_ready(logits)
    dt = time.perf_counter() - t0
    assert np.isfinite(np.asarray(logits)).all()
    print(f"{cfg.name}{' (reduced)' if args.reduced else ''}: "
          f"{args.batch * (args.tokens - 1) / dt:.1f} tok/s "
          f"(batch {args.batch}, {args.tokens} steps, "
          f"{jax.device_count()} device(s))")


if __name__ == "__main__":
    main()
