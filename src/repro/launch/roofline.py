"""Roofline analysis over dry-run records (EXPERIMENTS.md §Roofline).

Terms per (arch x shape) cell, from the compiled single-pod artifact:
    t_compute    = flops_per_device   / PEAK_FLOPS      (197 TF bf16, v5e)
    t_memory     = mem_bytes_per_dev  / HBM_BW          (819 GB/s)
    t_collective = coll_link_bytes    / ICI_LINK_BW     (50 GB/s/link)

flops / bytes / collective bytes come from the trip-count-aware HLO walk
(utils/hlo_cost.py), NOT from raw compiled.cost_analysis() — the latter
counts while bodies once (under-reports scans ~n_layers-fold; both numbers
are recorded in the dry-run JSONs for comparison).

MODEL_FLOPS (the useful-work yardstick):
    train    6 * N_active * tokens        (+ attention term, reported apart)
    prefill  2 * N_active * tokens
    decode   2 * N_active * batch
N_active excludes embeddings/positions and counts MoE experts at top_k/E.
"""

from __future__ import annotations

import glob
import json
import os

import numpy as np

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9
HBM_PER_CHIP = 16e9    # v5e

from repro.configs import SHAPES, ARCH_IDS, get_config
from repro.models import build_model
from repro.utils.tree import flatten_with_names


def active_param_count(cfg) -> tuple[int, int]:
    """(N_total_nonembed, N_active_nonembed) from the param spec tree."""
    api = build_model(cfg)
    specs = api.param_specs()
    total = active = 0
    moe_scale = (cfg.moe.top_k / cfg.moe.n_experts) if cfg.moe.n_experts else 1.0
    for name, x in flatten_with_names(specs):
        n = int(np.prod(x.shape))
        top = name.split("/")[0]
        if top in ("embed", "head") or name.endswith(("enc_pos", "dec_pos")):
            continue
        total += n
        if "/moe/w" in name:
            active += int(n * moe_scale)
        else:
            active += n
    return total, active


def model_flops(cfg, shape) -> float:
    _, n_active = active_param_count(cfg)
    if shape.kind == "train":
        return 6.0 * n_active * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n_active * shape.global_batch * shape.seq_len
    return 2.0 * n_active * shape.global_batch          # decode: 1 token/seq


def load_records(dryrun_dir: str, mesh: str = "pod16x16", tag: str = ""):
    recs = {}
    suffix = f"__{tag}" if tag else ""
    for path in glob.glob(os.path.join(dryrun_dir, f"*__{mesh}{suffix}.json")):
        rec = json.load(open(path))
        if tag == "" and rec.get("arch") and "__" in os.path.basename(path):
            base = os.path.basename(path)[:-5]
            parts = base.split("__")
            if len(parts) != 3:      # skip tagged variants
                continue
        recs[(rec["arch"], rec["shape"])] = rec
    return recs


def flash_kernel_traffic(cfg, shape, n_devices: int = 256) -> float:
    """Analytic HBM bytes/device of the flash-attention Pallas kernel
    (Q, K, V streamed + O written; K/V re-read per q-block is second-order
    and folded into the pass factor).  Used to replace the CPU-artifact
    attention-interior traffic in the kernel-adjusted memory term."""
    if cfg.n_heads == 0 or shape.kind == "decode":
        return 0.0
    n_attn = len(cfg.attn_layer_ids())
    if cfg.is_encoder_decoder:
        n_attn = cfg.n_encoder_layers + 2 * cfg.n_layers
    model_par = 16
    h_loc = cfg.n_heads // model_par if cfg.n_heads % model_par == 0 else cfg.n_heads
    kv_loc = (cfg.n_kv_heads // model_par
              if cfg.n_kv_heads % model_par == 0 else cfg.n_kv_heads)
    dp = n_devices // model_par
    b_loc = max(1, shape.global_batch // dp)
    passes = 4.0 if shape.is_training else 1.0   # fwd + remat-fwd + bwd(~2x)
    hd = cfg.resolved_head_dim()
    return (passes * n_attn * b_loc * shape.seq_len
            * (2 * h_loc + 2 * kv_loc) * hd * 2.0)


def roofline_row(rec, n_devices: int = 256) -> dict:
    arch, shape_name = rec["arch"], rec["shape"]
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    if not rec.get("applicable", False):
        return {"arch": arch, "shape": shape_name, "skip": rec.get("skip_reason", "")}
    if "error" in rec:
        return {"arch": arch, "shape": shape_name, "error": rec["error"]}
    walk = rec["hlo_walk"]
    t_c = walk["flops_per_device"] / PEAK_FLOPS
    t_m = walk["mem_bytes_per_device"] / HBM_BW
    # kernel-adjusted memory: attention tiles live in VMEM on TPU (Pallas
    # flash kernel); replace their CPU-artifact HBM traffic with the
    # kernel's true Q/K/V/O streams.
    attn_interior = walk.get("attn_interior_bytes", 0.0)
    mem_adj = (walk["mem_bytes_per_device"] - attn_interior
               + flash_kernel_traffic(cfg, shape, n_devices))
    t_m_adj = mem_adj / HBM_BW
    t_x = walk["coll_link_bytes_per_device"] / LINK_BW
    dom = max(("compute", t_c), ("memory", t_m_adj), ("collective", t_x),
              key=lambda kv: kv[1])[0]
    mf = model_flops(cfg, shape)
    hlo_total = walk["flops_per_device"] * n_devices
    bound = max(t_c, t_m_adj, t_x)
    mem = rec["memory_analysis"]
    hbm_gb = (mem["argument_bytes"] + mem["temp_bytes"]) / 1e9
    return {
        "arch": arch, "shape": shape_name,
        "t_compute": t_c, "t_memory": t_m, "t_memory_adj": t_m_adj,
        "t_collective": t_x,
        "dominant": dom,
        "model_flops": mf,
        "hlo_flops_total": hlo_total,
        "useful_ratio": mf / hlo_total if hlo_total else 0.0,
        # roofline fraction: useful work rate vs peak if perfectly compute-bound
        "roofline_frac": (mf / (n_devices * PEAK_FLOPS)) / bound if bound else 0.0,
        "step_time_bound_s": bound,
        "hbm_gb_per_device": hbm_gb,
        "fits_hbm": hbm_gb <= HBM_PER_CHIP / 1e9,
        "compile_s": rec.get("compile_s"),
    }


def improvement_note(row) -> str:
    if "skip" in row or "error" in row:
        return ""
    d = row["dominant"]
    if d == "collective":
        return ("reduce TP collective volume: fewer psums per layer "
                "(SP residuals / lower TP for this size / overlap)")
    if d == "memory":
        return ("cut HBM traffic: fuse attention interior (Pallas flash on "
                "TPU keeps tiles in VMEM), tighter remat policy")
    return "raise MXU utilization: larger per-device tiles, fewer pad ops"


def markdown_table(rows) -> str:
    hdr = ("| arch | shape | t_comp (s) | t_mem raw (s) | t_mem adj (s) | "
           "t_coll (s) | dominant | MODEL_FLOPS | useful/HLO | roofline frac | "
           "HBM GB/dev | fits |\n"
           "|---|---|---|---|---|---|---|---|---|---|---|---|\n")
    lines = []
    for r in rows:
        if "skip" in r:
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | — | SKIP | "
                         f"— | — | — | — | {r['skip'][:60]} |")
            continue
        if "error" in r:
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | — | ERROR | "
                         f"— | — | — | — | {r['error'][:60]} |")
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute']:.3f} | "
            f"{r['t_memory']:.3f} | {r['t_memory_adj']:.3f} | "
            f"{r['t_collective']:.3f} | {r['dominant']} | "
            f"{r['model_flops']:.3g} | {r['useful_ratio']:.2f} | "
            f"{r['roofline_frac']:.3f} | {r['hbm_gb_per_device']:.1f} | "
            f"{'y' if r['fits_hbm'] else 'NO'} |")
    return hdr + "\n".join(lines) + "\n"


def pick_hillclimb_cells(rows):
    """worst roofline fraction, most collective-bound, most paper-representative."""
    ok = [r for r in rows if "skip" not in r and "error" not in r]
    worst = min(ok, key=lambda r: r["roofline_frac"])
    coll = max(ok, key=lambda r: r["t_collective"] / max(r["step_time_bound_s"], 1e-9))
    return worst, coll


def main():
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--mesh", default="pod16x16")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()
    n_devices = 512 if args.mesh == "pod2x16x16" else 256
    recs = load_records(args.dir, args.mesh, args.tag)
    rows = [roofline_row(r, n_devices=n_devices)
            for (a, s), r in sorted(recs.items())]
    print(markdown_table(rows))
    ok = [r for r in rows if "skip" not in r and "error" not in r]
    if ok:
        worst, coll = pick_hillclimb_cells(rows)
        print(f"\nworst roofline frac: {worst['arch']} x {worst['shape']} "
              f"({worst['roofline_frac']:.3f})")
        print(f"most collective-bound: {coll['arch']} x {coll['shape']}")


if __name__ == "__main__":
    main()
