"""Production training launcher.

On TPU fleets this builds the production mesh, shards params/opt/batch per
repro.sharding rules and runs the fault-tolerant loop.  On this CPU
container use ``--reduced`` (smoke-size model, real full stack) — the full
configs are exercised via ``repro.launch.dryrun``.

    PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
        --reduced --steps 20 --ckpt-dir /tmp/ecosched_train
"""

from __future__ import annotations

import argparse

import jax

from repro.configs import ARCH_IDS, get_config, smoke_reduce
from repro.configs.base import ShapeConfig, SHAPES
from repro.models import build_model
from repro.optim import AdamWConfig
from repro.train import LoopConfig, run_training


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=ARCH_IDS)
    ap.add_argument("--shape", default="train_4k", choices=list(SHAPES))
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--reduced", action="store_true",
                    help="smoke-size config (CPU-runnable)")
    ap.add_argument("--batch", type=int, default=0, help="override batch")
    ap.add_argument("--seq", type=int, default=0, help="override seq len")
    ap.add_argument("--microbatches", type=int, default=0)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/ecosched_train")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--metrics", default="")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = smoke_reduce(cfg)
    shape = SHAPES[args.shape]
    if args.reduced:
        shape = ShapeConfig("reduced", seq_len=args.seq or 64,
                            global_batch=args.batch or 4, kind="train")
    elif args.batch or args.seq:
        shape = ShapeConfig("custom", seq_len=args.seq or shape.seq_len,
                            global_batch=args.batch or shape.global_batch,
                            kind="train")

    mb = args.microbatches or (1 if args.reduced else cfg.microbatches)
    api = build_model(cfg)
    ocfg = AdamWConfig(lr_peak=args.lr, warmup_steps=max(args.steps // 20, 2),
                      total_steps=args.steps)
    lcfg = LoopConfig(steps=args.steps, ckpt_dir=args.ckpt_dir,
                      ckpt_every=args.ckpt_every, microbatches=mb)
    print(f"training {cfg.name}{' (reduced)' if args.reduced else ''} "
          f"seq={shape.seq_len} batch={shape.global_batch} mb={mb} "
          f"on {jax.device_count()} device(s)")
    res = run_training(api, shape, ocfg, lcfg,
                       metrics_path=args.metrics or None)
    print(f"done: steps={res.final_step} resumed_from={res.resumed_from} "
          f"loss {res.losses[0]:.3f} -> {res.losses[-1]:.3f} "
          f"stragglers={len(res.straggler_events)}")


if __name__ == "__main__":
    main()
