"""Input/cache/state sharding specs for the dry-run and launchers."""

from __future__ import annotations

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.sharding.ctx import lm_rules
from repro.sharding.params import tree_partition_specs, _fit


def _axis_sizes(mesh):
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def batch_partition_specs(cfg: ModelConfig, shape: ShapeConfig, mesh, rules):
    """PartitionSpec tree matching ModelApi.input_specs()['batch']."""
    sizes = _axis_sizes(mesh)
    b = shape.global_batch
    bax = _fit(b, rules["batch"], sizes)
    specs = {"tokens": P(bax, None)}
    if shape.kind == "train":
        specs["labels"] = P(bax, None)
        specs["mask"] = P(bax, None)
    if cfg.is_encoder_decoder:
        specs["frame_embeds"] = P(bax, None, None)
    if cfg.frontend == "vision":
        specs["patch_embeds"] = P(bax, None, None)
    return specs


def cache_partition_specs(cfg: ModelConfig, shape: ShapeConfig, mesh, rules,
                          cache_specs):
    """PartitionSpec tree matching decode_cache_specs.

    Attention KV caches [.., b, S, kv, hd]: batch on the data axes when it
    divides; kv heads on 'model' when they divide, otherwise the SEQUENCE
    dim goes on 'model' (flash-decode style partial softmax — XLA inserts
    the combine collectives).  For global_batch=1 long-context, sequence is
    sharded over (data, model) jointly.
    """
    sizes = _axis_sizes(mesh)
    b = shape.global_batch
    bax = _fit(b, rules["batch"], sizes)

    def spec_for_leaf(path: str, x):
        nd = len(x.shape)
        name = path.split("/")[-1]
        if name in ("k", "v", "self_k", "self_v", "mem_k", "mem_v"):
            stacked = 1 if nd == 5 else 0
            _, bdim, sdim, kvdim, _ = ((None,) + x.shape) if stacked == 0 else x.shape
            kv_ax = _fit(x.shape[stacked + 2], rules["kv_heads"], sizes)
            if kv_ax is not None:
                seq_ax = None
            else:
                # sequence sharding fallback; join data axes when batch=1
                seq_ax = (("data", "model") if (bax is None or b == 1)
                          else "model")
                seq_ax = _fit(x.shape[stacked + 1], seq_ax, sizes)
            base = (bax, seq_ax, kv_ax, None)
            return P(*([None] * stacked + list(base)))
        if name == "conv":       # [G, b, K-1, conv_dim]
            cd_ax = _fit(x.shape[-1], rules["ff"], sizes)
            return P(*([None] * (nd - 3) + [bax, None, cd_ax]))
        if name == "state":      # [G, b, h, p, n]
            h_ax = _fit(x.shape[-3], rules["heads"], sizes)
            return P(*([None] * (nd - 4) + [bax, h_ax, None, None]))
        return P(*([None] * nd))

    from repro.utils.tree import flatten_with_names
    flat = flatten_with_names(cache_specs)
    specs = [spec_for_leaf(name, x) for name, x in flat]
    return jax.tree.unflatten(jax.tree.structure(cache_specs), specs)


def named(mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


def build_all_specs(api, shape: ShapeConfig, mesh, *, multi_pod: bool):
    """Returns dict with input specs (SDS) and sharding trees for the cell."""
    cfg = api.cfg
    rules = lm_rules(multi_pod, cfg.fsdp)
    inputs = api.input_specs(shape)
    out = {"rules": rules, "inputs": inputs}

    pspecs = api.param_specs()
    out["param_specs"] = pspecs
    out["param_part"] = tree_partition_specs(pspecs, rules, mesh)

    if shape.kind == "train":
        from repro.optim import adamw_init_specs
        ospecs = adamw_init_specs(pspecs)
        opart = {
            "master": out["param_part"], "m": out["param_part"],
            "v": out["param_part"], "step": P(),
        }
        out["opt_specs"], out["opt_part"] = ospecs, opart
        out["batch_part"] = batch_partition_specs(cfg, shape, mesh, rules)
    elif shape.kind == "prefill":
        out["batch_part"] = batch_partition_specs(cfg, shape, mesh, rules)
    else:  # decode
        out["cache_part"] = cache_partition_specs(
            cfg, shape, mesh, rules, inputs["cache"])
    return out
