"""BT / SP / LU analogues: ADI / SSOR iterations on a 3D grid.

The NPB CFD pseudo-apps share a structure: per iteration, compute the
right-hand side (nearest-neighbour stencil — the Pallas ``stencil3d``
kernel) and then sweep implicit line solves:
  BT/SP: ADI — tridiagonal solves along x, y, z (Thomas algorithm, a
         lax.scan along the line, vmapped over the other two axes);
  LU   : SSOR relaxation (two stencil half-sweeps).
The analogues keep those compute/communication patterns at configurable
scale; verification follows NPB's spirit: the solution must converge
(residual decreases) and stay finite.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.stencil3d import stencil7


def thomas_tridiag(a, b, c, d):
    """Solve tridiagonal systems along the LAST axis.
    a (sub), b (diag), c (super), d (rhs): [..., n]."""
    def fwd(carry, x):
        cp_prev, dp_prev = carry
        ai, bi, ci, di = x
        denom = bi - ai * cp_prev
        cp = ci / denom
        dp = (di - ai * dp_prev) / denom
        return (cp, dp), (cp, dp)

    xs = (jnp.moveaxis(a, -1, 0), jnp.moveaxis(b, -1, 0),
          jnp.moveaxis(c, -1, 0), jnp.moveaxis(d, -1, 0))
    zeros = jnp.zeros(a.shape[:-1])
    _, (cp, dp) = jax.lax.scan(fwd, (zeros, zeros), xs)

    def bwd(carry, x):
        cpi, dpi = x
        xi = dpi - cpi * carry
        return xi, xi

    _, xs_rev = jax.lax.scan(bwd, jnp.zeros_like(zeros), (cp, dp), reverse=True)
    return jnp.moveaxis(xs_rev, 0, -1)


def _adi_sweep(u, rhs, diag: float):
    """One ADI iteration: tridiagonal solves along z, y, x."""
    n = u.shape
    ones = jnp.ones_like(u)
    a = -0.25 * ones
    b = diag * ones
    c = -0.25 * ones
    u = thomas_tridiag(a, b, c, rhs)
    u = jnp.moveaxis(thomas_tridiag(a, b, c, jnp.moveaxis(u, 1, -1)), -1, 1)
    u = jnp.moveaxis(thomas_tridiag(a, b, c, jnp.moveaxis(u, 0, -1)), -1, 0)
    return u


@partial(jax.jit, static_argnames=("nx", "iters", "variant", "force"))
def run_cfd(nx: int = 32, iters: int = 10, variant: str = "BT",
            seed: int = 0, force: str | None = None):
    """variant: BT (5-sweep ADI), SP (3-sweep ADI, lighter), LU (SSOR)."""
    key = jax.random.key(seed)
    u0 = jax.random.normal(key, (nx, nx, nx), jnp.float32)
    omega = 0.8

    def bt_sp_step(u, _):
        rhs = stencil7(u, coef_c=-6.0, coef_n=1.0, force=force)
        sweeps = 2 if variant == "BT" else 1
        v = u
        for _ in range(sweeps):
            v = _adi_sweep(v, v - omega * 0.1 * rhs, diag=1.5)
        res = jnp.sqrt(jnp.mean(rhs * rhs))
        return v, res

    def lu_step(u, _):
        # SSOR: two diffusive relaxation half-sweeps (dt*|lambda_max| < 1)
        rhs = stencil7(u, coef_c=-6.0, coef_n=1.0, force=force)
        u = u + omega * 0.08 * rhs                       # lower sweep
        rhs2 = stencil7(u, coef_c=-6.0, coef_n=1.0, force=force)
        u = u + omega * 0.08 * rhs2                      # upper sweep
        res = jnp.sqrt(jnp.mean(rhs2 * rhs2))
        return u, res

    step = lu_step if variant == "LU" else bt_sp_step
    u, residuals = jax.lax.scan(step, u0, jnp.arange(iters))
    return {"u": u, "residuals": residuals}


def verify_cfd(result) -> bool:
    r = result["residuals"]
    finite = bool(jnp.isfinite(result["u"]).all())
    decreasing = float(r[-1]) < float(r[0])
    return finite and decreasing


def cfd_flops(nx: int, iters: int, variant: str) -> float:
    pts = nx ** 3
    stencil = 13.0 * pts                                  # 7-pt stencil flops
    thomas = 8.0 * pts                                    # per directional solve
    if variant == "BT":
        per_iter = stencil + 2 * 3 * thomas + 4 * pts
    elif variant == "SP":
        per_iter = stencil + 3 * thomas + 4 * pts
    else:  # LU
        per_iter = 2 * stencil + 4 * pts
    return per_iter * iters
