"""NPB IS analogue: bucket-histogram key ranking.

NPB IS ranks 2^n keys by bucket counting over ``iterations`` rounds (the
ranking, not a full reorder, is what NPB times).  The histogram is the
Pallas kernel; ranks come from the exclusive prefix sum over buckets, and
verification checks that ranks are a valid non-decreasing assignment.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.is_hist import key_histogram

OPS_PER_KEY_PER_ITER = 45.0   # NPB IS ~int ops per key per ranking iteration


def run_is(n_pow: int = 16, bucket_pow: int = 10, iterations: int = 10,
           seed: int = 0, force: str | None = None):
    n, n_buckets = 1 << n_pow, 1 << bucket_pow
    key_max_pow = n_pow + 3                         # keys in [0, 8n)
    shift = key_max_pow - bucket_pow
    key = jax.random.key(seed)

    def body(carry, i):
        # NPB mutates two keys per iteration; we fold i into the stream
        keys = jax.random.randint(jax.random.fold_in(key, i), (n,),
                                  0, 1 << key_max_pow, jnp.int32)
        hist = key_histogram(keys, n_buckets=n_buckets, bucket_shift=shift,
                             force=force)
        starts = jnp.cumsum(hist) - hist            # exclusive prefix sum
        ranks = starts[(keys >> shift)]
        return carry + hist.sum(), (keys, ranks)

    total, (keys, ranks) = jax.lax.scan(body, jnp.float32(0),
                                        jnp.arange(iterations))
    return {"keys": keys[-1], "ranks": ranks[-1], "total_counted": total,
            "n": n, "iterations": iterations}


def verify_is(result) -> bool:
    """Bucket-rank validity: sorting keys by rank must sort their buckets."""
    keys, ranks = result["keys"], result["ranks"]
    order = jnp.argsort(ranks)
    shifted = keys[order]
    # bucket ids (high bits) must be non-decreasing along the rank order
    n = result["n"]
    ok_count = float(result["total_counted"]) == result["n"] * result["iterations"]
    diffs = jnp.diff(shifted >> (int(jnp.log2(n)) + 3 - 10))
    return bool(ok_count and bool((diffs >= 0).all()))


def is_ops(n_pow: int, iterations: int = 10) -> float:
    return (1 << n_pow) * iterations * OPS_PER_KEY_PER_ITER
