"""NPB EP analogue (runnable, scaled by ``m``: n_pairs = 2^m).

Faithful to the NPB EP structure: uniform pairs -> Marsaglia polar ->
Gaussian deviates -> annuli counts + (sum X, sum Y).  The NPB LCG
(a = 5^13, modulus 2^46) is replaced by threefry (jax.random) — the LCG is
sequential and hostile to all vector hardware; NPB's own verification is
statistical, which we keep: annuli counts must sum to the accepted count
and the acceptance ratio must approach pi/4.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.ep import ep_pairs

FLOPS_PER_PAIR = 100.0   # transcendental-weighted (log, sqrt, div ~ dozens of flops)


def run_ep(m: int = 20, batch_pow: int = 16, seed: int = 0,
           force: str | None = None):
    """Returns dict(hist [10], sx, sy, n_pairs, accepted)."""
    n = 1 << m
    bn = 1 << min(batch_pow, m)
    n_batches = n // bn
    key = jax.random.key(seed)

    def body(carry, i):
        hist, sx, sy = carry
        u = jax.random.uniform(jax.random.fold_in(key, i), (2, bn),
                               minval=-1.0, maxval=1.0)
        h, s = ep_pairs(u, force=force)
        return (hist + h, sx + s[0], sy + s[1]), None

    (hist, sx, sy), _ = jax.lax.scan(
        body, (jnp.zeros((10,), jnp.float32), jnp.float32(0), jnp.float32(0)),
        jnp.arange(n_batches))
    return {"hist": hist, "sx": sx, "sy": sy, "n_pairs": n,
            "accepted": hist.sum()}


def verify_ep(result) -> bool:
    """NPB-style statistical verification."""
    ratio = float(result["accepted"]) / result["n_pairs"]
    ok_ratio = abs(ratio - 3.141592653589793 / 4) < 0.01
    mean_x = float(result["sx"]) / max(float(result["accepted"]), 1.0)
    return bool(ok_ratio and abs(mean_x) < 0.02)


def ep_flops(m: int) -> float:
    return (1 << m) * FLOPS_PER_PAIR
