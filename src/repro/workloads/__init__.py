"""Runnable NPB-analogue workloads + their scheduler-facing profiles."""

from repro.workloads.ep import run_ep, verify_ep, ep_flops
from repro.workloads.is_sort import run_is, verify_is, is_ops
from repro.workloads.cfd import run_cfd, verify_cfd, cfd_flops, thomas_tridiag


def run_benchmark(name: str, scale: str = "smoke", force=None):
    """Uniform entry point. scale: smoke (CI) | small (laptop)."""
    small = scale != "smoke"
    if name == "EP":
        m = 22 if small else 18
        res = run_ep(m=m, force=force)
        return res, verify_ep(res), ep_flops(m)
    if name == "IS":
        n_pow = 20 if small else 16
        res = run_is(n_pow=n_pow, force=force)
        return res, verify_is(res), is_ops(n_pow)
    if name in ("BT", "SP", "LU"):
        nx = 64 if small else 24
        iters = 20 if small else 5
        res = run_cfd(nx=nx, iters=iters, variant=name, force=force)
        return res, verify_cfd(res), cfd_flops(nx, iters, name)
    raise KeyError(name)


BENCHMARKS = ("BT", "EP", "IS", "LU", "SP")
