"""Phase-based runtime & energy model for jobs on computing systems.

The paper ([10], §Problem) decomposes parallel execution into compute,
external-memory and communication phases; SUPPZ measures per-phase power.
We *model* those measurements: a job carries total op/byte counts per phase
and the model predicts (T, E, C) on any system.  These predictions drive
(a) the simulator's ground truth and (b) the beyond-paper "predictive
cold-start" scheduler.

Units note (DESIGN.md §11): the paper reports C in the 1e-3..7.5e-3 "J/op"
range, which is consistent with NPB's native performance unit, Mop/s.  We
therefore express P in Mop/s and C in J/Mop — magnitudes then reproduce the
paper's Table 5 directly.
"""

from __future__ import annotations

from dataclasses import dataclass, replace

import numpy as np

from repro.core.systems import ComputeSystem


@dataclass(frozen=True)
class JobProfile:
    """Resource totals for one program run at its assigned scale."""
    name: str
    flops: float               # total computational operations
    net_bytes: float           # total communication volume (all nodes)
    disk_bytes: float          # total external-memory (I/O) volume
    mem_bytes: float = 0.0     # HBM/DRAM traffic (roofline memory term)
    parallel_eff: float = 0.9  # strong-scaling efficiency at the given CN count
    vector_friendly: float = 1.0   # how well the code uses wide SIMD (KNL/SKX skew)
    net_eff: float = 0.5       # achieved fraction of injection bw (pattern-dependent)


def predict_phases(prof: JobProfile, sys: ComputeSystem, n_nodes: int):
    """Return (t_comp, t_net, t_disk) in seconds (phases serialized, per the
    paper's phase model)."""
    eff = sys.efficiency * prof.parallel_eff
    # vector-unfriendly codes lose more on wide-SIMD machines (KNL):
    simd_factor = prof.vector_friendly + (1.0 - prof.vector_friendly) * sys.scalar_eff
    flops_rate = n_nodes * sys.peak_flops_node * eff * simd_factor
    t_comp = prof.flops / flops_rate
    # memory-bound correction: compute phase cannot beat the memory roofline
    if prof.mem_bytes:
        t_comp = max(t_comp, prof.mem_bytes / (n_nodes * sys.mem_bw_node))
    t_net = prof.net_bytes / (n_nodes * sys.net_bw_node * prof.net_eff)
    t_disk = prof.disk_bytes / (n_nodes * sys.disk_bw_node)
    return t_comp, t_net, t_disk


def predict_runtime(prof: JobProfile, sys: ComputeSystem, n_nodes: int) -> float:
    return float(sum(predict_phases(prof, sys, n_nodes)))


def predict_energy(prof: JobProfile, sys: ComputeSystem, n_nodes: int):
    """Paper eq. (1)+(2): E = sum_j int W^j(t) dt with W^j = idle + phase
    components.  Returns (E_joules, W_avg_watts, T_seconds)."""
    t_comp, t_net, t_disk = predict_phases(prof, sys, n_nodes)
    T = t_comp + t_net + t_disk
    E = n_nodes * (sys.idle_w * T + sys.cpu_w * t_comp
                   + sys.net_w * t_net + sys.disk_w * t_disk)
    W_avg = E / max(T, 1e-12)
    return E, W_avg, T


def energy_coefficient(prof: JobProfile, sys: ComputeSystem, n_nodes: int) -> float:
    """C = W / P with P in Mop/s  =>  C = E / (flops/1e6)   [J/Mop]."""
    E, _, _ = predict_energy(prof, sys, n_nodes)
    return E / (prof.flops / 1e6)


# --------------------------------------------------------------------------
# NPB class-D analytic profiles (documented approximations; DESIGN.md §11).
# Grid 408^3 for BT/SP/LU; EP 2^36 pairs; IS 2^31 keys, 10 ranking iters.
# flops/point/iteration from the NPB reports' operation counts.
# --------------------------------------------------------------------------

_GRID_D = 408 ** 3                # 6.79e7 points
_EP_PAIRS = 2 ** 36
_IS_KEYS = 2 ** 31

NPB_PROFILES = {
    # BT: ADI block-tridiagonal; compute-heavy, moderate nearest-neighbour comm
    "BT": JobProfile("BT", flops=_GRID_D * 250 * 5000,
                     net_bytes=250 * 6 * (408 ** 2) * 5 * 8 * 12,
                     disk_bytes=60e9, mem_bytes=_GRID_D * 250 * 900,
                     parallel_eff=0.85, vector_friendly=0.75, net_eff=0.5),
    # EP: embarrassingly parallel RNG (log/sqrt per pair); zero comm
    "EP": JobProfile("EP", flops=_EP_PAIRS * 100,
                     net_bytes=1e6, disk_bytes=1e8, mem_bytes=_EP_PAIRS * 16,
                     parallel_eff=0.99, vector_friendly=0.9, net_eff=0.5),
    # IS: integer bucket sort; all-to-all dominated, little compute
    "IS": JobProfile("IS", flops=_IS_KEYS * 45,
                     net_bytes=_IS_KEYS * 4 * 10 * 2.2,
                     disk_bytes=2e9, mem_bytes=_IS_KEYS * 4 * 10 * 6,
                     parallel_eff=0.80, vector_friendly=0.3, net_eff=0.15),
    # LU: SSOR wavefront; latency-sensitive pipelined comm, poor overlap
    "LU": JobProfile("LU", flops=_GRID_D * 300 * 2000,
                     net_bytes=300 * 6 * (408 ** 2) * 5 * 8 * 20,
                     disk_bytes=40e9, mem_bytes=_GRID_D * 300 * 600,
                     parallel_eff=0.70, vector_friendly=0.55, net_eff=0.10),
    # SP: scalar pentadiagonal ADI; like BT with more sweeps
    "SP": JobProfile("SP", flops=_GRID_D * 500 * 2800,
                     net_bytes=500 * 6 * (408 ** 2) * 5 * 8 * 12,
                     disk_bytes=50e9, mem_bytes=_GRID_D * 500 * 700,
                     parallel_eff=0.82, vector_friendly=0.7, net_eff=0.4),
}

# Paper Table 6: CNs allocated per system for each benchmark.
NPB_NODES = {
    #        Broadwell  CascadeLake  KNL  Skylake
    "BT": {"Broadwell": 5, "CascadeLake": 3, "KNL": 2, "Skylake": 4},
    "EP": {"Broadwell": 5, "CascadeLake": 3, "KNL": 2, "Skylake": 4},
    "IS": {"Broadwell": 8, "CascadeLake": 6, "KNL": 4, "Skylake": 8},
    "LU": {"Broadwell": 8, "CascadeLake": 6, "KNL": 4, "Skylake": 8},
    "SP": {"Broadwell": 8, "CascadeLake": 6, "KNL": 4, "Skylake": 8},
}

NPB_CORES = {"BT": 144, "EP": 144, "IS": 256, "LU": 256, "SP": 256}


def npb_tables(systems, programs=("BT", "EP", "IS", "LU", "SP")):
    """Dense (C, T, nodes) tables [P, S] for the NPB suite on the given
    systems — the ground truth the simulator and figures consume."""
    P, S = len(programs), len(systems)
    C = np.zeros((P, S))
    T = np.zeros((P, S))
    N = np.zeros((P, S), np.int32)
    for i, prog in enumerate(programs):
        prof = NPB_PROFILES[prog]
        for j, sys in enumerate(systems):
            n = NPB_NODES[prog][sys.name]
            N[i, j] = n
            C[i, j] = energy_coefficient(prof, sys, n)
            T[i, j] = predict_runtime(prof, sys, n)
    return C, T, N
