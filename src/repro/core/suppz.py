"""SUPPZ-style job-submission front-end (paper §Implementation).

Mirrors the paper's integration of the algorithm into SUPPZ's ``mpirun``:

- the submitted executable is identified by its HASH (the paper stores the
  hash of the binary as the program's unique id);
- the hash + submission arguments + measured (C, T) history live in a small
  on-disk database (msgpack);
- if the user names a resource type, the front-end only NOTIFIES (returns
  the recommendation); otherwise the job is auto-queued on the selected
  system;
- K comes from the administrator, or automatically from the ordered time:
  K = T_max / T (paper formula; as allowed-increase fraction).
"""

from __future__ import annotations

import hashlib
import os
from dataclasses import dataclass

import msgpack
import numpy as np
import jax
import jax.numpy as jnp

from repro.core.algorithm import select_system
from repro.core.profiles import k_auto


def program_id(executable_bytes: bytes) -> str:
    """The paper's unique program identifier: hash of the executable."""
    return hashlib.sha256(executable_bytes).hexdigest()[:16]


@dataclass
class Submission:
    executable: bytes           # or its contents; hashed for identity
    np_: int                    # processors requested ('np' in mpirun)
    t_max: float                # ordered occupancy time (seconds)
    resource_type: str | None = None   # user-pinned system (notify-only mode)
    k: float | None = None      # admin K (fraction); None => auto


@dataclass
class Decision:
    program: str
    system: str
    auto_queued: bool           # False => notification only (user pinned type)
    k_used: float
    explored: bool              # placement was an exploration run


class SuppzFrontend:
    """Persistent front-end over a set of systems (names fixed at init)."""

    def __init__(self, db_path: str, system_names):
        self.db_path = db_path
        self.systems = list(system_names)
        self.db = {"programs": {}}           # pid -> {"C": {}, "T": {}, "runs": {}, "submits": []}
        if os.path.exists(db_path):
            with open(db_path, "rb") as f:
                self.db = msgpack.unpackb(f.read())

    def _save(self):
        tmp = self.db_path + ".tmp"
        with open(tmp, "wb") as f:
            f.write(msgpack.packb(self.db))
        os.replace(tmp, self.db_path)

    def _entry(self, pid: str):
        return self.db["programs"].setdefault(
            pid, {"C": {}, "T": {}, "runs": {}, "submits": []})

    # ------------------------------------------------------------- submit
    def submit(self, sub: Submission, availability=None) -> Decision:
        pid = program_id(sub.executable)
        ent = self._entry(pid)
        ent["submits"].append({"np": sub.np_, "t_max": sub.t_max,
                               "type": sub.resource_type})

        c_row = np.array([ent["C"].get(s, 0.0) for s in self.systems])
        t_row = np.array([ent["T"].get(s, 0.0) for s in self.systems])
        runs = np.array([ent["runs"].get(s, 0) for s in self.systems])
        avail = (np.zeros(len(self.systems)) if availability is None
                 else np.asarray(availability, float))

        # K: admin-specified, else auto from ordered time vs best history
        if sub.k is not None:
            k = sub.k
        else:
            t_hist = t_row[runs > 0].min() if (runs > 0).any() else 0.0
            k = k_auto(sub.t_max, t_hist)

        idx = int(select_system(
            "paper",
            c_row=jnp.asarray(c_row, jnp.float32),
            t_row=jnp.asarray(t_row, jnp.float32),
            runs_row=jnp.asarray(runs, jnp.int32),
            avail_row=jnp.asarray(avail, jnp.float32),
            k=jnp.float32(k),
            c_pred_row=jnp.asarray(c_row, jnp.float32),
            t_pred_row=jnp.asarray(t_row, jnp.float32),
            key=jax.random.key(len(ent["submits"]))))

        self._save()
        return Decision(program=pid, system=self.systems[idx],
                        auto_queued=sub.resource_type is None,
                        k_used=k, explored=bool((runs == 0).any()))

    # ---------------------------------------------------------- complete
    def report_completion(self, executable: bytes, system: str,
                          c: float, t: float):
        """Store the measured profile after successful completion (running
        average over repeats, as ProfileStore does)."""
        pid = program_id(executable)
        ent = self._entry(pid)
        n = ent["runs"].get(system, 0)
        ent["C"][system] = (ent["C"].get(system, 0.0) * n + c) / (n + 1)
        ent["T"][system] = (ent["T"].get(system, 0.0) * n + t) / (n + 1)
        ent["runs"][system] = n + 1
        self._save()
