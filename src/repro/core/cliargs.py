"""One CLI option grammar for every scheduler entry point.

``launch/schedule.py`` (batch runs / campaigns) and
``launch/scheduler_service.py`` (the live JSONL loop, single session or
``--pool N``) used to declare near-identical-but-drifting option sets.
This module is the single definition of the shared grammar:

    --policy NAME[:key=val,...]   registered policy spec; values parse as
                                  floats, except ``window`` (int),
                                  ``queue`` (discipline name) and
                                  ``freq_tiers`` (a '+'-separated phi
                                  grid, e.g. ``freq_tiers=1.0+0.8+0.6``)
    --mode NAME / --k F           legacy spellings (--policy wins; --k
                                  fills in when the spec leaves k unset)
    --queue DISC[:window=W]       queue-discipline override:
                                  fcfs | easy_backfill | conservative
    --power-cap WATTS             SCC power cap (0 = uncapped); overrides
                                  the policy's ``power_cap`` leaf
    --engine {arrival,events}     scan granularity (``--core`` survives
                                  as a deprecated alias)
    --stragglers / --failures     fault-model probabilities
    --shards auto|N               device-shard the campaign grid axis
                                  (``add_scale_options``; shard_map over
                                  the ("grid",) mesh)
    --chunk SIZE                  stream the event scan in SIZE-step
                                  windows (bounded memory at J=10^6)

``build_policy`` / ``build_fault`` / ``build_engine`` resolve parsed
args into engine objects; ``policy_spec`` renders a scalar policy back
into the canonical ``--policy`` string (round-trip pinned in
tests/test_cliargs.py).  Every spelling that worked before the PR 9
consolidation still parses to the same Policy.
"""

from __future__ import annotations

import warnings

import numpy as np

from repro.core.engine import FaultConfig
from repro.core.policy import (QUEUES, Policy, apply_queue_spec, make_policy,
                               parse_policy_spec, policy_names)

#: caps at or above this count as "uncapped" when rendering a spec
#: (mirrors repro.core.policy.UNCAPPED without importing engine state)
_UNCAPPED = 1e29


def add_policy_options(ap, *, engine: bool = False, faults: bool = True):
    """Install the shared scheduler options on an argparse parser.

    ``engine=True`` adds the scan-granularity pair (``--engine`` plus the
    deprecated ``--core``); ``faults=True`` adds the fault-model
    probabilities.  Returns the parser for chaining.
    """
    ap.add_argument("--policy", default="", metavar="NAME[:key=val,...]",
                    help="registered policy spec, e.g. paper:k=0.1, "
                         "ucb:k=0.1,ucb_scale=0.25 or "
                         "dvfs_paper:freq_tiers=1.0+0.8+0.6,freq_weight=0.5"
                         f"; registry: {', '.join(policy_names())}")
    ap.add_argument("--mode", default="paper", choices=policy_names(),
                    help="legacy spelling of --policy NAME")
    ap.add_argument("--k", type=float, default=0.1,
                    help="legacy spelling of --policy NAME:k=F (fills in "
                         "when the spec does not set k)")
    ap.add_argument("--queue", default="", metavar="DISC[:window=W]",
                    help="queue discipline overriding the policy's own: "
                         f"{' | '.join(QUEUES)}; e.g. easy_backfill:window=16"
                         " or conservative:window=16")
    ap.add_argument("--power-cap", type=float, default=0.0, metavar="WATTS",
                    help="SCC power cap (0 = uncapped): placements are "
                         "deferred while cluster draw would exceed it "
                         "(event-granular core)")
    if engine:
        ap.add_argument("--engine", default="",
                        choices=("", "arrival", "events"),
                        help="scan granularity (default: auto — events for "
                             "conservative/power-capped runs)")
        ap.add_argument("--core", default="",
                        choices=("", "arrival", "events"),
                        help="DEPRECATED spelling of --engine")
    if faults:
        ap.add_argument("--stragglers", type=float, default=0.0,
                        help="per-job straggler probability")
        ap.add_argument("--failures", type=float, default=0.0,
                        help="per-job failure probability (enables retries)")
    return ap


def add_scale_options(ap):
    """Install the campaign scale-out pair (``--shards``/``--chunk``) —
    shared by the batch CLI and the million-job benches.  Returns the
    parser for chaining."""
    ap.add_argument("--shards", default="", metavar="auto|N",
                    help="shard the campaign grid across local devices "
                         "(shard_map): 'auto' = every device, N = explicit "
                         "count; default: single-device vmap")
    ap.add_argument("--chunk", type=int, default=0, metavar="SIZE",
                    help="stream the event scan in SIZE-step windows with "
                         "the carry threaded between chunks (bounded "
                         "memory for million-job traces; 0 = monolithic)")
    return ap


def build_scale(args) -> dict:
    """Resolve the scale-out pair into ``Scheduler(shards=, chunk=)``
    kwargs (absent flags resolve to the single-device monolithic
    defaults, so callers can always ``**build_scale(args)``)."""
    shards = getattr(args, "shards", "") or None
    if shards is not None and shards != "auto":
        try:
            shards = int(shards)
        except ValueError:
            raise ValueError(
                f"--shards expects 'auto' or a device count, got "
                f"{shards!r}") from None
    chunk = int(getattr(args, "chunk", 0) or 0) or None
    return {"shards": shards, "chunk": chunk}


def build_policy(args) -> Policy:
    """Resolve the parsed shared options into one ``Policy``: the spec
    (or the legacy ``--mode``/``--k`` pair), then the ``--queue``
    override, then the ``--power-cap`` override — the same precedence
    both CLIs historically applied."""
    if args.policy:
        pol = parse_policy_spec(args.policy, k=args.k)
    else:
        pol = make_policy(args.mode, k=args.k)
    if args.queue:
        pol = apply_queue_spec(pol, args.queue)
    if args.power_cap:
        from dataclasses import replace
        pol = replace(pol, power_cap=float(args.power_cap))
    return pol


def build_fault(args) -> FaultConfig | None:
    """The fault model the flags describe, or None when both are zero."""
    if args.failures or args.stragglers:
        return FaultConfig(straggler_prob=args.stragglers,
                           failure_prob=args.failures)
    return None


def build_engine(args) -> str | None:
    """Resolve ``--engine`` (with the deprecated ``--core`` alias) to the
    ``Scheduler(engine=...)`` value; conflicting values are an error."""
    core = getattr(args, "core", "")
    engine = getattr(args, "engine", "")
    if core:
        warnings.warn("--core is deprecated; use --engine",
                      DeprecationWarning, stacklevel=2)
        if engine and engine != core:
            raise ValueError(f"--core {core} conflicts with --engine "
                             f"{engine}")
        engine = engine or core
    return engine or None


def _fmt(x) -> str:
    f = float(np.asarray(x))
    if not np.isfinite(f):
        return "inf"
    return np.format_float_positional(f, trim="-")


def policy_spec(pol: Policy) -> str:
    """Render a scalar-leaf policy as the canonical ``--policy`` string
    (``parse_policy_spec(policy_spec(p)) == p``, tests/test_cliargs.py).
    Grid-leaf policies have no CLI spelling and are rejected."""
    if not pol.name:
        raise ValueError("only registered (named) policies have a spec")
    for leaf in ("k", "ucb_scale", "power_cap", "freq_weight"):
        if np.asarray(getattr(pol, leaf)).ndim:
            raise ValueError(f"policy leaf {leaf!r} is a grid; specs "
                             "describe single points")
    parts = [f"k={_fmt(pol.k)}", f"ucb_scale={_fmt(pol.ucb_scale)}",
             f"queue={pol.queue}", f"window={int(pol.window)}"]
    cap = float(np.asarray(pol.power_cap))
    if cap < _UNCAPPED:
        parts.append(f"power_cap={_fmt(cap)}")
    if pol.freq_tiers != (1.0,):
        parts.append("freq_tiers=" + "+".join(_fmt(t)
                                              for t in pol.freq_tiers))
        parts.append(f"freq_weight={_fmt(pol.freq_weight)}")
    return f"{pol.name}:{','.join(parts)}"
