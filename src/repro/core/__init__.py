from repro.core.systems import (
    ComputeSystem, JSCC_SYSTEMS, JSCC_BY_NAME, TPU_SYSTEMS, ALL_SYSTEMS,
    KNL, BROADWELL, SKYLAKE, CASCADE_LAKE,
)
from repro.core.workload_model import (
    JobProfile, NPB_PROFILES, NPB_NODES, NPB_CORES, npb_tables,
    predict_runtime, predict_energy, predict_phases, energy_coefficient,
)
from repro.core.profiles import ProfileStore, k_auto
from repro.core.policy import (
    Policy, register_policy, make_policy, policy_names, parse_policy_spec,
    parse_queue_spec, select_batched,
    EXPLORATIONS, FEASIBILITIES, OBJECTIVES, QUEUES,
)
from repro.core.algorithm import select_system, MODES
from repro.core.result import SimResult, CampaignResult
from repro.core.engine import Scheduler
from repro.core.simulator import (
    SimConfig, FaultConfig, Workload, make_npb_workload,
    simulate_jax, simulate_py, sweep_k, run_campaign,
)
from repro.core import energy
