"""Legacy simulator surface + float64 python differential mirror.

The scan core and batching now live in ``repro.core.engine`` behind the
``Scheduler`` facade; the policy family lives in ``repro.core.policy``.
This module keeps the historical entry points working unchanged:

  - ``simulate_jax(w, scfg)``      == ``Scheduler(policy).run(w)``
  - ``sweep_k(w, scfg, ks)``       == ``Scheduler(policy-with-K-grid).run(w)``
  - ``run_campaign(w, scfg, ...)`` == ``Scheduler(policy, faults, seeds).run(w)``

all returning the historical dict-of-arrays schema (now a superset: the
structured-result derived metrics ride along).  They are thin shims over
the same jitted engine, so their placements and totals are bit-identical
to the facade's — asserted in tests/test_engine_api.py.

``simulate_py`` is the plain-Python float64 mirror used for differential
testing.  It dispatches through the same policy registry as the engine
(``policy.select_py``), so every registered policy — including ones added
after this writing — is differential-testable with zero extra mirror code.
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.engine import (                     # noqa: F401 (re-exports)
    BIG, FaultConfig, Scheduler, SimConfig, Workload, make_npb_workload,
)
from repro.core.policy import (                     # noqa: F401 (re-exports)
    make_policy, select_py, _paper_rule_py,
)


def simulate_jax(w: Workload, scfg: SimConfig):
    """Run the sim; returns dict of per-job arrays + totals (all jnp).

    Legacy shim: ``Scheduler(scfg.policy(), ...).run(w).to_dict()``.
    """
    return _scheduler_for(scfg).run(w).to_dict()


def sweep_k(w: Workload, scfg: SimConfig, ks):
    """vmap the whole simulation over the K axis (Figs 1-4 are one call).

    As in ``run_campaign``, explicit per-job overrides in ``w.k_job`` take
    precedence over the swept K at their positions; jobs with NaN k_job
    (the default) follow the grid.  Legacy shim: a K-grid policy is one
    leaf-batched ``Policy``.
    """
    pol = scfg.policy().with_params(k=jnp.asarray(list(ks), jnp.float32))
    return _scheduler_for(scfg, policy=pol).run(w).to_dict()


def run_campaign(w: Workload, scfg: SimConfig, ks=None, seeds=None,
                 faults=None):
    """Simulate the whole (fault-config x K x seed) grid in ONE jitted call.

    ks:     iterable of K values            (default: [scfg.k])
    seeds:  iterable of PRNG seeds          (default: [scfg.seed])
    faults: iterable of FaultConfig         (default: scfg's fault fields)

    Returns the simulate_jax dict with leading axes [K, R] — or [F, K, R]
    when a fault grid is given — on every entry (per-job arrays become
    [..., J], totals become [...]).  Per-job K overrides in ``w.k_job``
    take precedence over the swept K at their positions.
    """
    ks = [scfg.k] if ks is None else list(ks)
    pol = scfg.policy().with_params(k=jnp.asarray(ks, jnp.float32))
    seeds = [scfg.seed] if seeds is None else list(seeds)
    sched = _scheduler_for(scfg, policy=pol, seeds=seeds,
                           faults=None if faults is None else tuple(faults))
    return sched.run(w).to_dict()


def _scheduler_for(scfg: SimConfig, policy=None, seeds=None, faults=None):
    """SimConfig -> Scheduler, preserving the legacy axis conventions."""
    return Scheduler(
        scfg.policy() if policy is None else policy,
        placer=scfg.placer, warm_start=scfg.warm_start,
        seeds=scfg.seed if seeds is None else seeds,
        faults=FaultConfig(
            straggler_prob=scfg.straggler_prob,
            straggler_factor=scfg.straggler_factor,
            failure_prob=scfg.failure_prob,
            restart_overhead=scfg.restart_overhead,
        ) if faults is None else faults)


# ------------------------------------------------------------ python mirror

class _PySim:
    """Mutable float64 simulation state shared by the mirror's queue
    disciplines: per-node free-time lists, learned tables, and the
    placement primitives that must stay in lockstep with the jax engine
    (``_earliest`` / ``_alloc`` / the table update in ``_scan_sim``)."""

    def __init__(self, w: Workload, scfg: SimConfig, pol):
        self.w, self.scfg, self.pol = w, scfg, pol
        P, S = w.T_true.shape
        self.S = S
        self.node_free = [list(np.zeros(int(n))) for n in w.n_nodes]
        if scfg.warm_start:
            self.C_tab, self.T_tab = w.C_true.copy(), w.T_true.copy()
            self.runs = np.ones((P, S), np.int64)
        else:
            self.C_tab = np.zeros((P, S))
            self.T_tab = np.zeros((P, S))
            self.runs = np.zeros((P, S), np.int64)
        self.sel_key = (jax.random.split(jax.random.key(scfg.seed))[0]
                        if pol.objective == "random" else None)

    def avail_for(self, p: int, arr: float, node_free=None) -> np.ndarray:
        """Earliest start per system (float64 kth-free + outage push)."""
        w, S = self.w, self.S
        node_free = self.node_free if node_free is None else node_free
        avail = np.empty(S)
        for s in range(S):
            free = sorted(node_free[s])
            need = int(w.n_req[p, s])
            avail[s] = max(arr, free[need - 1]) if need <= len(free) else BIG
            if w.outage is not None:
                for o0, o1 in w.outage[s]:
                    if o0 <= avail[s] < o1:
                        avail[s] = o1
        return avail

    def choose(self, j: int):
        """Policy selection for job j under current state: returns
        (p, arr, avail, sel)."""
        w = self.w
        p = int(w.prog[j])
        arr = float(w.arrival[j])
        kj = float(w.k_job[j])
        k = self.scfg.k if np.isnan(kj) else kj
        avail = self.avail_for(p, arr)
        rand_sel = None
        if self.pol.objective == "random":
            rand_sel = int(jax.random.randint(
                jax.random.fold_in(self.sel_key, j), (), 0, self.S))
        sel = select_py(
            self.pol, c_row=self.C_tab[p], t_row=self.T_tab[p],
            runs_row=self.runs[p], avail_row=avail, k=k,
            c_pred_row=w.C_pred[p], t_pred_row=w.T_pred[p],
            rand_sel=rand_sel)
        return p, arr, avail, sel

    @staticmethod
    def alloc(node_free, sel: int, need: int, finish: float):
        """Allocate the ``need`` earliest-free nodes (stable argsort ==
        the engine's first-by-index tie-break)."""
        idx = np.argsort(node_free[sel])[:need]
        for i in idx:
            node_free[sel][int(i)] = finish

    def place(self, j: int):
        """Place job j (the FCFS step body): allocate, update tables,
        return the per-job record."""
        w = self.w
        p, arr, avail, sel = self.choose(j)
        T_act = float(w.T_true[p, sel])
        E_act = float(w.E_true[p, sel])
        C_act = float(w.C_true[p, sel])
        start = float(avail[sel])
        finish = start + T_act
        self.alloc(self.node_free, sel, int(w.n_req[p, sel]), finish)
        n = self.runs[p, sel]
        self.C_tab[p, sel] = (self.C_tab[p, sel] * n + C_act) / (n + 1)
        self.T_tab[p, sel] = (self.T_tab[p, sel] * n + T_act) / (n + 1)
        self.runs[p, sel] += 1
        return (sel, start, finish, start - arr, E_act, T_act)


def _easy_order_py(sim: _PySim, J: int, window: int):
    """Replay the engine's EASY-backfill step decisions (one placement per
    step, bounded pending window, no-delay reservation guard); yields
    (job, backfilled) in placement order."""
    w = sim.w
    pend: list[int] = []
    for t in range(J + window):
        now = float(w.arrival[t]) if t < J else np.inf
        if t < J:
            pend.append(t)
        if not pend:
            continue
        h = pend[0]
        p_h, arr_h, avail_h, sel_h = sim.choose(h)
        r_h = float(avail_h[sel_h])
        chosen = None
        if len(pend) == window + 1 or r_h <= now:   # overflow: FCFS fallback
            chosen = 0
        else:
            for ci in range(1, len(pend)):
                b = pend[ci]
                p_b, _, avail_b, sel_b = sim.choose(b)
                s_b = float(avail_b[sel_b])
                trial = [list(fl) for fl in sim.node_free]
                sim.alloc(trial, sel_b, int(w.n_req[p_b, sel_b]),
                          s_b + float(w.T_true[p_b, sel_b]))
                if sim.avail_for(p_h, arr_h, trial)[sel_h] <= r_h:
                    chosen = ci
                    break
        if chosen is not None:
            yield pend.pop(chosen), chosen > 0


def simulate_py(w: Workload, scfg: SimConfig):
    """Reference implementation for differential tests (no faults path).

    Dispatches through the policy registry (``scfg.mode`` may name ANY
    registered policy) and mirrors both queue disciplines — FCFS arrival
    order and EASY backfilling (reservation semantics replayed step for
    step).  All arithmetic runs in float64 numpy — an independent-precision
    check of the f32 jax engine — except the "random" draw, which replays
    the jax PRNG stream so the two implementations place identically.
    """
    assert scfg.straggler_prob == 0 and scfg.failure_prob == 0, \
        "python mirror covers the deterministic path"
    pol = scfg.policy()
    sim = _PySim(w, scfg, pol)
    J = len(w.prog)
    if pol.queue == "easy_backfill":
        order = _easy_order_py(sim, J, int(pol.window))
    else:
        order = ((j, False) for j in range(J))
    out = [None] * J
    backfilled = np.zeros(J, bool)
    for j, bf in order:
        out[j] = sim.place(j)
        backfilled[j] = bf
    assert all(rec is not None for rec in out), "job left unplaced"

    sel, start, finish, wait, E, T_act = map(np.array, zip(*out))
    return {
        "system": sel, "start": start, "finish": finish, "wait": wait,
        "energy": E, "runtime": T_act, "backfilled": backfilled,
        "n_backfilled": int(backfilled.sum()),
        "total_energy": E.sum(), "makespan": finish.max(),
        "total_wait": wait.sum(), "max_wait": wait.max(),
    }
