"""Discrete-event multi-system JMS simulator.

Models the paper's SCC: several computing systems (CC_1..CC_S), each a pool
of interchangeable nodes with per-node free-times; a global job queue routed
by a meta-scheduler (repro.core.algorithm).  Jobs are programs with known
per-system ground-truth (T, C, E) from the phase model.

Two equivalent implementations:
  - ``simulate_jax``: lax.scan over the job stream; jit-able and vmap-able
    over the K sweep (Figs 1-4 are one vmapped call);
  - ``simulate_py``: plain-Python mirror used for differential testing.

Fault model (DESIGN.md §7): per-job deterministic pseudo-random straggler
slowdowns and node-failure restarts (checkpoint-restart semantics: a failed
job re-does ``restart_overhead`` of its work; energy scales accordingly).
The learned (C, T) tables absorb these — the paper's history mechanism
routes around chronically degraded systems automatically.

Accounting notes: energy is attributed per job (allocated nodes over the
job's span, paper eq. 2); idle energy of unallocated nodes is not attributed
to the suite (the paper compares job-attributed energy).  Learned-table
updates apply as each job is *placed* (the paper stores them at completion;
for the paper's simultaneous-submission experiment the two coincide —
distinct programs never wait on each other's profile entries).
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.algorithm import select_system
from repro.core.systems import ComputeSystem
from repro.core.workload_model import (
    NPB_PROFILES, NPB_NODES, npb_tables, predict_energy)

BIG = 1e30


@dataclass(frozen=True)
class SimConfig:
    mode: str = "paper"
    k: float = 0.0                 # allowed runtime-increase fraction
    straggler_prob: float = 0.0
    straggler_factor: float = 2.0
    failure_prob: float = 0.0
    restart_overhead: float = 0.5
    seed: int = 0
    # True => profile tables pre-filled with ground truth (the paper's
    # Figs 1-4 regime: 'all 5 previously run programs', Tables 3-4 full).
    warm_start: bool = False


@dataclass(frozen=True)
class Workload:
    """Static description of a job stream over P programs x S systems."""
    prog: np.ndarray            # [J] int32 program ids
    arrival: np.ndarray         # [J] f32 submit times
    k_job: np.ndarray           # [J] f32 per-job K (fraction); NaN -> global k
    n_req: np.ndarray           # [P, S] nodes needed
    T_true: np.ndarray          # [P, S] runtime ground truth
    C_true: np.ndarray          # [P, S] J/Mop ground truth
    E_true: np.ndarray          # [P, S] Joules ground truth
    T_pred: np.ndarray          # [P, S] phase-model predictions
    C_pred: np.ndarray
    n_nodes: np.ndarray         # [S] node counts
    programs: tuple = ()        # names, for reports
    systems: tuple = ()


def make_npb_workload(systems, order=("BT", "EP", "IS", "LU", "SP"),
                      arrivals=None, k_job=None, repeats: int = 1,
                      pred_noise: float = 0.0, noise_seed: int = 0):
    """The paper's experiment: NPB suite submitted (simultaneously by
    default) to the four JSCC systems. ``repeats`` re-submits the suite."""
    programs = tuple(sorted(set(order)))
    pidx = {p: i for i, p in enumerate(programs)}
    C, T, N = npb_tables(systems, programs)
    mops = np.array([NPB_PROFILES[p].flops / 1e6 for p in programs])
    E = C * mops[:, None]
    rng = np.random.default_rng(noise_seed)
    noise = (1.0 + pred_noise * rng.standard_normal(C.shape)) if pred_noise else 1.0
    seq = list(order) * repeats
    J = len(seq)
    return Workload(
        prog=np.array([pidx[p] for p in seq], np.int32),
        arrival=np.zeros(J, np.float32) if arrivals is None
        else np.asarray(arrivals, np.float32),
        k_job=np.full(J, np.nan, np.float32) if k_job is None
        else np.asarray(k_job, np.float32),
        n_req=N, T_true=T, C_true=C, E_true=E,
        T_pred=T * noise, C_pred=C * noise,
        n_nodes=np.array([s.n_nodes for s in systems], np.int32),
        programs=programs, systems=tuple(s.name for s in systems),
    )


def _fault_factor(key, j, scfg: SimConfig):
    u = jax.random.uniform(jax.random.fold_in(key, j), (2,))
    slow = jnp.where(u[0] < scfg.straggler_prob, scfg.straggler_factor, 1.0)
    fail = jnp.where(u[1] < scfg.failure_prob, 1.0 + scfg.restart_overhead, 1.0)
    return slow * fail


def _simulate_core(w: Workload, scfg: SimConfig, kvec):
    """lax.scan simulation core; kvec is the (possibly traced) per-job K."""
    P, S = w.T_true.shape
    max_n = int(w.n_nodes.max())
    J = len(w.prog)
    key = jax.random.key(scfg.seed)

    node_exists = np.arange(max_n)[None, :] < w.n_nodes[:, None]   # [S, maxN]
    free0 = jnp.where(jnp.asarray(node_exists), 0.0, BIG)
    prog = jnp.asarray(w.prog)
    arrival = jnp.asarray(w.arrival)
    n_req = jnp.asarray(w.n_req)
    T_true, C_true, E_true = map(jnp.asarray, (w.T_true, w.C_true, w.E_true))
    T_pred, C_pred = jnp.asarray(w.T_pred), jnp.asarray(w.C_pred)

    def step(carry, xs):
        node_free, C_tab, T_tab, runs = carry
        j, p, arr, k = xs

        nreq_row = n_req[p]                                      # [S]
        sorted_free = jnp.sort(node_free, axis=1)
        kth = jnp.take_along_axis(
            sorted_free, jnp.maximum(nreq_row - 1, 0)[:, None], axis=1)[:, 0]
        avail = jnp.maximum(arr, kth)

        sel = select_system(
            scfg.mode, c_row=C_tab[p], t_row=T_tab[p], runs_row=runs[p],
            avail_row=avail, k=k, c_pred_row=C_pred[p], t_pred_row=T_pred[p],
            key=jax.random.fold_in(key, j))

        factor = _fault_factor(key, j + 10_000, scfg)
        T_act = T_true[p, sel] * factor
        C_act = C_true[p, sel] * factor
        E_act = E_true[p, sel] * factor
        start = avail[sel]
        finish = start + T_act

        free_sel = node_free[sel]
        ranks = jnp.argsort(jnp.argsort(free_sel))
        mask = ranks < nreq_row[sel]
        node_free = node_free.at[sel].set(jnp.where(mask, finish, free_sel))

        n = runs[p, sel].astype(jnp.float32)
        C_tab = C_tab.at[p, sel].set((C_tab[p, sel] * n + C_act) / (n + 1))
        T_tab = T_tab.at[p, sel].set((T_tab[p, sel] * n + T_act) / (n + 1))
        runs = runs.at[p, sel].add(1)

        out = (sel, start, finish, start - arr, E_act, T_act)
        return (node_free, C_tab, T_tab, runs), out

    if scfg.warm_start:
        carry0 = (free0, C_true, T_true, jnp.ones((P, S), jnp.int32))
    else:
        carry0 = (free0, jnp.zeros((P, S)), jnp.zeros((P, S)),
                  jnp.zeros((P, S), jnp.int32))
    xs = (jnp.arange(J), prog, arrival, kvec)
    (node_free, C_tab, T_tab, runs), (sel, start, finish, wait, E, T_act) = \
        jax.lax.scan(step, carry0, xs)

    return {
        "system": sel, "start": start, "finish": finish, "wait": wait,
        "energy": E, "runtime": T_act,
        "total_energy": E.sum(), "makespan": finish.max(),
        "total_wait": wait.sum(),
        "C_tab": C_tab, "T_tab": T_tab, "runs": runs,
    }


def simulate_jax(w: Workload, scfg: SimConfig):
    """Run the sim; returns dict of per-job arrays + totals (all jnp)."""
    kvec = jnp.where(jnp.isnan(jnp.asarray(w.k_job)),
                     jnp.float32(scfg.k), jnp.asarray(w.k_job))
    return _simulate_core(w, scfg, kvec)


def sweep_k(w: Workload, scfg: SimConfig, ks):
    """vmap the whole simulation over the K axis (Figs 1-4 in one call)."""
    ks = jnp.asarray(ks, jnp.float32)
    return jax.jit(jax.vmap(
        lambda k: _simulate_core(w, scfg, jnp.full((len(w.prog),), k))))(ks)


# ------------------------------------------------------------ python mirror

def simulate_py(w: Workload, scfg: SimConfig):
    """Reference implementation for differential tests (no faults path)."""
    assert scfg.straggler_prob == 0 and scfg.failure_prob == 0, \
        "python mirror covers the deterministic path"
    P, S = w.T_true.shape
    node_free = [list(np.zeros(int(n))) for n in w.n_nodes]
    if scfg.warm_start:
        C_tab, T_tab = w.C_true.copy(), w.T_true.copy()
        runs = np.ones((P, S), np.int64)
    else:
        C_tab = np.zeros((P, S))
        T_tab = np.zeros((P, S))
        runs = np.zeros((P, S), np.int64)
    out = []
    for j, p in enumerate(w.prog):
        arr = float(w.arrival[j])
        kj = float(w.k_job[j])
        k = scfg.k if np.isnan(kj) else kj
        avail = np.empty(S)
        for s in range(S):
            free = sorted(node_free[s])
            need = int(w.n_req[p, s])
            avail[s] = max(arr, free[need - 1]) if need <= len(free) else BIG

        known = runs[p] > 0
        if scfg.mode in ("paper", "fastest", "greenest") and (~known).any():
            cand = np.where(~known)[0]
            sel = int(cand[np.argmin(avail[cand])])
        elif scfg.mode == "first_free":
            sel = int(np.argmin(avail))
        else:
            if scfg.mode == "paper":
                c_row, t_row = C_tab[p], T_tab[p]
            elif scfg.mode == "oracle":
                c_row, t_row = w.C_pred[p], w.T_pred[p]
            elif scfg.mode == "fastest":
                sel = int(np.argmin(np.where(known, T_tab[p], BIG)))
                c_row = None
            elif scfg.mode == "greenest":
                sel = int(np.argmin(np.where(known, C_tab[p], BIG)))
                c_row = None
            else:
                raise NotImplementedError(scfg.mode)
            if scfg.mode in ("paper", "oracle"):
                t_min = t_row.min()
                feas = t_row <= t_min * (1 + k)
                score = np.where(feas, c_row, BIG)
                best = score.min()
                tie = score <= best * (1 + 1e-9)
                sel = int(np.argmin(np.where(tie, t_row, BIG)))

        T_act = float(w.T_true[p, sel])
        E_act = float(w.E_true[p, sel])
        C_act = float(w.C_true[p, sel])
        start = float(avail[sel])
        finish = start + T_act
        need = int(w.n_req[p, sel])
        idx = np.argsort(node_free[sel])[:need]
        for i in idx:
            node_free[sel][int(i)] = finish
        n = runs[p, sel]
        C_tab[p, sel] = (C_tab[p, sel] * n + C_act) / (n + 1)
        T_tab[p, sel] = (T_tab[p, sel] * n + T_act) / (n + 1)
        runs[p, sel] += 1
        out.append((sel, start, finish, start - arr, E_act, T_act))

    sel, start, finish, wait, E, T_act = map(np.array, zip(*out))
    return {
        "system": sel, "start": start, "finish": finish, "wait": wait,
        "energy": E, "runtime": T_act,
        "total_energy": E.sum(), "makespan": finish.max(),
        "total_wait": wait.sum(),
    }
