"""Legacy simulator surface + float64 python differential mirror.

The scan core and batching now live in ``repro.core.engine`` behind the
``Scheduler`` facade; the policy family lives in ``repro.core.policy``.
This module keeps the historical entry points working unchanged:

  - ``simulate_jax(w, scfg)``      == ``Scheduler(policy).run(w)``
  - ``sweep_k(w, scfg, ks)``       == ``Scheduler(policy-with-K-grid).run(w)``
  - ``run_campaign(w, scfg, ...)`` == ``Scheduler(policy, faults, seeds).run(w)``

all returning the historical dict-of-arrays schema (now a superset: the
structured-result derived metrics ride along).  They are thin shims over
the same jitted engine, so their placements and totals are bit-identical
to the facade's — asserted in tests/test_engine_api.py.

``simulate_py`` is the plain-Python float64 mirror used for differential
testing.  It dispatches through the same policy registry as the engine
(``policy.select_py``), so every registered policy — including ones added
after this writing — is differential-testable with zero extra mirror code.
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.engine import (                     # noqa: F401 (re-exports)
    BIG, FaultConfig, Scheduler, SimConfig, Workload, make_npb_workload,
)
from repro.core.policy import (                     # noqa: F401 (re-exports)
    make_policy, select_py, _paper_rule_py,
)


def simulate_jax(w: Workload, scfg: SimConfig):
    """Run the sim; returns dict of per-job arrays + totals (all jnp).

    Legacy shim: ``Scheduler(scfg.policy(), ...).run(w).to_dict()``.
    """
    return _scheduler_for(scfg).run(w).to_dict()


def sweep_k(w: Workload, scfg: SimConfig, ks):
    """vmap the whole simulation over the K axis (Figs 1-4 are one call).

    As in ``run_campaign``, explicit per-job overrides in ``w.k_job`` take
    precedence over the swept K at their positions; jobs with NaN k_job
    (the default) follow the grid.  Legacy shim: a K-grid policy is one
    leaf-batched ``Policy``.
    """
    pol = make_policy(scfg.mode, k=jnp.asarray(list(ks), jnp.float32))
    return _scheduler_for(scfg, policy=pol).run(w).to_dict()


def run_campaign(w: Workload, scfg: SimConfig, ks=None, seeds=None,
                 faults=None):
    """Simulate the whole (fault-config x K x seed) grid in ONE jitted call.

    ks:     iterable of K values            (default: [scfg.k])
    seeds:  iterable of PRNG seeds          (default: [scfg.seed])
    faults: iterable of FaultConfig         (default: scfg's fault fields)

    Returns the simulate_jax dict with leading axes [K, R] — or [F, K, R]
    when a fault grid is given — on every entry (per-job arrays become
    [..., J], totals become [...]).  Per-job K overrides in ``w.k_job``
    take precedence over the swept K at their positions.
    """
    ks = [scfg.k] if ks is None else list(ks)
    pol = make_policy(scfg.mode, k=jnp.asarray(ks, jnp.float32))
    seeds = [scfg.seed] if seeds is None else list(seeds)
    sched = _scheduler_for(scfg, policy=pol, seeds=seeds,
                           faults=None if faults is None else tuple(faults))
    return sched.run(w).to_dict()


def _scheduler_for(scfg: SimConfig, policy=None, seeds=None, faults=None):
    """SimConfig -> Scheduler, preserving the legacy axis conventions."""
    return Scheduler(
        scfg.policy() if policy is None else policy,
        placer=scfg.placer, warm_start=scfg.warm_start,
        seeds=scfg.seed if seeds is None else seeds,
        faults=FaultConfig(
            straggler_prob=scfg.straggler_prob,
            straggler_factor=scfg.straggler_factor,
            failure_prob=scfg.failure_prob,
            restart_overhead=scfg.restart_overhead,
        ) if faults is None else faults)


# ------------------------------------------------------------ python mirror

def simulate_py(w: Workload, scfg: SimConfig):
    """Reference implementation for differential tests (no faults path).

    Dispatches through the policy registry (``scfg.mode`` may name ANY
    registered policy).  All arithmetic runs in float64 numpy — an
    independent-precision check of the f32 jax engine — except the
    "random" draw, which replays the jax PRNG stream so the two
    implementations place identically.
    """
    assert scfg.straggler_prob == 0 and scfg.failure_prob == 0, \
        "python mirror covers the deterministic path"
    pol = make_policy(scfg.mode)
    P, S = w.T_true.shape
    node_free = [list(np.zeros(int(n))) for n in w.n_nodes]
    if scfg.warm_start:
        C_tab, T_tab = w.C_true.copy(), w.T_true.copy()
        runs = np.ones((P, S), np.int64)
    else:
        C_tab = np.zeros((P, S))
        T_tab = np.zeros((P, S))
        runs = np.zeros((P, S), np.int64)
    sel_key = (jax.random.split(jax.random.key(scfg.seed))[0]
               if pol.objective == "random" else None)
    out = []
    for j, p in enumerate(w.prog):
        arr = float(w.arrival[j])
        kj = float(w.k_job[j])
        k = scfg.k if np.isnan(kj) else kj
        avail = np.empty(S)
        for s in range(S):
            free = sorted(node_free[s])
            need = int(w.n_req[p, s])
            avail[s] = max(arr, free[need - 1]) if need <= len(free) else BIG
            if w.outage is not None:
                for o0, o1 in w.outage[s]:
                    if o0 <= avail[s] < o1:
                        avail[s] = o1

        rand_sel = None
        if pol.objective == "random":
            rand_sel = int(jax.random.randint(
                jax.random.fold_in(sel_key, j), (), 0, S))
        sel = select_py(
            pol, c_row=C_tab[p], t_row=T_tab[p], runs_row=runs[p],
            avail_row=avail, k=k, c_pred_row=w.C_pred[p],
            t_pred_row=w.T_pred[p], rand_sel=rand_sel)

        T_act = float(w.T_true[p, sel])
        E_act = float(w.E_true[p, sel])
        C_act = float(w.C_true[p, sel])
        start = float(avail[sel])
        finish = start + T_act
        need = int(w.n_req[p, sel])
        idx = np.argsort(node_free[sel])[:need]
        for i in idx:
            node_free[sel][int(i)] = finish
        n = runs[p, sel]
        C_tab[p, sel] = (C_tab[p, sel] * n + C_act) / (n + 1)
        T_tab[p, sel] = (T_tab[p, sel] * n + T_act) / (n + 1)
        runs[p, sel] += 1
        out.append((sel, start, finish, start - arr, E_act, T_act))

    sel, start, finish, wait, E, T_act = map(np.array, zip(*out))
    return {
        "system": sel, "start": start, "finish": finish, "wait": wait,
        "energy": E, "runtime": T_act,
        "total_energy": E.sum(), "makespan": finish.max(),
        "total_wait": wait.sum(),
    }
