"""Discrete-event multi-system JMS simulator — campaign-scale engine.

Models the paper's SCC: several computing systems (CC_1..CC_S), each a pool
of interchangeable nodes with per-node free-times; a global job queue routed
by a meta-scheduler (repro.core.algorithm).  Jobs are programs with known
per-system ground-truth (T, C, E) from the phase model.

Two equivalent implementations:
  - ``simulate_jax``: lax.scan over the job stream through ONE jitted,
    vmap-batched core shared with ``sweep_k`` and ``run_campaign`` — the
    whole (fault-config x K x seed) grid of a campaign is a single jit;
  - ``simulate_py``: plain-Python mirror covering every mode in
    ``algorithm.MODES``, used for differential testing.

Placement hot path: the per-step question "when are n_req[s] nodes of
system s free?" is the n_req-th smallest entry of the node-free row.  The
seed implementation re-sorted the full [S, maxN] matrix every step; the
engine now radix-selects the kth value directly (repro.kernels.kth_free:
Pallas kernel on TPU, pure-jnp twin elsewhere, O(S·maxN) per step and
bit-exact against the sort oracle), and allocates nodes by thresholding
against that value instead of double-argsort ranking.  Which of several
nodes tied at the threshold get allocated is unspecified — they carry the
same free time, so the node-free multiset (and hence every downstream
placement) is identical either way.

Fault model (DESIGN.md §7): per-job deterministic pseudo-random straggler
slowdowns and node-failure restarts (checkpoint-restart semantics: a failed
job re-does ``restart_overhead`` of its work; energy scales accordingly).
The learned (C, T) tables absorb these — the paper's history mechanism
routes around chronically degraded systems automatically.

Maintenance/outage windows (scenario library, repro.data.scenarios): a
system accepts no new placements while a window [t0, t1) is open; jobs
whose earliest start falls inside a window are pushed to its end.  Windows
must be sorted by start and non-overlapping per system.  Jobs already
running ride through (drain semantics).

Accounting notes: energy is attributed per job (allocated nodes over the
job's span, paper eq. 2); idle energy of unallocated nodes is not attributed
to the suite (the paper compares job-attributed energy).  Learned-table
updates apply as each job is *placed* (the paper stores them at completion;
for the paper's simultaneous-submission experiment the two coincide —
distinct programs never wait on each other's profile entries).
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.algorithm import select_system
from repro.core.systems import ComputeSystem
from repro.core.workload_model import (
    NPB_PROFILES, NPB_NODES, npb_tables, predict_energy)
from repro.kernels.kth_free import kth_free_time

BIG = 1e30


@dataclass(frozen=True)
class SimConfig:
    mode: str = "paper"
    k: float = 0.0                 # allowed runtime-increase fraction
    straggler_prob: float = 0.0
    straggler_factor: float = 2.0
    failure_prob: float = 0.0
    restart_overhead: float = 0.5
    seed: int = 0
    # True => profile tables pre-filled with ground truth (the paper's
    # Figs 1-4 regime: 'all 5 previously run programs', Tables 3-4 full).
    warm_start: bool = False
    # kth-free placement dispatch: None = auto (Pallas on TPU, jnp radix
    # select elsewhere); or force "pallas"/"pallas_interpret"/"jnp"/"sort".
    placer: str | None = None


@dataclass(frozen=True)
class FaultConfig:
    """One point of a fault grid for ``run_campaign``."""
    straggler_prob: float = 0.0
    straggler_factor: float = 2.0
    failure_prob: float = 0.0
    restart_overhead: float = 0.5


@dataclass(frozen=True)
class Workload:
    """Static description of a job stream over P programs x S systems."""
    prog: np.ndarray            # [J] int32 program ids
    arrival: np.ndarray         # [J] f32 submit times
    k_job: np.ndarray           # [J] f32 per-job K (fraction); NaN -> global k
    n_req: np.ndarray           # [P, S] nodes needed
    T_true: np.ndarray          # [P, S] runtime ground truth
    C_true: np.ndarray          # [P, S] J/Mop ground truth
    E_true: np.ndarray          # [P, S] Joules ground truth
    T_pred: np.ndarray          # [P, S] phase-model predictions
    C_pred: np.ndarray
    n_nodes: np.ndarray         # [S] node counts
    programs: tuple = ()        # names, for reports
    systems: tuple = ()
    # [S, W, 2] maintenance windows (start, end), sorted, non-overlapping
    # per system; None = no outages.
    outage: np.ndarray | None = None


def make_npb_workload(systems, order=("BT", "EP", "IS", "LU", "SP"),
                      arrivals=None, k_job=None, repeats: int = 1,
                      pred_noise: float = 0.0, noise_seed: int = 0,
                      outage=None):
    """The paper's experiment: NPB suite submitted (simultaneously by
    default) to the four JSCC systems. ``repeats`` re-submits the suite."""
    programs = tuple(sorted(set(order)))
    pidx = {p: i for i, p in enumerate(programs)}
    C, T, N = npb_tables(systems, programs)
    mops = np.array([NPB_PROFILES[p].flops / 1e6 for p in programs])
    E = C * mops[:, None]
    rng = np.random.default_rng(noise_seed)
    noise = (1.0 + pred_noise * rng.standard_normal(C.shape)) if pred_noise else 1.0
    seq = list(order) * repeats
    J = len(seq)
    return Workload(
        prog=np.array([pidx[p] for p in seq], np.int32),
        arrival=np.zeros(J, np.float32) if arrivals is None
        else np.asarray(arrivals, np.float32),
        k_job=np.full(J, np.nan, np.float32) if k_job is None
        else np.asarray(k_job, np.float32),
        n_req=N, T_true=T, C_true=C, E_true=E,
        T_pred=T * noise, C_pred=C * noise,
        n_nodes=np.array([s.n_nodes for s in systems], np.int32),
        programs=programs, systems=tuple(s.name for s in systems),
        outage=None if outage is None else np.asarray(outage, np.float32),
    )


def _fault_factor(key, j, fvec):
    """fvec: [straggler_prob, straggler_factor, failure_prob, restart_ovh]."""
    u = jax.random.uniform(jax.random.fold_in(key, j), (2,))
    slow = jnp.where(u[0] < fvec[0], fvec[1], 1.0)
    fail = jnp.where(u[1] < fvec[2], 1.0 + fvec[3], 1.0)
    return slow * fail


def _workload_arrays(w: Workload) -> dict:
    """Workload -> the jnp pytree the jitted core consumes."""
    max_n = int(w.n_nodes.max())
    node_exists = np.arange(max_n)[None, :] < w.n_nodes[:, None]   # [S, maxN]
    arrs = {
        "free0": jnp.where(jnp.asarray(node_exists), 0.0, BIG),
        "prog": jnp.asarray(w.prog),
        "arrival": jnp.asarray(w.arrival),
        "n_req": jnp.asarray(w.n_req),
        "T_true": jnp.asarray(w.T_true),
        "C_true": jnp.asarray(w.C_true),
        "E_true": jnp.asarray(w.E_true),
        "T_pred": jnp.asarray(w.T_pred),
        "C_pred": jnp.asarray(w.C_pred),
    }
    if w.outage is not None and w.outage.size:
        arrs["outage"] = jnp.asarray(w.outage, jnp.float32)
    return arrs


def _push_out_of_outage(avail, outage):
    """Earliest start per system, pushed past any open maintenance window.
    Windows sorted by start per system, so one in-order pass resolves
    cascades (a push landing inside the next window is pushed again)."""
    for wi in range(outage.shape[1]):
        o0, o1 = outage[:, wi, 0], outage[:, wi, 1]
        avail = jnp.where((avail >= o0) & (avail < o1), o1, avail)
    return avail


def _scan_sim(arrs: dict, mode: str, warm_start: bool, placer: str | None,
              kvec, seed, fvec):
    """One full simulation as a lax.scan; every argument traced except the
    static (mode, warm_start, placer)."""
    T_true, C_true, E_true = arrs["T_true"], arrs["C_true"], arrs["E_true"]
    T_pred, C_pred = arrs["T_pred"], arrs["C_pred"]
    n_req, prog, arrival = arrs["n_req"], arrs["prog"], arrs["arrival"]
    outage = arrs.get("outage")
    P, S = T_true.shape
    J = prog.shape[0]
    # independent streams for selection and fault draws — folding a shared
    # key with j and j+offset would collide once J exceeds the offset,
    # which campaign streams (10k+ jobs) do
    sel_key, fault_key = jax.random.split(jax.random.key(seed))

    def step(carry, xs):
        node_free, C_tab, T_tab, runs = carry
        j, p, arr, k = xs

        nreq_row = n_req[p]                                      # [S]
        kth = kth_free_time(node_free, nreq_row, force=placer)
        avail = jnp.maximum(arr, kth)
        if outage is not None:
            avail = _push_out_of_outage(avail, outage)

        sel = select_system(
            mode, c_row=C_tab[p], t_row=T_tab[p], runs_row=runs[p],
            avail_row=avail, k=k, c_pred_row=C_pred[p], t_pred_row=T_pred[p],
            key=jax.random.fold_in(sel_key, j))

        factor = _fault_factor(fault_key, j, fvec)
        T_act = T_true[p, sel] * factor
        C_act = C_true[p, sel] * factor
        E_act = E_true[p, sel] * factor
        start = avail[sel]
        finish = start + T_act

        # allocate the n_req earliest-free nodes of sel: everything strictly
        # below the kth free time, plus first-by-index ties at it
        free_sel = node_free[sel]
        need = nreq_row[sel]
        below = free_sel < kth[sel]
        tie = free_sel == kth[sel]
        tie_rank = jnp.cumsum(tie) - 1
        take = below | (tie & (tie_rank < need - jnp.sum(below)))
        node_free = node_free.at[sel].set(jnp.where(take, finish, free_sel))

        n = runs[p, sel].astype(jnp.float32)
        C_tab = C_tab.at[p, sel].set((C_tab[p, sel] * n + C_act) / (n + 1))
        T_tab = T_tab.at[p, sel].set((T_tab[p, sel] * n + T_act) / (n + 1))
        runs = runs.at[p, sel].add(1)

        out = (sel, start, finish, start - arr, E_act, T_act)
        return (node_free, C_tab, T_tab, runs), out

    if warm_start:
        carry0 = (arrs["free0"], C_true, T_true, jnp.ones((P, S), jnp.int32))
    else:
        carry0 = (arrs["free0"], jnp.zeros((P, S)), jnp.zeros((P, S)),
                  jnp.zeros((P, S), jnp.int32))
    xs = (jnp.arange(J), prog, arrival, kvec)
    (node_free, C_tab, T_tab, runs), (sel, start, finish, wait, E, T_act) = \
        jax.lax.scan(step, carry0, xs)

    return {
        "system": sel, "start": start, "finish": finish, "wait": wait,
        "energy": E, "runtime": T_act,
        "total_energy": E.sum(), "makespan": finish.max(),
        "total_wait": wait.sum(),
        "C_tab": C_tab, "T_tab": T_tab, "runs": runs,
    }


@partial(jax.jit, static_argnames=("mode", "warm_start", "placer"))
def _batched_sim(arrs, kvec, seeds, faults, *, mode, warm_start, placer):
    """vmap the scan core over a flat batch axis: kvec [B, J], seeds [B],
    faults [B, 4].  One compile per (shapes, mode, warm_start, placer)."""
    return jax.vmap(
        lambda kv, sd, fv: _scan_sim(arrs, mode, warm_start, placer,
                                     kv, sd, fv))(kvec, seeds, faults)


def _fault_vec(scfg: SimConfig | FaultConfig):
    return jnp.array([scfg.straggler_prob, scfg.straggler_factor,
                      scfg.failure_prob, scfg.restart_overhead], jnp.float32)


def _kvec(w: Workload, k):
    """Per-job K: the workload's explicit overrides win over the global k."""
    kj = jnp.asarray(w.k_job)
    return jnp.where(jnp.isnan(kj), jnp.float32(k), kj)


def simulate_jax(w: Workload, scfg: SimConfig):
    """Run the sim; returns dict of per-job arrays + totals (all jnp)."""
    out = _batched_sim(
        _workload_arrays(w), _kvec(w, scfg.k)[None],
        jnp.asarray([scfg.seed], jnp.int32), _fault_vec(scfg)[None],
        mode=scfg.mode, warm_start=scfg.warm_start, placer=scfg.placer)
    return jax.tree.map(lambda x: x[0], out)


def sweep_k(w: Workload, scfg: SimConfig, ks):
    """vmap the whole simulation over the K axis (Figs 1-4 are one call).

    As in ``run_campaign``, explicit per-job overrides in ``w.k_job`` take
    precedence over the swept K at their positions; jobs with NaN k_job
    (the default) follow the grid."""
    ks = jnp.asarray(ks, jnp.float32)
    B = ks.shape[0]
    kvec = jax.vmap(lambda k: _kvec(w, k))(ks)
    return _batched_sim(
        _workload_arrays(w), kvec,
        jnp.full((B,), scfg.seed, jnp.int32),
        jnp.broadcast_to(_fault_vec(scfg), (B, 4)),
        mode=scfg.mode, warm_start=scfg.warm_start, placer=scfg.placer)


def run_campaign(w: Workload, scfg: SimConfig, ks=None, seeds=None,
                 faults=None):
    """Simulate the whole (fault-config x K x seed) grid in ONE jitted call.

    ks:     iterable of K values            (default: [scfg.k])
    seeds:  iterable of PRNG seeds          (default: [scfg.seed])
    faults: iterable of FaultConfig         (default: scfg's fault fields)

    Returns the simulate_jax dict with leading axes [K, R] — or [F, K, R]
    when a fault grid is given — on every entry (per-job arrays become
    [..., J], totals become [...]).  Per-job K overrides in ``w.k_job``
    take precedence over the swept K at their positions.
    """
    ks = jnp.asarray([scfg.k] if ks is None else list(ks), jnp.float32)
    seeds = jnp.asarray([scfg.seed] if seeds is None else list(seeds),
                        jnp.int32)
    fmat = (_fault_vec(scfg)[None] if faults is None
            else jnp.stack([_fault_vec(f) for f in faults]))
    F, K, R = fmat.shape[0], ks.shape[0], seeds.shape[0]

    kvec_k = jax.vmap(lambda k: _kvec(w, k))(ks)                   # [K, J]
    kvec = jnp.broadcast_to(kvec_k[None, :, None, :], (F, K, R, kvec_k.shape[1]))
    seed_b = jnp.broadcast_to(seeds[None, None, :], (F, K, R))
    fault_b = jnp.broadcast_to(fmat[:, None, None, :], (F, K, R, 4))

    B = F * K * R
    out = _batched_sim(
        _workload_arrays(w), kvec.reshape(B, -1), seed_b.reshape(B),
        fault_b.reshape(B, 4),
        mode=scfg.mode, warm_start=scfg.warm_start, placer=scfg.placer)
    lead = (K, R) if faults is None else (F, K, R)
    return jax.tree.map(lambda x: x.reshape(lead + x.shape[1:]), out)


# ------------------------------------------------------------ python mirror

def _paper_rule_py(c_row, t_row, k):
    """numpy twin of algorithm._paper_rule."""
    t_min = t_row.min()
    feasible = t_row <= t_min * (1.0 + k)
    score = np.where(feasible, c_row, BIG)
    cbest = score.min()
    tie = score <= cbest * (1 + 1e-9)
    return int(np.argmin(np.where(tie, t_row, BIG)))


def _select_py(mode, *, c_row, t_row, runs_row, avail_row, k,
               c_pred_row, t_pred_row, rand_sel):
    """numpy mirror of algorithm.select_system, every mode in MODES."""
    known = runs_row > 0
    any_unknown = bool((~known).any())
    explore = int(np.argmin(np.where(~known, avail_row, BIG)))

    if mode == "paper":
        if any_unknown:
            return explore
        return _paper_rule_py(np.where(known, c_row, BIG),
                              np.where(known, t_row, BIG), k)
    if mode == "queue_aware":
        if any_unknown:
            return explore
        wait = avail_row - avail_row.min()
        comp = np.where(known, t_row + wait, BIG)
        return _paper_rule_py(np.where(known, c_row, BIG), comp, k)
    if mode == "predictive":
        return _paper_rule_py(np.where(known, c_row, c_pred_row),
                              np.where(known, t_row, t_pred_row), k)
    if mode == "ucb":
        c_floor = np.where(known, c_row, BIG).min() * 0.5
        t_floor = np.where(known, t_row, BIG).min()
        return _paper_rule_py(np.where(known, c_row, c_floor),
                              np.where(known, t_row, t_floor), k)
    if mode == "fastest":
        if any_unknown:
            return explore
        return int(np.argmin(np.where(known, t_row, BIG)))
    if mode == "greenest":
        if any_unknown:
            return explore
        return int(np.argmin(np.where(known, c_row, BIG)))
    if mode == "first_free":
        return int(np.argmin(avail_row))
    if mode == "random":
        return rand_sel
    if mode == "oracle":
        return _paper_rule_py(c_pred_row, t_pred_row, k)
    raise ValueError(f"unknown mode {mode!r}")


def simulate_py(w: Workload, scfg: SimConfig):
    """Reference implementation for differential tests (no faults path).

    Covers every mode in ``algorithm.MODES``.  All arithmetic runs in
    float64 numpy — an independent-precision check of the f32 jax engine —
    except the "random" draw, which replays the jax PRNG stream so the two
    implementations place identically.
    """
    assert scfg.straggler_prob == 0 and scfg.failure_prob == 0, \
        "python mirror covers the deterministic path"
    P, S = w.T_true.shape
    node_free = [list(np.zeros(int(n))) for n in w.n_nodes]
    if scfg.warm_start:
        C_tab, T_tab = w.C_true.copy(), w.T_true.copy()
        runs = np.ones((P, S), np.int64)
    else:
        C_tab = np.zeros((P, S))
        T_tab = np.zeros((P, S))
        runs = np.zeros((P, S), np.int64)
    sel_key = (jax.random.split(jax.random.key(scfg.seed))[0]
               if scfg.mode == "random" else None)
    out = []
    for j, p in enumerate(w.prog):
        arr = float(w.arrival[j])
        kj = float(w.k_job[j])
        k = scfg.k if np.isnan(kj) else kj
        avail = np.empty(S)
        for s in range(S):
            free = sorted(node_free[s])
            need = int(w.n_req[p, s])
            avail[s] = max(arr, free[need - 1]) if need <= len(free) else BIG
            if w.outage is not None:
                for o0, o1 in w.outage[s]:
                    if o0 <= avail[s] < o1:
                        avail[s] = o1

        rand_sel = None
        if scfg.mode == "random":
            rand_sel = int(jax.random.randint(
                jax.random.fold_in(sel_key, j), (), 0, S))
        sel = _select_py(
            scfg.mode, c_row=C_tab[p], t_row=T_tab[p], runs_row=runs[p],
            avail_row=avail, k=k, c_pred_row=w.C_pred[p],
            t_pred_row=w.T_pred[p], rand_sel=rand_sel)

        T_act = float(w.T_true[p, sel])
        E_act = float(w.E_true[p, sel])
        C_act = float(w.C_true[p, sel])
        start = float(avail[sel])
        finish = start + T_act
        need = int(w.n_req[p, sel])
        idx = np.argsort(node_free[sel])[:need]
        for i in idx:
            node_free[sel][int(i)] = finish
        n = runs[p, sel]
        C_tab[p, sel] = (C_tab[p, sel] * n + C_act) / (n + 1)
        T_tab[p, sel] = (T_tab[p, sel] * n + T_act) / (n + 1)
        runs[p, sel] += 1
        out.append((sel, start, finish, start - arr, E_act, T_act))

    sel, start, finish, wait, E, T_act = map(np.array, zip(*out))
    return {
        "system": sel, "start": start, "finish": finish, "wait": wait,
        "energy": E, "runtime": T_act,
        "total_energy": E.sum(), "makespan": finish.max(),
        "total_wait": wait.sum(),
    }
