"""Legacy simulator surface + float64 python differential mirror.

The scan core and batching now live in ``repro.core.engine`` behind the
``Scheduler`` facade; the policy family lives in ``repro.core.policy``.
This module keeps the historical entry points working unchanged:

  - ``simulate_jax(w, scfg)``      == ``Scheduler(policy).run(w)``
  - ``sweep_k(w, scfg, ks)``       == ``Scheduler(policy-with-K-grid).run(w)``
  - ``run_campaign(w, scfg, ...)`` == ``Scheduler(policy, faults, seeds).run(w)``

all returning the historical dict-of-arrays schema (now a superset: the
structured-result derived metrics ride along).  They are thin shims over
the same jitted engine, so their placements and totals are bit-identical
to the facade's — asserted in tests/test_engine_api.py.

``simulate_py`` is the plain-Python float64 mirror used for differential
testing.  It dispatches through the same policy registry as the engine
(``policy.select_py``), so every registered policy — including ones added
after this writing — is differential-testable with zero extra mirror code.
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.dvfs import tier_tables_py
from repro.core.engine import (                     # noqa: F401 (re-exports)
    BIG, FaultConfig, Scheduler, SimConfig, Workload, make_npb_workload,
)
from repro.core.policy import (                     # noqa: F401 (re-exports)
    UNCAPPED, make_policy, select_py, _paper_rule_py,
)


def simulate_jax(w: Workload, scfg: SimConfig):
    """Run the sim; returns dict of per-job arrays + totals (all jnp).

    Legacy shim: ``Scheduler(scfg.policy(), ...).run(w).to_dict()``.
    """
    return _scheduler_for(scfg).run(w).to_dict()


def sweep_k(w: Workload, scfg: SimConfig, ks):
    """vmap the whole simulation over the K axis (Figs 1-4 are one call).

    As in ``run_campaign``, explicit per-job overrides in ``w.k_job`` take
    precedence over the swept K at their positions; jobs with NaN k_job
    (the default) follow the grid.  Legacy shim: a K-grid policy is one
    leaf-batched ``Policy``.
    """
    pol = scfg.policy().with_params(k=jnp.asarray(list(ks), jnp.float32))
    return _scheduler_for(scfg, policy=pol).run(w).to_dict()


def run_campaign(w: Workload, scfg: SimConfig, ks=None, seeds=None,
                 faults=None):
    """Simulate the whole (fault-config x K x seed) grid in ONE jitted call.

    ks:     iterable of K values            (default: [scfg.k])
    seeds:  iterable of PRNG seeds          (default: [scfg.seed])
    faults: iterable of FaultConfig         (default: scfg's fault fields)

    Returns the simulate_jax dict with leading axes [K, R] — or [F, K, R]
    when a fault grid is given — on every entry (per-job arrays become
    [..., J], totals become [...]).  Per-job K overrides in ``w.k_job``
    take precedence over the swept K at their positions.
    """
    ks = [scfg.k] if ks is None else list(ks)
    pol = scfg.policy().with_params(k=jnp.asarray(ks, jnp.float32))
    seeds = [scfg.seed] if seeds is None else list(seeds)
    sched = _scheduler_for(scfg, policy=pol, seeds=seeds,
                           faults=None if faults is None else tuple(faults))
    return sched.run(w).to_dict()


def _scheduler_for(scfg: SimConfig, policy=None, seeds=None, faults=None):
    """SimConfig -> Scheduler, preserving the legacy axis conventions.
    The built policy carries scfg's queue/window/power_cap overrides (the
    shims must not drop them — ISSUE 3 + ISSUE 5 regressions), and the
    core override rides separately."""
    return Scheduler(
        scfg.policy() if policy is None else policy,
        placer=scfg.placer, warm_start=scfg.warm_start,
        engine=scfg.core or None,
        seeds=scfg.seed if seeds is None else seeds,
        faults=FaultConfig(
            straggler_prob=scfg.straggler_prob,
            straggler_factor=scfg.straggler_factor,
            failure_prob=scfg.failure_prob,
            restart_overhead=scfg.restart_overhead,
        ) if faults is None else faults)


# ------------------------------------------------------------ python mirror

class _PySim:
    """Mutable float64 simulation state shared by the mirror's queue
    disciplines: per-node free-time lists, learned tables, and the
    placement primitives that must stay in lockstep with the jax engine
    (``_earliest`` / ``_alloc`` / the table update in ``_scan_sim``)."""

    def __init__(self, w: Workload, scfg: SimConfig, pol):
        self.w, self.scfg, self.pol = w, scfg, pol
        P, S = w.T_true.shape
        self.S = S
        # [S, maxN] float64 free-time table, BIG-padded past each system's
        # real node count.  Pads sort last and never win an allocation, so
        # they stay exactly BIG for the whole run; ``counts``/``mask``
        # bound the real slots.  The array form keeps every hot path
        # (sort / stable argsort / masked sums) vectorized, which is what
        # lets differential streams reach >=10k jobs.
        self.counts = np.asarray(w.n_nodes, np.int64)
        self.mask = (np.arange(int(self.counts.max()))[None, :]
                     < self.counts[:, None])
        self.node_free = np.where(self.mask, 0.0, BIG)
        if scfg.warm_start:
            self.C_tab, self.T_tab = w.C_true.copy(), w.T_true.copy()
            self.runs = np.ones((P, S), np.int64)
        else:
            self.C_tab = np.zeros((P, S))
            self.T_tab = np.zeros((P, S))
            self.runs = np.zeros((P, S), np.int64)
        self.sel_key = (jax.random.split(jax.random.key(scfg.seed))[0]
                        if pol.objective == "random" else None)
        # DVFS tier axis (float64 twin of the engine's tier_tables; None
        # for untier policies so the historical path is untouched)
        self.tiers = tuple(pol.freq_tiers)
        self.F = len(self.tiers)
        self.tt = tier_tables_py(w, self.tiers) if pol.tiered else None

    # tier-aware ground-truth lookups (base values when untier)
    def T_of(self, p, f, s):
        return float(self.tt["T"][p, f, s] if self.tt is not None
                     else self.w.T_true[p, s])

    def E_of(self, p, f, s):
        return float(self.tt["E"][p, f, s] if self.tt is not None
                     else self.w.E_true[p, s])

    def w_of(self, p, f, s):
        if self.tt is not None:
            return float(self.tt["w"][p, f, s])
        return float(self.w_pow[p, s])

    def avail_for(self, p: int, arr: float, node_free=None) -> np.ndarray:
        """Earliest start per system (float64 kth-free + outage push),
        vectorized over systems: sort the free table, gather the kth free
        time per system, then push through maintenance windows in order."""
        w = self.w
        nf = self.node_free if node_free is None else node_free
        need = np.asarray(w.n_req[p], np.int64)                      # [S]
        kidx = np.maximum(np.minimum(need, self.counts) - 1, 0)
        kth = np.sort(nf, axis=1)[np.arange(self.S), kidx]
        avail = np.where(need <= self.counts, np.maximum(arr, kth), BIG)
        if w.outage is not None:
            og = np.asarray(w.outage, np.float64)
            for wi in range(og.shape[1]):            # in-order window push
                o0, o1 = og[:, wi, 0], og[:, wi, 1]
                avail = np.where((o0 <= avail) & (avail < o1), o1, avail)
        return avail

    def choose(self, j: int, node_free=None, arr=None, avail=None):
        """Policy selection for job j under current state: returns
        (p, arr, avail, sel, f) — ``f`` the chosen frequency tier (0 for
        untier policies).  ``node_free`` selects an alternate table,
        ``avail`` overrides the availability row entirely (the
        conservative mirror's hole-aware earliest fit: [S], or [F, S]
        per-tier under DVFS), ``arr`` overrides the arrival floor."""
        w, S, F = self.w, self.S, self.F
        p = int(w.prog[j])
        arr = float(w.arrival[j]) if arr is None else float(arr)
        kj = float(w.k_job[j])
        k = self.scfg.k if np.isnan(kj) else kj
        if avail is None:
            avail = self.avail_for(p, arr, node_free)
        if self.tt is None:
            rand_sel = None
            if self.pol.objective == "random":
                rand_sel = int(jax.random.randint(
                    jax.random.fold_in(self.sel_key, j), (), 0, S))
            sel = select_py(
                self.pol, c_row=self.C_tab[p], t_row=self.T_tab[p],
                runs_row=self.runs[p], avail_row=avail, k=k,
                c_pred_row=w.C_pred[p], t_pred_row=w.T_pred[p],
                rand_sel=rand_sel)
            return p, arr, avail, sel, 0
        # tier-major expansion, the float64 twin of engine._tier_rows
        rc, rt = self.tt["rc"][p], self.tt["rt"][p]              # [F, S]
        av = np.asarray(avail, np.float64)
        avail_x = (av.reshape(-1) if av.ndim == 2
                   else np.broadcast_to(av, (F, S)).reshape(-1))
        rand_sel = None
        if self.pol.objective == "random":
            rand_sel = int(jax.random.randint(
                jax.random.fold_in(self.sel_key, j), (), 0, F * S))
        sel_x = select_py(
            self.pol,
            c_row=(self.C_tab[p][None, :] * rc).reshape(-1),
            t_row=(self.T_tab[p][None, :] * rt).reshape(-1),
            runs_row=np.broadcast_to(self.runs[p], (F, S)).reshape(-1),
            avail_row=avail_x, k=k,
            c_pred_row=(np.asarray(w.C_pred[p], np.float64)[None, :]
                        * rc).reshape(-1),
            t_pred_row=(np.asarray(w.T_pred[p], np.float64)[None, :]
                        * rt).reshape(-1),
            rand_sel=rand_sel)
        return p, arr, avail, sel_x % S, sel_x // S

    @staticmethod
    def alloc(node_free, sel: int, need: int, finish: float):
        """Allocate the ``need`` earliest-free nodes (stable argsort ==
        the engine's first-by-index tie-break; BIG pads sort last, so only
        real slots are ever written)."""
        idx = np.argsort(node_free[sel], kind="stable")[:need]
        node_free[sel, idx] = finish

    def place(self, j: int):
        """Place job j (the FCFS step body): allocate, update tables,
        return the per-job record."""
        w = self.w
        p, arr, avail, sel, f = self.choose(j)
        T_act = self.T_of(p, f, sel)
        E_act = self.E_of(p, f, sel)
        # learned tables absorb BASE (tier-0) observations
        T_upd = float(w.T_true[p, sel])
        C_act = float(w.C_true[p, sel])
        start = float(avail[sel])
        finish = start + T_act
        self.alloc(self.node_free, sel, int(w.n_req[p, sel]), finish)
        n = self.runs[p, sel]
        self.C_tab[p, sel] = (self.C_tab[p, sel] * n + C_act) / (n + 1)
        self.T_tab[p, sel] = (self.T_tab[p, sel] * n + T_upd) / (n + 1)
        self.runs[p, sel] += 1
        return (sel, start, finish, start - arr, E_act, T_act, f)

    # ------------------------------------------- event-replay helpers
    # The power / event / placement bookkeeping shared verbatim by the
    # two event-granular mirrors (``_events_py`` / ``_cons_py``).  Both
    # replays mutate this state through the same methods, so the
    # float64 op order is identical on the shared path by construction
    # (the differential suite pins both sides against the engine).

    def init_event_state(self, pol):
        """Power model + event-clock accumulators of an event replay."""
        w, S = self.w, self.S
        J = len(w.prog)
        self.ev_cap = float(np.asarray(pol.power_cap).reshape(-1)[0])
        self.ev_capped = self.ev_cap < UNCAPPED
        self.idle_pw = (np.zeros(S) if w.idle_w is None
                        else np.asarray(w.idle_w, np.float64))
        self.w_pow = np.asarray(w.E_true, np.float64) / np.maximum(
            np.asarray(w.T_true, np.float64), 1e-30)
        self.node_pow = np.zeros_like(self.node_free)
        self.ev_out = [None] * J
        self.backfilled = np.zeros(J, bool)
        self.a, self.now = 0, float(w.arrival[0])
        self.nbf = 0
        self.peak = float(sum(self.idle_pw[s] * int(w.n_nodes[s])
                              for s in range(S)))
        self.cdel = 0.0
        self.pblock: dict[int, float] = {}
        self.placed_n = 0

    def power_at(self, t: float) -> float:
        """Cluster draw at ``t``: per-node allocated watts while busy,
        idle watts otherwise (pads contribute 0 via the slot mask)."""
        draw = np.where(self.node_free > t, self.node_pow,
                        self.idle_pw[:, None])
        return float(np.sum(draw, where=self.mask))

    def next_event(self, extra=()) -> bool:
        """Advance ``now`` to the next event: the earliest node-free
        time, the next arrival, any ``extra`` times (the conservative
        replay's reservation starts), or an outage end.  Returns whether
        the clock moved.  Pad slots sit at exactly BIG and are excluded —
        they are capacity that never existed, not completions."""
        w = self.w
        nf = self.node_free
        cand = nf[(nf > self.now) & (nf < BIG)]
        nxt = [float(cand.min())] if cand.size else []
        if self.a < len(w.prog) and float(w.arrival[self.a]) > self.now:
            nxt.append(float(w.arrival[self.a]))
        nxt.extend(t for t in extra if t > self.now)
        if w.outage is not None:
            nxt.extend(float(t1) for _, t1 in w.outage.reshape(-1, 2)
                       if t1 > self.now)
        if nxt:
            self.now = min(nxt)
            return True
        return False

    def record_block(self, j: int):
        """First time job j is the next would-be placement but
        power-blocked (feeds ``capped_delay``)."""
        self.pblock[j] = min(self.pblock.get(j, np.inf), self.now)

    def outage_gated(self, sel: int, start_q: float) -> bool:
        """Capped starts quantize to ``now``: the start gate must hold
        there (mirrors the engine's res_ok outage clause)."""
        return self.ev_capped and self.w.outage is not None and any(
            o0 <= start_q < o1 for o0, o1 in self.w.outage[sel])

    def realize(self, j: int, chosen: int, p: int, sel: int, start: float,
                T_act: float, E_act: float, wjob: float, arr: float,
                p_now: float, tier: int = 0):
        """Realize a placement: allocate + per-node power, update the
        learned tables, and record the power / backfill / per-job
        outputs — the float64 twin of the engine's placement tail.
        ``T_act``/``E_act`` are the (possibly tier-scaled) realized
        values; the learned tables always absorb the BASE observation
        (``w.T_true[p, sel]`` — identical for untier policies)."""
        w = self.w
        finish = start + T_act
        need = int(w.n_req[p, sel])
        idx = np.argsort(self.node_free[sel], kind="stable")[:need]
        self.node_free[sel, idx] = finish
        self.node_pow[sel, idx] = wjob / max(need, 1)
        n = self.runs[p, sel]
        C_act = float(w.C_true[p, sel])
        T_upd = float(w.T_true[p, sel])
        self.C_tab[p, sel] = (self.C_tab[p, sel] * n + C_act) / (n + 1)
        self.T_tab[p, sel] = (self.T_tab[p, sel] * n + T_upd) / (n + 1)
        self.runs[p, sel] += 1
        new_P = p_now - need * self.idle_pw[sel] + wjob
        self.peak = max(self.peak, new_P)
        if j in self.pblock:
            self.cdel += self.now - self.pblock.pop(j)
        if chosen > 0:
            self.backfilled[j] = True
            self.nbf += 1
        self.ev_out[j] = (sel, start, finish, start - arr, E_act, T_act,
                          tier)
        self.placed_n += 1

    def event_results(self):
        return (self.ev_out, self.backfilled, self.nbf, self.peak,
                self.cdel, self.idle_pw)


def _easy_order_py(sim: _PySim, J: int, window: int):
    """Replay the engine's EASY-backfill step decisions (one placement per
    step, bounded pending window, no-delay reservation guard); yields
    (job, backfilled) in placement order."""
    w = sim.w
    pend: list[int] = []
    for t in range(J + window):
        now = float(w.arrival[t]) if t < J else np.inf
        if t < J:
            pend.append(t)
        if not pend:
            continue
        h = pend[0]
        p_h, arr_h, avail_h, sel_h, _ = sim.choose(h)
        r_h = float(avail_h[sel_h])
        chosen = None
        if len(pend) == window + 1 or r_h <= now:   # overflow: FCFS fallback
            chosen = 0
        else:
            for ci in range(1, len(pend)):
                b = pend[ci]
                p_b, _, avail_b, sel_b, f_b = sim.choose(b)
                s_b = float(avail_b[sel_b])
                trial = sim.node_free.copy()
                sim.alloc(trial, sel_b, int(w.n_req[p_b, sel_b]),
                          s_b + sim.T_of(p_b, f_b, sel_b))
                if sim.avail_for(p_h, arr_h, trial)[sel_h] <= r_h:
                    chosen = ci
                    break
        if chosen is not None:
            yield pend.pop(chosen), chosen > 0


def _events_py(sim: _PySim, pol):
    """Float64 replay of the event-granular core (``make_event_step``
    under ``_sim_pieces``, fcfs / easy_backfill): merged
    arrival/completion event clock, bounded
    pending buffer with stalled admission, per-discipline eligibility,
    and power-cap deferral with the same start rule (capped runs start at
    the current event).  Returns the per-job records plus the power
    accumulators."""
    w = sim.w
    J = len(w.prog)
    Wc = int(pol.window) + 1
    queue = pol.queue
    sim.init_event_state(pol)
    capped = sim.ev_capped
    pend: list[int] = []
    max_iters = 16 * J + 64           # far above the engine's step bound

    for _ in range(max_iters):
        if sim.placed_n == J:
            break
        now = sim.now
        pushed = False
        if sim.a < J and float(w.arrival[sim.a]) <= now and len(pend) < Wc:
            pend.append(sim.a)
            sim.a += 1
            pushed = True

        chosen = None
        evals = [sim.choose(j) for j in pend]    # (p, arr, avail, sel, f)
        starts_res = [float(ev[2][ev[3]]) for ev in evals]
        p_now = sim.power_at(now)

        def trial_of(ci):
            p_b, _, avail_b, sel_b, f_b = evals[ci]
            s_b = max(starts_res[ci], now) if capped else starts_res[ci]
            trial = sim.node_free.copy()
            sim.alloc(trial, sel_b, int(w.n_req[p_b, sel_b]),
                      s_b + sim.T_of(p_b, f_b, sel_b))
            return trial

        def guard_ok(ci):
            if ci == 0:
                return True
            if queue == "fcfs":
                return False
            trial = trial_of(ci)        # EASY: only the head is guarded
            p_h, arr_h, _, sel_h, _ = evals[0]
            return sim.avail_for(p_h, arr_h, trial)[sel_h] <= starts_res[0]

        blocked_recorded = False
        for ci in range(len(pend)):
            if starts_res[ci] > now or not guard_ok(ci):
                continue
            p_b, _, _, sel_b, f_b = evals[ci]
            if sim.outage_gated(sel_b, max(starts_res[ci], now)):
                continue
            new_P = (p_now
                     - int(w.n_req[p_b, sel_b]) * sim.idle_pw[sel_b]
                     + sim.w_of(p_b, f_b, sel_b))
            if capped and new_P > sim.ev_cap:
                if not blocked_recorded:
                    # the next would-be placement is power-blocked
                    sim.record_block(pend[ci])
                    blocked_recorded = True
                continue
            chosen = ci
            break

        if chosen is None and not pushed:
            if sim.next_event():
                continue
            if not pend:
                break
            chosen = 0                  # cap below the idle floor

        if chosen is None:
            continue

        # ---- place pend[chosen] (float64 twin of the engine's step)
        j = pend.pop(chosen)
        p, arr, avail, sel, f = evals[chosen]
        start = (max(starts_res[chosen], now) if capped
                 else starts_res[chosen])
        sim.realize(j, chosen, p, sel, start, sim.T_of(p, f, sel),
                    sim.E_of(p, f, sel), sim.w_of(p, f, sel), arr,
                    p_now, tier=f)
    assert sim.placed_n == J, \
        f"event mirror stalled: {sim.placed_n}/{J} placed"
    return sim.event_results()


def _cons_py(sim: _PySim, pol, check_reservations: bool = False):
    """Float64 replay of the conservative core (``make_cons_step`` under
    ``_sim_pieces``):
    hole-aware reservations assigned at admission (earliest capacity fit
    around every pending reservation interval), placements realizing
    reservations as their starts arrive, power-cap deferral in
    reservation order.

    ``check_reservations=True`` additionally asserts the conservative
    invariant at every placement: the real table can honor the
    reservation (earliest realizable start <= reserved start) — i.e. no
    backfill ever delayed a pending reservation (uncapped runs only;
    a binding cap legitimately breaks promises downstream)."""
    w, S = sim.w, sim.S
    J = len(w.prog)
    Wc = int(pol.window) + 1
    sim.init_event_state(pol)
    capped = sim.ev_capped
    pend: list[dict] = []
    max_iters = 16 * J + 64

    def earliest_fit(p, t0, Trow=None):
        """Float64 twin of the engine's hole-aware earliest fit,
        vectorized over the candidate set: per system, the first
        candidate start whose capacity (free nodes minus reservation
        occupancy) covers the job's whole window — i.e. capacity holds at
        the start AND at every reservation start that dips inside it.
        ``Trow`` overrides the per-system durations (the DVFS mirror's
        per-tier evaluation)."""
        out = np.full(S, BIG)
        r_sel = np.asarray([r["sel"] for r in pend], np.int64)
        r_start = np.asarray([r["start"] for r in pend], np.float64)
        r_fin = np.asarray([r["fin"] for r in pend], np.float64)
        r_need = np.asarray([r["need"] for r in pend], np.float64)
        fin_c = np.maximum(r_fin, t0)       # candidates shared across S
        for s in range(S):
            n = int(w.n_req[p, s])
            Td = float(w.T_true[p, s] if Trow is None else Trow[s])
            free = sim.node_free[s, :int(sim.counts[s])]
            mine = r_sel == s
            rs, rf, rn = r_start[mine], r_fin[mine], r_need[mine]

            def availn(ts):
                """Free-node count minus this system's reservation
                occupancy at each time in ``ts``."""
                cnt = (free[None, :] <= ts[:, None]).sum(1)
                occ = (((rs[None, :] <= ts[:, None])
                        & (ts[:, None] < rf[None, :])) * rn).sum(1)
                return cnt - occ

            cands = np.concatenate(([t0], np.maximum(free, t0), fin_c))
            if w.outage is not None:
                og = np.asarray(w.outage, np.float64)
                for wi in range(og.shape[1]):    # in-order window push
                    o0, o1 = og[s, wi]
                    cands = np.where((o0 <= cands) & (cands < o1),
                                     o1, cands)
            cands = np.unique(cands)             # == sorted(set(...))
            ok = availn(cands) >= n
            if rs.size:
                dip = availn(rs) < n             # capacity at res starts
                ok &= ~(((cands[:, None] < rs[None, :])
                         & (rs[None, :] < cands[:, None] + Td))
                        & dip[None, :]).any(1)
            hit = np.flatnonzero(ok)
            if hit.size:
                out[s] = cands[hit[0]]
        return out

    def reserve(j, t0):
        """Admission: hole-aware earliest fit + selection — the new
        reservation row (reservations are NOT committed to node_free).
        Under DVFS each tier gets its own earliest fit (a slower tier's
        longer window may land in a different hole)."""
        pp = int(w.prog[j])
        if sim.tt is not None:
            avail = np.stack([
                earliest_fit(pp, t0, np.asarray(sim.tt["T"][pp, fi],
                                                np.float64))
                for fi in range(sim.F)])                         # [F, S]
            p, _, _, sel, f = sim.choose(j, arr=t0, avail=avail)
            start = float(avail[f, sel])
        else:
            avail = earliest_fit(pp, t0)
            p, _, _, sel, f = sim.choose(j, arr=t0, avail=avail)
            start = float(avail[sel])
        T_act = sim.T_of(p, f, sel)
        return dict(j=j, p=p, t0=t0, sel=sel, start=start, T=T_act,
                    fin=start + T_act, E=sim.E_of(p, f, sel),
                    need=int(w.n_req[p, sel]),
                    wjob=sim.w_of(p, f, sel), tier=f)

    for _ in range(max_iters):
        if sim.placed_n == J:
            break
        now = sim.now
        pushed = False
        if sim.a < J and float(w.arrival[sim.a]) <= now and len(pend) < Wc:
            pend.append(reserve(sim.a, float(w.arrival[sim.a])))
            sim.a += 1
            pushed = True

        # realizability + power, in slot (admission) order
        p_now = sim.power_at(now)
        chosen = None
        blocked_recorded = False
        elig_res = []
        for ci, rec in enumerate(pend):
            avail_real = sim.avail_for(rec["p"], rec["t0"])[rec["sel"]]
            ok = rec["start"] <= now and avail_real <= now
            if ok:
                # the engine's cap-deferred start gate: now must not sit
                # inside the reserved system's maintenance window
                ok = not sim.outage_gated(rec["sel"],
                                          max(rec["start"], now))
            elig_res.append(ok)
            if not ok:
                continue
            new_P = (p_now - rec["need"] * sim.idle_pw[rec["sel"]]
                     + rec["wjob"])
            if capped and new_P > sim.ev_cap:
                if not blocked_recorded:
                    sim.record_block(rec["j"])
                    blocked_recorded = True
                continue
            chosen = ci
            break

        if chosen is None and not pushed:
            if sim.next_event(extra=(r["start"] for r in pend)):
                continue
            if not any(elig_res):
                break                      # drained
            chosen = elig_res.index(True)   # cap below the idle floor

        if chosen is None:
            continue

        rec = pend.pop(chosen)
        j, p, sel = rec["j"], rec["p"], rec["sel"]
        start = max(rec["start"], now) if capped else rec["start"]
        if check_reservations and not capped:
            avail_real = sim.avail_for(p, rec["t0"])[sel]
            assert avail_real <= rec["start"] + 1e-6, (
                f"reservation of job {j} not realizable: {avail_real} > "
                f"{rec['start']} (a backfill delayed it)")
        sim.realize(j, chosen, p, sel, start, rec["T"], rec["E"],
                    rec["wjob"], float(w.arrival[j]), p_now,
                    tier=rec["tier"])
    assert sim.placed_n == J, \
        f"conservative mirror stalled: {sim.placed_n}/{J}"
    return sim.event_results()


def simulate_py(w: Workload, scfg: SimConfig, *,
                check_reservations: bool = False):
    """Reference implementation for differential tests (no faults path).

    Dispatches through the policy registry (``scfg.mode`` may name ANY
    registered policy) and mirrors every queue discipline — FCFS arrival
    order, EASY backfilling (arrival-indexed reservation semantics
    replayed step for step), and the event-granular core (conservative
    backfilling, power caps, or an explicit ``core="events"`` override),
    replayed event for event.  All arithmetic runs in float64 numpy — an
    independent-precision check of the f32 jax engine — except the
    "random" draw, which replays the jax PRNG stream so the two
    implementations place identically.
    """
    assert scfg.straggler_prob == 0 and scfg.failure_prob == 0, \
        "python mirror covers the deterministic path"
    pol = scfg.policy()
    sim = _PySim(w, scfg, pol)
    J = len(w.prog)
    use_events = scfg.core == "events" or pol.capped
    if pol.queue == "conservative":
        out, backfilled, nbf, peak, cdel, idle_w = _cons_py(
            sim, pol, check_reservations=check_reservations)
    elif use_events:
        out, backfilled, nbf, peak, cdel, idle_w = _events_py(sim, pol)
    else:
        if pol.queue == "easy_backfill":
            order = _easy_order_py(sim, J, int(pol.window))
        else:
            order = ((j, False) for j in range(J))
        out = [None] * J
        backfilled = np.zeros(J, bool)
        for j, bf in order:
            out[j] = sim.place(j)
            backfilled[j] = bf
        nbf, peak, cdel = int(backfilled.sum()), np.nan, 0.0
        idle_w = (np.zeros(sim.S) if w.idle_w is None
                  else np.asarray(w.idle_w, np.float64))
    assert all(rec is not None for rec in out), "job left unplaced"

    sel, start, finish, wait, E, T_act, tier = map(np.array, zip(*out))
    makespan = finish.max()
    busy = np.zeros(sim.S)
    np.add.at(busy, sel, T_act * np.asarray(w.n_req)[np.asarray(w.prog), sel])
    idle_energy = (float(np.sum(idle_w * np.asarray(w.n_nodes))) * makespan
                   - float(np.sum(idle_w * busy)))
    return {
        "system": sel, "start": start, "finish": finish, "wait": wait,
        "energy": E, "runtime": T_act, "backfilled": backfilled,
        "tier": tier, "n_backfilled": int(nbf),
        "total_energy": E.sum(), "makespan": makespan,
        "total_wait": wait.sum(), "max_wait": wait.max(),
        "peak_power": peak, "capped_delay": cdel,
        "idle_energy": idle_energy,
    }
