"""DVFS power-capping as an extra scheduling dimension (DESIGN.md §9.4).

The paper cites frequency/voltage scaling ([7], [8]) as the second classic
energy lever.  We model a frequency multiplier phi on the compute phases:
runtime of compute phases scales 1/phi, dynamic compute power scales ~phi^3
(voltage tracks frequency), idle/net/disk unchanged.  Each (system, phi)
pair becomes a VIRTUAL system — the paper's algorithm then chooses over
systems AND frequency levels with the same (C, T, K) machinery, unifying
both energy levers under one decision rule (beyond-paper contribution).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.systems import ComputeSystem
from repro.core.workload_model import (NPB_PROFILES, NPB_NODES,
                                       predict_phases)


def dvfs_variant(sys: ComputeSystem, phi: float) -> ComputeSystem:
    """Virtual system at frequency multiplier phi (phi <= 1 = capped)."""
    return dataclasses.replace(
        sys,
        name=f"{sys.name}@{int(phi * 100)}",
        peak_flops_node=sys.peak_flops_node * phi,
        cpu_w=sys.cpu_w * phi ** 3,
    )


def expand_with_dvfs(systems, phis=(1.0, 0.8, 0.6)):
    """[CC1, CC2, ...] -> [CC1@100, CC1@80, ..., CC2@100, ...]."""
    return tuple(dvfs_variant(s, p) for s in systems for p in phis)


def dvfs_npb_workload(systems, phis=(1.0, 0.8, 0.6), **kw):
    """NPB workload over the DVFS-expanded system list.  Node counts for a
    virtual system follow its physical host (Table 6)."""
    from repro.core.simulator import make_npb_workload
    expanded = expand_with_dvfs(systems, phis)
    # make_npb_workload reads NPB_NODES by system NAME; register virtuals
    for s in expanded:
        host = s.name.split("@")[0]
        for prog in NPB_NODES:
            NPB_NODES[prog].setdefault(s.name, NPB_NODES[prog][host])
    return make_npb_workload(expanded, **kw)
