"""DVFS frequency scaling as a scheduling dimension.

The paper cites frequency/voltage scaling ([7], [8]) as the second classic
energy lever.  We model a frequency multiplier phi on the compute phases:
runtime of compute phases scales 1/phi, dynamic compute power scales ~phi^3
(voltage tracks frequency), idle/net/disk unchanged.

Two integrations live here (docs/API.md "Frequency axis"):

- **first-class tier axis** (the engine path): ``Policy.freq_tiers``
  expands every placement candidate to a (system x tier) pair, scored by
  the per-tier tables built below — ``tier_tables`` (jnp, the scan cores)
  and ``tier_tables_py`` (float64, the differential mirror).  Per tier phi
  and the seed phase model (``workload_model.predict_energy``):

      T(phi) = T + T_comp * (1/phi - 1)
      E(phi) = E + E_comp * (phi^2 - 1)
                 + n_req * idle_w * T_comp * (1/phi - 1)

  (dynamic compute energy cpu_w * t_comp picks up phi^3 power over 1/phi
  time = phi^2; the stretched tail still draws idle watts).  The unit
  tier's entries are the base tables bit for bit.
- **virtual systems** (the legacy seed path): ``dvfs_variant`` /
  ``expand_with_dvfs`` bake each (system, phi) pair into a separate
  ``ComputeSystem``.  Kept for A/B comparisons; new code should sweep
  ``freq_tiers`` instead (migration notes in docs/API.md).
"""

from __future__ import annotations

import dataclasses

import numpy as np
import jax.numpy as jnp

from repro.core.systems import ComputeSystem
from repro.core.workload_model import (NPB_PROFILES, NPB_NODES,
                                       predict_phases)

_TINY = 1e-30


def dvfs_variant(sys: ComputeSystem, phi: float) -> ComputeSystem:
    """Virtual system at frequency multiplier phi (phi <= 1 = capped)."""
    return dataclasses.replace(
        sys,
        name=f"{sys.name}@{int(phi * 100)}",
        peak_flops_node=sys.peak_flops_node * phi,
        cpu_w=sys.cpu_w * phi ** 3,
    )


def expand_with_dvfs(systems, phis=(1.0, 0.8, 0.6)):
    """[CC1, CC2, ...] -> [CC1@100, CC1@80, ..., CC2@100, ...]."""
    return tuple(dvfs_variant(s, p) for s in systems for p in phis)


def dvfs_npb_workload(systems, phis=(1.0, 0.8, 0.6), **kw):
    """NPB workload over the DVFS-expanded system list.  Node counts for a
    virtual system follow its physical host (Table 6)."""
    from repro.core.simulator import make_npb_workload
    expanded = expand_with_dvfs(systems, phis)
    # make_npb_workload reads NPB_NODES by system NAME; register virtuals
    for s in expanded:
        host = s.name.split("@")[0]
        for prog in NPB_NODES:
            NPB_NODES[prog].setdefault(s.name, NPB_NODES[prog][host])
    return make_npb_workload(expanded, **kw)


# ------------------------------------------------- first-class tier axis

def phase_split(w) -> tuple:
    """``(T_comp, E_comp)`` float64 [P, S] for a ``Workload``.

    Uses the workload's explicit phase split when present (NPB workloads
    carry the exact ``predict_phases`` decomposition); otherwise the
    engine default for trace/stream workloads without one: the whole
    runtime is compute-phase and every non-idle joule is dynamic
    (``E_comp = max(E - n_req * idle_w * T, 0)``) — the most DVFS-sensitive
    reading consistent with the first-order trace energy model.
    """
    T = np.asarray(w.T_true, np.float64)
    E = np.asarray(w.E_true, np.float64)
    Tc = T if w.T_comp is None else np.asarray(w.T_comp, np.float64)
    if w.E_comp is not None:
        Ec = np.asarray(w.E_comp, np.float64)
    else:
        idle = (np.zeros(len(w.n_nodes)) if w.idle_w is None
                else np.asarray(w.idle_w, np.float64))
        Ec = np.maximum(E - np.asarray(w.n_req, np.float64) * idle[None, :]
                        * T, 0.0)
    return Tc, Ec


def _tier_model(T, E, C, w_pow, Tc, Ec, n_idle, phi, xp):
    """Shared per-tier table math (``xp`` = jnp or np).  All inputs are
    [P, 1, S] except ``phi`` [1, F, 1]; unit tiers short-circuit to the
    base values bit for bit (``where`` on phi == 1.0, so the no-op axis is
    exactly free even under f32 rounding)."""
    unit = phi == 1.0
    stretch = Tc * (1.0 / phi - 1.0)
    T_f = xp.where(unit, T, T + stretch)
    E_f = xp.where(unit, E, E + Ec * (phi ** 2 - 1.0) + n_idle * stretch)
    r_t = xp.where(unit, 1.0, T_f / xp.maximum(T, _TINY))
    r_c = xp.where(unit, 1.0, E_f / xp.maximum(E, _TINY))
    C_f = xp.where(unit, C, C * r_c)
    w_f = xp.where(unit, w_pow, E_f / xp.maximum(T_f, _TINY))
    return {"T": T_f, "E": E_f, "C": C_f, "rt": r_t, "rc": r_c, "w": w_f}


def tier_tables(arrs: dict, tiers: tuple) -> dict:
    """Per-tier ground-truth tables for the jitted scan cores.

    ``arrs`` is the ``_workload_arrays`` dict; returns [P, F, S] f32
    tables: absolute ``T``/``E``/``C``/``w`` (runtime, joules, J/Mop,
    average watts) plus the ratios ``rt``/``rc`` that scale *learned*
    table rows and predictions at selection time (learned tables stay
    [P, S] — they are always updated with base, tier-0 observations).
    """
    phi = jnp.asarray(tiers, jnp.float32)[None, :, None]
    one = lambda x: x[:, None, :]
    n_idle = one(arrs["n_req"] * arrs["idle_w"][None, :])
    return _tier_model(one(arrs["T_true"]), one(arrs["E_true"]),
                       one(arrs["C_true"]), one(arrs["w_pow"]),
                       one(arrs["T_comp"]), one(arrs["E_comp"]),
                       n_idle, phi, jnp)


def tier_tables_py(w, tiers: tuple) -> dict:
    """float64 twin of ``tier_tables`` for the differential mirror."""
    phi = np.asarray(tiers, np.float64)[None, :, None]
    Tc, Ec = phase_split(w)
    idle = (np.zeros(len(w.n_nodes)) if w.idle_w is None
            else np.asarray(w.idle_w, np.float64))
    T = np.asarray(w.T_true, np.float64)
    E = np.asarray(w.E_true, np.float64)
    one = lambda x: np.asarray(x, np.float64)[:, None, :]
    n_idle = one(np.asarray(w.n_req, np.float64) * idle[None, :])
    w_pow = E / np.maximum(T, _TINY)
    return _tier_model(one(T), one(E), one(np.asarray(w.C_true)),
                       one(w_pow), one(Tc), one(Ec), n_idle, phi, np)


def npb_phase_split(systems, programs, N) -> tuple:
    """Exact ``(T_comp, E_comp)`` [P, S] for an NPB workload: compute-phase
    seconds from ``predict_phases`` at the Table 6 node counts, dynamic
    compute joules ``n * cpu_w * t_comp``."""
    P, S = len(programs), len(systems)
    Tc = np.zeros((P, S))
    Ec = np.zeros((P, S))
    for pi, prog in enumerate(programs):
        for si, sys in enumerate(systems):
            n = int(N[pi, si])
            t_comp, _, _ = predict_phases(NPB_PROFILES[prog], sys, n)
            Tc[pi, si] = t_comp
            Ec[pi, si] = n * sys.cpu_w * t_comp
    return Tc, Ec


def pareto_mask(energy, makespan) -> np.ndarray:
    """Boolean mask of the non-dominated (energy, makespan) points
    (minimizing both).  A point is dominated when another is <= on both
    objectives and strictly < on at least one; ties survive together."""
    e = np.asarray(energy, np.float64).ravel()
    m = np.asarray(makespan, np.float64).ravel()
    dom = ((e[None, :] <= e[:, None]) & (m[None, :] <= m[:, None])
           & ((e[None, :] < e[:, None]) | (m[None, :] < m[:, None])))
    return ~dom.any(axis=1)
