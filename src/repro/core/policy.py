"""Composable scheduling policies: registry-backed, PyTree-parameterized.

The paper's algorithm is one point in a family of selection rules factored
along four orthogonal axes:

  exploration  — what to do about (program, system) pairs that were never
                 run (empty profile-table rows):
                   first_released   submit to the first released unexplored
                                    system (the paper's exploration phase)
                   predictive_fill  fill unknown entries from the phase-model
                                    prediction (no exploration runs wasted)
                   optimistic_bound optimistic C lower bound for unknowns
                                    (best known C x ``ucb_scale``)
  feasibility  — which runtime enters the paper's K constraint
                 ``T <= T_min * (1 + K)``:
                   bare             the learned runtime itself (the paper)
                   queue_aware      wait + runtime completion estimate (the
                                    paper's stated future work)
                   none             no K guard (every system feasible)
  objective    — what to minimize over the feasible set:
                   min_c            energy coefficient C, tie-break on T
                                    (the paper's step 4)
                   min_t            runtime (performance-first)
                   min_avail        earliest availability (multi-cluster FIFO)
                   random           uniform random system
                   oracle           the paper rule on the TRUE tables
  queue        — the discipline deciding WHICH pending job is placed next
                 (an engine axis: it reorders placement decisions, not the
                 per-job system selection):
                   fcfs             strict arrival order (the paper; every
                                    job is placed, with a possibly-future
                                    start, the moment it arrives)
                   easy_backfill    EASY backfilling over a bounded pending
                                    window of ``window`` jobs: the oldest
                                    pending job (the head) holds a
                                    reservation computed from current
                                    node-free times, and a later pending
                                    job may be placed early only if it
                                    cannot delay that reservation (the
                                    no-delay guard; backfills may carry
                                    future starts — see the engine
                                    docstring); when the window overflows
                                    the head is force-placed (FCFS
                                    fallback)

The K guard binds only for ``min_c``: for ``min_t`` it is vacuous by
construction (the argmin-T system is always feasible), and ``min_avail``
/ ``random`` / ``oracle`` skip the table axes entirely.  The feasibility
*transform* still matters for ``min_t`` — ``queue_aware`` + ``min_t`` is
earliest-finish-time ("fastest_completion").

A ``Policy`` is a frozen dataclass registered as a JAX PyTree: the four
axis names are static metadata (they pick code paths), while the
hyperparameters ``k`` and ``ucb_scale`` are leaves — so the engine can
``vmap`` one compiled simulation over a whole policy-hyperparameter grid
(e.g. K x ucb-scale) exactly as it vmaps over fault grids.  ``window``
(the EASY pending-window bound) is static metadata too, NOT a leaf: it
sets the shape of the scan carry (the pending buffer), so changing it
retraces — exactly like changing the discipline itself.

Named compositions live in a registry (``@register_policy``); the paper's
nine historical modes are thin entries here, and a new policy registered
with three lines is automatically picked up by the CLI (``--policy``), the
benchmarks, and the jax-vs-python differential test suite.

Both selector implementations live here: ``select`` (branchless jnp, used
by the scan engine) and ``select_py`` (float64 numpy mirror, used by the
differential oracle ``simulator.simulate_py``).  They are the same
composition expressed twice; keep them in lockstep.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass

import numpy as np
import jax
import jax.numpy as jnp

BIG = 1e30

EXPLORATIONS = ("first_released", "predictive_fill", "optimistic_bound")
FEASIBILITIES = ("bare", "queue_aware", "none")
OBJECTIVES = ("min_c", "min_t", "min_avail", "random", "oracle")
QUEUES = ("fcfs", "easy_backfill", "conservative")

#: power_cap values at or above this are "uncapped" (routing + start rule).
UNCAPPED = 1e29


@dataclass(frozen=True)
class Policy:
    """One point (or a leaf-batched grid) of the policy family.

    ``exploration``/``feasibility``/``objective``/``queue``/``window`` are
    static metadata; ``k`` and ``ucb_scale`` are PyTree leaves and may be
    arrays — a Policy whose leaves carry a leading axis is a policy *grid*
    the engine vmaps over in a single compilation.
    """
    exploration: str = "first_released"
    feasibility: str = "bare"
    objective: str = "min_c"
    name: str = ""
    k: float | jax.Array = 0.0           # allowed runtime-increase fraction
    ucb_scale: float | jax.Array = 0.5   # optimism scale for unexplored C
    queue: str = "fcfs"                  # queue discipline (engine axis)
    window: int = 8                      # pending-window bound (static)
    # SCC power cap in Watts (a PyTree LEAF like k/ucb_scale, so cap grids
    # batch in one jit); >= UNCAPPED (the default) disables enforcement.
    # A finite cap routes the run onto the event-granular core, where a
    # placement can actually be deferred until cluster power drops.
    power_cap: float | jax.Array = float("inf")
    # DVFS frequency multipliers phi made available to the selector.  STATIC
    # metadata (it sizes the candidate axis: each placement candidate is a
    # (system x tier) pair, so changing the tier set retraces — exactly like
    # ``window``).  Tier 0 must be phi = 1.0: it anchors first_released
    # exploration, min_avail tie-breaks and the K-guard T_min baseline at
    # the uncapped frequency.  ``(1.0,)`` (the default) is the exact
    # pre-DVFS engine, bit for bit.
    freq_tiers: tuple = (1.0,)
    # Energy<->time scalarization weight across frequency tiers (a LEAF, so
    # whole cap x phi-weight grids batch in one jit): for ``min_c`` under
    # tiers the scored coefficient becomes C + freq_weight * T_sel, i.e.
    # 0.0 picks the lowest-energy tier outright and larger weights trade
    # joules back for speed.  Units are C-per-second; ignored untiered.
    freq_weight: float | jax.Array = 0.0

    def __post_init__(self):
        if self.exploration not in EXPLORATIONS:
            raise ValueError(f"exploration {self.exploration!r} not in "
                             f"{EXPLORATIONS}")
        if self.feasibility not in FEASIBILITIES:
            raise ValueError(f"feasibility {self.feasibility!r} not in "
                             f"{FEASIBILITIES}")
        if self.objective not in OBJECTIVES:
            raise ValueError(f"objective {self.objective!r} not in "
                             f"{OBJECTIVES}")
        if self.queue not in QUEUES:
            raise ValueError(f"queue {self.queue!r} not in {QUEUES}")
        # the window sizes the scan-carry pending buffer: a static int >= 1
        # (CLI specs arrive as floats; normalize on the frozen instance)
        object.__setattr__(self, "window", int(self.window))
        if self.window < 1:
            raise ValueError(f"window must be >= 1, got {self.window}")
        # freq_tiers is static metadata (hashable tuple of python floats);
        # CLI specs may deliver lists — normalize on the frozen instance
        tiers = tuple(float(p) for p in np.atleast_1d(
            np.asarray(self.freq_tiers, dtype=np.float64)))
        object.__setattr__(self, "freq_tiers", tiers)
        if not tiers:
            raise ValueError("freq_tiers must be non-empty")
        if tiers[0] != 1.0:
            raise ValueError(f"freq_tiers[0] must be 1.0 (the uncapped "
                             f"anchor tier), got {tiers}")
        if any(not (0.0 < p <= 1.0) for p in tiers):
            raise ValueError(f"every freq tier must be in (0, 1], got "
                             f"{tiers}")

    def with_params(self, **params) -> "Policy":
        """New Policy with replaced hyperparameter leaves (k, ucb_scale,
        power_cap, freq_weight)."""
        return dataclasses.replace(self, **params)

    @property
    def grid_size(self) -> int | None:
        """Number of grid points when leaf-batched, else None."""
        k = np.asarray(self.k)
        u = np.asarray(self.ucb_scale)
        p = np.asarray(self.power_cap)
        f = np.asarray(self.freq_weight)
        if k.ndim == 0 and u.ndim == 0 and p.ndim == 0 and f.ndim == 0:
            return None
        return int(np.broadcast_shapes(k.shape, u.shape, p.shape,
                                       f.shape)[0])

    @property
    def tiered(self) -> bool:
        """True when the DVFS tier axis is non-trivial (static python
        check — picks the expanded (system x tier) candidate code path)."""
        return self.freq_tiers != (1.0,)

    @property
    def capped(self) -> bool:
        """True when any grid point carries a finite power cap (facade-time
        python check on the concrete leaf — decides the core routing)."""
        return bool((np.asarray(self.power_cap) < UNCAPPED).any())


jax.tree_util.register_dataclass(
    Policy, data_fields=("k", "ucb_scale", "power_cap", "freq_weight"),
    meta_fields=("exploration", "feasibility", "objective", "name",
                 "queue", "window", "freq_tiers"))


# ---------------------------------------------------------------- registry

_REGISTRY: dict[str, object] = {}

#: The paper's nine historical selector modes, in their historical order.
LEGACY_MODES = ("paper", "queue_aware", "predictive", "ucb", "fastest",
                "greenest", "first_free", "random", "oracle")


def register_policy(name: str):
    """Decorator: register a Policy factory under ``name``.

    The factory takes hyperparameter overrides (``k=``, ``ucb_scale=``) and
    returns a ``Policy``.  Registered names are picked up by
    ``make_policy``, the ``--policy`` CLI flag, and the differential test
    sweep over the whole registry.
    """
    def deco(factory):
        if name in _REGISTRY:
            raise ValueError(f"policy {name!r} already registered")
        _REGISTRY[name] = factory
        return factory
    return deco


def policy_names() -> tuple[str, ...]:
    """All registered policy names (legacy modes first, then extensions)."""
    extra = tuple(n for n in _REGISTRY if n not in LEGACY_MODES)
    return LEGACY_MODES + extra


def make_policy(name: str, **params) -> Policy:
    """Instantiate a registered policy, overriding hyperparameters."""
    try:
        factory = _REGISTRY[name]
    except KeyError:
        raise ValueError(f"unknown policy {name!r}; registered: "
                         f"{policy_names()}") from None
    return factory(**params)


def parse_policy_spec(spec: str, **defaults) -> Policy:
    """Parse a CLI policy spec ``name`` or ``name:key=val,key=val``.

    Values parse as floats (``window`` as int, ``queue`` as a discipline
    name); e.g. ``ucb:k=0.1,ucb_scale=0.25`` or
    ``paper:k=0.1,queue=easy_backfill,window=16``.  Keyword ``defaults``
    fill hyperparameters the spec does not set explicitly (the CLI passes
    its ``--k`` here so ``--policy paper`` matches the legacy ``--mode
    paper`` default).
    """
    name, _, rest = spec.partition(":")
    params = {}
    if rest:
        for item in rest.split(","):
            key, _, val = item.partition("=")
            if not _ or not key:
                raise ValueError(f"bad policy param {item!r} in {spec!r} "
                                 "(expected key=val)")
            key = key.strip()
            if key == "queue":
                params[key] = val.strip()
            elif key == "window":
                params[key] = int(val)
            elif key == "freq_tiers":
                # '+'-separated phi grid: freq_tiers=1.0+0.8+0.6
                params[key] = tuple(float(p) for p in val.split("+"))
            else:
                params[key] = float(val)
    return make_policy(name.strip(), **{**defaults, **params})


def parse_queue_spec(spec: str) -> tuple:
    """Parse a CLI queue spec ``fcfs`` | ``easy_backfill[:window=W]`` into
    ``(discipline, window-or-None)``."""
    name, _, rest = spec.partition(":")
    name = name.strip()
    if name not in QUEUES:
        raise ValueError(f"unknown queue discipline {name!r}; known: "
                         f"{QUEUES}")
    window = None
    if rest:
        key, eq, val = rest.partition("=")
        if key.strip() != "window" or not eq:
            raise ValueError(f"bad queue param {rest!r} in {spec!r} "
                             "(expected window=W)")
        window = int(val)
    return name, window


def apply_queue_spec(policy: Policy, spec: str) -> Policy:
    """Return ``policy`` with its queue discipline overridden by a CLI
    spec (``parse_queue_spec`` grammar).  The single place queue specs are
    applied — used by ``Scheduler(queue=...)`` and the ``--queue`` flag."""
    name, window = parse_queue_spec(spec)
    over = {"queue": name}
    if window is not None:
        over["window"] = window
    return dataclasses.replace(policy, **over)


def _entry(name, exploration="first_released", feasibility="bare",
           objective="min_c", queue="fcfs", window=8, freq_tiers=(1.0,)):
    @register_policy(name)
    def factory(**params):
        base = dict(exploration=exploration, feasibility=feasibility,
                    objective=objective, name=name, queue=queue,
                    window=window, freq_tiers=freq_tiers)
        base.update(params)          # spec overrides (incl. queue/window)
        return Policy(**base)
    return factory


# The paper + the historical beyond-paper modes as registry entries.
_entry("paper")                                   # the paper's algorithm
_entry("queue_aware", feasibility="queue_aware")  # paper's future work
_entry("predictive", exploration="predictive_fill")
_entry("ucb", exploration="optimistic_bound")
_entry("fastest", objective="min_t")
_entry("greenest", feasibility="none")            # argmin C, no K guard
_entry("first_free", objective="min_avail")
_entry("random", objective="random")
_entry("oracle", objective="oracle")
# New compositions the factored space exposes for free:
_entry("fastest_completion", feasibility="queue_aware", objective="min_t")
_entry("predictive_queue_aware", exploration="predictive_fill",
       feasibility="queue_aware")
# Queue-discipline axis (ISSUE 3): the paper's selection rule under EASY
# backfilling, and its queue-aware variant (reservation-conscious selection
# composes naturally with reservation-based backfill).
_entry("easy_backfill", queue="easy_backfill")
_entry("easy_queue_aware", feasibility="queue_aware", queue="easy_backfill")
# Conservative backfilling (ISSUE 5): every pending job holds a
# reservation; a backfill may not delay ANY of them.  Always runs on the
# event-granular core (reservations are rechecked whenever nodes free up).
_entry("conservative", queue="conservative")
# DVFS tier axis (ISSUE 8): the paper's selection rule over the expanded
# (system x frequency tier) candidate set — frequency scales compute-phase
# runtime up by 1/phi and dynamic compute power down by phi^3 (core/dvfs.py),
# so argmin-C naturally trades makespan for joules; freq_weight (a leaf)
# dials the trade back toward speed.
_entry("dvfs_paper", freq_tiers=(1.0, 0.8, 0.6))
_entry("dvfs_queue_aware", feasibility="queue_aware",
       freq_tiers=(1.0, 0.8, 0.6))


# ------------------------------------------------------------ jnp selector

def _lex_argmin(c_row, t_row, feasible):
    """Masked lexicographic argmin: smallest C over ``feasible``, exact-tie
    break on T.  If no system is feasible (possible only for pathological
    K < 0 or sentinel-saturated rows), falls back to considering all —
    never returns an out-of-range or BIG-biased index."""
    feasible = jnp.where(jnp.any(feasible), feasible, True)
    cbest = jnp.where(feasible, c_row, BIG).min()
    tie = feasible & (c_row == cbest)
    return jnp.argmin(jnp.where(tie, t_row, BIG))


def _paper_rule(c_row, t_row, k):
    """The paper's step 4: argmin C s.t. T <= T_min*(1+K); tie-break on T.
    Rows must be fully known (no zeros)."""
    feasible = t_row <= t_row.min() * (1.0 + k)
    return _lex_argmin(c_row, t_row, feasible)


def select(policy: Policy, *, c_row, t_row, runs_row, avail_row, k,
           c_pred_row=None, t_pred_row=None, key=None):
    """Composed branchless selector: returns the chosen system index
    (traced int32) for one job.

    c_row/t_row: learned tables for this program [S]; runs_row: run counts
    [S]; avail_row: earliest start per system [S]; k: allowed
    runtime-increase fraction (per-job effective value — overrides
    ``policy.k``); *_pred: phase-model predictions [S] (the TRUE tables for
    the oracle objective); key: PRNG key for the random objective.
    """
    obj = policy.objective
    if obj == "min_avail":
        return jnp.argmin(avail_row)
    if obj == "random":
        return jax.random.randint(key, (), 0, c_row.shape[0])
    if obj == "oracle":
        return _paper_rule(c_pred_row, t_pred_row, k)

    known = runs_row > 0

    expl = policy.exploration
    if expl == "first_released":
        c_eff = jnp.where(known, c_row, BIG)
        t_eff = jnp.where(known, t_row, BIG)
    elif expl == "predictive_fill":
        c_eff = jnp.where(known, c_row, c_pred_row)
        t_eff = jnp.where(known, t_row, t_pred_row)
    else:  # optimistic_bound
        # optimistic lower bound on C for unexplored systems: best known C
        # scaled by ucb_scale => systems get tried when promising
        c_floor = jnp.where(known, c_row, BIG).min() * policy.ucb_scale
        c_eff = jnp.where(known, c_row, c_floor)
        t_eff = jnp.where(known, t_row, jnp.where(known, t_row, BIG).min())

    feas = policy.feasibility
    if feas == "queue_aware":
        wait = avail_row - avail_row.min()
        t_sel = jnp.where(t_eff < BIG, t_eff + wait, BIG)
    else:  # "bare" and "none" share the runtime estimate
        t_sel = t_eff

    if obj == "min_c" and policy.tiered:
        # tier scalarization: C + freq_weight * T biases the energy argmin
        # toward faster tiers (freq_weight = 0 => lowest-energy tier);
        # unknown-row BIG sentinels stay astronomically large either way
        c_eff = c_eff + policy.freq_weight * jnp.where(t_sel < BIG,
                                                       t_sel, 0.0)

    if obj == "min_c":
        if feas == "none":
            exploit = _lex_argmin(c_eff, t_sel,
                                  jnp.ones_like(c_eff, dtype=bool))
        else:
            exploit = _paper_rule(c_eff, t_sel, k)
    else:  # min_t
        exploit = jnp.argmin(t_sel)

    if expl == "first_released":
        explore = jnp.argmin(jnp.where(~known, avail_row, BIG))
        return jnp.where(jnp.any(~known), explore, exploit)
    return exploit


def select_batched(policy: Policy, *, c_rows, t_rows, runs_rows, avail_rows,
                   k, c_pred_rows=None, t_pred_rows=None, keys=None):
    """``select`` over a leading candidate axis: one call scores a whole
    batch of pending jobs (the EASY window) against their per-candidate
    table rows and availability vectors.

    Every argument is the batched counterpart of the ``select`` keyword of
    the same stem, with a leading [W] axis: c_rows/t_rows/runs_rows/
    avail_rows/\\*_pred_rows are [W, S], ``k`` is [W] (per-candidate
    effective K), ``keys`` is a [W] PRNG key array (fold_in per job id —
    required for the random objective, optional otherwise).  Returns [W]
    int32 chosen systems, bit-identical per row to W scalar ``select``
    calls: the vmap only adds a leading axis to elementwise comparisons
    and per-row reductions, and jax PRNG draws are deterministic per key.
    """
    def one(c_row, t_row, runs_row, avail_row, kk, c_pred, t_pred, key):
        return select(policy, c_row=c_row, t_row=t_row, runs_row=runs_row,
                      avail_row=avail_row, k=kk, c_pred_row=c_pred,
                      t_pred_row=t_pred, key=key)
    return jax.vmap(one)(c_rows, t_rows, runs_rows, avail_rows, k,
                         c_pred_rows, t_pred_rows, keys)


# ---------------------------------------------------------- numpy mirror

def _lex_argmin_py(c_row, t_row, feasible):
    if not feasible.any():
        feasible = np.ones_like(feasible, dtype=bool)
    cbest = np.where(feasible, c_row, BIG).min()
    tie = feasible & (c_row == cbest)
    return int(np.argmin(np.where(tie, t_row, BIG)))


def _paper_rule_py(c_row, t_row, k):
    feasible = t_row <= t_row.min() * (1.0 + k)
    return _lex_argmin_py(c_row, t_row, feasible)


def select_py(policy: Policy, *, c_row, t_row, runs_row, avail_row, k,
              c_pred_row=None, t_pred_row=None, rand_sel=None):
    """float64 numpy mirror of ``select`` for differential testing.  The
    random objective cannot be mirrored in numpy; the caller replays the
    jax PRNG stream and passes the draw as ``rand_sel``."""
    obj = policy.objective
    if obj == "min_avail":
        return int(np.argmin(avail_row))
    if obj == "random":
        return rand_sel
    if obj == "oracle":
        return _paper_rule_py(c_pred_row, t_pred_row, k)

    known = runs_row > 0

    expl = policy.exploration
    if expl == "first_released":
        c_eff = np.where(known, c_row, BIG)
        t_eff = np.where(known, t_row, BIG)
    elif expl == "predictive_fill":
        c_eff = np.where(known, c_row, c_pred_row)
        t_eff = np.where(known, t_row, t_pred_row)
    else:  # optimistic_bound
        c_floor = np.where(known, c_row, BIG).min() * float(policy.ucb_scale)
        c_eff = np.where(known, c_row, c_floor)
        t_eff = np.where(known, t_row, np.where(known, t_row, BIG).min())

    feas = policy.feasibility
    if feas == "queue_aware":
        wait = avail_row - avail_row.min()
        t_sel = np.where(t_eff < BIG, t_eff + wait, BIG)
    else:
        t_sel = t_eff

    if obj == "min_c" and policy.tiered:
        fw = float(np.asarray(policy.freq_weight))
        c_eff = c_eff + fw * np.where(t_sel < BIG, t_sel, 0.0)

    if obj == "min_c":
        if feas == "none":
            exploit = _lex_argmin_py(c_eff, t_sel,
                                     np.ones(len(c_eff), dtype=bool))
        else:
            exploit = _paper_rule_py(c_eff, t_sel, k)
    else:  # min_t
        exploit = int(np.argmin(t_sel))

    if expl == "first_released" and not known.all():
        return int(np.argmin(np.where(~known, avail_row, BIG)))
    return exploit
