"""Campaign-scale scheduling engine: one jitted core, one ``Scheduler`` facade.

Models the paper's SCC: several computing systems (CC_1..CC_S), each a pool
of interchangeable nodes with per-node free-times; a global job queue routed
by a meta-scheduler (a ``repro.core.policy.Policy``).  Jobs are programs
with known per-system ground-truth (T, C, E) from the phase model.

The facade::

    res = Scheduler("paper", seeds=range(4)).run(workload)        # seed axis
    res = Scheduler(make_policy("ucb", k=k_grid, ucb_scale=u_grid),
                    faults=fault_list).run(workload)    # fault x policy grid

``Scheduler.run`` flattens the (fault x policy x seed) grid to one batch
axis, vmaps the lax.scan core over it inside a single jit, and reshapes
back into a structured ``SimResult``/``CampaignResult`` with named axes.
Because Policy hyperparameters (K, ucb_scale) are PyTree *leaves*, a whole
policy-hyperparameter grid shares one compilation — the static policy
metadata (exploration/feasibility/objective) is the only thing that
retraces.

``totals_only=True`` keeps the per-job accounting in the scan carry instead
of materializing [*grid, J] placement arrays — a 10^5-job x large-grid
campaign returns [*grid] aggregates in O(grid) memory.

Placement hot path: the per-step question "when are n_req[s] nodes of
system s free?" is the n_req-th smallest entry of the node-free row,
radix-selected directly (repro.kernels.kth_free: Pallas kernel on TPU,
pure-jnp twin elsewhere, O(S·maxN) per step and bit-exact against the sort
oracle); nodes are allocated by thresholding against that value.

Fault model (DESIGN.md §7): per-job deterministic pseudo-random straggler
slowdowns and node-failure restarts (checkpoint-restart semantics: a failed
job re-does ``restart_overhead`` of its work; energy scales accordingly).
The learned (C, T) tables absorb these — the paper's history mechanism
routes around chronically degraded systems automatically.

Maintenance/outage windows (scenario library, repro.data.scenarios): a
system accepts no new placements while a window [t0, t1) is open; jobs
whose earliest start falls inside a window are pushed to its end.  Windows
must be sorted by start and non-overlapping per system.  Jobs already
running ride through (drain semantics).

Accounting notes: energy is attributed per job (allocated nodes over the
job's span, paper eq. 2); idle energy of unallocated nodes is not attributed
to the suite (the paper compares job-attributed energy).  Learned-table
updates apply as each job is *placed* (the paper stores them at completion;
for the paper's simultaneous-submission experiment the two coincide —
distinct programs never wait on each other's profile entries).
"""

from __future__ import annotations

import warnings
from dataclasses import dataclass, replace
from functools import partial
from typing import NamedTuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.dvfs import npb_phase_split, phase_split, tier_tables
from repro.core.policy import (BIG, UNCAPPED, Policy, apply_queue_spec,
                               make_policy, select, select_batched)
from repro.core.result import SimResult, CampaignResult
from repro.core.workload_model import NPB_PROFILES, npb_tables
from repro.kernels.kth_free import (kth_free_time, kth_free_time_rows,
                                    kth_free_time_shared)
from repro.sharding.grid import (grid_spec as _grid_spec,
                                 replicated as _replicated,
                                 shard_map as _shard_map)


@dataclass(frozen=True)
class SimConfig:
    """Legacy single-run configuration (mode string + fault fields).

    The ``Scheduler`` facade supersedes this for new code; it survives for
    the ``simulate_jax``/``sweep_k``/``run_campaign`` shims and the python
    differential mirror.  ``mode`` accepts any registered policy name.
    """
    mode: str = "paper"
    k: float = 0.0                 # allowed runtime-increase fraction
    straggler_prob: float = 0.0
    straggler_factor: float = 2.0
    failure_prob: float = 0.0
    restart_overhead: float = 0.5
    seed: int = 0
    # True => profile tables pre-filled with ground truth (the paper's
    # Figs 1-4 regime: 'all 5 previously run programs', Tables 3-4 full).
    warm_start: bool = False
    # kth-free placement dispatch: None = auto (Pallas on TPU, jnp radix
    # select elsewhere); or force "pallas"/"pallas_interpret"/"jnp"/"sort".
    placer: str | None = None
    # queue-discipline overrides; "" / 0 defer to the registered policy's
    # own metadata (so mode="easy_backfill" backfills out of the box)
    queue: str = ""
    queue_window: int = 0
    # SCC power cap (Watts); inf = uncapped.  A finite cap routes onto the
    # event-granular core.  Must ride the built policy's leaf so the
    # sweep_k/run_campaign shims pass it through (ISSUE 5 regression).
    power_cap: float = float("inf")
    # scan granularity override: "" = auto ("events" for conservative /
    # capped, "arrival" otherwise), or "arrival" / "events" explicitly.
    core: str = ""

    def policy(self) -> Policy:
        pol = make_policy(self.mode, k=self.k)
        over = {}
        if self.queue:
            over["queue"] = self.queue
        if self.queue_window:
            over["window"] = self.queue_window
        if self.power_cap != float("inf"):
            over["power_cap"] = float(self.power_cap)
        return replace(pol, **over) if over else pol


@dataclass(frozen=True)
class FaultConfig:
    """One point of a fault grid."""
    straggler_prob: float = 0.0
    straggler_factor: float = 2.0
    failure_prob: float = 0.0
    restart_overhead: float = 0.5


@dataclass(frozen=True)
class Workload:
    """Static description of a job stream over P programs x S systems."""
    prog: np.ndarray            # [J] int32 program ids
    arrival: np.ndarray         # [J] f32 submit times
    k_job: np.ndarray           # [J] f32 per-job K (fraction); NaN -> global k
    n_req: np.ndarray           # [P, S] nodes needed
    T_true: np.ndarray          # [P, S] runtime ground truth
    C_true: np.ndarray          # [P, S] J/Mop ground truth
    E_true: np.ndarray          # [P, S] Joules ground truth
    T_pred: np.ndarray          # [P, S] phase-model predictions
    C_pred: np.ndarray
    n_nodes: np.ndarray         # [S] node counts
    programs: tuple = ()        # names, for reports
    systems: tuple = ()
    # [S, W, 2] maintenance windows (start, end), sorted, non-overlapping
    # per system; None = no outages.
    outage: np.ndarray | None = None
    # [S] per-node idle watts (systems.py power model); None = 0 W (no
    # idle draw, power metrics degenerate to job-attributed power only).
    idle_w: np.ndarray | None = None
    # [P, S] compute-phase seconds / dynamic compute joules — the
    # DVFS-sensitive share of T_true / E_true (core/dvfs.py tier model).
    # None = engine defaults (dvfs.phase_split): the whole runtime is
    # compute-phase and every non-idle joule is dynamic.
    T_comp: np.ndarray | None = None
    E_comp: np.ndarray | None = None


def make_npb_workload(systems, order=("BT", "EP", "IS", "LU", "SP"),
                      arrivals=None, k_job=None, repeats: int = 1,
                      pred_noise: float = 0.0, noise_seed: int = 0,
                      outage=None):
    """The paper's experiment: NPB suite submitted (simultaneously by
    default) to the four JSCC systems. ``repeats`` re-submits the suite."""
    programs = tuple(sorted(set(order)))
    pidx = {p: i for i, p in enumerate(programs)}
    C, T, N = npb_tables(systems, programs)
    mops = np.array([NPB_PROFILES[p].flops / 1e6 for p in programs])
    E = C * mops[:, None]
    rng = np.random.default_rng(noise_seed)
    noise = (1.0 + pred_noise * rng.standard_normal(C.shape)) if pred_noise else 1.0
    seq = list(order) * repeats
    J = len(seq)
    T_comp, E_comp = npb_phase_split(systems, programs, N)
    return Workload(
        prog=np.array([pidx[p] for p in seq], np.int32),
        arrival=np.zeros(J, np.float32) if arrivals is None
        else np.asarray(arrivals, np.float32),
        k_job=np.full(J, np.nan, np.float32) if k_job is None
        else np.asarray(k_job, np.float32),
        n_req=N, T_true=T, C_true=C, E_true=E,
        T_pred=T * noise, C_pred=C * noise,
        n_nodes=np.array([s.n_nodes for s in systems], np.int32),
        programs=programs, systems=tuple(s.name for s in systems),
        outage=None if outage is None else np.asarray(outage, np.float32),
        idle_w=np.array([s.idle_w for s in systems], np.float32),
        T_comp=T_comp, E_comp=E_comp,
    )


def _fault_factor(key, j, fvec):
    """fvec: [straggler_prob, straggler_factor, failure_prob, restart_ovh]."""
    u = jax.random.uniform(jax.random.fold_in(key, j), (2,))
    slow = jnp.where(u[0] < fvec[0], fvec[1], 1.0)
    fail = jnp.where(u[1] < fvec[2], 1.0 + fvec[3], 1.0)
    return slow * fail


def _workload_arrays(w: Workload) -> dict:
    """Workload -> the jnp pytree the jitted core consumes."""
    max_n = int(w.n_nodes.max())
    node_exists = np.arange(max_n)[None, :] < w.n_nodes[:, None]   # [S, maxN]
    arrs = {
        "free0": jnp.where(jnp.asarray(node_exists), 0.0, BIG),
        "prog": jnp.asarray(w.prog),
        "arrival": jnp.asarray(w.arrival),
        "k_job": jnp.asarray(w.k_job),
        "n_req": jnp.asarray(w.n_req),
        "T_true": jnp.asarray(w.T_true),
        "C_true": jnp.asarray(w.C_true),
        "E_true": jnp.asarray(w.E_true),
        "T_pred": jnp.asarray(w.T_pred),
        "C_pred": jnp.asarray(w.C_pred),
        # power model: per-job average draw (paper eq. 1-2: the phase
        # components integrate to E, so E/T is the job's step-function
        # contribution to the cluster trace) + per-system idle watts
        "w_pow": jnp.asarray(w.E_true / np.maximum(w.T_true, 1e-30),
                             jnp.float32),
        "idle_w": jnp.zeros(len(w.n_nodes), jnp.float32)
        if w.idle_w is None else jnp.asarray(w.idle_w, jnp.float32),
    }
    # DVFS tier model inputs (explicit phase split, or the trace-workload
    # defaults — see dvfs.phase_split); consumed only under freq_tiers
    T_comp, E_comp = phase_split(w)
    arrs["T_comp"] = jnp.asarray(T_comp, jnp.float32)
    arrs["E_comp"] = jnp.asarray(E_comp, jnp.float32)
    if w.outage is not None and w.outage.size:
        arrs["outage"] = jnp.asarray(w.outage, jnp.float32)
    return arrs


def _push_out_of_outage(avail, outage):
    """Earliest start per system, pushed past any open maintenance window.
    Windows sorted by start per system, so one in-order pass resolves
    cascades (a push landing inside the next window is pushed again).
    ``avail``'s last axis is the system axis (leading axes broadcast)."""
    for wi in range(outage.shape[1]):
        o0, o1 = outage[:, wi, 0], outage[:, wi, 1]
        avail = jnp.where((avail >= o0) & (avail < o1), o1, avail)
    return avail


def _earliest(node_free, nreq_row, arr, placer, outage):
    """(kth free time, earliest start) per system for one job: the kth-free
    radix select, floored at the arrival and pushed out of any open
    maintenance window.  Shared by the FCFS step, the EASY reservation /
    backfill guard, and the final placement."""
    kth = kth_free_time(node_free, nreq_row, force=placer)
    avail = jnp.maximum(arr, kth)
    if outage is not None:
        avail = _push_out_of_outage(avail, outage)
    return kth, avail


def _earliest_shared(node_free, nreq_rows, arr_col, placer, outage):
    """``_earliest`` for a whole candidate batch against ONE node-free
    table: [W, S] requests -> ([W, S] kth, [W, S] earliest start), via the
    shared-table kernel entry (one sort serves every candidate).
    ``arr_col``: [W, 1] per-candidate arrival floors."""
    kth = kth_free_time_shared(node_free, nreq_rows, force=placer)
    avail = jnp.maximum(arr_col, kth)
    if outage is not None:
        avail = _push_out_of_outage(avail, outage)
    return kth, avail


def _alloc_mask(node_free, sel, kth_sel, need):
    """The nodes ``_alloc`` takes on system ``sel``: everything strictly
    below the kth free time, plus first-by-index ties at it (the python
    mirror's stable argsort picks the same nodes).  Exposed separately so
    the event core can mirror an allocation onto its node-power table."""
    free_sel = node_free[sel]
    below = free_sel < kth_sel
    tie = free_sel == kth_sel
    tie_rank = jnp.cumsum(tie) - 1
    return below | (tie & (tie_rank < need - jnp.sum(below)))


def _alloc(node_free, sel, kth_sel, need, finish):
    """Allocate the ``need`` earliest-free nodes of system ``sel`` until
    ``finish`` (see ``_alloc_mask`` for the tie-break)."""
    take = _alloc_mask(node_free, sel, kth_sel, need)
    return node_free.at[sel].set(jnp.where(take, finish, node_free[sel]))


def _idle_energy(arrs, makespan, busy):
    """Idle draw of UNallocated existing nodes over the makespan (Joules).
    Job-attributed energy already covers allocated nodes' idle component
    (predict_energy integrates idle_w over the job span), so this is the
    complement the paper's site-level power view adds."""
    idle_w = arrs["idle_w"]                                      # [S]
    n_exist = jnp.sum(arrs["free0"] < BIG, axis=1)               # [S]
    return (jnp.sum(idle_w * n_exist) * makespan
            - jnp.sum(idle_w * busy))


def _tier_rows(tt, p, C_row, T_row, runs_row, avail_row, C_pred_row,
               T_pred_row):
    """Expand one job's (or a [W]-batched set of) selection rows over the
    (tier x system) candidate axis, tier-major (flat index = f * S + s,
    so tier 0 / phi = 1.0 occupies the first S entries and argmin
    tie-breaks anchor at full frequency).

    ``tt`` is the ``tier_tables`` dict; learned rows and predictions are
    scaled by the per-tier energy/runtime ratios (unit ratios are exactly
    1.0, so tier-0 entries are the base rows bit for bit), run counts are
    tier-independent (tables always learn base observations), and
    ``avail_row`` is tiled when per-system ([..., S]) or flattened when
    already per-(tier, system) ([..., F, S] — the conservative core's
    per-tier earliest-fit)."""
    rc, rt = tt["rc"][p], tt["rt"][p]                    # [..., F, S]
    F, S = rc.shape[-2], rc.shape[-1]
    flat = lambda x: x.reshape(x.shape[:-2] + (F * S,))
    tile = lambda x: flat(jnp.broadcast_to(x[..., None, :],
                                           x.shape[:-1] + (F, S)))
    avail_x = flat(avail_row) if avail_row.shape == rc.shape \
        else tile(avail_row)
    return (flat(C_row[..., None, :] * rc), flat(T_row[..., None, :] * rt),
            tile(runs_row), avail_x,
            flat(C_pred_row[..., None, :] * rc),
            flat(T_pred_row[..., None, :] * rt))


class _SimPieces(NamedTuple):
    """One simulation, disassembled for streamed execution:
    ``lax.scan(step, carry0, xs, length=length)`` followed by
    ``finish(carry, ys)`` IS ``_scan_sim`` — same step trace, same
    epilogue ops.  The chunked driver (``_run_chunked``) instead slices
    ``xs`` into fixed windows, threads the carry between per-chunk scans
    and reassembles spilled ``ys`` before the shared finish, so chunked
    results are bit-identical to the monolithic scan by construction."""
    step: object      # step(carry, x) -> (carry, out)
    xs: object        # [length]-leading scan inputs, or None (event cores)
    length: int       # static step count
    carry0: object    # initial carry
    finish: object    # finish(carry, ys) -> result dict


def _stream_xs(arrs: dict, policy: Policy, core: str = "arrival",
               retries: bool = False):
    """Scan inputs + static step count of the routed core, buildable on
    the host (the chunked driver slices these without constructing the
    full pieces).  Step counts: the event core needs one push + one
    placement per job and every advance lands on a distinct event time,
    so ``4J + |outage| + 4`` steps suffice (``7J`` with retries: a
    failure adds one push, one placement, one event); the conservative
    core's reservation starts add at most one advance each (``5J`` /
    ``9J``).  The arrival xs carry the RAW per-job K column — steps
    resolve NaN -> policy.k at use, so no per-lane [J] K vector ever
    materializes under the batched vmap."""
    J = arrs["prog"].shape[0]
    n_out = arrs["outage"][..., 1].size if "outage" in arrs else 0
    if policy.queue == "conservative":
        return None, (9 if retries else 5) * J + n_out + 4
    if core == "events":
        return None, (7 if retries else 4) * J + n_out + 4
    if policy.queue == "easy_backfill":
        W = int(policy.window)
        jxs = jnp.concatenate([jnp.arange(J, dtype=jnp.int32),
                               jnp.full((W,), J, jnp.int32)])
        nows = jnp.concatenate([arrs["arrival"],
                                jnp.full((W,), BIG, jnp.float32)])
        return (jxs, nows), J + W
    return (jnp.arange(J), arrs["prog"], arrs["arrival"], arrs["k_job"]), J


def _sim_pieces(arrs: dict, policy: Policy, warm_start: bool,
                placer: str | None, totals_only: bool, seed, fvec,
                easy_eval: str = "batched", core: str = "arrival",
                retries: bool = False) -> _SimPieces:
    """Build one simulation's pieces; every argument traced except the
    static (policy metadata, warm_start, placer, totals_only, easy_eval,
    core, retries).  Dispatch:

    - ``core="arrival"`` (default): the historical arrival-indexed scans —
      the FCFS path bit-identical to the pre-queue-axis engine, EASY via
      the windowed scan (``_easy_pieces``);
    - ``core="events"`` (or ``queue="conservative"``, which requires it):
      the event-granular step folded with an open horizon — the core that
      can defer placements under an SCC power cap and re-queue mid-job
      failures (``retries``).
    """
    T_true, C_true = arrs["T_true"], arrs["C_true"]
    P, S = T_true.shape
    # independent streams for selection and fault draws — folding a shared
    # key with j and j+offset would collide once J exceeds the offset,
    # which campaign streams (10k+ jobs) do
    sel_key, fault_key = jax.random.split(jax.random.key(seed))

    if warm_start:
        tabs0 = (C_true, T_true, jnp.ones((P, S), jnp.int32))
    else:
        tabs0 = (jnp.zeros((P, S)), jnp.zeros((P, S)),
                 jnp.zeros((P, S), jnp.int32))
    xs, length = _stream_xs(arrs, policy, core, retries)

    if policy.queue == "conservative" or core == "events":
        cons = policy.queue == "conservative"
        estep = (make_cons_step if cons else make_event_step)(
            policy, placer, totals_only, retries)
        ctx = {"arrs": arrs, "sel_key": sel_key, "fault_key": fault_key,
               "fvec": fvec}
        if policy.tiered:
            ctx["tt"] = tier_tables(arrs, policy.freq_tiers)
        hor = jnp.float32(BIG)
        carry0 = (cons_carry0 if cons else event_carry0)(
            arrs, policy, tabs0, totals_only)
        return _SimPieces(
            lambda c, _: estep(ctx, c, hor), xs, length, carry0,
            lambda carry, ys: _event_results(arrs, totals_only, ys, carry))
    if policy.queue == "easy_backfill":
        step, carry0, fin = _easy_pieces(arrs, policy, placer, totals_only,
                                         sel_key, fault_key, fvec, tabs0,
                                         easy_eval)
    else:
        step, carry0, fin = _arrival_pieces(arrs, policy, placer,
                                            totals_only, sel_key,
                                            fault_key, fvec, tabs0)
    return _SimPieces(step, xs, length, carry0, fin)


def _scan_sim(arrs: dict, policy: Policy, warm_start: bool,
              placer: str | None, totals_only: bool, seed, fvec,
              easy_eval: str = "batched", core: str = "arrival",
              retries: bool = False):
    """One full simulation: fold the routed core's pieces through a
    single lax.scan (see ``_sim_pieces`` for dispatch and staticness)."""
    pieces = _sim_pieces(arrs, policy, warm_start, placer, totals_only,
                         seed, fvec, easy_eval, core, retries)
    carry, ys = jax.lax.scan(pieces.step, pieces.carry0, pieces.xs,
                             length=pieces.length)
    return pieces.finish(carry, ys)


def _arrival_pieces(arrs: dict, policy: Policy, placer: str | None,
                    totals_only: bool, sel_key, fault_key, fvec, tabs0):
    """Pieces of the arrival-indexed FCFS scan (the historical core)."""
    T_true, C_true, E_true = arrs["T_true"], arrs["C_true"], arrs["E_true"]
    T_pred, C_pred = arrs["T_pred"], arrs["C_pred"]
    n_req, prog, arrival = arrs["n_req"], arrs["prog"], arrs["arrival"]
    outage = arrs.get("outage")
    P, S = T_true.shape
    J = prog.shape[0]
    tiered = policy.tiered
    tt = tier_tables(arrs, policy.freq_tiers) if tiered else None
    pol_k = jnp.asarray(policy.k, jnp.float32)

    def step(carry, xs):
        node_free, C_tab, T_tab, runs, acc = carry
        j, p, arr, kj = xs
        # per-job effective K: explicit workload overrides win over the
        # policy's (resolved at use — the xs carry the raw NaN-padded
        # column, see _stream_xs)
        k = jnp.where(jnp.isnan(kj), pol_k, kj)

        nreq_row = n_req[p]                                      # [S]
        kth, avail = _earliest(node_free, nreq_row, arr, placer, outage)

        key = jax.random.fold_in(sel_key, j)
        if tiered:
            c_x, t_x, r_x, a_x, cp_x, tp_x = _tier_rows(
                tt, p, C_tab[p], T_tab[p], runs[p], avail, C_pred[p],
                T_pred[p])
            sel_x = select(policy, c_row=c_x, t_row=t_x, runs_row=r_x,
                           avail_row=a_x, k=k, c_pred_row=cp_x,
                           t_pred_row=tp_x, key=key)
            f = (sel_x // S).astype(jnp.int32)
            sel = sel_x % S
        else:
            f = jnp.int32(0)
            sel = select(
                policy, c_row=C_tab[p], t_row=T_tab[p], runs_row=runs[p],
                avail_row=avail, k=k, c_pred_row=C_pred[p],
                t_pred_row=T_pred[p], key=key)

        factor = _fault_factor(fault_key, j, fvec)
        # tables learn base (tier-0) observations — a tier choice changes
        # the realized runtime/energy, never the learned profile
        C_act = C_true[p, sel] * factor
        T_upd = T_true[p, sel] * factor
        if tiered:
            T_act = tt["T"][p, f, sel] * factor
            E_act = tt["E"][p, f, sel] * factor
        else:
            T_act = T_upd
            E_act = E_true[p, sel] * factor
        start = avail[sel]
        finish = start + T_act

        need = nreq_row[sel]
        node_free = _alloc(node_free, sel, kth[sel], need, finish)

        n = runs[p, sel].astype(jnp.float32)
        C_tab = C_tab.at[p, sel].set((C_tab[p, sel] * n + C_act) / (n + 1))
        T_tab = T_tab.at[p, sel].set((T_tab[p, sel] * n + T_upd) / (n + 1))
        runs = runs.at[p, sel].add(1)

        wait = start - arr
        if totals_only:
            sums, comps, fin_max, busy, wait_max = acc
            # Kahan-compensated f32 sums: 10^5 sequential adds would
            # otherwise drift ~0.1% vs the full path's array reduction
            # (x64 is unavailable, so compensation stands in for f64)
            add = jnp.stack([E_act, wait, (wait + T_act) / T_act])
            y = add - comps
            t = sums + y
            acc = (t, (t - sums) - y, jnp.maximum(fin_max, finish),
                   busy.at[sel].add(T_act * need),
                   jnp.maximum(wait_max, wait))
            out = None
        else:
            out = (sel, start, finish, wait, E_act, T_act, f)
        return (node_free, C_tab, T_tab, runs, acc), out

    acc0 = ((jnp.zeros(3, jnp.float32), jnp.zeros(3, jnp.float32),
             jnp.float32(0.0), jnp.zeros(S, jnp.float32),
             jnp.float32(0.0))
            if totals_only else ())
    carry0 = (arrs["free0"], *tabs0, acc0)

    def finish(carry, ys):
        node_free, C_tab, T_tab, runs, acc = carry
        tabs = {"C_tab": C_tab, "T_tab": T_tab, "runs": runs,
                "n_backfilled": jnp.zeros((), jnp.int32)}
        if totals_only:
            sums, _, fin_max, busy, wait_max = acc
            return {"total_energy": sums[0], "makespan": fin_max,
                    "total_wait": sums[1], "slowdown_sum": sums[2],
                    "max_wait": wait_max, "busy": busy,
                    **_power_totals(arrs, fin_max, busy), **tabs}
        sel, start, fin, wait, E, T_act, tier = ys
        nodes = n_req[prog, sel]                                 # [J]
        busy = jnp.zeros(S, jnp.float32).at[sel].add(T_act * nodes)
        makespan = fin.max()
        return {
            "system": sel, "start": start, "finish": fin, "wait": wait,
            "energy": E, "runtime": T_act, "nodes": nodes, "tier": tier,
            "backfilled": jnp.zeros(J, bool),
            "total_energy": E.sum(), "makespan": makespan,
            "total_wait": wait.sum(), "max_wait": wait.max(),
            "slowdown_sum": ((wait + T_act) / T_act).sum(), "busy": busy,
            **_power_totals(arrs, makespan, busy), **tabs,
        }

    return step, carry0, finish


def _power_totals(arrs, makespan, busy, peak_power=None, capped_delay=None):
    """The SCC power fields every result carries.  The arrival-indexed
    scans do not track a cluster power trace (placements may carry future
    starts, so no running peak exists): they report ``peak_power`` NaN and
    zero ``capped_delay``; ``idle_energy`` is derivable from busy
    node-seconds on every core."""
    return {
        "peak_power": jnp.float32(jnp.nan) if peak_power is None
        else peak_power,
        "capped_delay": jnp.float32(0.0) if capped_delay is None
        else capped_delay,
        "idle_energy": _idle_energy(arrs, makespan, busy),
    }


def _easy_pieces(arrs: dict, policy: Policy, placer: str | None,
                 totals_only: bool, sel_key, fault_key, fvec, tabs0,
                 easy_eval: str = "batched"):
    """EASY-backfilling scan: J + W steps over a bounded pending window.

    The carry grows a pending buffer of W + 1 job-id slots (ascending,
    padded with the sentinel J).  Each step pushes the arriving job (steps
    past J are the drain tail) and places AT MOST one job:

      1. the head (oldest pending) — forced when the window overflows
         (FCFS fallback), or placed when its reserved start ``r_h`` (policy
         selection over current node-free times) is <= ``now``, the latest
         arrival time (BIG during the drain, so the tail drains FCFS);
      2. otherwise the first pending job (arrival order) whose tentative
         allocation does not push the head's earliest start on its
         reserved system past ``r_h`` — the EASY no-delay reservation
         guard.  (No "starts now" requirement: the scan's only events are
         arrivals, so a backfill may carry a future start — it fills the
         gap under the reservation exactly as an event-driven EASY would
         at the next completion event.)
      3. or nothing: the head keeps waiting for a backfill opportunity.

    Because at most one job is placed per step and a full window forces a
    head placement, every job is placed within J + W steps.  Placement
    math (kth-free selection, allocation tie-breaks, table updates, fault
    draws keyed by job id) is shared with the FCFS step, so ``fcfs`` and
    ``easy_backfill`` differ only in placement ORDER, never in per-job
    semantics.  Per-step outputs carry (job id | sentinel); the full path
    scatters them back into arrival-indexed [J] arrays after the scan.

    Candidate evaluation (``easy_eval``, static): every trial allocation
    in a step is computed against the SAME starting node-free table, so
    the W + 1 slots are independent and the first-fit choice is a masked
    argmin over slot index.  ``"batched"`` (default) scores all slots in
    one shared-table [W+1, S] kth-free call (``kth_free_time_shared`` —
    one sort serves every candidate) + one vmapped ``select`` + one
    vmapped tentative allocation; the no-delay guard then needs only the
    head's RESERVED system, so one per-row kth query over the trials'
    ``sel_h`` rows ([W+1, maxN]) rechecks every candidate at once — two
    batched kernel calls per step instead of ~2W sequential radix walks.
    ``"unrolled"`` is the historical python-unrolled loop, kept as the
    bit-identity reference (``tests/test_easy_batched.py`` asserts the
    two agree exactly across the whole policy registry).
    """
    T_true, C_true, E_true = arrs["T_true"], arrs["C_true"], arrs["E_true"]
    T_pred, C_pred = arrs["T_pred"], arrs["C_pred"]
    n_req, prog, arrival = arrs["n_req"], arrs["prog"], arrs["arrival"]
    outage = arrs.get("outage")
    P, S = T_true.shape
    J = prog.shape[0]
    W = int(policy.window)
    Wc = W + 1                           # buffer capacity (push-then-place)
    tiered = policy.tiered
    if tiered and easy_eval != "batched":
        raise ValueError("freq_tiers requires easy_eval='batched' (the "
                         "unrolled loop predates the tier axis and exists "
                         "only as the single-tier bit-identity reference)")
    tt = tier_tables(arrs, policy.freq_tiers) if tiered else None
    k_job = arrs["k_job"]
    pol_k = jnp.asarray(policy.k, jnp.float32)

    def k_of(j):
        """Per-job effective K at use (NaN -> the policy leaf); no [J]
        K vector materializes per batch lane."""
        kj = k_job[j]
        return jnp.where(jnp.isnan(kj), pol_k, kj)

    def sel_for(j, node_free, C_tab, T_tab, runs):
        """Policy selection + earliest start for job id j (sentinel-safe:
        j == J evaluates job J-1; callers mask the result)."""
        jj = jnp.minimum(j, J - 1)
        p = prog[jj]
        kth, avail = _earliest(node_free, n_req[p], arrival[jj], placer,
                               outage)
        sel = select(
            policy, c_row=C_tab[p], t_row=T_tab[p], runs_row=runs[p],
            avail_row=avail, k=k_of(jj), c_pred_row=C_pred[p],
            t_pred_row=T_pred[p], key=jax.random.fold_in(sel_key, jj))
        return jj, p, kth, avail, sel

    def eval_candidates(node_free, C_tab, T_tab, runs, pend):
        """Score every pending slot against the SAME node-free table in
        one batched pass (sentinel slots evaluate job J-1; callers mask).
        Returns per-slot [Wc]-leading arrays: job ids, programs, chosen
        systems, starts, actual runtimes, fault factors, node needs, and
        the [Wc, S, maxN] tentative-allocation stack."""
        jjs = jnp.minimum(pend, J - 1)                            # [Wc]
        ps = prog[jjs]                                            # [Wc]
        kths, avails = _earliest_shared(node_free, n_req[ps],
                                        arrival[jjs][:, None], placer,
                                        outage)                   # [Wc, S]
        keys = jax.vmap(lambda j: jax.random.fold_in(sel_key, j))(jjs)
        if tiered:
            c_x, t_x, runs_x, avail_x, cp_x, tp_x = _tier_rows(
                tt, ps, C_tab[ps], T_tab[ps], runs[ps], avails,
                C_pred[ps], T_pred[ps])
            sels_x = select_batched(
                policy, c_rows=c_x, t_rows=t_x, runs_rows=runs_x,
                avail_rows=avail_x, k=k_of(jjs), c_pred_rows=cp_x,
                t_pred_rows=tp_x, keys=keys)                      # [Wc]
            fs = (sels_x // S).astype(jnp.int32)
            sels = sels_x % S
        else:
            sels = select_batched(
                policy, c_rows=C_tab[ps], t_rows=T_tab[ps],
                runs_rows=runs[ps], avail_rows=avails, k=k_of(jjs),
                c_pred_rows=C_pred[ps], t_pred_rows=T_pred[ps],
                keys=keys)                                        # [Wc]
            fs = jnp.zeros(Wc, jnp.int32)
        factors = jax.vmap(lambda j: _fault_factor(fault_key, j, fvec))(jjs)
        idx = jnp.arange(Wc)
        starts = avails[idx, sels]                                # [Wc]
        T_acts = (tt["T"][ps, fs, sels] if tiered
                  else T_true[ps, sels]) * factors
        needs = n_req[ps, sels]
        trials = jax.vmap(_alloc, in_axes=(None, 0, 0, 0, 0))(
            node_free, sels, kths[idx, sels], needs, starts + T_acts)
        return jjs, ps, sels, fs, starts, T_acts, factors, needs, trials

    def step(carry, xs):
        node_free, C_tab, T_tab, runs, acc, pend, nbf = carry
        jx, now = xs

        # push the arrival into the first sentinel slot (the invariant
        # size <= W at step start keeps the index in range; drain steps
        # push the sentinel J over a sentinel — a no-op)
        size0 = jnp.sum(pend < J)
        pend = pend.at[jnp.minimum(size0, Wc - 1)].set(jx)
        size = size0 + (jx < J)
        forced = size == Wc                       # window full: FCFS fallback
        head_valid = pend[0] < J

        if easy_eval == "batched":
            # one batched evaluation of all Wc slots; slot 0 is the head
            jjs, ps, sels, fs, starts, T_acts, factors, needs, trials = \
                eval_candidates(node_free, C_tab, T_tab, runs, pend)
            hj, p_h, sel_h = jjs[0], ps[0], sels[0]
            r_h = starts[0]                       # head reservation
            place_head = head_valid & (forced | (r_h <= now))

            # EASY no-delay guard for ALL candidates at once: a trial can
            # only delay the head on the head's RESERVED system, so one
            # per-row kth query over the trials' sel_h rows answers every
            # candidate (rows untouched by a trial reproduce r_h exactly,
            # so their guard passes as it must)
            # (every kth mode is bit-exact, so absent an explicit placer
            # the recheck picks the cheapest: one sort op over [Wc, maxN]
            # beats Wc radix walks inside a scan)
            kth_h2 = kth_free_time(
                trials[:, sel_h, :],
                jnp.broadcast_to(n_req[p_h, sel_h], (Wc,)),
                force=placer or "sort")
            avail_h2 = jnp.maximum(arrival[hj], kth_h2)           # [Wc]
            if outage is not None:
                # only sel_h's windows apply; [1, W0, 2] broadcasts the
                # shared push over the [Wc] candidate vector
                avail_h2 = _push_out_of_outage(avail_h2, outage[sel_h][None])
            ok = avail_h2 <= r_h                                  # [Wc]

            # first-fit == masked argmin over slot index (Wc = none)
            idx = jnp.arange(Wc)
            elig = jnp.where(idx == 0, place_head,
                             head_valid & ~place_head & (pend < J) & ok)
            chosen = jnp.min(jnp.where(elig, idx, Wc))
            placed = chosen < Wc
            ci = jnp.minimum(chosen, Wc - 1)

            # gather the chosen slot: its trial allocation was computed
            # against the real starting node_free, so it IS the placement
            jj, p, sel, f = jjs[ci], ps[ci], sels[ci], fs[ci]
            factor = factors[ci]
            T_act = T_acts[ci]
            start = starts[ci]
            need = needs[ci]
            j_pl = jnp.where(placed, pend[ci], J)
            node_free = jnp.where(placed, trials[ci], node_free)
        else:
            # head-of-queue reservation from current node-free times
            h = pend[0]
            hj, p_h, _, avail_h, sel_h = sel_for(h, node_free, C_tab, T_tab,
                                                 runs)
            r_h = avail_h[sel_h]
            place_head = head_valid & (forced | (r_h <= now))

            # EASY backfill: first pending job (arrival order) whose
            # tentative allocation cannot delay the head's reservation on
            # its reserved system
            chosen = jnp.where(place_head, 0, Wc)     # slot index; Wc = none
            may_backfill = head_valid & ~place_head
            for ci in range(1, Wc):
                b = pend[ci]
                live = may_backfill & (b < J) & (chosen == Wc)
                bj, p_b, kth_b, avail_b, sel_b = sel_for(b, node_free, C_tab,
                                                         T_tab, runs)
                s_b = avail_b[sel_b]
                fin_b = s_b + T_true[p_b, sel_b] * _fault_factor(
                    fault_key, bj, fvec)
                trial = _alloc(node_free, sel_b, kth_b[sel_b],
                               n_req[p_b, sel_b], fin_b)
                _, avail_h2 = _earliest(trial, n_req[p_h], arrival[hj],
                                        placer, outage)
                ok = avail_h2[sel_h] <= r_h
                chosen = jnp.where(live & ok, ci, chosen)

            # place the chosen job (if any): same math as the FCFS step
            placed = chosen < Wc
            j_pl = jnp.where(placed, pend[jnp.minimum(chosen, Wc - 1)], J)
            jj, p, kth, avail, sel = sel_for(j_pl, node_free, C_tab, T_tab,
                                             runs)
            f = jnp.int32(0)                      # unrolled path is untier
            factor = _fault_factor(fault_key, jj, fvec)
            T_act = T_true[p, sel] * factor
            start = avail[sel]
            need = n_req[p, sel]
            node_free = jnp.where(
                placed,
                _alloc(node_free, sel, kth[sel], need, start + T_act),
                node_free)

        # learned tables always absorb BASE (tier-0) observations; the
        # recorded energy/runtime use the tier-scaled values
        C_act = C_true[p, sel] * factor
        T_upd = T_true[p, sel] * factor
        E_act = (tt["E"][p, f, sel] if tiered else E_true[p, sel]) * factor
        finish = start + T_act

        n = runs[p, sel].astype(jnp.float32)
        C_tab = C_tab.at[p, sel].set(jnp.where(
            placed, (C_tab[p, sel] * n + C_act) / (n + 1), C_tab[p, sel]))
        T_tab = T_tab.at[p, sel].set(jnp.where(
            placed, (T_tab[p, sel] * n + T_upd) / (n + 1), T_tab[p, sel]))
        runs = runs.at[p, sel].add(jnp.where(placed, 1, 0))

        was_backfill = placed & (chosen > 0)
        nbf = nbf + was_backfill.astype(jnp.int32)

        # pop the chosen slot (shift the tail left; chosen == Wc: no-op)
        shifted = jnp.concatenate([pend[1:], jnp.full((1,), J, jnp.int32)])
        pend = jnp.where(jnp.arange(Wc) < chosen, pend, shifted)

        wait = start - arrival[jj]
        if totals_only:
            sums, comps, fin_max, busy, wait_max = acc
            add = jnp.where(placed,
                            jnp.stack([E_act, wait, (wait + T_act) / T_act]),
                            0.0)
            y = add - comps
            t = sums + y
            acc = (t, (t - sums) - y,
                   jnp.maximum(fin_max, jnp.where(placed, finish, 0.0)),
                   busy.at[sel].add(jnp.where(placed, T_act * need, 0.0)),
                   jnp.maximum(wait_max, jnp.where(placed, wait, 0.0)))
            out = None
        else:
            out = (j_pl, sel, start, finish, wait, E_act, T_act,
                   was_backfill, f)
        return (node_free, C_tab, T_tab, runs, acc, pend, nbf), out

    acc0 = ((jnp.zeros(3, jnp.float32), jnp.zeros(3, jnp.float32),
             jnp.float32(0.0), jnp.zeros(S, jnp.float32),
             jnp.float32(0.0))
            if totals_only else ())
    pend0 = jnp.full((Wc,), J, jnp.int32)
    carry0 = (arrs["free0"], *tabs0, acc0, pend0, jnp.zeros((), jnp.int32))

    def finish(carry, ys):
        node_free, C_tab, T_tab, runs, acc, pend, nbf = carry
        tabs = {"C_tab": C_tab, "T_tab": T_tab, "runs": runs,
                "n_backfilled": nbf}
        if totals_only:
            sums, _, fin_max, busy, wait_max = acc
            return {"total_energy": sums[0], "makespan": fin_max,
                    "total_wait": sums[1], "slowdown_sum": sums[2],
                    "max_wait": wait_max, "busy": busy,
                    **_power_totals(arrs, fin_max, busy), **tabs}

        # scatter per-step outputs back to arrival order; sentinels drop
        j_pl, sel_s, start_s, fin_s, wait_s, E_s, T_s, bf_s, f_s = ys
        def scat(vals, dtype):
            return jnp.zeros(J, dtype).at[j_pl].set(vals, mode="drop")
        sel = scat(sel_s, sel_s.dtype)
        start = scat(start_s, jnp.float32)
        fin = scat(fin_s, jnp.float32)
        wait = scat(wait_s, jnp.float32)
        E = scat(E_s, jnp.float32)
        T_act = scat(T_s, jnp.float32)
        backfilled = scat(bf_s, bool)
        tier = scat(f_s, jnp.int32)
        nodes = n_req[prog, sel]                                 # [J]
        busy = jnp.zeros(S, jnp.float32).at[sel].add(T_act * nodes)
        makespan = fin.max()
        return {
            "system": sel, "start": start, "finish": fin, "wait": wait,
            "energy": E, "runtime": T_act, "nodes": nodes,
            "backfilled": backfilled, "tier": tier,
            "total_energy": E.sum(), "makespan": makespan,
            "total_wait": wait.sum(), "max_wait": wait.max(),
            "slowdown_sum": ((wait + T_act) / T_act).sum(), "busy": busy,
            **_power_totals(arrs, makespan, busy), **tabs,
        }

    return step, carry0, finish


class EventCarry(NamedTuple):
    """Live state of the event-granular core between two ``make_event_step``
    calls.  A NamedTuple (still an ordinary pytree to scan/jit) so the
    service dispatcher and the checkpoint manifest address fields by name.
    """
    node_free: jnp.ndarray   # [S, maxN] node free-from times
    node_pow: jnp.ndarray    # [S, maxN] per-node allocated draw (Watts)
    C_tab: jnp.ndarray       # [P, S] learned energy coefficients
    T_tab: jnp.ndarray       # [P, S] learned runtimes
    runs: jnp.ndarray        # [P, S] observation counts
    acc: tuple               # Kahan totals accumulator (empty if full path)
    busy: jnp.ndarray        # [S] busy node-seconds
    pend: jnp.ndarray        # [Wc] pending job ids (J = sentinel)
    t0s: jnp.ndarray         # [Wc] effective arrivals
    rts: jnp.ndarray         # [Wc] retry flags
    accTs: jnp.ndarray       # [Wc] accrued runtime of failed attempts
    accFs: jnp.ndarray       # [Wc] accrued fault factor
    accWs: jnp.ndarray       # [Wc] accrued wait
    s0s: jnp.ndarray         # [Wc] first-attempt starts
    pblocks: jnp.ndarray     # [Wc] first power-blocked times (BIG = never)
    a: jnp.ndarray           # next-arrival cursor
    now: jnp.ndarray         # event clock
    nbf: jnp.ndarray         # backfill count
    peak: jnp.ndarray        # running peak cluster draw
    cdel: jnp.ndarray        # cap-attributed placement delay


def event_context(arrs: dict, policy: Policy, seed, fvec) -> dict:
    """The traced per-run inputs of the factored event steps (everything a
    step reads besides its carry): workload arrays and the selection /
    fault PRNG keys — derived exactly as ``_sim_pieces`` derives them, so
    a service session shares the batch scan's streams.  The ``kvec`` entry
    (precomputed per-job effective K) is retained for checkpoint/back-
    compat; steps resolve K at use from ``arrs["k_job"]`` and the policy
    leaf (elementwise identical), so no [J] K vector rides the hot path."""
    kvec = jnp.where(jnp.isnan(arrs["k_job"]),
                     jnp.asarray(policy.k, jnp.float32), arrs["k_job"])
    sel_key, fault_key = jax.random.split(jax.random.key(seed))
    ctx = {"arrs": arrs, "kvec": kvec, "sel_key": sel_key,
           "fault_key": fault_key, "fvec": fvec}
    if policy.tiered:
        ctx["tt"] = tier_tables(arrs, policy.freq_tiers)
    return ctx


def event_carry0(arrs: dict, policy: Policy, tabs0, totals_only: bool,
                 now0=None) -> EventCarry:
    """The event core's initial carry.  ``now0`` overrides the starting
    clock (the batch scan opens at the first arrival; a live dispatcher
    opens at 0 and advances to the first submission)."""
    S = arrs["T_true"].shape[1]
    J = arrs["prog"].shape[0]
    Wc = int(policy.window) + 1
    idle_total = jnp.where(arrs["free0"] < BIG,
                           arrs["idle_w"][:, None], 0.0).sum()
    acc0 = ((jnp.zeros(3, jnp.float32), jnp.zeros(3, jnp.float32),
             jnp.float32(0.0), jnp.float32(0.0))
            if totals_only else ())
    if now0 is None:
        now0 = arrs["arrival"][0]
    return EventCarry(
        node_free=arrs["free0"], node_pow=jnp.zeros_like(arrs["free0"]),
        C_tab=tabs0[0], T_tab=tabs0[1], runs=tabs0[2], acc=acc0,
        busy=jnp.zeros(S, jnp.float32),
        pend=jnp.full((Wc,), J, jnp.int32), t0s=jnp.zeros(Wc, jnp.float32),
        rts=jnp.zeros(Wc, bool), accTs=jnp.zeros(Wc, jnp.float32),
        accFs=jnp.zeros(Wc, jnp.float32), accWs=jnp.zeros(Wc, jnp.float32),
        s0s=jnp.zeros(Wc, jnp.float32),
        pblocks=jnp.full((Wc,), BIG, jnp.float32),
        a=jnp.int32(0), now=jnp.asarray(now0, jnp.float32),
        nbf=jnp.int32(0), peak=idle_total, cdel=jnp.float32(0.0))


def make_event_step(policy: Policy, placer: str | None = None,
                    totals_only: bool = False, retries: bool = False):
    """Event-granular step: the clock advances through the merged stream
    of arrival AND completion events, so the pending buffer is
    re-evaluated whenever nodes free up.

    Carry: node-free AND node-power tables, learned tables, a pending
    buffer of ``window + 1`` slots (job id + per-slot effective arrival /
    retry flag / accrued runtime / accrued fault factor / accrued wait /
    first-attempt start / first-power-blocked time), the next-arrival
    cursor ``a``, the clock ``now``, and the power accumulators (running
    peak, cap-attributed delay).  Each step performs at least one of:

      push     admit the next arrival (``arrival[a] <= now`` and the
               buffer has room; a full buffer stalls admission — arrivals
               wait OUTSIDE the window, so no placement is ever forced
               with a future start and the power cap stays enforceable);
      place    at most one pending job whose start is feasible *now*:
               resource-feasible (earliest start <= now), discipline-
               eligible, and power-feasible (below).  Eligibility by
               ``policy.queue``:
                 fcfs          the head only — placements in strict
                               arrival order (bit-identical to the
                               arrival-indexed scan, asserted per
                               registered policy);
                 easy_backfill head, or any slot whose tentative
                               allocation cannot delay the head's
                               reservation (event-driven EASY: backfills
                               start at the current event, never in the
                               future);
               (``conservative`` runs its own event-granular step,
               ``make_cons_step`` — reservations chained through a
               profile table instead of per-step re-evaluation);
      advance  otherwise move ``now`` to the next event: the earliest of
               the next arrival, the earliest node-free time > now (a
               completion), or the next outage end.

    Every job needs one push + one placement and every advance lands on a
    distinct event time, so ``4J + |outage| + 4`` steps suffice (``7J``
    with retries: a failure adds one push, one placement, one event).

    Power-cap enforcement (``policy.power_cap``, a LEAF — cap grids batch
    in one jit): the carry's node-power table gives the cluster draw
    ``P(now) = sum(busy ? node_pow : idle_w)``; a placement converting
    ``need`` idle nodes to a job drawing ``E/T`` Watts is deferred while
    ``P(now) - need*idle_w + E/T > cap``.  Under a finite cap starts are
    quantized to the current event (``start = now``), so the recorded
    trace is exact and ``peak_power <= cap`` holds whenever the cap is
    above the idle floor (a cap below the all-idle draw is unsatisfiable;
    the head is force-placed rather than stalling forever, and the
    recorded peak honestly exceeds the cap).  Uncapped runs keep the
    resource-earliest start (possibly before ``now`` — nodes were idle
    since then), which preserves FCFS bit-identity; ``peak_power`` is
    then the draw sampled at placement instants.  ``capped_delay`` sums,
    over placed jobs, the gap between the first time a job was the next
    would-be placement but power-blocked and its actual start.

    Mid-job failures (``retries=True``, chosen by the facade when a fault
    grid carries ``failure_prob > 0``): instead of the arrival cores'
    contiguous ``(1 + restart_overhead)`` inflation, the first attempt of
    a failing job occupies its nodes for ``restart_overhead`` of its work
    and then RE-QUEUES through the same pending buffer (effective arrival
    = the failure time, a completion event like any other).  The retry
    re-selects a system with current tables and never fails again.
    Tables update once, at the final attempt, with the job's accumulated
    fault factor — for a same-system retry exactly the contiguous
    model's ``(1 + restart_overhead)`` totals.

    Factored form (the online-service refactor): this builder returns the
    bare ``step(ctx, carry, horizon) -> (carry, out)`` callable — ``ctx``
    from ``event_context``, ``carry`` from ``event_carry0``.  The batch
    scan (``_sim_pieces``) folds it through ``lax.scan`` with
    ``horizon = BIG`` (bit-identical to the pre-refactor closure, asserted
    across tests/test_event_core.py); the service dispatcher jits it once
    and calls it per event with a finite horizon, which only gates the
    clock: ``advance`` never moves ``now`` past ``horizon`` (so a live
    session cannot run ahead of arrivals it has not been told about) and
    the stuck valve stays closed under a finite horizon (waiting for the
    operator to drive further is always legal).  With ``horizon = BIG``
    both gates are no-ops, so the batch op sequence is unchanged.  The
    full-path ``out`` is a dict: the batch-result channels consumed by
    ``_event_results`` plus live-decision extras (pushed/placed/advanced
    flags, realized start, post-step clock, queue depth, cluster draw).
    """
    W = int(policy.window)
    Wc = W + 1
    queue = policy.queue
    tiered = policy.tiered
    idx = jnp.arange(Wc)

    def step(ctx, carry, horizon):
        arrs, fvec = ctx["arrs"], ctx["fvec"]
        sel_key, fault_key = ctx["sel_key"], ctx["fault_key"]
        tt = ctx["tt"] if tiered else None
        T_true, C_true, E_true = (arrs["T_true"], arrs["C_true"],
                                  arrs["E_true"])
        T_pred, C_pred = arrs["T_pred"], arrs["C_pred"]
        n_req, prog, arrival = arrs["n_req"], arrs["prog"], arrs["arrival"]
        outage = arrs.get("outage")
        w_pow, idle_w = arrs["w_pow"], arrs["idle_w"]
        # per-job effective K at use (NaN -> the policy leaf; elementwise
        # identical to the historical precomputed kvec gather, without a
        # per-lane [J] intermediate)
        pol_k = jnp.asarray(policy.k, jnp.float32)
        k_of = lambda j: jnp.where(jnp.isnan(arrs["k_job"][j]), pol_k,
                                   arrs["k_job"][j])
        J = prog.shape[0]
        exists = arrs["free0"] < BIG                             # [S, maxN]
        idle_mat = jnp.where(exists, idle_w[:, None], 0.0)       # [S, maxN]
        pc = jnp.asarray(policy.power_cap, jnp.float32)
        capped = pc < UNCAPPED                                   # traced
        out_ends = (None if outage is None
                    else outage[..., 1].reshape(-1))             # [S*W0]

        (node_free, node_pow, C_tab, T_tab, runs, acc, busy,
         pend, t0s, rts, accTs, accFs, accWs, s0s, pblocks,
         a, now, nbf, peak, cdel) = carry

        # ---- push: admit the next arrival if due and there is room
        size0 = jnp.sum(pend < J)
        arr_a = arrival[jnp.minimum(a, J - 1)]
        do_push = (a < J) & (size0 < Wc) & (arr_a <= now)
        slot = jnp.minimum(size0, Wc - 1)

        def pushed(arr, val):
            return arr.at[slot].set(jnp.where(do_push, val, arr[slot]))
        pend = pushed(pend, a.astype(jnp.int32))
        t0s = pushed(t0s, arr_a)
        rts = pushed(rts, False)
        accTs = pushed(accTs, 0.0)
        accFs = pushed(accFs, 0.0)
        accWs = pushed(accWs, 0.0)
        s0s = pushed(s0s, 0.0)
        pblocks = pushed(pblocks, BIG)
        a = a + do_push

        # ---- next event (pre-placement state; used by advance + the
        # stuck valve).  Completions are node-free times > now.
        next_evt = jnp.min(jnp.where(node_free > now, node_free, BIG))
        arr_next = arrival[jnp.minimum(a, J - 1)]
        next_evt = jnp.minimum(
            next_evt, jnp.where((a < J) & (arr_next > now), arr_next, BIG))
        if out_ends is not None:
            next_evt = jnp.minimum(
                next_evt,
                jnp.min(jnp.where(out_ends > now, out_ends, BIG)))

        # ---- batched evaluation of every pending slot (sentinel slots
        # evaluate job J-1 behind a BIG arrival floor; never eligible)
        valid = pend < J
        jjs = jnp.minimum(pend, J - 1)
        ps = prog[jjs]
        t0f = jnp.where(valid, t0s, BIG)
        kths, avails = _earliest_shared(node_free, n_req[ps],
                                        t0f[:, None], placer, outage)
        keys = jax.vmap(lambda j: jax.random.fold_in(sel_key, j))(jjs)
        if tiered:
            S = T_true.shape[1]
            c_x, t_x, runs_x, avail_x, cp_x, tp_x = _tier_rows(
                tt, ps, C_tab[ps], T_tab[ps], runs[ps], avails,
                C_pred[ps], T_pred[ps])
            sels_x = select_batched(
                policy, c_rows=c_x, t_rows=t_x, runs_rows=runs_x,
                avail_rows=avail_x, k=k_of(jjs), c_pred_rows=cp_x,
                t_pred_rows=tp_x, keys=keys)                     # [Wc]
            fs = (sels_x // S).astype(jnp.int32)
            sels = sels_x % S
        else:
            sels = select_batched(
                policy, c_rows=C_tab[ps], t_rows=T_tab[ps],
                runs_rows=runs[ps], avail_rows=avails, k=k_of(jjs),
                c_pred_rows=C_pred[ps], t_pred_rows=T_pred[ps],
                keys=keys)                                       # [Wc]
            fs = jnp.zeros(Wc, jnp.int32)
        starts_res = avails[idx, sels]                           # [Wc]

        # fault draws (keyed by job id, as _fault_factor does)
        u = jax.vmap(lambda j: jax.random.uniform(
            jax.random.fold_in(fault_key, j), (2,)))(jjs)        # [Wc, 2]
        slows = jnp.where(u[:, 0] < fvec[0], fvec[1], 1.0)
        fails = u[:, 1] < fvec[2]
        if retries:
            first_fail = fails & ~rts        # retries never fail again
            scale = jnp.where(first_fail, fvec[3], 1.0)
        else:
            first_fail = jnp.zeros(Wc, bool)
            scale = jnp.where(fails, 1.0 + fvec[3], 1.0)
        factors = slows * scale
        T_acts = (tt["T"][ps, fs, sels] if tiered
                  else T_true[ps, sels]) * factors
        E_acts = (tt["E"][ps, fs, sels] if tiered
                  else E_true[ps, sels]) * factors
        needs = n_req[ps, sels]

        # start rule: capped runs quantize to the current event (exact
        # power trace); uncapped keep the resource-earliest start (FCFS
        # bit-identity — the nodes were idle since then)
        starts = jnp.where(capped, jnp.maximum(starts_res, now), starts_res)
        finishes = starts + T_acts
        trials = jax.vmap(_alloc, in_axes=(None, 0, 0, 0, 0))(
            node_free, sels, kths[idx, sels], needs, finishes)

        # ---- discipline eligibility (resource side)
        res_ok = valid & (starts_res <= now)
        if outage is not None:
            # a cap-deferred start quantizes to ``now`` — which must
            # itself respect the start gate: a slot whose system has an
            # open maintenance window is not placeable until the window
            # ends (an event the clock advances to).  Uncapped starts are
            # already outage-pushed inside ``starts_res``.
            gated = _push_out_of_outage(starts, outage[sels])
            res_ok = res_ok & (~capped | (gated <= now))
        if queue == "fcfs":
            elig_res = res_ok & (idx == 0)
        else:  # event-driven EASY: only the head's reservation is guarded
            p_h, sel_h = ps[0], sels[0]
            r_h = starts_res[0]
            kth_h2 = kth_free_time(
                trials[:, sel_h, :],
                jnp.broadcast_to(n_req[p_h, sel_h], (Wc,)),
                force=placer or "sort")
            avail_h2 = jnp.maximum(t0f[0], kth_h2)               # [Wc]
            if outage is not None:
                avail_h2 = _push_out_of_outage(avail_h2,
                                               outage[sel_h][None])
            elig_res = res_ok & ((idx == 0) | (avail_h2 <= r_h))

        # ---- power feasibility + the stuck valve
        p_now = jnp.sum(jnp.where(node_free > now, node_pow, idle_mat))
        w_jobs = (tt["w"][ps, fs, sels] if tiered
                  else w_pow[ps, sels])                          # [Wc]
        new_P = p_now - needs * idle_w[sels] + w_jobs            # [Wc]
        power_ok = ~capped | (new_P <= pc)
        elig0 = elig_res & power_ok
        head_valid = valid[0]
        # no event ahead + nothing placeable can only mean the cap is
        # below the idle floor: force the head rather than stall forever
        # (only with an open horizon — under a finite one the session is
        # simply waiting to be driven further, never stuck)
        stuck = (head_valid & ~do_push & ~jnp.any(elig0)
                 & (next_evt >= BIG) & (horizon >= BIG))
        elig = jnp.where(idx == 0, elig0[0] | stuck, elig0)

        chosen = jnp.min(jnp.where(elig, idx, Wc))
        placed = chosen < Wc
        ci = jnp.minimum(chosen, Wc - 1)

        # cap-attributed delay: the next would-be placement, power-blocked
        chosen_res = jnp.min(jnp.where(elig_res, idx, Wc))
        cri = jnp.minimum(chosen_res, Wc - 1)
        blocked = (chosen_res < Wc) & ~power_ok[cri]
        pblocks = pblocks.at[cri].set(
            jnp.where(blocked, jnp.minimum(pblocks[cri], now), pblocks[cri]))

        # ---- place the chosen slot (its trial IS the allocation)
        jj, p, sel = jjs[ci], ps[ci], sels[ci]
        factor, T_act, E_act = factors[ci], T_acts[ci], E_acts[ci]
        start, finish, need = starts[ci], finishes[ci], needs[ci]
        failed_now = placed & first_fail[ci]
        final = placed & ~first_fail[ci]
        # per-slot accruals, captured before the pop shifts the buffer
        accT_ci, accF_ci, accW_ci = accTs[ci], accFs[ci], accWs[ci]
        s0_ci = jnp.where(rts[ci], s0s[ci], start)
        wait_step = start - t0s[ci]
        pb_ci = pblocks[ci]

        take = _alloc_mask(node_free, sel, kths[ci, sel], need)
        node_free = jnp.where(placed, trials[ci], node_free)
        per_node = w_jobs[ci] / jnp.maximum(need, 1).astype(jnp.float32)
        node_pow = jnp.where(
            placed,
            node_pow.at[sel].set(jnp.where(take, per_node, node_pow[sel])),
            node_pow)

        fac_tot = accF_ci + factor
        C_upd = C_true[p, sel] * fac_tot
        T_upd = T_true[p, sel] * fac_tot
        n = runs[p, sel].astype(jnp.float32)
        C_tab = C_tab.at[p, sel].set(jnp.where(
            final, (C_tab[p, sel] * n + C_upd) / (n + 1), C_tab[p, sel]))
        T_tab = T_tab.at[p, sel].set(jnp.where(
            final, (T_tab[p, sel] * n + T_upd) / (n + 1), T_tab[p, sel]))
        runs = runs.at[p, sel].add(jnp.where(final, 1, 0))

        busy = busy.at[sel].add(jnp.where(placed, T_act * need, 0.0))
        nbf = nbf + (final & (chosen > 0)).astype(jnp.int32)
        peak = jnp.maximum(peak, jnp.where(placed, new_P[ci], 0.0))
        cdel = cdel + jnp.where(placed & (pb_ci < BIG), now - pb_ci, 0.0)

        # pop the chosen slot (shift left; chosen == Wc: no-op)
        def pop(arr, fill):
            shifted = jnp.concatenate(
                [arr[1:], jnp.full((1,), fill, arr.dtype)])
            return jnp.where(idx < chosen, arr, shifted)
        pend = pop(pend, J)
        t0s, rts = pop(t0s, 0.0), pop(rts, False)
        accTs, accFs, accWs = pop(accTs, 0.0), pop(accFs, 0.0), \
            pop(accWs, 0.0)
        s0s, pblocks = pop(s0s, 0.0), pop(pblocks, BIG)

        if retries:
            # a failed first attempt re-queues at the tail: effective
            # arrival = the failure time (a completion event)
            size2 = jnp.sum(pend < J)
            slot2 = jnp.minimum(size2, Wc - 1)

            def requeue(arr, val):
                return arr.at[slot2].set(
                    jnp.where(failed_now, val, arr[slot2]))
            pend = requeue(pend, jj.astype(jnp.int32))
            t0s = requeue(t0s, finish)
            rts = requeue(rts, True)
            accTs = requeue(accTs, accT_ci + T_act)
            accFs = requeue(accFs, fac_tot)
            accWs = requeue(accWs, accW_ci + wait_step)
            s0s = requeue(s0s, s0_ci)
            pblocks = requeue(pblocks, BIG)

        T_tot = accT_ci + T_act
        wait_tot = accW_ci + wait_step

        # ---- advance the clock only when nothing else happened (and
        # never past the horizon)
        advance = (~do_push & ~placed & (next_evt < BIG)
                   & (next_evt <= horizon))
        now = jnp.where(advance, next_evt, now)

        if totals_only:
            sums, comps, fin_max, wait_max = acc
            add = jnp.stack([
                E_act,
                jnp.where(final, wait_tot, 0.0),
                jnp.where(final, (wait_tot + T_tot) / T_tot, 0.0)])
            # Kahan update applied ONLY on placement steps, so the FCFS
            # op sequence matches the arrival-indexed core bit for bit
            y = add - comps
            t = sums + y
            acc = (jnp.where(placed, t, sums),
                   jnp.where(placed, (t - sums) - y, comps),
                   jnp.maximum(fin_max, jnp.where(placed, finish, 0.0)),
                   jnp.maximum(wait_max, jnp.where(final, wait_tot, 0.0)))
            out = None
        else:
            out = {
                # batch-result channels (_event_results scatters these)
                "j_add": jnp.where(placed, jj, J), "E": E_act,
                "j_fin": jnp.where(final, jj, J), "sys": sel,
                "s0": s0_ci, "finish": finish, "wait": wait_tot,
                "T": T_tot, "bf": final & (chosen > 0),
                "tier": fs[ci],
                # live-decision channels (the service dispatcher reads
                # these; pure additions, the batch channels are untouched)
                "pushed": do_push, "j_push": jnp.where(do_push, a - 1, J),
                "placed": placed, "final": final, "advanced": advance,
                "start": start, "now": now, "qlen": jnp.sum(pend < J),
                "power": jnp.where(placed, new_P[ci], p_now),
            }

        return EventCarry(
            node_free, node_pow, C_tab, T_tab, runs, acc, busy,
            pend, t0s, rts, accTs, accFs, accWs, s0s, pblocks,
            a, now, nbf, peak, cdel), out

    return step


def _event_results(arrs, totals_only, ys, carry):
    """Shared result epilogue of the two event-granular scans: unpack the
    totals accumulator, or scatter the per-step (attempt-energy,
    final-attempt fields) output channels back to arrival order.  Takes
    the final carry (EventCarry or ConsCarry — same field names)."""
    n_req, prog = arrs["n_req"], arrs["prog"]
    J = prog.shape[0]
    busy, peak, cdel = carry.busy, carry.peak, carry.cdel
    tabs = {"C_tab": carry.C_tab, "T_tab": carry.T_tab, "runs": carry.runs,
            "n_backfilled": carry.nbf}
    if totals_only:
        sums, _, fin_max, wait_max = carry.acc
        return {"total_energy": sums[0], "makespan": fin_max,
                "total_wait": sums[1], "slowdown_sum": sums[2],
                "max_wait": wait_max, "busy": busy,
                **_power_totals(arrs, fin_max, busy, peak, cdel), **tabs}

    j_add, E_s, j_fin = ys["j_add"], ys["E"], ys["j_fin"]
    sel_s, s0_s, fin_s = ys["sys"], ys["s0"], ys["finish"]
    wait_s, T_s, bf_s = ys["wait"], ys["T"], ys["bf"]
    E = jnp.zeros(J, jnp.float32).at[j_add].add(E_s, mode="drop")
    def scat(vals, dtype):
        return jnp.zeros(J, dtype).at[j_fin].set(vals, mode="drop")
    sel = scat(sel_s, sel_s.dtype)
    start = scat(s0_s, jnp.float32)
    finish = scat(fin_s, jnp.float32)
    wait = scat(wait_s, jnp.float32)
    T_act = scat(T_s, jnp.float32)
    backfilled = scat(bf_s, bool)
    tier = scat(ys["tier"], jnp.int32)
    nodes = n_req[prog, sel]                                     # [J]
    makespan = finish.max()
    return {
        "system": sel, "start": start, "finish": finish, "wait": wait,
        "energy": E, "runtime": T_act, "nodes": nodes,
        "backfilled": backfilled, "tier": tier,
        "total_energy": E.sum(), "makespan": makespan,
        "total_wait": wait.sum(), "max_wait": wait.max(),
        "slowdown_sum": ((wait + T_act) / T_act).sum(), "busy": busy,
        **_power_totals(arrs, makespan, busy, peak, cdel), **tabs,
    }


class ConsCarry(NamedTuple):
    """Live state of the conservative event core (``make_cons_step``).
    Field names shared with ``EventCarry`` where semantics coincide; the
    per-slot pending columns live in the ``slots`` dict (job id, timing
    accruals, and the reservation row: system/start/finish/need/...)."""
    node_free: jnp.ndarray   # [S, maxN] node free-from times
    node_pow: jnp.ndarray    # [S, maxN] per-node allocated draw (Watts)
    C_tab: jnp.ndarray       # [P, S] learned energy coefficients
    T_tab: jnp.ndarray       # [P, S] learned runtimes
    runs: jnp.ndarray        # [P, S] observation counts
    acc: tuple               # Kahan totals accumulator (empty if full path)
    busy: jnp.ndarray        # [S] busy node-seconds
    slots: dict              # [Wc]-leading per-slot reservation table
    a: jnp.ndarray           # next-arrival cursor
    now: jnp.ndarray         # event clock
    nbf: jnp.ndarray         # backfill count
    peak: jnp.ndarray        # running peak cluster draw
    cdel: jnp.ndarray        # cap-attributed placement delay


def cons_carry0(arrs: dict, policy: Policy, tabs0, totals_only: bool,
                now0=None) -> ConsCarry:
    """The conservative core's initial carry (see ``event_carry0``)."""
    S = arrs["T_true"].shape[1]
    J = arrs["prog"].shape[0]
    Wc = int(policy.window) + 1
    idle_total = jnp.where(arrs["free0"] < BIG,
                           arrs["idle_w"][:, None], 0.0).sum()
    acc0 = ((jnp.zeros(3, jnp.float32), jnp.zeros(3, jnp.float32),
             jnp.float32(0.0), jnp.float32(0.0))
            if totals_only else ())
    if now0 is None:
        now0 = arrs["arrival"][0]
    slots0 = dict(
        pend=jnp.full((Wc,), J, jnp.int32), t0=jnp.zeros(Wc, jnp.float32),
        rt=jnp.zeros(Wc, bool), accT=jnp.zeros(Wc, jnp.float32),
        accF=jnp.zeros(Wc, jnp.float32), accW=jnp.zeros(Wc, jnp.float32),
        s0=jnp.zeros(Wc, jnp.float32),
        pblock=jnp.full((Wc,), BIG, jnp.float32),
        sel=jnp.zeros(Wc, jnp.int32), start=jnp.zeros(Wc, jnp.float32),
        fin=jnp.zeros(Wc, jnp.float32),
        T=jnp.ones(Wc, jnp.float32), E=jnp.zeros(Wc, jnp.float32),
        need=jnp.zeros(Wc, jnp.int32), wjob=jnp.zeros(Wc, jnp.float32),
        fac=jnp.zeros(Wc, jnp.float32), fail=jnp.zeros(Wc, bool),
        tier=jnp.zeros(Wc, jnp.int32))
    return ConsCarry(
        node_free=arrs["free0"], node_pow=jnp.zeros_like(arrs["free0"]),
        C_tab=tabs0[0], T_tab=tabs0[1], runs=tabs0[2], acc=acc0,
        busy=jnp.zeros(S, jnp.float32), slots=slots0,
        a=jnp.int32(0), now=jnp.asarray(now0, jnp.float32),
        nbf=jnp.int32(0), peak=idle_total, cdel=jnp.float32(0.0))


def make_cons_step(policy: Policy, placer: str | None = None,
                   totals_only: bool = False, retries: bool = False):
    """Conservative backfilling: hole-aware chained reservations on the
    event-granular clock.

    Textbook conservative gives EVERY queued job a reservation the moment
    it is admitted, computed around all earlier pending reservations — so
    backfilling is hole-filling by construction and no reservation is
    ever delayed.  Crucially, reservations are NOT committed into the
    node-free table (a free-from time per node cannot represent "idle
    until the reservation starts", which is exactly the hole backfilling
    lives on — committing eagerly is why the arrival-indexed FCFS scan
    wastes those gaps).  Instead the carry keeps:

      node_free      reality — realized placements only;
      the slot reservation table — per pending slot its (system, start,
                     finish, nodes): explicit intervals.

    Admission evaluates, per system, the earliest start where FREE
    CAPACITY (count of really-free nodes minus reservation occupancy)
    covers the job for its whole duration: candidate starts are the
    arrival, node free times and reservation finishes (capacity rises),
    each checked against every reservation start inside the candidate
    window (the only capacity dips).  That [S, E] piecewise-capacity
    evaluation is a handful of vectorized comparisons against the [W]
    reservation table — the admission IS the reservation-table update.
    The policy then selects over the per-system earliest starts and the
    chosen (sel, start, finish, need) joins the table.  Selection thus
    happens at ADMISSION time with the tables as of admission (learned
    tables still update at placement).

    A placement *realizes* a reservation once the clock reaches its
    start: the per-slot realizability recheck (``kth_free_time_rows`` —
    one shared sort of the real table serves every pending reservation)
    confirms the promised nodes, and the job starts exactly at its
    reserved time.  Uncapped, realized == reserved always (asserted by
    the mirror's ``check_reservations``); under a binding power cap a
    deferred start breaks promises downstream, and realized starts
    degrade gracefully to ``max(reserved, realizable, power-feasible)``
    in reservation order.  The ``window`` bounds the reservation horizon
    (pending slots); admission stalls when it is full.

    Compared to EASY this queue both *guards more* (every reservation,
    not just the head's) and *backfills more*: EASY only exploits the
    idle gap under the head's reservation (everything else is committed
    eagerly), while the interval table exposes the holes under EVERY
    pending job.  Faults ride the event stream as in
    ``make_event_step``: with ``retries`` a failing first attempt
    occupies exactly its reserved span (the failure IS a completion
    event) and re-queues for a fresh reservation at the failure time.

    Factored form: as ``make_event_step`` — returns the bare
    ``step(ctx, carry, horizon)`` shared verbatim by the batch scan
    (``_sim_pieces``, open horizon) and the service dispatcher
    (finite horizon gates the clock and the stuck valve).
    """
    Wc = int(policy.window) + 1
    tiered = policy.tiered
    idx = jnp.arange(Wc)

    def step(ctx, carry, horizon):
        arrs, fvec = ctx["arrs"], ctx["fvec"]
        sel_key, fault_key = ctx["sel_key"], ctx["fault_key"]
        tt = ctx["tt"] if tiered else None
        T_true, C_true, E_true = (arrs["T_true"], arrs["C_true"],
                                  arrs["E_true"])
        T_pred, C_pred = arrs["T_pred"], arrs["C_pred"]
        n_req, prog, arrival = arrs["n_req"], arrs["prog"], arrs["arrival"]
        outage = arrs.get("outage")
        w_pow, idle_w = arrs["w_pow"], arrs["idle_w"]
        # per-job effective K at use (see make_event_step's k_of)
        pol_k = jnp.asarray(policy.k, jnp.float32)
        k_of = lambda j: jnp.where(jnp.isnan(arrs["k_job"][j]), pol_k,
                                   arrs["k_job"][j])
        S = T_true.shape[1]
        J = prog.shape[0]
        exists = arrs["free0"] < BIG
        idle_mat = jnp.where(exists, idle_w[:, None], 0.0)
        pc = jnp.asarray(policy.power_cap, jnp.float32)
        capped = pc < UNCAPPED
        out_ends = (None if outage is None
                    else outage[..., 1].reshape(-1))
        #: per-slot pop fill values (sentinel slot state)
        FILLS = dict(pend=J, t0=0.0, rt=False, accT=0.0, accF=0.0,
                     accW=0.0, s0=0.0, pblock=BIG, sel=0, start=0.0,
                     fin=0.0, T=1.0, E=0.0, need=0, wjob=0.0, fac=0.0,
                     fail=False, tier=0)
        sys_col = jnp.arange(S)[:, None, None]                   # [S, 1, 1]

        def earliest_fit(p, t0, Tdur, node_free, slots):
            """Per-system earliest start where free capacity (really-free
            node count minus reservation occupancy) covers ``n_req[p]``
            nodes for the whole [t, t + Tdur) window.  Candidates: the
            arrival floor, node free times, reservation finishes (the only
            capacity rises); dips happen only at reservation starts, so each
            candidate is checked against the [W] reservation table."""
            need = n_req[p]                                          # [S]
            r_valid = slots["pend"] < J                              # [Wc]
            r_sel, r_sta = slots["sel"], slots["start"]
            r_fin, r_need = slots["fin"], slots["need"]
            cands = jnp.concatenate([
                jnp.full((S, 1), t0, jnp.float32), node_free,
                jnp.broadcast_to(r_fin[None], (S, Wc)),
            ], axis=1)                                               # [S, E]
            cands = jnp.maximum(cands, t0)
            if outage is not None:
                # start gating only (jobs ride through windows, as in the
                # other cores); outage ends are free-time candidates via the
                # floored duplicates below
                for wi in range(outage.shape[1]):
                    o0 = outage[:, wi, 0][:, None]
                    o1 = outage[:, wi, 1][:, None]
                    cands = jnp.where((cands >= o0) & (cands < o1), o1, cands)
            q = jnp.concatenate(
                [cands, jnp.broadcast_to(r_sta[None], (S, Wc))], axis=1)
            cnt = jnp.sum(node_free[:, None, :] <= q[:, :, None], axis=2)
            on_sys = r_valid[None, None, :] & (r_sel[None, None, :] == sys_col)
            occ = jnp.sum(jnp.where(
                on_sys & (r_sta[None, None, :] <= q[:, :, None])
                & (q[:, :, None] < r_fin[None, None, :]),
                r_need[None, None, :], 0), axis=2)
            availn = cnt - occ                                   # [S, E + Wc]
            E_c = cands.shape[1]
            cap_ok = availn[:, :E_c] >= need[:, None]                # [S, E]
            avail_rs = availn[:, E_c:]                               # [S, Wc]
            dips = (on_sys & (cands[:, :, None] < r_sta[None, None, :])
                    & (r_sta[None, None, :]
                       < cands[:, :, None] + Tdur[:, None, None]))
            dip_ok = jnp.all(
                ~dips | (avail_rs[:, None, :] >= need[:, None, None]), axis=2)
            return jnp.min(jnp.where(cap_ok & dip_ok, cands, BIG), axis=1)

        def reserve(jp, t0, is_retry, node_free, slots, C_tab, T_tab, runs):
            """Admission: fault draw + hole-aware earliest fit + selection —
            the new reservation row for the slot table."""
            p = prog[jp]
            u = jax.random.uniform(jax.random.fold_in(fault_key, jp), (2,))
            slow = jnp.where(u[0] < fvec[0], fvec[1], 1.0)
            fail = u[1] < fvec[2]
            if retries:
                first_fail = fail & ~is_retry
                scale = jnp.where(first_fail, fvec[3], 1.0)
            else:
                first_fail = jnp.zeros((), bool)
                scale = jnp.where(fail, 1.0 + fvec[3], 1.0)
            factor = slow * scale
            key = jax.random.fold_in(sel_key, jp)
            if tiered:
                # hole-aware earliest fit per tier: a slower tier's longer
                # window may fit a different hole, so each tier gets its
                # own piecewise-capacity evaluation
                Tdur_f = tt["T"][p] * factor                     # [F, S]
                avail_f = jax.vmap(
                    lambda td: earliest_fit(p, t0, td, node_free, slots)
                )(Tdur_f)                                        # [F, S]
                c_x, t_x, runs_x, avail_x, cp_x, tp_x = _tier_rows(
                    tt, p, C_tab[p], T_tab[p], runs[p], avail_f,
                    C_pred[p], T_pred[p])
                sel_x = select(
                    policy, c_row=c_x, t_row=t_x, runs_row=runs_x,
                    avail_row=avail_x, k=k_of(jp), c_pred_row=cp_x,
                    t_pred_row=tp_x, key=key)
                f = (sel_x // S).astype(jnp.int32)
                sel = sel_x % S
                start = avail_f[f, sel]
                T_act = Tdur_f[f, sel]
                E_res = tt["E"][p, f, sel] * factor
                wjob = tt["w"][p, f, sel]
            else:
                Tdur = T_true[p] * factor                            # [S]
                avail_p = earliest_fit(p, t0, Tdur, node_free, slots)
                sel = select(
                    policy, c_row=C_tab[p], t_row=T_tab[p],
                    runs_row=runs[p], avail_row=avail_p, k=k_of(jp),
                    c_pred_row=C_pred[p], t_pred_row=T_pred[p], key=key)
                f = jnp.int32(0)
                start = avail_p[sel]
                T_act = Tdur[sel]
                E_res = E_true[p, sel] * factor
                wjob = w_pow[p, sel]
            return dict(sel=sel.astype(jnp.int32), start=start,
                        fin=start + T_act, T=T_act,
                        E=E_res, need=n_req[p, sel],
                        wjob=wjob, fac=factor, fail=first_fail, tier=f)

        (node_free, node_pow, C_tab, T_tab, runs, acc, busy,
         slots, a, now, nbf, peak, cdel) = carry

        # ---- push: admit + reserve the next arrival if due and room
        size0 = jnp.sum(slots["pend"] < J)
        jp = jnp.minimum(a, J - 1)
        arr_a = arrival[jp]
        do_push = (a < J) & (size0 < Wc) & (arr_a <= now)
        vals = reserve(jp, arr_a, jnp.zeros((), bool), node_free, slots,
                       C_tab, T_tab, runs)
        slot = jnp.minimum(size0, Wc - 1)
        newv = dict(pend=jp.astype(jnp.int32), t0=arr_a, rt=False,
                    accT=0.0, accF=0.0, accW=0.0, s0=0.0, pblock=BIG,
                    **vals)
        slots = {k: v.at[slot].set(jnp.where(do_push, newv[k], v[slot]))
                 for k, v in slots.items()}
        a = a + do_push

        valid = slots["pend"] < J
        r_start, r_sel, r_need = slots["start"], slots["sel"], slots["need"]

        # ---- next event: arrivals, completions, reservation starts,
        # outage ends (reserved starts need not coincide with node-free
        # times once a cap defers placements)
        next_evt = jnp.min(jnp.where(node_free > now, node_free, BIG))
        arr_next = arrival[jnp.minimum(a, J - 1)]
        next_evt = jnp.minimum(
            next_evt, jnp.where((a < J) & (arr_next > now), arr_next, BIG))
        next_evt = jnp.minimum(
            next_evt,
            jnp.min(jnp.where(valid & (r_start > now), r_start, BIG)))
        if out_ends is not None:
            next_evt = jnp.minimum(
                next_evt,
                jnp.min(jnp.where(out_ends > now, out_ends, BIG)))

        # ---- realizability on the REAL table (one shared sort)
        kth_rows = kth_free_time_rows(node_free, r_sel, r_need,
                                      force=placer)              # [Wc]
        avail_real = jnp.maximum(jnp.where(valid, slots["t0"], BIG),
                                 kth_rows)
        if outage is not None:
            avail_real = _push_out_of_outage(avail_real, outage[r_sel])
        elig_res = valid & (r_start <= now) & (avail_real <= now)
        if outage is not None:
            # cap-deferred starts quantize to ``now``: the start gate
            # must hold there too (reserved starts are already pushed)
            q = jnp.maximum(r_start, now)
            gated = _push_out_of_outage(q, outage[r_sel])
            elig_res = elig_res & (~capped | (gated <= now))

        # ---- power feasibility + the stuck valve
        p_now = jnp.sum(jnp.where(node_free > now, node_pow, idle_mat))
        new_P = p_now - r_need * idle_w[r_sel] + slots["wjob"]
        power_ok = ~capped | (new_P <= pc)
        elig0 = elig_res & power_ok
        stuck = (jnp.any(elig_res) & ~do_push & ~jnp.any(elig0)
                 & (next_evt >= BIG) & (horizon >= BIG))
        elig = elig0 | (elig_res & stuck)

        chosen = jnp.min(jnp.where(elig, idx, Wc))
        placed = chosen < Wc
        ci = jnp.minimum(chosen, Wc - 1)

        chosen_res = jnp.min(jnp.where(elig_res, idx, Wc))
        cri = jnp.minimum(chosen_res, Wc - 1)
        blocked = (chosen_res < Wc) & ~power_ok[cri]
        slots["pblock"] = slots["pblock"].at[cri].set(
            jnp.where(blocked, jnp.minimum(slots["pblock"][cri], now),
                      slots["pblock"][cri]))

        # ---- realize the chosen reservation
        jj = jnp.minimum(slots["pend"][ci], J - 1)
        p = prog[jj]
        sel, need = r_sel[ci], jnp.maximum(r_need[ci], 1)
        T_act, E_act, fac = slots["T"][ci], slots["E"][ci], slots["fac"][ci]
        tier_ci = slots["tier"][ci]
        start = jnp.where(capped, jnp.maximum(r_start[ci], now),
                          r_start[ci])
        finish = start + T_act
        failed_now = placed & slots["fail"][ci]
        final = placed & ~slots["fail"][ci]
        accT_ci, accF_ci = slots["accT"][ci], slots["accF"][ci]
        accW_ci = slots["accW"][ci]
        s0_ci = jnp.where(slots["rt"][ci], slots["s0"][ci], start)
        wait_step = start - slots["t0"][ci]
        pb_ci = slots["pblock"][ci]

        kth_ci = kth_rows[ci]
        take = _alloc_mask(node_free, sel, kth_ci, need)
        node_free = jnp.where(
            placed, _alloc(node_free, sel, kth_ci, need, finish),
            node_free)
        per_node = slots["wjob"][ci] / need.astype(jnp.float32)
        node_pow = jnp.where(
            placed,
            node_pow.at[sel].set(jnp.where(take, per_node, node_pow[sel])),
            node_pow)

        fac_tot = accF_ci + fac
        C_upd = C_true[p, sel] * fac_tot
        T_upd = T_true[p, sel] * fac_tot
        n = runs[p, sel].astype(jnp.float32)
        C_tab = C_tab.at[p, sel].set(jnp.where(
            final, (C_tab[p, sel] * n + C_upd) / (n + 1), C_tab[p, sel]))
        T_tab = T_tab.at[p, sel].set(jnp.where(
            final, (T_tab[p, sel] * n + T_upd) / (n + 1), T_tab[p, sel]))
        runs = runs.at[p, sel].add(jnp.where(final, 1, 0))

        busy = busy.at[sel].add(jnp.where(placed, T_act * need, 0.0))
        nbf = nbf + (final & (chosen > 0)).astype(jnp.int32)
        peak = jnp.maximum(peak, jnp.where(placed, new_P[ci], 0.0))
        cdel = cdel + jnp.where(placed & (pb_ci < BIG), now - pb_ci, 0.0)

        def pop(arr, fill):
            shifted = jnp.concatenate(
                [arr[1:], jnp.full((1,), fill, arr.dtype)])
            return jnp.where(idx < chosen, arr, shifted)
        slots = {k: pop(v, FILLS[k]) for k, v in slots.items()}

        if retries:
            # failed first attempt: fresh reservation at the failure time
            vals2 = reserve(jj, finish, jnp.ones((), bool), node_free,
                            slots, C_tab, T_tab, runs)
            size2 = jnp.sum(slots["pend"] < J)
            slot2 = jnp.minimum(size2, Wc - 1)
            newv2 = dict(pend=jj.astype(jnp.int32), t0=finish, rt=True,
                         accT=accT_ci + T_act, accF=fac_tot,
                         accW=accW_ci + wait_step, s0=s0_ci, pblock=BIG,
                         **vals2)
            slots = {k: v.at[slot2].set(
                jnp.where(failed_now, newv2[k], v[slot2]))
                for k, v in slots.items()}

        T_tot = accT_ci + T_act
        wait_tot = accW_ci + wait_step

        # ---- advance the clock only when nothing else happened (and
        # never past the horizon)
        advance = (~do_push & ~placed & (next_evt < BIG)
                   & (next_evt <= horizon))
        now = jnp.where(advance, next_evt, now)

        if totals_only:
            sums, comps, fin_max, wait_max = acc
            add = jnp.stack([
                E_act,
                jnp.where(final, wait_tot, 0.0),
                jnp.where(final, (wait_tot + T_tot) / T_tot, 0.0)])
            y = add - comps
            t = sums + y
            acc = (jnp.where(placed, t, sums),
                   jnp.where(placed, (t - sums) - y, comps),
                   jnp.maximum(fin_max, jnp.where(placed, finish, 0.0)),
                   jnp.maximum(wait_max, jnp.where(final, wait_tot, 0.0)))
            out = None
        else:
            out = {
                # batch-result channels (_event_results scatters these)
                "j_add": jnp.where(placed, jj, J), "E": E_act,
                "j_fin": jnp.where(final, jj, J), "sys": sel,
                "s0": s0_ci, "finish": finish, "wait": wait_tot,
                "T": T_tot, "bf": final & (chosen > 0),
                "tier": tier_ci,
                # live-decision channels (the service dispatcher reads
                # these; pure additions, the batch channels are untouched)
                "pushed": do_push, "j_push": jnp.where(do_push, a - 1, J),
                "placed": placed, "final": final, "advanced": advance,
                "start": start, "now": now,
                "qlen": jnp.sum(slots["pend"] < J),
                "power": jnp.where(placed, new_P[ci], p_now),
            }

        return ConsCarry(node_free, node_pow, C_tab, T_tab, runs, acc,
                         busy, slots, a, now, nbf, peak, cdel), out

    return step


@partial(jax.jit, static_argnames=("warm_start", "placer", "totals_only",
                                   "easy_eval", "core", "retries"))
def _batched_run(arrs, policy, seeds, faults, *, warm_start, placer,
                 totals_only, easy_eval="batched", core="arrival",
                 retries=False):
    """vmap the scan core over a flat batch axis: policy leaves [B], seeds
    [B], faults [B, 4].  One compile per (shapes, policy metadata,
    warm_start, placer, totals_only, easy_eval, core, retries)."""
    return jax.vmap(
        lambda pol, sd, fv: _scan_sim(arrs, pol, warm_start, placer,
                                      totals_only, sd, fv, easy_eval,
                                      core, retries))(
        policy, seeds, faults)


#: static argnames shared by the sharded/chunked grid entries; ``mesh``
#: (a hashable jax.sharding.Mesh, or None = single-device) is static so
#: shard_map specializes per mesh like every other compile key
_GRID_STATICS = ("warm_start", "placer", "totals_only", "easy_eval",
                 "core", "retries", "mesh")


@partial(jax.jit, static_argnames=_GRID_STATICS)
def _sharded_run(arrs, policy, seeds, faults, *, mesh, warm_start, placer,
                 totals_only, easy_eval="batched", core="arrival",
                 retries=False):
    """``_batched_run`` with the flat batch axis partitioned over a 1-D
    ``("grid",)`` mesh (launch.mesh.make_grid_mesh): each device vmaps
    its B/n slice of the (policy leaves, seeds, faults) batch against
    the replicated workload arrays.  Grid lanes never communicate, so
    sharding is a pure partition of the batch axis and results are
    bit-identical to the single-device vmap (asserted in
    tests/test_sharded_campaign.py)."""
    def body(arrs_, pol, sd, fv):
        return jax.vmap(
            lambda p_, s_, f_: _scan_sim(arrs_, p_, warm_start, placer,
                                         totals_only, s_, f_, easy_eval,
                                         core, retries))(pol, sd, fv)
    return _shard_map(
        body, mesh=mesh,
        in_specs=(_replicated, _grid_spec, _grid_spec, _grid_spec),
        out_specs=_grid_spec)(arrs, policy, seeds, faults)


@partial(jax.jit, static_argnames=_GRID_STATICS)
def _chunk_init(arrs, policy, seeds, faults, *, mesh, warm_start, placer,
                totals_only, easy_eval="batched", core="arrival",
                retries=False):
    """Initial [B]-leading carries of the chunked campaign (sharded over
    ``mesh`` when given, so the carry is born device-resident on its
    shard and never gathers)."""
    def body(arrs_, pol, sd, fv):
        return jax.vmap(
            lambda p_, s_, f_: _sim_pieces(
                arrs_, p_, warm_start, placer, totals_only, s_, f_,
                easy_eval, core, retries).carry0)(pol, sd, fv)
    if mesh is None:
        return body(arrs, policy, seeds, faults)
    return _shard_map(
        body, mesh=mesh,
        in_specs=(_replicated, _grid_spec, _grid_spec, _grid_spec),
        out_specs=_grid_spec)(arrs, policy, seeds, faults)


@partial(jax.jit, static_argnames=_GRID_STATICS + ("nsteps",))
def _chunk_advance(arrs, policy, seeds, faults, carries, xs, *, mesh,
                   nsteps, warm_start, placer, totals_only,
                   easy_eval="batched", core="arrival", retries=False):
    """Advance every batch lane ``nsteps`` scan steps: the per-lane step
    closure is the monolithic scan's own (``_sim_pieces``), the carry is
    threaded in and out, and ``xs`` is the host-sliced window of the
    stream inputs (replicated across shards; None for the event cores,
    whose scans are length-driven).  At most two compilations exist per
    configuration: the full chunk and the remainder."""
    def body(arrs_, pol, sd, fv, carry, xs_):
        def lane(p_, s_, f_, c_):
            pieces = _sim_pieces(arrs_, p_, warm_start, placer,
                                 totals_only, s_, f_, easy_eval, core,
                                 retries)
            return jax.lax.scan(pieces.step, c_, xs_, length=nsteps)
        return jax.vmap(lane)(pol, sd, fv, carry)
    if mesh is None:
        return body(arrs, policy, seeds, faults, carries, xs)
    return _shard_map(
        body, mesh=mesh,
        in_specs=(_replicated, _grid_spec, _grid_spec, _grid_spec,
                  _grid_spec, _replicated),
        out_specs=_grid_spec)(arrs, policy, seeds, faults, carries, xs)


@partial(jax.jit, static_argnames=_GRID_STATICS)
def _chunk_finish(arrs, policy, seeds, faults, carries, ys, *, mesh,
                  warm_start, placer, totals_only, easy_eval="batched",
                  core="arrival", retries=False):
    """The routed core's result epilogue over final carries (+ the
    reassembled per-step outputs on the full path; None when
    ``totals_only``) — the same ops the monolithic scan's finish runs."""
    def body(arrs_, pol, sd, fv, carry, ys_):
        return jax.vmap(
            lambda p_, s_, f_, c_, y_: _sim_pieces(
                arrs_, p_, warm_start, placer, totals_only, s_, f_,
                easy_eval, core, retries).finish(c_, y_))(
            pol, sd, fv, carry, ys_)
    if mesh is None:
        return body(arrs, policy, seeds, faults, carries, ys)
    return _shard_map(
        body, mesh=mesh,
        in_specs=(_replicated, _grid_spec, _grid_spec, _grid_spec,
                  _grid_spec, _grid_spec),
        out_specs=_grid_spec)(arrs, policy, seeds, faults, carries, ys)


def _run_chunked(arrs, policy, seeds, faults, *, chunk, mesh, warm_start,
                 placer, totals_only, easy_eval="batched", core="arrival",
                 retries=False):
    """Stream the campaign scan through fixed-size windows of ``chunk``
    steps: jitted per-chunk advances thread the carry, per-step outputs
    (full path only) spill to host per chunk and are reassembled for the
    shared finish.  The step trace is the monolithic scan's own, so
    results are bit-identical (asserted per core in
    tests/test_sharded_campaign.py).  ``totals_only`` keeps O(B) carry
    state end to end — no [B, J]-shaped intermediate ever materializes,
    which is what lets a 10^6-job trace stream through device memory."""
    kw = dict(mesh=mesh, warm_start=warm_start, placer=placer,
              totals_only=totals_only, easy_eval=easy_eval, core=core,
              retries=retries)
    xs, length = _stream_xs(arrs, policy, core, retries)
    chunk = max(1, int(chunk))
    carries = _chunk_init(arrs, policy, seeds, faults, **kw)
    parts = []
    for lo in range(0, length, chunk):
        n = min(chunk, length - lo)
        xs_c = (None if xs is None
                else jax.tree.map(lambda x: x[lo:lo + n], xs))
        carries, ys = _chunk_advance(arrs, policy, seeds, faults, carries,
                                     xs_c, nsteps=n, **kw)
        if not totals_only:
            parts.append(jax.device_get(ys))
    ys_all = None
    if not totals_only:
        ys_all = jax.tree.map(lambda *cs: np.concatenate(cs, axis=1),
                              *parts)
    return _chunk_finish(arrs, policy, seeds, faults, carries, ys_all,
                         **kw)


def _fault_vec(cfg: SimConfig | FaultConfig):
    return jnp.array([cfg.straggler_prob, cfg.straggler_factor,
                      cfg.failure_prob, cfg.restart_overhead], jnp.float32)


#: distinguishes "core= not passed" from an explicit core=None (both mean
#: auto granularity, but only the explicit spelling earns the deprecation
#: warning)
_CORE_UNSET = object()


def stack_sessions(trees):
    """Stack N same-structure session pytrees (carries / contexts /
    scalar-leaf policies) along a new leading axis — the pool's [N, ...]
    batch the vmapped step consumes.  Leaves must agree in shape, which
    the fixed-capacity session arrays guarantee."""
    trees = list(trees)
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def index_session(tree, i: int):
    """Slice session ``i`` back out of a stacked pool pytree (the inverse
    of ``stack_sessions`` for one lane)."""
    return jax.tree.map(lambda x: x[i], tree)


class Scheduler:
    """The one entry point: a policy (point or grid), a placement backend,
    optional fault and seed grids — ``run`` simulates everything in a
    single jitted call.

    policy:     registered name, or a ``Policy`` (leaf-batch ``k`` /
                ``ucb_scale`` with a shared leading axis to sweep a
                hyperparameter grid in one compilation)
    placer:     kth-free dispatch (None = auto; "pallas" / "jnp" / "sort" /
                "pallas_interpret")
    faults:     one FaultConfig (no axis) or an iterable (adds a ``fault``
                axis); None = fault-free
    seeds:      one int (no axis) or an iterable (adds a ``seed`` axis)
    warm_start: profile tables pre-filled with ground truth
    queue:      queue-discipline spec overriding the policy's metadata:
                "fcfs" | "easy_backfill[:window=W]" |
                "conservative[:window=W]" (None = keep the policy's own)
    easy_eval:  EASY candidate-evaluation strategy (static): "batched"
                (default — one [W, S] kth-free call per step) or
                "unrolled" (the historical per-slot loop, kept as the
                bit-identity reference; ~W x slower at large windows)
    power_cap:  SCC power cap in Watts — a scalar, or a 1-D grid that
                leaf-batches with k/ucb_scale (cap sweeps share one jit).
                Overrides the policy's ``power_cap`` leaf; any finite cap
                routes onto the event-granular core.  None = keep the
                policy's leaf (default: uncapped).
    engine:     scan granularity: None (auto — "events" for conservative
                queues or finite power caps, "arrival" otherwise),
                "arrival" (the historical arrival-indexed scans), or
                "events" (force the event-granular core the online
                dispatcher runs — see docs/SERVICE.md; FCFS placements
                are bit-identical to "arrival", asserted per registered
                policy in tests/test_event_core.py; EASY divergence vs
                the arrival-indexed scan is documented in
                tests/test_service.py).
    core:       DEPRECATED spelling of ``engine`` (emits a
                ``DeprecationWarning``; docs/API.md migration table).
                Passing both with different values is an error.
    shards:     partition the flat (fault x policy x seed) batch axis
                over the local devices via shard_map on a 1-D
                ``("grid",)`` mesh: "auto" = every local device, or an
                explicit count; None (default) = single-device vmap.
                Lanes never communicate, so sharded results are
                bit-identical to unsharded.  The batch is padded to a
                multiple of the device count (duplicate tail lanes,
                sliced off the result).
    chunk:      stream the scan in windows of ``chunk`` steps instead of
                one monolithic lax.scan: the carry threads between
                jitted per-chunk advances, per-job outputs spill to host
                per chunk (full path), and ``totals_only`` stays O(grid)
                memory with no [grid, J] intermediate ever materialized
                — the million-job campaign mode.  Bit-identical to the
                monolithic scan (same step trace).  None (default) =
                monolithic.  Composes with ``shards``.

    ``run(w)`` returns a ``SimResult`` when no axis is present, else a
    ``CampaignResult`` with ``axes`` ordered (fault, policy, seed) — the
    legacy campaign layout.  ``totals_only=True`` skips materializing
    per-job arrays (campaign memory: [*grid] aggregates instead of
    [*grid, J]).
    """

    def __init__(self, policy: str | Policy = "paper", *,
                 placer: str | None = None, faults=None, seeds=0,
                 warm_start: bool = False, queue: str | None = None,
                 easy_eval: str = "batched", power_cap=None,
                 engine: str | None = None, core=_CORE_UNSET,
                 shards=None, chunk=None):
        if core is not _CORE_UNSET:
            warnings.warn(
                "Scheduler(core=...) is deprecated; use engine=... "
                "(docs/API.md migration table)", DeprecationWarning,
                stacklevel=2)
            if engine is not None and core is not None and core != engine:
                raise ValueError(f"core={core!r} conflicts with "
                                 f"engine={engine!r}")
            if engine is None:
                engine = core
        self.policy = make_policy(policy) if isinstance(policy, str) else policy
        if queue is not None:
            self.policy = apply_queue_spec(self.policy, queue)
        if power_cap is not None:
            self.policy = replace(self.policy,
                                  power_cap=np.asarray(power_cap, np.float32))
        if easy_eval not in ("batched", "unrolled"):
            raise ValueError(f"easy_eval {easy_eval!r} not in "
                             "('batched', 'unrolled')")
        if engine not in (None, "arrival", "events"):
            raise ValueError(f"engine {engine!r} not in (None, 'arrival', "
                             "'events')")
        if engine == "arrival" and self.policy.queue == "conservative":
            raise ValueError("queue='conservative' requires the event-"
                             "granular core (engine='events' or None)")
        if engine == "arrival" and self.policy.capped:
            raise ValueError("a finite power_cap requires the event-"
                             "granular core (engine='events' or None): the "
                             "arrival-indexed scan cannot defer placements")
        if shards is not None and shards != "auto":
            shards = int(shards)
            if shards < 1:
                raise ValueError(f"shards must be >= 1 or 'auto', "
                                 f"got {shards}")
        self.shards = shards
        if chunk is not None:
            chunk = int(chunk)
            if chunk < 1:
                raise ValueError(f"chunk must be a positive step count, "
                                 f"got {chunk}")
        self.chunk = chunk
        self.engine = engine
        self.easy_eval = easy_eval
        self.placer = placer
        self.warm_start = bool(warm_start)
        if faults is None or isinstance(faults, FaultConfig):
            self.faults = faults
        else:
            self.faults = tuple(faults)
        self.seeds = seeds if isinstance(seeds, (int, np.integer)) \
            else tuple(int(s) for s in seeds)

    @property
    def core(self):
        """Deprecated read alias of ``engine`` (docs/API.md migration)."""
        return self.engine

    def run(self, w: Workload, *, totals_only: bool = False):
        pol = self.policy
        k = jnp.asarray(pol.k, jnp.float32)
        u = jnp.asarray(pol.ucb_scale, jnp.float32)
        pc = jnp.asarray(pol.power_cap, jnp.float32)
        fw = jnp.asarray(pol.freq_weight, jnp.float32)
        if k.ndim > 1 or u.ndim > 1 or pc.ndim > 1 or fw.ndim > 1:
            raise ValueError("policy leaves must be scalars or 1-D grids; "
                             "flatten K x ucb meshes with .ravel()")
        has_policy_axis = (k.ndim == 1 or u.ndim == 1 or pc.ndim == 1
                           or fw.ndim == 1)
        k, u, pc, fw = jnp.broadcast_arrays(
            jnp.atleast_1d(k), jnp.atleast_1d(u), jnp.atleast_1d(pc),
            jnp.atleast_1d(fw))
        G = k.shape[0]

        has_seed_axis = not isinstance(self.seeds, (int, np.integer))
        seeds = jnp.atleast_1d(jnp.asarray(self.seeds, jnp.int32))
        R = seeds.shape[0]

        has_fault_axis = isinstance(self.faults, tuple)
        if self.faults is None:
            fmat = _fault_vec(FaultConfig())[None]
        elif has_fault_axis:
            fmat = jnp.stack([_fault_vec(f) for f in self.faults])
        else:
            fmat = _fault_vec(self.faults)[None]
        F = fmat.shape[0]

        # core routing (static): conservative queues and finite caps need
        # completion-event granularity; mid-job failure re-queue rides the
        # event stream whenever the fault grid can fail jobs
        core = self.engine or ("events" if (pol.queue == "conservative"
                                            or pol.capped) else "arrival")
        fault_list = (() if self.faults is None else
                      (self.faults,) if isinstance(self.faults, FaultConfig)
                      else self.faults)
        retries = core == "events" and any(
            f.failure_prob > 0 for f in fault_list)

        B = F * G * R
        kb = jnp.broadcast_to(k[None, :, None], (F, G, R)).reshape(B)
        ub = jnp.broadcast_to(u[None, :, None], (F, G, R)).reshape(B)
        pb = jnp.broadcast_to(pc[None, :, None], (F, G, R)).reshape(B)
        fwb = jnp.broadcast_to(fw[None, :, None], (F, G, R)).reshape(B)
        sb = jnp.broadcast_to(seeds[None, None, :], (F, G, R)).reshape(B)
        fb = jnp.broadcast_to(fmat[:, None, None, :], (F, G, R, 4))
        fbB = fb.reshape(B, 4)

        mesh, pad = None, 0
        if self.shards is not None:
            # lazy: core must stay importable without touching device
            # state (launch.mesh counts devices at call time only)
            from repro.launch.mesh import make_grid_mesh
            mesh = make_grid_mesh(self.shards)
            pad = (-B) % mesh.devices.size
            if pad:
                # shard_map needs B % n_devices == 0: duplicate the last
                # lane (cheapest valid work) and slice it back off below
                def padb(x):
                    tail = jnp.broadcast_to(x[-1:], (pad,) + x.shape[1:])
                    return jnp.concatenate([x, tail])
                kb, ub, pb, fwb, sb, fbB = map(
                    padb, (kb, ub, pb, fwb, sb, fbB))

        arrs = _workload_arrays(w)
        polb = replace(pol, k=kb, ucb_scale=ub, power_cap=pb,
                       freq_weight=fwb)
        common = dict(warm_start=self.warm_start, placer=self.placer,
                      totals_only=totals_only, easy_eval=self.easy_eval,
                      core=core, retries=retries)
        if self.chunk is not None:
            out = _run_chunked(arrs, polb, sb, fbB, chunk=self.chunk,
                               mesh=mesh, **common)
        elif mesh is not None:
            out = _sharded_run(arrs, polb, sb, fbB, mesh=mesh, **common)
        else:
            out = _batched_run(arrs, polb, sb, fbB, **common)
        if pad:
            out = jax.tree.map(lambda x: x[:B], out)

        axes, lead = [], []
        for name, present, size in (("fault", has_fault_axis, F),
                                    ("policy", has_policy_axis, G),
                                    ("seed", has_seed_axis, R)):
            if present:
                axes.append(name)
                lead.append(size)
        out = jax.tree.map(
            lambda x: x.reshape(tuple(lead) + x.shape[1:]), out)

        meta = dict(axes=tuple(axes), n_jobs=int(len(w.prog)),
                    n_nodes=np.asarray(w.n_nodes), programs=w.programs,
                    systems=w.systems, freq_tiers=pol.freq_tiers)
        if not axes:
            return SimResult(**out, **meta)
        coords = {}
        if has_fault_axis:
            coords["fault"] = self.faults
        if has_policy_axis:
            coords["policy"] = replace(pol, k=k, ucb_scale=u, power_cap=pc,
                                       freq_weight=fw)
        if has_seed_axis:
            coords["seed"] = self.seeds
        return CampaignResult(**out, **meta, coords=coords)
