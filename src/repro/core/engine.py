"""Campaign-scale scheduling engine: one jitted core, one ``Scheduler`` facade.

Models the paper's SCC: several computing systems (CC_1..CC_S), each a pool
of interchangeable nodes with per-node free-times; a global job queue routed
by a meta-scheduler (a ``repro.core.policy.Policy``).  Jobs are programs
with known per-system ground-truth (T, C, E) from the phase model.

The facade::

    res = Scheduler("paper", seeds=range(4)).run(workload)        # seed axis
    res = Scheduler(make_policy("ucb", k=k_grid, ucb_scale=u_grid),
                    faults=fault_list).run(workload)    # fault x policy grid

``Scheduler.run`` flattens the (fault x policy x seed) grid to one batch
axis, vmaps the lax.scan core over it inside a single jit, and reshapes
back into a structured ``SimResult``/``CampaignResult`` with named axes.
Because Policy hyperparameters (K, ucb_scale) are PyTree *leaves*, a whole
policy-hyperparameter grid shares one compilation — the static policy
metadata (exploration/feasibility/objective) is the only thing that
retraces.

``totals_only=True`` keeps the per-job accounting in the scan carry instead
of materializing [*grid, J] placement arrays — a 10^5-job x large-grid
campaign returns [*grid] aggregates in O(grid) memory.

Placement hot path: the per-step question "when are n_req[s] nodes of
system s free?" is the n_req-th smallest entry of the node-free row,
radix-selected directly (repro.kernels.kth_free: Pallas kernel on TPU,
pure-jnp twin elsewhere, O(S·maxN) per step and bit-exact against the sort
oracle); nodes are allocated by thresholding against that value.

Fault model (DESIGN.md §7): per-job deterministic pseudo-random straggler
slowdowns and node-failure restarts (checkpoint-restart semantics: a failed
job re-does ``restart_overhead`` of its work; energy scales accordingly).
The learned (C, T) tables absorb these — the paper's history mechanism
routes around chronically degraded systems automatically.

Maintenance/outage windows (scenario library, repro.data.scenarios): a
system accepts no new placements while a window [t0, t1) is open; jobs
whose earliest start falls inside a window are pushed to its end.  Windows
must be sorted by start and non-overlapping per system.  Jobs already
running ride through (drain semantics).

Accounting notes: energy is attributed per job (allocated nodes over the
job's span, paper eq. 2); idle energy of unallocated nodes is not attributed
to the suite (the paper compares job-attributed energy).  Learned-table
updates apply as each job is *placed* (the paper stores them at completion;
for the paper's simultaneous-submission experiment the two coincide —
distinct programs never wait on each other's profile entries).
"""

from __future__ import annotations

from dataclasses import dataclass, replace
from functools import partial

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.policy import (BIG, Policy, apply_queue_spec, make_policy,
                               select, select_batched)
from repro.core.result import SimResult, CampaignResult
from repro.core.workload_model import NPB_PROFILES, npb_tables
from repro.kernels.kth_free import kth_free_time, kth_free_time_shared


@dataclass(frozen=True)
class SimConfig:
    """Legacy single-run configuration (mode string + fault fields).

    The ``Scheduler`` facade supersedes this for new code; it survives for
    the ``simulate_jax``/``sweep_k``/``run_campaign`` shims and the python
    differential mirror.  ``mode`` accepts any registered policy name.
    """
    mode: str = "paper"
    k: float = 0.0                 # allowed runtime-increase fraction
    straggler_prob: float = 0.0
    straggler_factor: float = 2.0
    failure_prob: float = 0.0
    restart_overhead: float = 0.5
    seed: int = 0
    # True => profile tables pre-filled with ground truth (the paper's
    # Figs 1-4 regime: 'all 5 previously run programs', Tables 3-4 full).
    warm_start: bool = False
    # kth-free placement dispatch: None = auto (Pallas on TPU, jnp radix
    # select elsewhere); or force "pallas"/"pallas_interpret"/"jnp"/"sort".
    placer: str | None = None
    # queue-discipline overrides; "" / 0 defer to the registered policy's
    # own metadata (so mode="easy_backfill" backfills out of the box)
    queue: str = ""
    queue_window: int = 0

    def policy(self) -> Policy:
        pol = make_policy(self.mode, k=self.k)
        over = {}
        if self.queue:
            over["queue"] = self.queue
        if self.queue_window:
            over["window"] = self.queue_window
        return replace(pol, **over) if over else pol


@dataclass(frozen=True)
class FaultConfig:
    """One point of a fault grid."""
    straggler_prob: float = 0.0
    straggler_factor: float = 2.0
    failure_prob: float = 0.0
    restart_overhead: float = 0.5


@dataclass(frozen=True)
class Workload:
    """Static description of a job stream over P programs x S systems."""
    prog: np.ndarray            # [J] int32 program ids
    arrival: np.ndarray         # [J] f32 submit times
    k_job: np.ndarray           # [J] f32 per-job K (fraction); NaN -> global k
    n_req: np.ndarray           # [P, S] nodes needed
    T_true: np.ndarray          # [P, S] runtime ground truth
    C_true: np.ndarray          # [P, S] J/Mop ground truth
    E_true: np.ndarray          # [P, S] Joules ground truth
    T_pred: np.ndarray          # [P, S] phase-model predictions
    C_pred: np.ndarray
    n_nodes: np.ndarray         # [S] node counts
    programs: tuple = ()        # names, for reports
    systems: tuple = ()
    # [S, W, 2] maintenance windows (start, end), sorted, non-overlapping
    # per system; None = no outages.
    outage: np.ndarray | None = None


def make_npb_workload(systems, order=("BT", "EP", "IS", "LU", "SP"),
                      arrivals=None, k_job=None, repeats: int = 1,
                      pred_noise: float = 0.0, noise_seed: int = 0,
                      outage=None):
    """The paper's experiment: NPB suite submitted (simultaneously by
    default) to the four JSCC systems. ``repeats`` re-submits the suite."""
    programs = tuple(sorted(set(order)))
    pidx = {p: i for i, p in enumerate(programs)}
    C, T, N = npb_tables(systems, programs)
    mops = np.array([NPB_PROFILES[p].flops / 1e6 for p in programs])
    E = C * mops[:, None]
    rng = np.random.default_rng(noise_seed)
    noise = (1.0 + pred_noise * rng.standard_normal(C.shape)) if pred_noise else 1.0
    seq = list(order) * repeats
    J = len(seq)
    return Workload(
        prog=np.array([pidx[p] for p in seq], np.int32),
        arrival=np.zeros(J, np.float32) if arrivals is None
        else np.asarray(arrivals, np.float32),
        k_job=np.full(J, np.nan, np.float32) if k_job is None
        else np.asarray(k_job, np.float32),
        n_req=N, T_true=T, C_true=C, E_true=E,
        T_pred=T * noise, C_pred=C * noise,
        n_nodes=np.array([s.n_nodes for s in systems], np.int32),
        programs=programs, systems=tuple(s.name for s in systems),
        outage=None if outage is None else np.asarray(outage, np.float32),
    )


def _fault_factor(key, j, fvec):
    """fvec: [straggler_prob, straggler_factor, failure_prob, restart_ovh]."""
    u = jax.random.uniform(jax.random.fold_in(key, j), (2,))
    slow = jnp.where(u[0] < fvec[0], fvec[1], 1.0)
    fail = jnp.where(u[1] < fvec[2], 1.0 + fvec[3], 1.0)
    return slow * fail


def _workload_arrays(w: Workload) -> dict:
    """Workload -> the jnp pytree the jitted core consumes."""
    max_n = int(w.n_nodes.max())
    node_exists = np.arange(max_n)[None, :] < w.n_nodes[:, None]   # [S, maxN]
    arrs = {
        "free0": jnp.where(jnp.asarray(node_exists), 0.0, BIG),
        "prog": jnp.asarray(w.prog),
        "arrival": jnp.asarray(w.arrival),
        "k_job": jnp.asarray(w.k_job),
        "n_req": jnp.asarray(w.n_req),
        "T_true": jnp.asarray(w.T_true),
        "C_true": jnp.asarray(w.C_true),
        "E_true": jnp.asarray(w.E_true),
        "T_pred": jnp.asarray(w.T_pred),
        "C_pred": jnp.asarray(w.C_pred),
    }
    if w.outage is not None and w.outage.size:
        arrs["outage"] = jnp.asarray(w.outage, jnp.float32)
    return arrs


def _push_out_of_outage(avail, outage):
    """Earliest start per system, pushed past any open maintenance window.
    Windows sorted by start per system, so one in-order pass resolves
    cascades (a push landing inside the next window is pushed again).
    ``avail``'s last axis is the system axis (leading axes broadcast)."""
    for wi in range(outage.shape[1]):
        o0, o1 = outage[:, wi, 0], outage[:, wi, 1]
        avail = jnp.where((avail >= o0) & (avail < o1), o1, avail)
    return avail


def _earliest(node_free, nreq_row, arr, placer, outage):
    """(kth free time, earliest start) per system for one job: the kth-free
    radix select, floored at the arrival and pushed out of any open
    maintenance window.  Shared by the FCFS step, the EASY reservation /
    backfill guard, and the final placement."""
    kth = kth_free_time(node_free, nreq_row, force=placer)
    avail = jnp.maximum(arr, kth)
    if outage is not None:
        avail = _push_out_of_outage(avail, outage)
    return kth, avail


def _earliest_shared(node_free, nreq_rows, arr_col, placer, outage):
    """``_earliest`` for a whole candidate batch against ONE node-free
    table: [W, S] requests -> ([W, S] kth, [W, S] earliest start), via the
    shared-table kernel entry (one sort serves every candidate).
    ``arr_col``: [W, 1] per-candidate arrival floors."""
    kth = kth_free_time_shared(node_free, nreq_rows, force=placer)
    avail = jnp.maximum(arr_col, kth)
    if outage is not None:
        avail = _push_out_of_outage(avail, outage)
    return kth, avail


def _alloc(node_free, sel, kth_sel, need, finish):
    """Allocate the ``need`` earliest-free nodes of system ``sel`` until
    ``finish``: everything strictly below the kth free time, plus
    first-by-index ties at it (the python mirror's stable argsort picks the
    same nodes)."""
    free_sel = node_free[sel]
    below = free_sel < kth_sel
    tie = free_sel == kth_sel
    tie_rank = jnp.cumsum(tie) - 1
    take = below | (tie & (tie_rank < need - jnp.sum(below)))
    return node_free.at[sel].set(jnp.where(take, finish, free_sel))


def _scan_sim(arrs: dict, policy: Policy, warm_start: bool,
              placer: str | None, totals_only: bool, seed, fvec,
              easy_eval: str = "batched"):
    """One full simulation as a lax.scan; every argument traced except the
    static (policy metadata, warm_start, placer, totals_only, easy_eval).
    Dispatches on the policy's static ``queue`` metadata: the FCFS path is
    the historical arrival-order scan, bit-identical to the pre-queue-axis
    engine; ``easy_backfill`` runs the windowed scan (``_scan_sim_easy``).
    """
    T_true, C_true, E_true = arrs["T_true"], arrs["C_true"], arrs["E_true"]
    T_pred, C_pred = arrs["T_pred"], arrs["C_pred"]
    n_req, prog, arrival = arrs["n_req"], arrs["prog"], arrs["arrival"]
    outage = arrs.get("outage")
    P, S = T_true.shape
    J = prog.shape[0]
    # per-job effective K: explicit workload overrides win over the policy's
    kvec = jnp.where(jnp.isnan(arrs["k_job"]),
                     jnp.asarray(policy.k, jnp.float32), arrs["k_job"])
    # independent streams for selection and fault draws — folding a shared
    # key with j and j+offset would collide once J exceeds the offset,
    # which campaign streams (10k+ jobs) do
    sel_key, fault_key = jax.random.split(jax.random.key(seed))

    if warm_start:
        tabs0 = (C_true, T_true, jnp.ones((P, S), jnp.int32))
    else:
        tabs0 = (jnp.zeros((P, S)), jnp.zeros((P, S)),
                 jnp.zeros((P, S), jnp.int32))

    if policy.queue == "easy_backfill":
        return _scan_sim_easy(arrs, policy, placer, totals_only,
                              kvec, sel_key, fault_key, fvec, tabs0,
                              easy_eval)

    def step(carry, xs):
        node_free, C_tab, T_tab, runs, acc = carry
        j, p, arr, k = xs

        nreq_row = n_req[p]                                      # [S]
        kth, avail = _earliest(node_free, nreq_row, arr, placer, outage)

        sel = select(
            policy, c_row=C_tab[p], t_row=T_tab[p], runs_row=runs[p],
            avail_row=avail, k=k, c_pred_row=C_pred[p], t_pred_row=T_pred[p],
            key=jax.random.fold_in(sel_key, j))

        factor = _fault_factor(fault_key, j, fvec)
        T_act = T_true[p, sel] * factor
        C_act = C_true[p, sel] * factor
        E_act = E_true[p, sel] * factor
        start = avail[sel]
        finish = start + T_act

        need = nreq_row[sel]
        node_free = _alloc(node_free, sel, kth[sel], need, finish)

        n = runs[p, sel].astype(jnp.float32)
        C_tab = C_tab.at[p, sel].set((C_tab[p, sel] * n + C_act) / (n + 1))
        T_tab = T_tab.at[p, sel].set((T_tab[p, sel] * n + T_act) / (n + 1))
        runs = runs.at[p, sel].add(1)

        wait = start - arr
        if totals_only:
            sums, comps, fin_max, busy, wait_max = acc
            # Kahan-compensated f32 sums: 10^5 sequential adds would
            # otherwise drift ~0.1% vs the full path's array reduction
            # (x64 is unavailable, so compensation stands in for f64)
            add = jnp.stack([E_act, wait, (wait + T_act) / T_act])
            y = add - comps
            t = sums + y
            acc = (t, (t - sums) - y, jnp.maximum(fin_max, finish),
                   busy.at[sel].add(T_act * need),
                   jnp.maximum(wait_max, wait))
            out = None
        else:
            out = (sel, start, finish, wait, E_act, T_act)
        return (node_free, C_tab, T_tab, runs, acc), out

    acc0 = ((jnp.zeros(3, jnp.float32), jnp.zeros(3, jnp.float32),
             jnp.float32(0.0), jnp.zeros(S, jnp.float32),
             jnp.float32(0.0))
            if totals_only else ())
    carry0 = (arrs["free0"], *tabs0, acc0)
    xs = (jnp.arange(J), prog, arrival, kvec)
    (node_free, C_tab, T_tab, runs, acc), ys = jax.lax.scan(step, carry0, xs)

    tabs = {"C_tab": C_tab, "T_tab": T_tab, "runs": runs,
            "n_backfilled": jnp.zeros((), jnp.int32)}
    if totals_only:
        sums, _, fin_max, busy, wait_max = acc
        return {"total_energy": sums[0], "makespan": fin_max,
                "total_wait": sums[1], "slowdown_sum": sums[2],
                "max_wait": wait_max, "busy": busy, **tabs}
    sel, start, finish, wait, E, T_act = ys
    nodes = n_req[prog, sel]                                     # [J]
    busy = jnp.zeros(S, jnp.float32).at[sel].add(T_act * nodes)
    return {
        "system": sel, "start": start, "finish": finish, "wait": wait,
        "energy": E, "runtime": T_act, "nodes": nodes,
        "backfilled": jnp.zeros(J, bool),
        "total_energy": E.sum(), "makespan": finish.max(),
        "total_wait": wait.sum(), "max_wait": wait.max(),
        "slowdown_sum": ((wait + T_act) / T_act).sum(), "busy": busy,
        **tabs,
    }


def _scan_sim_easy(arrs: dict, policy: Policy, placer: str | None,
                   totals_only: bool, kvec, sel_key, fault_key, fvec, tabs0,
                   easy_eval: str = "batched"):
    """EASY-backfilling scan: J + W steps over a bounded pending window.

    The carry grows a pending buffer of W + 1 job-id slots (ascending,
    padded with the sentinel J).  Each step pushes the arriving job (steps
    past J are the drain tail) and places AT MOST one job:

      1. the head (oldest pending) — forced when the window overflows
         (FCFS fallback), or placed when its reserved start ``r_h`` (policy
         selection over current node-free times) is <= ``now``, the latest
         arrival time (BIG during the drain, so the tail drains FCFS);
      2. otherwise the first pending job (arrival order) whose tentative
         allocation does not push the head's earliest start on its
         reserved system past ``r_h`` — the EASY no-delay reservation
         guard.  (No "starts now" requirement: the scan's only events are
         arrivals, so a backfill may carry a future start — it fills the
         gap under the reservation exactly as an event-driven EASY would
         at the next completion event.)
      3. or nothing: the head keeps waiting for a backfill opportunity.

    Because at most one job is placed per step and a full window forces a
    head placement, every job is placed within J + W steps.  Placement
    math (kth-free selection, allocation tie-breaks, table updates, fault
    draws keyed by job id) is shared with the FCFS step, so ``fcfs`` and
    ``easy_backfill`` differ only in placement ORDER, never in per-job
    semantics.  Per-step outputs carry (job id | sentinel); the full path
    scatters them back into arrival-indexed [J] arrays after the scan.

    Candidate evaluation (``easy_eval``, static): every trial allocation
    in a step is computed against the SAME starting node-free table, so
    the W + 1 slots are independent and the first-fit choice is a masked
    argmin over slot index.  ``"batched"`` (default) scores all slots in
    one shared-table [W+1, S] kth-free call (``kth_free_time_shared`` —
    one sort serves every candidate) + one vmapped ``select`` + one
    vmapped tentative allocation; the no-delay guard then needs only the
    head's RESERVED system, so one per-row kth query over the trials'
    ``sel_h`` rows ([W+1, maxN]) rechecks every candidate at once — two
    batched kernel calls per step instead of ~2W sequential radix walks.
    ``"unrolled"`` is the historical python-unrolled loop, kept as the
    bit-identity reference (``tests/test_easy_batched.py`` asserts the
    two agree exactly across the whole policy registry).
    """
    T_true, C_true, E_true = arrs["T_true"], arrs["C_true"], arrs["E_true"]
    T_pred, C_pred = arrs["T_pred"], arrs["C_pred"]
    n_req, prog, arrival = arrs["n_req"], arrs["prog"], arrs["arrival"]
    outage = arrs.get("outage")
    P, S = T_true.shape
    J = prog.shape[0]
    W = int(policy.window)
    Wc = W + 1                           # buffer capacity (push-then-place)

    def sel_for(j, node_free, C_tab, T_tab, runs):
        """Policy selection + earliest start for job id j (sentinel-safe:
        j == J evaluates job J-1; callers mask the result)."""
        jj = jnp.minimum(j, J - 1)
        p = prog[jj]
        kth, avail = _earliest(node_free, n_req[p], arrival[jj], placer,
                               outage)
        sel = select(
            policy, c_row=C_tab[p], t_row=T_tab[p], runs_row=runs[p],
            avail_row=avail, k=kvec[jj], c_pred_row=C_pred[p],
            t_pred_row=T_pred[p], key=jax.random.fold_in(sel_key, jj))
        return jj, p, kth, avail, sel

    def eval_candidates(node_free, C_tab, T_tab, runs, pend):
        """Score every pending slot against the SAME node-free table in
        one batched pass (sentinel slots evaluate job J-1; callers mask).
        Returns per-slot [Wc]-leading arrays: job ids, programs, chosen
        systems, starts, actual runtimes, fault factors, node needs, and
        the [Wc, S, maxN] tentative-allocation stack."""
        jjs = jnp.minimum(pend, J - 1)                            # [Wc]
        ps = prog[jjs]                                            # [Wc]
        kths, avails = _earliest_shared(node_free, n_req[ps],
                                        arrival[jjs][:, None], placer,
                                        outage)                   # [Wc, S]
        keys = jax.vmap(lambda j: jax.random.fold_in(sel_key, j))(jjs)
        sels = select_batched(
            policy, c_rows=C_tab[ps], t_rows=T_tab[ps], runs_rows=runs[ps],
            avail_rows=avails, k=kvec[jjs], c_pred_rows=C_pred[ps],
            t_pred_rows=T_pred[ps], keys=keys)                    # [Wc]
        factors = jax.vmap(lambda j: _fault_factor(fault_key, j, fvec))(jjs)
        idx = jnp.arange(Wc)
        starts = avails[idx, sels]                                # [Wc]
        T_acts = T_true[ps, sels] * factors
        needs = n_req[ps, sels]
        trials = jax.vmap(_alloc, in_axes=(None, 0, 0, 0, 0))(
            node_free, sels, kths[idx, sels], needs, starts + T_acts)
        return jjs, ps, sels, starts, T_acts, factors, needs, trials

    def step(carry, xs):
        node_free, C_tab, T_tab, runs, acc, pend, nbf = carry
        jx, now = xs

        # push the arrival into the first sentinel slot (the invariant
        # size <= W at step start keeps the index in range; drain steps
        # push the sentinel J over a sentinel — a no-op)
        size0 = jnp.sum(pend < J)
        pend = pend.at[jnp.minimum(size0, Wc - 1)].set(jx)
        size = size0 + (jx < J)
        forced = size == Wc                       # window full: FCFS fallback
        head_valid = pend[0] < J

        if easy_eval == "batched":
            # one batched evaluation of all Wc slots; slot 0 is the head
            jjs, ps, sels, starts, T_acts, factors, needs, trials = \
                eval_candidates(node_free, C_tab, T_tab, runs, pend)
            hj, p_h, sel_h = jjs[0], ps[0], sels[0]
            r_h = starts[0]                       # head reservation
            place_head = head_valid & (forced | (r_h <= now))

            # EASY no-delay guard for ALL candidates at once: a trial can
            # only delay the head on the head's RESERVED system, so one
            # per-row kth query over the trials' sel_h rows answers every
            # candidate (rows untouched by a trial reproduce r_h exactly,
            # so their guard passes as it must)
            # (every kth mode is bit-exact, so absent an explicit placer
            # the recheck picks the cheapest: one sort op over [Wc, maxN]
            # beats Wc radix walks inside a scan)
            kth_h2 = kth_free_time(
                trials[:, sel_h, :],
                jnp.broadcast_to(n_req[p_h, sel_h], (Wc,)),
                force=placer or "sort")
            avail_h2 = jnp.maximum(arrival[hj], kth_h2)           # [Wc]
            if outage is not None:
                # only sel_h's windows apply; [1, W0, 2] broadcasts the
                # shared push over the [Wc] candidate vector
                avail_h2 = _push_out_of_outage(avail_h2, outage[sel_h][None])
            ok = avail_h2 <= r_h                                  # [Wc]

            # first-fit == masked argmin over slot index (Wc = none)
            idx = jnp.arange(Wc)
            elig = jnp.where(idx == 0, place_head,
                             head_valid & ~place_head & (pend < J) & ok)
            chosen = jnp.min(jnp.where(elig, idx, Wc))
            placed = chosen < Wc
            ci = jnp.minimum(chosen, Wc - 1)

            # gather the chosen slot: its trial allocation was computed
            # against the real starting node_free, so it IS the placement
            jj, p, sel = jjs[ci], ps[ci], sels[ci]
            factor = factors[ci]
            T_act = T_acts[ci]
            start = starts[ci]
            need = needs[ci]
            j_pl = jnp.where(placed, pend[ci], J)
            node_free = jnp.where(placed, trials[ci], node_free)
        else:
            # head-of-queue reservation from current node-free times
            h = pend[0]
            hj, p_h, _, avail_h, sel_h = sel_for(h, node_free, C_tab, T_tab,
                                                 runs)
            r_h = avail_h[sel_h]
            place_head = head_valid & (forced | (r_h <= now))

            # EASY backfill: first pending job (arrival order) whose
            # tentative allocation cannot delay the head's reservation on
            # its reserved system
            chosen = jnp.where(place_head, 0, Wc)     # slot index; Wc = none
            may_backfill = head_valid & ~place_head
            for ci in range(1, Wc):
                b = pend[ci]
                live = may_backfill & (b < J) & (chosen == Wc)
                bj, p_b, kth_b, avail_b, sel_b = sel_for(b, node_free, C_tab,
                                                         T_tab, runs)
                s_b = avail_b[sel_b]
                fin_b = s_b + T_true[p_b, sel_b] * _fault_factor(
                    fault_key, bj, fvec)
                trial = _alloc(node_free, sel_b, kth_b[sel_b],
                               n_req[p_b, sel_b], fin_b)
                _, avail_h2 = _earliest(trial, n_req[p_h], arrival[hj],
                                        placer, outage)
                ok = avail_h2[sel_h] <= r_h
                chosen = jnp.where(live & ok, ci, chosen)

            # place the chosen job (if any): same math as the FCFS step
            placed = chosen < Wc
            j_pl = jnp.where(placed, pend[jnp.minimum(chosen, Wc - 1)], J)
            jj, p, kth, avail, sel = sel_for(j_pl, node_free, C_tab, T_tab,
                                             runs)
            factor = _fault_factor(fault_key, jj, fvec)
            T_act = T_true[p, sel] * factor
            start = avail[sel]
            need = n_req[p, sel]
            node_free = jnp.where(
                placed,
                _alloc(node_free, sel, kth[sel], need, start + T_act),
                node_free)

        C_act = C_true[p, sel] * factor
        E_act = E_true[p, sel] * factor
        finish = start + T_act

        n = runs[p, sel].astype(jnp.float32)
        C_tab = C_tab.at[p, sel].set(jnp.where(
            placed, (C_tab[p, sel] * n + C_act) / (n + 1), C_tab[p, sel]))
        T_tab = T_tab.at[p, sel].set(jnp.where(
            placed, (T_tab[p, sel] * n + T_act) / (n + 1), T_tab[p, sel]))
        runs = runs.at[p, sel].add(jnp.where(placed, 1, 0))

        was_backfill = placed & (chosen > 0)
        nbf = nbf + was_backfill.astype(jnp.int32)

        # pop the chosen slot (shift the tail left; chosen == Wc: no-op)
        shifted = jnp.concatenate([pend[1:], jnp.full((1,), J, jnp.int32)])
        pend = jnp.where(jnp.arange(Wc) < chosen, pend, shifted)

        wait = start - arrival[jj]
        if totals_only:
            sums, comps, fin_max, busy, wait_max = acc
            add = jnp.where(placed,
                            jnp.stack([E_act, wait, (wait + T_act) / T_act]),
                            0.0)
            y = add - comps
            t = sums + y
            acc = (t, (t - sums) - y,
                   jnp.maximum(fin_max, jnp.where(placed, finish, 0.0)),
                   busy.at[sel].add(jnp.where(placed, T_act * need, 0.0)),
                   jnp.maximum(wait_max, jnp.where(placed, wait, 0.0)))
            out = None
        else:
            out = (j_pl, sel, start, finish, wait, E_act, T_act,
                   was_backfill)
        return (node_free, C_tab, T_tab, runs, acc, pend, nbf), out

    acc0 = ((jnp.zeros(3, jnp.float32), jnp.zeros(3, jnp.float32),
             jnp.float32(0.0), jnp.zeros(S, jnp.float32),
             jnp.float32(0.0))
            if totals_only else ())
    pend0 = jnp.full((Wc,), J, jnp.int32)
    carry0 = (arrs["free0"], *tabs0, acc0, pend0, jnp.zeros((), jnp.int32))
    T_steps = J + W
    jxs = jnp.concatenate([jnp.arange(J, dtype=jnp.int32),
                           jnp.full((W,), J, jnp.int32)])
    nows = jnp.concatenate([arrival, jnp.full((W,), BIG, jnp.float32)])
    (node_free, C_tab, T_tab, runs, acc, pend, nbf), ys = jax.lax.scan(
        step, carry0, (jxs, nows), length=T_steps)

    tabs = {"C_tab": C_tab, "T_tab": T_tab, "runs": runs,
            "n_backfilled": nbf}
    if totals_only:
        sums, _, fin_max, busy, wait_max = acc
        return {"total_energy": sums[0], "makespan": fin_max,
                "total_wait": sums[1], "slowdown_sum": sums[2],
                "max_wait": wait_max, "busy": busy, **tabs}

    # scatter per-step outputs back to arrival order; sentinel ids drop
    j_pl, sel_s, start_s, fin_s, wait_s, E_s, T_s, bf_s = ys
    def scat(vals, dtype):
        return jnp.zeros(J, dtype).at[j_pl].set(vals, mode="drop")
    sel = scat(sel_s, sel_s.dtype)
    start = scat(start_s, jnp.float32)
    finish = scat(fin_s, jnp.float32)
    wait = scat(wait_s, jnp.float32)
    E = scat(E_s, jnp.float32)
    T_act = scat(T_s, jnp.float32)
    backfilled = scat(bf_s, bool)
    nodes = n_req[prog, sel]                                     # [J]
    busy = jnp.zeros(S, jnp.float32).at[sel].add(T_act * nodes)
    return {
        "system": sel, "start": start, "finish": finish, "wait": wait,
        "energy": E, "runtime": T_act, "nodes": nodes,
        "backfilled": backfilled,
        "total_energy": E.sum(), "makespan": finish.max(),
        "total_wait": wait.sum(), "max_wait": wait.max(),
        "slowdown_sum": ((wait + T_act) / T_act).sum(), "busy": busy,
        **tabs,
    }


@partial(jax.jit, static_argnames=("warm_start", "placer", "totals_only",
                                   "easy_eval"))
def _batched_run(arrs, policy, seeds, faults, *, warm_start, placer,
                 totals_only, easy_eval="batched"):
    """vmap the scan core over a flat batch axis: policy leaves [B], seeds
    [B], faults [B, 4].  One compile per (shapes, policy metadata,
    warm_start, placer, totals_only, easy_eval)."""
    return jax.vmap(
        lambda pol, sd, fv: _scan_sim(arrs, pol, warm_start, placer,
                                      totals_only, sd, fv, easy_eval))(
        policy, seeds, faults)


def _fault_vec(cfg: SimConfig | FaultConfig):
    return jnp.array([cfg.straggler_prob, cfg.straggler_factor,
                      cfg.failure_prob, cfg.restart_overhead], jnp.float32)


class Scheduler:
    """The one entry point: a policy (point or grid), a placement backend,
    optional fault and seed grids — ``run`` simulates everything in a
    single jitted call.

    policy:     registered name, or a ``Policy`` (leaf-batch ``k`` /
                ``ucb_scale`` with a shared leading axis to sweep a
                hyperparameter grid in one compilation)
    placer:     kth-free dispatch (None = auto; "pallas" / "jnp" / "sort" /
                "pallas_interpret")
    faults:     one FaultConfig (no axis) or an iterable (adds a ``fault``
                axis); None = fault-free
    seeds:      one int (no axis) or an iterable (adds a ``seed`` axis)
    warm_start: profile tables pre-filled with ground truth
    queue:      queue-discipline spec overriding the policy's metadata:
                "fcfs" | "easy_backfill" | "easy_backfill:window=W"
                (None = keep the policy's own discipline)
    easy_eval:  EASY candidate-evaluation strategy (static): "batched"
                (default — one [W, S] kth-free call per step) or
                "unrolled" (the historical per-slot loop, kept as the
                bit-identity reference; ~W x slower at large windows)

    ``run(w)`` returns a ``SimResult`` when no axis is present, else a
    ``CampaignResult`` with ``axes`` ordered (fault, policy, seed) — the
    legacy campaign layout.  ``totals_only=True`` skips materializing
    per-job arrays (campaign memory: [*grid] aggregates instead of
    [*grid, J]).
    """

    def __init__(self, policy: str | Policy = "paper", *,
                 placer: str | None = None, faults=None, seeds=0,
                 warm_start: bool = False, queue: str | None = None,
                 easy_eval: str = "batched"):
        self.policy = make_policy(policy) if isinstance(policy, str) else policy
        if queue is not None:
            self.policy = apply_queue_spec(self.policy, queue)
        if easy_eval not in ("batched", "unrolled"):
            raise ValueError(f"easy_eval {easy_eval!r} not in "
                             "('batched', 'unrolled')")
        self.easy_eval = easy_eval
        self.placer = placer
        self.warm_start = bool(warm_start)
        if faults is None or isinstance(faults, FaultConfig):
            self.faults = faults
        else:
            self.faults = tuple(faults)
        self.seeds = seeds if isinstance(seeds, (int, np.integer)) \
            else tuple(int(s) for s in seeds)

    def run(self, w: Workload, *, totals_only: bool = False):
        pol = self.policy
        k = jnp.asarray(pol.k, jnp.float32)
        u = jnp.asarray(pol.ucb_scale, jnp.float32)
        if k.ndim > 1 or u.ndim > 1:
            raise ValueError("policy leaves must be scalars or 1-D grids; "
                             "flatten K x ucb meshes with .ravel()")
        has_policy_axis = k.ndim == 1 or u.ndim == 1
        k, u = jnp.broadcast_arrays(jnp.atleast_1d(k), jnp.atleast_1d(u))
        G = k.shape[0]

        has_seed_axis = not isinstance(self.seeds, (int, np.integer))
        seeds = jnp.atleast_1d(jnp.asarray(self.seeds, jnp.int32))
        R = seeds.shape[0]

        has_fault_axis = isinstance(self.faults, tuple)
        if self.faults is None:
            fmat = _fault_vec(FaultConfig())[None]
        elif has_fault_axis:
            fmat = jnp.stack([_fault_vec(f) for f in self.faults])
        else:
            fmat = _fault_vec(self.faults)[None]
        F = fmat.shape[0]

        B = F * G * R
        kb = jnp.broadcast_to(k[None, :, None], (F, G, R)).reshape(B)
        ub = jnp.broadcast_to(u[None, :, None], (F, G, R)).reshape(B)
        sb = jnp.broadcast_to(seeds[None, None, :], (F, G, R)).reshape(B)
        fb = jnp.broadcast_to(fmat[:, None, None, :], (F, G, R, 4))

        out = _batched_run(
            _workload_arrays(w), replace(pol, k=kb, ucb_scale=ub),
            sb, fb.reshape(B, 4), warm_start=self.warm_start,
            placer=self.placer, totals_only=totals_only,
            easy_eval=self.easy_eval)

        axes, lead = [], []
        for name, present, size in (("fault", has_fault_axis, F),
                                    ("policy", has_policy_axis, G),
                                    ("seed", has_seed_axis, R)):
            if present:
                axes.append(name)
                lead.append(size)
        out = jax.tree.map(
            lambda x: x.reshape(tuple(lead) + x.shape[1:]), out)

        meta = dict(axes=tuple(axes), n_jobs=int(len(w.prog)),
                    n_nodes=np.asarray(w.n_nodes), programs=w.programs,
                    systems=w.systems)
        if not axes:
            return SimResult(**out, **meta)
        coords = {}
        if has_fault_axis:
            coords["fault"] = self.faults
        if has_policy_axis:
            coords["policy"] = replace(pol, k=k, ucb_scale=u)
        if has_seed_axis:
            coords["seed"] = self.seeds
        return CampaignResult(**out, **meta, coords=coords)
