"""The paper's energy formalism, verbatim (eqs. in §Problem).

These functions operate on *measured/sampled* power traces (what SUPPZ's
monitoring provides on real hardware; what our simulator and roofline model
synthesize here).
"""

from __future__ import annotations

import jax.numpy as jnp


def node_power(e_calc_sigma, e_disk, e_net):
    """W^j(t) = E_CALC,Σ^j(t) + E_disk^j(t) + E_net^j(t)   — paper eq. (1).
    Inputs are per-timepoint component powers (any matching shapes)."""
    return e_calc_sigma + e_disk + e_net


def average_power(w_jt, dt=1.0):
    """W̄ = ∫ Σ_j W^j(t) dt / T   — paper eq. (2).
    w_jt: [N_nodes, T_steps] power samples; dt: sample spacing (s)."""
    w_jt = jnp.asarray(w_jt)
    total = jnp.trapezoid(w_jt.sum(axis=0), dx=dt)
    duration = (w_jt.shape[1] - 1) * dt
    return total / jnp.maximum(duration, 1e-12)


def energy_coefficient(w_avg, p_mops):
    """C = W / P  [J/Mop]  — paper eq. (3); P in Mop/s (NPB's native unit,
    see DESIGN.md §11 units note)."""
    return w_avg / jnp.maximum(p_mops, 1e-12)


def profile(k_percent, c):
    """A power-consumption profile is the pair (K, C) — paper §Problem."""
    return {"K": k_percent, "C": c}
