"""The paper's energy-efficient selection algorithm + baselines + extensions.

Paper §Algorithm (4 steps), for one job of program p:
  1. Systems list = all systems able to run it.
  2-3. Look up C[p, s] and T[p, s] from previous runs (0 if never run).
  4. Pick the system with smallest C subject to the K threshold:
         feasible = { s : T[p,s] <= min_s' T[p,s'] * (1 + K) }
         choose     argmin_{s in feasible} C[p,s]      (tie -> smaller T)
     If some systems are unexplored (C = T = 0), the job goes to the FIRST
     RELEASED unexplored system (paper's exploration phase: 'each parallel
     program will be submitted on the first released computing system' until
     the tables fill).

All selectors are branchless jnp functions of row vectors, so the simulator
can scan/vmap them.  ``mode`` is static.

Modes:
  paper        — the algorithm above (faithful reproduction)
  queue_aware  — beyond-paper (the paper's stated future work): feasibility
                 tested on wait+run completion time instead of bare runtime
  predictive   — beyond-paper cold start: unexplored entries are filled from
                 the phase-model prediction (no exploration runs wasted)
  ucb          — beyond-paper exploration: optimistic C bound instead of
                 first-released ordering
  fastest      — argmin T (classic performance-first)
  greenest     — argmin C unconditionally (energy-first, no K guard)
  first_free   — argmin availability (classic multi-cluster FIFO placement)
  random       — uniform random system
  oracle       — paper rule evaluated on the TRUE (C, T) tables
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

BIG = 1e30

MODES = ("paper", "queue_aware", "predictive", "ucb", "fastest",
         "greenest", "first_free", "random", "oracle")


def _paper_rule(c_row, t_row, k):
    """argmin C s.t. T <= T_min*(1+K); tie-break on T. Rows must be fully
    known (no zeros)."""
    t_min = t_row.min()
    feasible = t_row <= t_min * (1.0 + k)
    # lexicographic: minimize (C, T) over feasible
    score = jnp.where(feasible, c_row, BIG)
    cbest = score.min()
    tie = score <= cbest * (1 + 1e-9)
    t_score = jnp.where(tie, t_row, BIG)
    return jnp.argmin(t_score)


def select_system(mode: str, *, c_row, t_row, runs_row, avail_row, k,
                  c_pred_row=None, t_pred_row=None, key=None):
    """Return selected system index (traced int32).

    c_row/t_row: learned tables for this program [S];
    runs_row: run counts [S]; avail_row: earliest start per system [S];
    k: allowed runtime-increase fraction; *_pred: model predictions [S].
    """
    known = runs_row > 0
    any_unknown = jnp.any(~known)

    if mode == "paper":
        # exploration: first released among unexplored systems
        explore_score = jnp.where(~known, avail_row, BIG)
        explore_idx = jnp.argmin(explore_score)
        exploit_idx = _paper_rule(jnp.where(known, c_row, BIG),
                                  jnp.where(known, t_row, BIG), k)
        return jnp.where(any_unknown, explore_idx, exploit_idx)

    if mode == "queue_aware":
        # feasibility on completion = wait + T (paper's stated future work)
        explore_score = jnp.where(~known, avail_row, BIG)
        explore_idx = jnp.argmin(explore_score)
        wait = avail_row - avail_row.min()
        comp = jnp.where(known, t_row + wait, BIG)
        exploit_idx = _paper_rule(jnp.where(known, c_row, BIG), comp, k)
        return jnp.where(any_unknown, explore_idx, exploit_idx)

    if mode == "predictive":
        c_eff = jnp.where(known, c_row, c_pred_row)
        t_eff = jnp.where(known, t_row, t_pred_row)
        return _paper_rule(c_eff, t_eff, k)

    if mode == "ucb":
        # optimistic lower bound on C for unexplored systems: best known C
        # scaled down => systems get tried when promising, not round-robin
        c_floor = jnp.where(known, c_row, BIG).min() * 0.5
        c_eff = jnp.where(known, c_row, c_floor)
        t_eff = jnp.where(known, t_row, jnp.where(known, t_row, BIG).min())
        return _paper_rule(c_eff, t_eff, k)

    if mode == "fastest":
        explore_score = jnp.where(~known, avail_row, BIG)
        explore_idx = jnp.argmin(explore_score)
        exploit_idx = jnp.argmin(jnp.where(known, t_row, BIG))
        return jnp.where(any_unknown, explore_idx, exploit_idx)

    if mode == "greenest":
        explore_score = jnp.where(~known, avail_row, BIG)
        explore_idx = jnp.argmin(explore_score)
        exploit_idx = jnp.argmin(jnp.where(known, c_row, BIG))
        return jnp.where(any_unknown, explore_idx, exploit_idx)

    if mode == "first_free":
        return jnp.argmin(avail_row)

    if mode == "random":
        return jax.random.randint(key, (), 0, c_row.shape[0])

    if mode == "oracle":
        # caller passes TRUE tables via c_pred/t_pred
        return _paper_rule(c_pred_row, t_pred_row, k)

    raise ValueError(f"unknown mode {mode!r}; known: {MODES}")
