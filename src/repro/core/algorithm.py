"""The paper's energy-efficient selection algorithm + baselines + extensions.

Paper §Algorithm (4 steps), for one job of program p:
  1. Systems list = all systems able to run it.
  2-3. Look up C[p, s] and T[p, s] from previous runs (0 if never run).
  4. Pick the system with smallest C subject to the K threshold:
         feasible = { s : T[p,s] <= min_s' T[p,s'] * (1 + K) }
         choose     argmin_{s in feasible} C[p,s]      (tie -> smaller T)
     If some systems are unexplored (C = T = 0), the job goes to the FIRST
     RELEASED unexplored system (paper's exploration phase: 'each parallel
     program will be submitted on the first released computing system' until
     the tables fill).

The selector family now lives in ``repro.core.policy`` as composable
(exploration x feasibility x objective) ``Policy`` entries in a registry;
this module keeps the historical mode-string surface as a thin shim.

Modes (each a registry entry; see ``policy.policy_names()`` for the full
registry including post-paper compositions):
  paper        — the algorithm above (faithful reproduction)
  queue_aware  — beyond-paper (the paper's stated future work): feasibility
                 tested on wait+run completion time instead of bare runtime
  predictive   — beyond-paper cold start: unexplored entries are filled from
                 the phase-model prediction (no exploration runs wasted)
  ucb          — beyond-paper exploration: optimistic C bound instead of
                 first-released ordering
  fastest      — argmin T (classic performance-first)
  greenest     — argmin C unconditionally (energy-first, no K guard)
  first_free   — argmin availability (classic multi-cluster FIFO placement)
  random       — uniform random system
  oracle       — paper rule evaluated on the TRUE (C, T) tables
"""

from __future__ import annotations

from repro.core.policy import (
    BIG, LEGACY_MODES, make_policy, select,
    _lex_argmin, _paper_rule,                       # noqa: F401 (re-export)
)

MODES = LEGACY_MODES


def select_system(mode: str, *, c_row, t_row, runs_row, avail_row, k,
                  c_pred_row=None, t_pred_row=None, key=None):
    """Return selected system index (traced int32).

    Legacy string-dispatch shim over the policy registry: equivalent to
    ``policy.select(make_policy(mode), ...)`` with the historical default
    hyperparameters (ucb_scale=0.5).  ``mode`` accepts any registered
    policy name, not just the nine historical ones.

    c_row/t_row: learned tables for this program [S];
    runs_row: run counts [S]; avail_row: earliest start per system [S];
    k: allowed runtime-increase fraction; *_pred: model predictions [S].
    """
    return select(make_policy(mode), c_row=c_row, t_row=t_row,
                  runs_row=runs_row, avail_row=avail_row, k=k,
                  c_pred_row=c_pred_row, t_pred_row=t_pred_row, key=key)
