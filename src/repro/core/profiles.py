"""(K, C) profile store: per-(program, system) history tables.

The paper's algorithm steps 2-3: look up C and T from previous runs; a
never-run (program, system) pair holds C = 0, T = 0 (the exploration
sentinel).  ``k_auto`` implements the paper's automatic K:  K = T_max / T
(ordered time over historical runtime), expressed here as the equivalent
allowed *increase fraction* max(0, T_max/T - 1).
"""

from __future__ import annotations

import numpy as np


class ProfileStore:
    """Dense history tables over |P| programs x |S| systems."""

    def __init__(self, n_programs: int, n_systems: int):
        self.C = np.zeros((n_programs, n_systems))
        self.T = np.zeros((n_programs, n_systems))
        self.runs = np.zeros((n_programs, n_systems), np.int64)

    def update(self, p: int, s: int, c: float, t: float):
        """Store the profile measured after a successful completion (paper:
        'After the successful completion ... the C and T values are stored').
        Running averages over repeat runs."""
        n = self.runs[p, s]
        self.C[p, s] = (self.C[p, s] * n + c) / (n + 1)
        self.T[p, s] = (self.T[p, s] * n + t) / (n + 1)
        self.runs[p, s] = n + 1

    def known(self, p: int) -> np.ndarray:
        return self.runs[p] > 0

    def fully_explored(self) -> bool:
        return bool((self.runs > 0).all())


def k_auto(t_max: float, t_hist: float) -> float:
    """Paper §Implementation: K = T_max / T when the program ran before and
    fit in its ordered time.  Returned as allowed-increase fraction."""
    if t_hist <= 0:
        return 0.0
    return max(0.0, t_max / t_hist - 1.0)
