"""Computing-system models (the paper's CC_1..CC_n).

The four JSCC RAS systems from the paper's experimental platform
(MVS-10P MP2 KNL / OP BRD / OP SKX / OP CLK).  Cores-per-node are fixed by
the paper's Table 6 (144 cores => KNL 2 CN, BDW 5 CN, SKX 4 CN, CLK 3 CN;
256 cores => 4/8/8/6 CN), which matches the public MVS-10P configurations:
KNL 72c, BDW 32c, SKX 36c, CLK 48c per node.

Power figures are public-TDP-based estimates calibrated per DESIGN.md §11
(exact per-benchmark JSCC power is not published); peak flops are the
nominal double-precision node peaks.  The scheduler only ever consumes
*relative* C/T across systems, which these models fix well.

A second registry models heterogeneous TPU pod tiers for the production
half (LM jobs) — same ComputeSystem abstraction, constants from the
assignment (v5e: 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI).
"""

from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class ComputeSystem:
    name: str
    n_nodes: int               # nodes available to the scheduler
    cores_per_node: int
    peak_flops_node: float     # op/s per node (DP for CPU systems, bf16 for TPU)
    mem_bw_node: float         # B/s
    net_bw_node: float         # B/s injection bandwidth per node
    disk_bw_node: float        # B/s parallel-fs bandwidth per node
    # power model, Watts per node (paper eq. (1): W = E_CALC + E_disk + E_net)
    idle_w: float              # baseline (always drawn while allocated)
    cpu_w: float               # extra during compute phases
    net_w: float               # extra during communication phases
    disk_w: float              # extra during disk phases
    efficiency: float          # sustained fraction of peak for well-vectorized code
    scalar_eff: float = 0.55   # fraction of `efficiency` reachable by scalar-ish code


# --- the paper's experimental platform (JSCC RAS) -------------------------

KNL = ComputeSystem(
    name="KNL", n_nodes=38, cores_per_node=72,
    peak_flops_node=3.0e12, mem_bw_node=400e9,   # MCDRAM
    net_bw_node=12.5e9, disk_bw_node=2e9,
    idle_w=120.0, cpu_w=230.0, net_w=18.0, disk_w=12.0,
    efficiency=0.16, scalar_eff=0.20,  # KNL: wide-SIMD friendly, dies on scalar code
)

BROADWELL = ComputeSystem(
    name="Broadwell", n_nodes=136, cores_per_node=32,
    peak_flops_node=1.33e12, mem_bw_node=153e9,
    net_bw_node=12.5e9, disk_bw_node=2e9,
    idle_w=110.0, cpu_w=290.0, net_w=15.0, disk_w=12.0,
    efficiency=0.14, scalar_eff=0.60,
)

SKYLAKE = ComputeSystem(
    name="Skylake", n_nodes=58, cores_per_node=36,
    peak_flops_node=3.46e12, mem_bw_node=256e9,
    net_bw_node=12.5e9, disk_bw_node=2e9,
    idle_w=130.0, cpu_w=420.0, net_w=15.0, disk_w=12.0,
    efficiency=0.13, scalar_eff=0.50,
)

CASCADE_LAKE = ComputeSystem(
    name="CascadeLake", n_nodes=51, cores_per_node=48,
    peak_flops_node=4.6e12, mem_bw_node=282e9,
    net_bw_node=12.5e9, disk_bw_node=2e9,
    idle_w=135.0, cpu_w=430.0, net_w=15.0, disk_w=12.0,
    efficiency=0.135, scalar_eff=0.50,
)

JSCC_SYSTEMS = (KNL, BROADWELL, SKYLAKE, CASCADE_LAKE)
JSCC_BY_NAME = {s.name: s for s in JSCC_SYSTEMS}


# --- heterogeneous TPU pod tiers (production half) ------------------------
# One "node" = one chip; a pod tier exposes n_nodes chips to the scheduler.

TPU_V5E_POD = ComputeSystem(
    name="tpu-v5e-256", n_nodes=256, cores_per_node=1,
    peak_flops_node=197e12, mem_bw_node=819e9,
    net_bw_node=50e9, disk_bw_node=4e9,
    idle_w=70.0, cpu_w=130.0, net_w=15.0, disk_w=5.0,   # ~200W/chip active
    efficiency=0.55,
)

TPU_V4_POD = ComputeSystem(
    name="tpu-v4-256", n_nodes=256, cores_per_node=1,
    peak_flops_node=275e12, mem_bw_node=1200e9,
    net_bw_node=100e9, disk_bw_node=4e9,
    idle_w=90.0, cpu_w=200.0, net_w=20.0, disk_w=5.0,   # ~310W/chip active
    efficiency=0.50,
)

TPU_V5P_POD = ComputeSystem(
    name="tpu-v5p-128", n_nodes=128, cores_per_node=1,
    peak_flops_node=459e12, mem_bw_node=2765e9,
    net_bw_node=100e9, disk_bw_node=4e9,
    idle_w=100.0, cpu_w=250.0, net_w=20.0, disk_w=5.0,
    efficiency=0.55,
)

TPU_SYSTEMS = (TPU_V5E_POD, TPU_V4_POD, TPU_V5P_POD)
TPU_BY_NAME = {s.name: s for s in TPU_SYSTEMS}

ALL_SYSTEMS = {**JSCC_BY_NAME, **TPU_BY_NAME}
