"""Structured simulation results with named axes and derived metrics.

``SimResult`` wraps one simulation's outputs; ``CampaignResult`` is the
same shape with leading named axes (any of ``fault``/``policy``/``seed``)
plus the grid coordinates they index.  Every array field carries the
leading axes, so ``res.total_energy[f, g, r]`` and
``res.system[f, g, r, j]`` line up by construction.

Derived metrics (properties, cheap to compute lazily):
  mean_slowdown   mean over jobs of (wait + runtime) / runtime
  mean_wait       total_wait / n_jobs
  utilization     per-system busy node-seconds / (nodes * makespan)
  backfill_rate   fraction of jobs placed out of arrival order by the
                  EASY queue discipline (0 under fcfs)

Queue-discipline fields (ISSUE 3): ``n_backfilled`` / ``max_wait`` are
totals on every result; the per-job ``backfilled`` mask rides with the
other per-job arrays (``None`` when ``totals_only``).

``to_dict()`` flattens everything (including the derived metrics) for
benchmark CSVs and the legacy dict-based callers; per-job arrays are
``None`` on results produced with ``totals_only=True``.

Memory model at campaign scale (ISSUE 10): a ``totals_only`` result
holds only ``[*axes]`` totals and ``[*axes, P, S]`` tables — nothing
sized by J — which is what lets ``Scheduler(chunk=...)`` stream a
million-job trace without ever materializing a ``[*axes, J]`` array
(docs/API.md "Sharded & chunked campaigns").  Full-path results built by
the chunked driver reassemble their per-job ``[*axes, J]`` fields on the
HOST (numpy, spilled chunk by chunk), so field arrays may be numpy
rather than jax arrays; both satisfy the same ``np.asarray`` contract
every consumer here uses.
"""

from __future__ import annotations

from dataclasses import dataclass, field, fields

import numpy as np
import jax.numpy as jnp

#: Array fields carrying only the leading (grid) axes.
_TOTAL_FIELDS = ("total_energy", "makespan", "total_wait", "slowdown_sum",
                 "max_wait", "n_backfilled",
                 "peak_power", "idle_energy", "capped_delay")
#: Array fields with a trailing per-job axis [..., J]; None if totals_only.
_PERJOB_FIELDS = ("system", "start", "finish", "wait", "energy", "runtime",
                  "nodes", "backfilled", "tier")
#: Learned-table fields [..., P, S] and the per-system busy field [..., S].
_TABLE_FIELDS = ("C_tab", "T_tab", "runs", "busy")


@dataclass(frozen=True)
class SimResult:
    """One simulation run (``axes == ()``) or a stacked grid of them."""
    # totals [*axes]
    total_energy: jnp.ndarray
    makespan: jnp.ndarray
    total_wait: jnp.ndarray
    slowdown_sum: jnp.ndarray
    # per-system [*axes, S]
    busy: jnp.ndarray
    # learned tables [*axes, P, S]
    C_tab: jnp.ndarray
    T_tab: jnp.ndarray
    runs: jnp.ndarray
    # queue-discipline totals [*axes]
    max_wait: jnp.ndarray | None = None
    n_backfilled: jnp.ndarray | None = None
    # SCC power totals [*axes] (ISSUE 5): peak cluster draw (NaN on the
    # arrival-indexed core, which tracks no power trace), idle energy of
    # unallocated nodes over the makespan, and the total placement delay
    # attributable to a binding power cap
    peak_power: jnp.ndarray | None = None
    idle_energy: jnp.ndarray | None = None
    capped_delay: jnp.ndarray | None = None
    # per-job [*axes, J]; None when produced with totals_only=True
    system: jnp.ndarray | None = None
    start: jnp.ndarray | None = None
    finish: jnp.ndarray | None = None
    wait: jnp.ndarray | None = None
    energy: jnp.ndarray | None = None
    runtime: jnp.ndarray | None = None
    nodes: jnp.ndarray | None = None
    backfilled: jnp.ndarray | None = None
    # per-job DVFS tier index into ``freq_tiers`` (0 = full frequency;
    # all-zero for untier policies) [*axes, J]
    tier: jnp.ndarray | None = None
    # metadata
    axes: tuple = ()
    n_jobs: int = 0
    n_nodes: np.ndarray | None = None        # [S]
    programs: tuple = ()
    systems: tuple = ()
    freq_tiers: tuple = (1.0,)

    @property
    def totals_only(self) -> bool:
        return self.system is None

    @property
    def mean_wait(self):
        return self.total_wait / max(self.n_jobs, 1)

    @property
    def mean_slowdown(self):
        """Mean over jobs of (wait + runtime) / runtime; 1.0 = no queueing."""
        return self.slowdown_sum / max(self.n_jobs, 1)

    @property
    def utilization(self):
        """Per-system busy node-seconds / (node count x makespan), shaped
        [*axes, S]."""
        denom = self.n_nodes * jnp.expand_dims(self.makespan, -1)
        return self.busy / denom

    @property
    def backfill_rate(self):
        """Fraction of jobs placed out of arrival order (EASY backfill);
        0.0 under the fcfs discipline."""
        if self.n_backfilled is None:
            return None
        return self.n_backfilled / max(self.n_jobs, 1)

    @property
    def tier_counts(self):
        """Placements per frequency tier, shaped [*axes, F] (F =
        ``len(freq_tiers)``); None when ``totals_only``."""
        if self.tier is None:
            return None
        F = len(self.freq_tiers)
        return (self.tier[..., None] == jnp.arange(F)).sum(axis=-2)

    @property
    def tier_energy(self):
        """Job-attributed energy per frequency tier [*axes, F]; rows sum
        to ``total_energy`` up to f32 reduction order."""
        if self.tier is None:
            return None
        F = len(self.freq_tiers)
        onehot = (self.tier[..., None] == jnp.arange(F))
        return (self.energy[..., None]
                * onehot.astype(self.energy.dtype)).sum(axis=-2)

    def to_dict(self, arrays: bool = True) -> dict:
        """Flatten to a plain dict (the legacy ``simulate_jax`` schema plus
        the derived metrics).  ``arrays=False`` keeps only totals/derived —
        handy for CSV rows."""
        out = {k: getattr(self, k) for k in _TOTAL_FIELDS
               if getattr(self, k) is not None}
        out["mean_wait"] = self.mean_wait
        out["mean_slowdown"] = self.mean_slowdown
        out["utilization"] = self.utilization
        if self.backfill_rate is not None:
            out["backfill_rate"] = self.backfill_rate
        if self.tier_counts is not None:
            out["tier_counts"] = self.tier_counts
            out["tier_energy"] = self.tier_energy
        if arrays:
            for k in _TABLE_FIELDS:
                out[k] = getattr(self, k)
            for k in _PERJOB_FIELDS:
                if getattr(self, k) is not None:
                    out[k] = getattr(self, k)
        return out

    def __repr__(self):
        ax = ",".join(self.axes) if self.axes else "scalar"
        kind = "totals" if self.totals_only else "full"
        return (f"{type(self).__name__}(axes=[{ax}], jobs={self.n_jobs}, "
                f"{kind})")


@dataclass(frozen=True, repr=False)
class CampaignResult(SimResult):
    """A grid of simulations with named leading axes and their coordinates.

    ``coords`` maps each axis name to what it indexes: ``fault`` -> the
    FaultConfig tuple, ``policy`` -> the leaf-batched Policy, ``seed`` ->
    the seed tuple.
    """
    coords: dict = field(default_factory=dict)

    def index(self, **sel) -> "SimResult":
        """Select one point per named axis, e.g. ``res.index(policy=3,
        seed=0)``; axes not named are kept."""
        bad = set(sel) - set(self.axes)
        if bad:
            raise KeyError(f"unknown axes {sorted(bad)}; have {self.axes}")
        not_int = {a: v for a, v in sel.items()
                   if not isinstance(v, (int, np.integer))}
        if not_int:
            raise TypeError(f"index() takes integer points, got {not_int}; "
                            "slice arrays directly for ranges")
        idx = tuple(sel.get(a, slice(None)) for a in self.axes)
        kept = tuple(a for a in self.axes if a not in sel)
        kw = {}
        for f in fields(SimResult):
            v = getattr(self, f.name)
            kw[f.name] = v[idx] if (f.name in _TOTAL_FIELDS + _PERJOB_FIELDS
                                    + _TABLE_FIELDS and v is not None) else v
        kw["axes"] = kept
        if kept:
            coords = {a: v for a, v in self.coords.items() if a in kept}
            return CampaignResult(coords=coords, **kw)
        return SimResult(**kw)
