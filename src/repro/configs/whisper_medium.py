"""whisper-medium [audio] — enc-dec, conv frontend (stub). [arXiv:2212.04356; unverified]
24L d_model=1024 16H d_ff=4096 vocab=51865.  The conv frontend is a STUB:
``input_specs()`` provides precomputed frame embeddings (1500, d_model).
Whisper uses LayerNorm + GELU MLPs and absolute positions (no RoPE).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium",
    family="audio",
    n_layers=24,              # decoder layers
    n_encoder_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab_size=51_865,
    mlp_type="gelu",
    norm_type="layernorm",
    is_encoder_decoder=True,
    encoder_seq=1500,
    frontend="audio",
    tie_embeddings=True,
)
