"""moonshot-v1-16b-a3b [moe] — kimi/moonlight, 64e top-6.
[hf:moonshotai/Moonlight-16B-A3B; hf]
48L d_model=2048 16H (GQA kv=16) d_ff=1408 vocab=163840, MoE 64e top-6.
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab_size=163_840,
    rope_theta=50_000.0,
    moe=MoEConfig(n_experts=64, top_k=6, layer_period=1),
    fsdp=True,
)
