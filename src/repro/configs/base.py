"""Architecture + shape configuration system.

Every assigned architecture is a ``ModelConfig`` in its own module under
``repro.configs``; ``repro.configs.registry`` maps ``--arch <id>`` to it.
Shapes (train_4k / prefill_32k / decode_32k / long_500k) are ``ShapeConfig``s
shared by all LM-family archs.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field, replace


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 0
    top_k: int = 0
    layer_period: int = 1     # MoE on layers where i % layer_period == period_offset
    period_offset: int = 0
    capacity_factor: float = 1.25


@dataclass(frozen=True)
class SSMConfig:
    """Mamba-2 (SSD) mixer configuration."""
    state: int = 128          # N: SSM state size per head
    head_dim: int = 64        # P: channels per SSD head
    expand: int = 2           # d_inner = expand * d_model
    conv_kernel: int = 4
    chunk: int = 256          # SSD chunk length
    n_groups: int = 1         # B/C groups (GVA)


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str               # dense | moe | hybrid | ssm | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int              # query heads (attention layers); 0 => attn-free
    n_kv_heads: int
    d_ff: int
    vocab_size: int

    head_dim: int = 0         # 0 => d_model // n_heads
    mlp_type: str = "swiglu"  # swiglu | geglu | gelu
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    norm_type: str = "rmsnorm"       # rmsnorm | layernorm
    tie_embeddings: bool = False

    moe: MoEConfig = field(default_factory=MoEConfig)
    ssm: SSMConfig | None = None

    # hybrid layer pattern: layer i is ATTENTION iff
    #   attn_layer_period == 1  or  i % attn_layer_period == attn_layer_offset
    # (pure-SSM models set attn_layer_period=0 => no attention layers at all)
    attn_layer_period: int = 1
    attn_layer_offset: int = 0

    # encoder-decoder (whisper)
    is_encoder_decoder: bool = False
    n_encoder_layers: int = 0
    encoder_seq: int = 0       # frames after the (stubbed) conv frontend

    # stub modality frontends: inputs carry precomputed embeddings
    frontend: str = "none"     # none | audio | vision
    n_patches: int = 0         # vision: patch embeddings prepended to the text sequence

    # numerics / runtime knobs (overridable per run)
    dtype: str = "bfloat16"
    remat_policy: str = "nothing_saveable"   # nothing_saveable | dots | none
    scan_layers: bool = True
    use_flash: str = "auto"    # auto | never  (never on CPU / dry-run)
    # causal blocked-attention schedule: "full" (rectangular, baseline) or
    # "tri" (triangular — skips fully-masked tiles, §Perf iteration 2)
    attn_schedule: str = "tri"    # confirmed §Perf iteration 2 (use "full" for baseline)
    # gradient-accumulation microbatches for the train step (§Perf lever)
    microbatches: int = 8         # fits-HBM default (§Perf iteration 4)
    # MoE dispatch locality: "shard" (per-data-shard, §Perf iteration 1) or
    # "global" (baseline: global argsort — forces token all-gather)
    moe_dispatch: str = "shard"
    # sequence-shard attention q-blocks over 'model' (for archs whose head
    # counts do not divide the model axis — §Perf iteration 3)
    attn_seq_shard: bool = False
    # sequence-parallel residual stream (perf lever, see EXPERIMENTS.md §Perf)
    seq_parallel: bool = False
    # ZeRO/FSDP: additionally shard params & opt state over the data axis
    fsdp: bool = False

    # -------------------------------------------------- derived helpers
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    def layer_is_attn(self, i: int) -> bool:
        if self.ssm is None:
            return True
        if self.attn_layer_period <= 0:
            return False
        if self.attn_layer_period == 1:
            return True
        return i % self.attn_layer_period == self.attn_layer_offset

    def layer_is_moe(self, i: int) -> bool:
        if self.moe.n_experts == 0:
            return False
        return i % self.moe.layer_period == self.moe.period_offset

    def attn_layer_ids(self) -> list[int]:
        return [i for i in range(self.n_layers) if self.layer_is_attn(i)]

    def supports_long_context(self) -> bool:
        """True iff attention cost per decoded token is sub-quadratic-friendly:
        pure SSM, or hybrid with a small fixed number of attention layers."""
        if self.is_encoder_decoder:
            return False
        if self.ssm is None:
            return False  # pure full attention
        return True       # ssm or hybrid

    def with_overrides(self, **kw) -> "ModelConfig":
        return replace(self, **kw)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                  # train | prefill | decode

    @property
    def is_training(self) -> bool:
        return self.kind == "train"


SHAPES: dict[str, ShapeConfig] = {
    "train_4k":    ShapeConfig("train_4k",    seq_len=4_096,   global_batch=256, kind="train"),
    "prefill_32k": ShapeConfig("prefill_32k", seq_len=32_768,  global_batch=32,  kind="prefill"),
    "decode_32k":  ShapeConfig("decode_32k",  seq_len=32_768,  global_batch=128, kind="decode"),
    "long_500k":   ShapeConfig("long_500k",   seq_len=524_288, global_batch=1,   kind="decode"),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """(applicable, reason-if-not). Mirrors DESIGN.md §5 skip rules."""
    if shape.name == "long_500k" and not cfg.supports_long_context():
        return False, "long_500k needs sub-quadratic attention; %s is pure full-attention" % cfg.name
    return True, ""


def smoke_reduce(cfg: ModelConfig) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests (small layers/width/vocab)."""
    kw: dict = dict(
        n_layers=min(cfg.n_layers, 4 if cfg.ssm is None else max(4, cfg.attn_layer_period)),
        d_model=128,
        d_ff=256,
        vocab_size=512,
        scan_layers=cfg.scan_layers,
        use_flash="never",
        dtype="float32",
    )
    if cfg.n_heads:
        kw["n_heads"] = 4
        kw["n_kv_heads"] = max(1, min(cfg.n_kv_heads, 2))
        kw["head_dim"] = 32
    if cfg.moe.n_experts:
        kw["moe"] = dataclasses.replace(cfg.moe, n_experts=4, top_k=min(cfg.moe.top_k, 2))
    if cfg.ssm is not None:
        kw["ssm"] = dataclasses.replace(cfg.ssm, state=16, head_dim=16, chunk=32)
    if cfg.is_encoder_decoder:
        kw["n_encoder_layers"] = 2
        kw["encoder_seq"] = 16
    if cfg.frontend == "vision":
        kw["n_patches"] = 8
    return cfg.with_overrides(**kw)
