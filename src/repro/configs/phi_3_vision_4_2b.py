"""phi-3-vision-4.2b [vlm] — phi3-mini backbone + CLIP (stub).
[hf:microsoft/Phi-3-vision-128k-instruct; hf]
32L d_model=3072 32H (GQA kv=32) d_ff=8192 vocab=32064.
The CLIP frontend is a STUB: ``input_specs()`` provides precomputed patch
embeddings (n_patches, d_model) prepended to the text sequence.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="phi-3-vision-4.2b",
    family="vlm",
    n_layers=32,
    d_model=3072,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab_size=32_064,
    frontend="vision",
    n_patches=576,            # one 24x24 CLIP-L/14 tile
)
