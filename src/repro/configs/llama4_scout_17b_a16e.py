"""llama4-scout-17b-a16e [moe] — MoE, early fusion.
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]
48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048, MoE 16e top-1.
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=202_048,
    rope_theta=500_000.0,
    moe=MoEConfig(n_experts=16, top_k=1, layer_period=1),
    fsdp=True,   # ~103B total params: FSDP over data axis required to fit
    microbatches=16,  # §Perf iteration 4: fits 16GB HBM/chip
)
