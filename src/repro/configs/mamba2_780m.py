"""mamba2-780m [ssm] — SSD (state-space duality). [arXiv:2405.21060; unverified]
48L d_model=1536 (attn-free) vocab=50280, ssm_state=128.
d_inner = 2*1536 = 3072; SSD head_dim 64 => 48 heads.
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-780m",
    family="ssm",
    n_layers=48,
    d_model=1536,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,                 # no MLP: mamba2 blocks are mixer-only
    vocab_size=50_280,
    ssm=SSMConfig(state=128, head_dim=64, expand=2, conv_kernel=4, chunk=256),
    attn_layer_period=0,    # attn-free
    tie_embeddings=True,
)
