from repro.configs.base import (
    ModelConfig,
    MoEConfig,
    SSMConfig,
    ShapeConfig,
    SHAPES,
    shape_applicable,
    smoke_reduce,
)
from repro.configs.registry import ARCH_IDS, get_config, get_shape, all_cells
