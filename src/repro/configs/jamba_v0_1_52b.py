"""jamba-v0.1-52b [hybrid] — Mamba+attn 1:7 interleave, MoE.
[arXiv:2403.19887; hf]
32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=65536, MoE 16e top-2.
Attention on every 8th layer (offset 3 within each 8-layer Jamba block,
per the paper's l=8, a=1 period with the attention layer mid-block);
MoE on every 2nd layer (e=2, offset 1).
"""
from repro.configs.base import ModelConfig, MoEConfig, SSMConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=65_536,
    ssm=SSMConfig(state=16, head_dim=64, expand=2, conv_kernel=4, chunk=256),
    attn_layer_period=8,
    attn_layer_offset=3,
    moe=MoEConfig(n_experts=16, top_k=2, layer_period=2, period_offset=1),
    fsdp=True,   # 52B total
    microbatches=16,  # fits-HBM (§Perf)
)
