"""--arch <id> registry mapping architecture ids to ModelConfigs."""

from __future__ import annotations

import importlib

from repro.configs.base import ModelConfig, ShapeConfig, SHAPES, shape_applicable, smoke_reduce

_ARCH_MODULES = {
    "llama4-scout-17b-a16e": "repro.configs.llama4_scout_17b_a16e",
    "moonshot-v1-16b-a3b":   "repro.configs.moonshot_v1_16b_a3b",
    "jamba-v0.1-52b":        "repro.configs.jamba_v0_1_52b",
    "gemma-7b":              "repro.configs.gemma_7b",
    "qwen2-1.5b":            "repro.configs.qwen2_1_5b",
    "internlm2-20b":         "repro.configs.internlm2_20b",
    "tinyllama-1.1b":        "repro.configs.tinyllama_1_1b",
    "mamba2-780m":           "repro.configs.mamba2_780m",
    "whisper-medium":        "repro.configs.whisper_medium",
    "phi-3-vision-4.2b":     "repro.configs.phi_3_vision_4_2b",
}

ARCH_IDS = tuple(_ARCH_MODULES)


def get_config(arch: str) -> ModelConfig:
    if arch not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_ARCH_MODULES)}")
    return importlib.import_module(_ARCH_MODULES[arch]).CONFIG


def get_shape(name: str) -> ShapeConfig:
    return SHAPES[name]


def all_cells(include_skipped: bool = True):
    """Yield (arch_id, shape_name, applicable, reason) for the 40-cell matrix."""
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for sname, shape in SHAPES.items():
            ok, reason = shape_applicable(cfg, shape)
            if ok or include_skipped:
                yield arch, sname, ok, reason
