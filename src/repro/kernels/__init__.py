# Pallas TPU kernels for the compute hot spots (DESIGN.md §3):
#   flash_attention — blocked online-softmax attention (GQA index maps)
#   ssd_scan        — Mamba-2 SSD chunk scan (state in VMEM scratch)
#   ep              — NPB EP Gaussian-pair acceptance + annuli histogram
#   is_hist         — NPB IS key histogram (one-hot lane reduction)
#   stencil3d       — 7-point stencil with shifted-index-map halos
#   kth_free        — scheduler placement: kth-smallest node-free time
#                     by 32-pass radix select (replaces per-step jnp.sort)
# Each subpackage: kernel.py (pl.pallas_call + BlockSpec), ops.py (jit'd
# dispatch: Mosaic on TPU, jnp twin elsewhere), ref.py (pure-jnp oracle).
