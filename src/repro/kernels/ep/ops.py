"""jit'd dispatch wrapper for the EP kernel."""

from __future__ import annotations

from functools import partial

import jax

from repro.kernels.ep.kernel import ep_pairs_pallas
from repro.kernels.ep.ref import ep_pairs_ref


@partial(jax.jit, static_argnames=("block_n", "force"))
def ep_pairs(u, *, block_n: int = 2048, force: str | None = None):
    mode = force or ("pallas" if jax.default_backend() == "tpu" else "jnp")
    if mode == "pallas":
        return ep_pairs_pallas(u, block_n=block_n, interpret=False)
    if mode == "pallas_interpret":
        return ep_pairs_pallas(u, block_n=block_n, interpret=True)
    return ep_pairs_ref(u)
