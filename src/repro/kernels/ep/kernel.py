"""NPB EP (embarrassingly parallel) Gaussian-pair kernel in Pallas.

The hot loop of EP: given uniform pairs (x, y) in (-1,1)^2, apply the
Marsaglia polar acceptance t = x^2+y^2 <= 1, form Gaussian deviates
X = x*sqrt(-2 ln t / t), Y likewise, and histogram max(|X|,|Y|) into 10
annuli, accumulating sums of X and Y.

TPU adaptation: the NPB LCG (a=5^13, 2^46 modulus) is inherently sequential
per stream — it stays outside the kernel (jax.random provides the uniform
blocks; repro.workloads.ep keeps an LCG-faithful mode for verification).
The kernel is the vectorizable hot loop, blocked so each grid step streams
one [2, block_n] uniform tile through VMEM; the 10-bin histogram and the
(sx, sy) sums accumulate in VMEM across the whole grid (all grid steps map
to the same output block).

Grid: (n // block_n,)
  u    : [2, n] uniforms in (-1, 1)      block (2, block_n)
  hist : [16]  (10 annuli, padded)       single block, accumulated
  sums : [2]   (sum X, sum Y)            single block, accumulated
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

N_ANNULI = 10
_PAD = 16   # lane-aligned histogram size


def _ep_kernel(u_ref, hist_ref, sums_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        hist_ref[...] = jnp.zeros_like(hist_ref)
        sums_ref[...] = jnp.zeros_like(sums_ref)

    x = u_ref[0, :]
    y = u_ref[1, :]
    t = x * x + y * y
    accept = (t <= 1.0) & (t > 0.0)
    t_safe = jnp.where(accept, t, 1.0)
    factor = jnp.sqrt(-2.0 * jnp.log(t_safe) / t_safe)
    gx = jnp.where(accept, x * factor, 0.0)
    gy = jnp.where(accept, y * factor, 0.0)

    amax = jnp.maximum(jnp.abs(gx), jnp.abs(gy))
    annulus = jnp.clip(amax.astype(jnp.int32), 0, N_ANNULI - 1)
    # one-hot reduce into the 10 annuli (masked to accepted pairs)
    bins = jax.lax.broadcasted_iota(jnp.int32, (_PAD, annulus.shape[0]), 0)
    onehot = (bins == annulus[None, :]) & accept[None, :]
    hist_ref[...] += onehot.astype(jnp.float32).sum(axis=1)
    sums_ref[...] += jnp.stack([gx.sum(), gy.sum()])


def ep_pairs_pallas(u, *, block_n: int = 2048, interpret: bool = True):
    """u: [2, n] uniforms in (-1, 1). Returns (hist [10] f32, sums [2] f32)."""
    _, n = u.shape
    block_n = min(block_n, n)
    assert n % block_n == 0
    grid = (n // block_n,)
    hist, sums = pl.pallas_call(
        _ep_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((2, block_n), lambda i: (0, i))],
        out_specs=[pl.BlockSpec((_PAD,), lambda i: (0,)),
                   pl.BlockSpec((2,), lambda i: (0,))],
        out_shape=[jax.ShapeDtypeStruct((_PAD,), jnp.float32),
                   jax.ShapeDtypeStruct((2,), jnp.float32)],
        interpret=interpret,
    )(u)
    return hist[:N_ANNULI], sums
