from repro.kernels.ep.ops import ep_pairs
from repro.kernels.ep.kernel import ep_pairs_pallas, N_ANNULI
from repro.kernels.ep.ref import ep_pairs_ref
