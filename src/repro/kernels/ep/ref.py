"""Pure-jnp oracle for the EP Gaussian-pair kernel."""

import jax.numpy as jnp

from repro.kernels.ep.kernel import N_ANNULI


def ep_pairs_ref(u):
    """u: [2, n] uniforms in (-1,1). Returns (hist [10], sums [2])."""
    x, y = u[0], u[1]
    t = x * x + y * y
    accept = (t <= 1.0) & (t > 0.0)
    t_safe = jnp.where(accept, t, 1.0)
    factor = jnp.sqrt(-2.0 * jnp.log(t_safe) / t_safe)
    gx = jnp.where(accept, x * factor, 0.0)
    gy = jnp.where(accept, y * factor, 0.0)
    amax = jnp.maximum(jnp.abs(gx), jnp.abs(gy))
    annulus = jnp.clip(amax.astype(jnp.int32), 0, N_ANNULI - 1)
    hist = jnp.zeros((N_ANNULI,), jnp.float32).at[annulus].add(
        accept.astype(jnp.float32))
    return hist, jnp.stack([gx.sum(), gy.sum()])
