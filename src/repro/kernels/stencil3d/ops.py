"""jit'd dispatch wrapper for the stencil kernel."""

from __future__ import annotations

from functools import partial

import jax

from repro.kernels.stencil3d.kernel import stencil7_pallas
from repro.kernels.stencil3d.ref import stencil7_ref


@partial(jax.jit, static_argnames=("coef_c", "coef_n", "bx", "force"))
def stencil7(u, *, coef_c: float = -6.0, coef_n: float = 1.0, bx: int = 16,
             force: str | None = None):
    mode = force or ("pallas" if jax.default_backend() == "tpu" else "jnp")
    if mode == "pallas":
        return stencil7_pallas(u, coef_c=coef_c, coef_n=coef_n, bx=bx,
                               interpret=False)
    if mode == "pallas_interpret":
        return stencil7_pallas(u, coef_c=coef_c, coef_n=coef_n, bx=bx,
                               interpret=True)
    return stencil7_ref(u, coef_c=coef_c, coef_n=coef_n)
