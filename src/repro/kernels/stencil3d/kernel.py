"""7-point 3D stencil sweep in Pallas (the BT/SP/LU rhs compute core).

The dominant compute of the NPB CFD pseudo-apps (BT/SP/LU) is repeated
nearest-neighbour stencil evaluation over a 3D grid.  TPU adaptation: the
grid is blocked along x; each grid step holds a [bx, ny, nz] tile in VMEM
plus its two x-neighbour tiles, obtained by passing the SAME input array
with shifted BlockSpec index maps (i-1, i, i+1) — the Pallas analogue of a
halo exchange, with no HBM duplication.  y/z neighbours are in-tile shifts.
Dirichlet boundaries (zero) are enforced with iota masks at the global
edges.

Grid: (nx // bx,)
  u      : [nx, ny, nz] f32   three views: left (i-1), center (i), right (i+1)
  out    : [nx, ny, nz] f32   block (bx, ny, nz) at i
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _stencil_kernel(left_ref, c_ref, right_ref, o_ref, *,
                    coef_c: float, coef_n: float, bx: int):
    i = pl.program_id(0)
    n_i = pl.num_programs(0)
    c = c_ref[...]                                    # [bx, ny, nz]
    _, ny, nz = c.shape

    # x-neighbours via the halo views (left/right blocks are clamped at the
    # global edges; mask those contributions to zero = Dirichlet boundary)
    up = jnp.concatenate([left_ref[bx - 1:bx], c[:-1]], axis=0)
    dn = jnp.concatenate([c[1:], right_ref[0:1]], axis=0)
    row = jax.lax.broadcasted_iota(jnp.int32, c.shape, 0)
    gx = i * bx + row
    up = jnp.where(gx == 0, 0.0, up)
    dn = jnp.where(gx == (n_i * bx - 1), 0.0, dn)

    # y/z neighbours: in-tile shifts with zero boundaries
    yp = jnp.pad(c[:, 1:, :], ((0, 0), (0, 1), (0, 0)))
    ym = jnp.pad(c[:, :-1, :], ((0, 0), (1, 0), (0, 0)))
    zp = jnp.pad(c[:, :, 1:], ((0, 0), (0, 0), (0, 1)))
    zm = jnp.pad(c[:, :, :-1], ((0, 0), (0, 0), (1, 0)))

    o_ref[...] = coef_c * c + coef_n * (up + dn + yp + ym + zp + zm)


def stencil7_pallas(u, *, coef_c: float = -6.0, coef_n: float = 1.0,
                    bx: int = 16, interpret: bool = True):
    """u: [nx, ny, nz] f32. Returns the 7-point stencil applied to u."""
    nx, ny, nz = u.shape
    bx = min(bx, nx)
    assert nx % bx == 0
    n_i = nx // bx
    return pl.pallas_call(
        functools.partial(_stencil_kernel, coef_c=coef_c, coef_n=coef_n, bx=bx),
        grid=(n_i,),
        in_specs=[
            pl.BlockSpec((bx, ny, nz), lambda i: (jnp.maximum(i - 1, 0), 0, 0)),
            pl.BlockSpec((bx, ny, nz), lambda i: (i, 0, 0)),
            pl.BlockSpec((bx, ny, nz),
                         lambda i: (jnp.minimum(i + 1, n_i - 1), 0, 0)),
        ],
        out_specs=pl.BlockSpec((bx, ny, nz), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((nx, ny, nz), u.dtype),
        interpret=interpret,
    )(u, u, u)
