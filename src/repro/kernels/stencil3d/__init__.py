from repro.kernels.stencil3d.ops import stencil7
from repro.kernels.stencil3d.kernel import stencil7_pallas
from repro.kernels.stencil3d.ref import stencil7_ref
