"""Pure-jnp oracle for the 7-point stencil kernel."""

import jax.numpy as jnp


def stencil7_ref(u, *, coef_c: float = -6.0, coef_n: float = 1.0):
    pad = lambda x: x  # Dirichlet-zero boundaries via jnp.pad shifts
    up = jnp.pad(u[:-1], ((1, 0), (0, 0), (0, 0)))
    dn = jnp.pad(u[1:], ((0, 1), (0, 0), (0, 0)))
    yp = jnp.pad(u[:, 1:, :], ((0, 0), (0, 1), (0, 0)))
    ym = jnp.pad(u[:, :-1, :], ((0, 0), (1, 0), (0, 0)))
    zp = jnp.pad(u[:, :, 1:], ((0, 0), (0, 0), (0, 1)))
    zm = jnp.pad(u[:, :, :-1], ((0, 0), (0, 0), (1, 0)))
    return coef_c * u + coef_n * (up + dn + yp + ym + zp + zm)
