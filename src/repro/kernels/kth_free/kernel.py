"""Kth-free-time radix-select kernel in Pallas.

The scheduler's inner loop asks, for every system s, for the time at which
n_req[s] nodes are simultaneously free: the n_req[s]-th smallest entry of
the node-free row.  A full ``jnp.sort`` per simulation step is
O(S·maxN·log maxN) and serializes badly; instead we radix-select the kth
smallest directly: map f32 free-times to order-preserving uint32 keys and
walk the 32 bits MSB->LSB, at each bit counting candidates whose bit is 0
and descending into the half that contains rank k.  32 counting passes over
the [S, maxN] tile — O(S·maxN) work, fully vectorized over both axes (VPU
lanes hold nodes, sublanes hold systems), and bit-exact against the sort
reference because the selected value is an element of the input, not an
approximation.

Single-block kernel (no grid): the node matrix of any realistic SCC fits
VMEM many times over ([S, maxN] is a few KB); the win is replacing the sort
network with 32 compare-and-count sweeps.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _f32_to_ordered_u32(x):
    """Order-preserving bijection f32 -> uint32 (IEEE-754 trick: flip sign
    bit for positives, flip all bits for negatives)."""
    b = jax.lax.bitcast_convert_type(x, jnp.uint32)
    sign = (b >> 31).astype(jnp.bool_)
    return jnp.where(sign, ~b, b | jnp.uint32(0x80000000))


def _ordered_u32_to_f32(u):
    sign = (u >> 31).astype(jnp.bool_)
    b = jnp.where(sign, u & jnp.uint32(0x7FFFFFFF), ~u)
    return jax.lax.bitcast_convert_type(b, jnp.float32)


def radix_select_kth(node_free, n_req):
    """Pure-jnp radix select (the kernel's algorithm, usable on any backend
    and inside scan/vmap).  node_free: [S, maxN] f32; n_req: [S] int.
    Returns [S] f32: the n_req-th smallest per row (1-indexed, clipped)."""
    S, N = node_free.shape
    u = _f32_to_ordered_u32(node_free)                      # [S, N]
    k0 = jnp.clip(n_req, 1, N).astype(jnp.int32)            # [S]

    def bit_step(i, carry):
        active, k, val = carry
        shift = jnp.uint32(31) - i.astype(jnp.uint32)
        bit = ((u >> shift) & jnp.uint32(1)).astype(jnp.int32)   # [S, N]
        zeros = jnp.sum(active * (1 - bit), axis=1)              # [S]
        go_one = k > zeros                                       # [S]
        val = val | jnp.where(go_one, jnp.uint32(1) << shift, jnp.uint32(0))
        keep_bit = go_one.astype(jnp.int32)[:, None]             # [S, 1]
        active = active * (bit == keep_bit).astype(jnp.int32)
        k = jnp.where(go_one, k - zeros, k)
        return active, k, val

    active0 = jnp.ones((S, N), jnp.int32)
    val0 = jnp.zeros((S,), jnp.uint32)
    _, _, val = jax.lax.fori_loop(0, 32, bit_step, (active0, k0, val0))
    return _ordered_u32_to_f32(val)


def radix_select_kth_batched(node_free, n_req):
    """Batched radix select over a leading candidate axis (the EASY
    window's W tentative allocations per step are independent, so one
    vectorized call replaces W sequential selects).  node_free:
    [W, S, maxN] f32; n_req: [W, S] int.  Returns [W, S] f32, bit-exact
    per slice against ``radix_select_kth`` (the bit walk is integer
    counting — vmap only adds a leading axis to the counts)."""
    return jax.vmap(radix_select_kth)(node_free, n_req)


def _kth_free_kernel(free_ref, nreq_ref, out_ref):
    out_ref[...] = radix_select_kth(free_ref[...], nreq_ref[...][:, 0])


def kth_free_pallas(node_free, n_req, *, interpret: bool = True):
    """node_free: [S, maxN] f32; n_req: [S] int32.  Returns [S] f32."""
    S, _ = node_free.shape
    return pl.pallas_call(
        _kth_free_kernel,
        in_specs=[pl.BlockSpec(node_free.shape, lambda: (0, 0)),
                  pl.BlockSpec((S, 1), lambda: (0, 0))],
        out_specs=pl.BlockSpec((S,), lambda: (0,)),
        out_shape=jax.ShapeDtypeStruct((S,), jnp.float32),
        interpret=interpret,
    )(node_free.astype(jnp.float32), n_req.astype(jnp.int32)[:, None])


def _kth_free_kernel_batched(free_ref, nreq_ref, out_ref):
    out_ref[...] = radix_select_kth(free_ref[0], nreq_ref[0, :, 0])[None]


def kth_free_pallas_batched(node_free, n_req, *, interpret: bool = True):
    """Pallas twin of ``radix_select_kth_batched``: the grid runs one
    program instance per candidate, each radix-selecting its own [S, maxN]
    block.  node_free: [W, S, maxN] f32; n_req: [W, S] int32.  Returns
    [W, S] f32."""
    W, S, N = node_free.shape
    return pl.pallas_call(
        _kth_free_kernel_batched,
        grid=(W,),
        in_specs=[pl.BlockSpec((1, S, N), lambda w: (w, 0, 0)),
                  pl.BlockSpec((1, S, 1), lambda w: (w, 0, 0))],
        out_specs=pl.BlockSpec((1, S), lambda w: (w, 0)),
        out_shape=jax.ShapeDtypeStruct((W, S), jnp.float32),
        interpret=interpret,
    )(node_free.astype(jnp.float32), n_req.astype(jnp.int32)[..., None])
