"""Dispatch wrapper for the kth-free-time placement kernel.

Modes (``force``):
  pallas            — compiled Pallas kernel (TPU)
  pallas_interpret  — Pallas interpreter (any backend; tests)
  jnp               — pure-jnp radix select (same algorithm, scan/vmap safe)
  sort              — jnp.sort reference oracle

Default: Pallas on TPU, radix-select jnp elsewhere.  All four agree
bit-exactly (the selected value is an element of the input).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.kth_free.kernel import (kth_free_pallas,
                                           kth_free_pallas_batched,
                                           radix_select_kth,
                                           radix_select_kth_batched)
from repro.kernels.kth_free.ref import kth_free_batched_ref, kth_free_ref


@partial(jax.jit, static_argnames=("force",))
def kth_free_time(node_free, n_req, *, force: str | None = None):
    """node_free: [S, maxN] f32 per-node free times; n_req: [S] int.
    Returns [S] f32: earliest time n_req[s] nodes of system s are free."""
    mode = force or ("pallas" if jax.default_backend() == "tpu" else "jnp")
    if mode == "pallas":
        return kth_free_pallas(node_free, n_req, interpret=False)
    if mode == "pallas_interpret":
        return kth_free_pallas(node_free, n_req, interpret=True)
    if mode == "jnp":
        return radix_select_kth(node_free, n_req)
    if mode == "sort":
        return kth_free_ref(node_free, n_req)
    raise ValueError(f"unknown kth_free mode {mode!r}")


@partial(jax.jit, static_argnames=("force",))
def kth_free_time_shared(node_free, n_req, *, force: str | None = None):
    """Many requests against ONE node-free table: node_free [S, maxN] f32,
    n_req [W, S] int -> [W, S] f32 (per candidate w and system s, the
    n_req[w, s]-th smallest entry of row s).

    With a shared table the W order statistics per row share one sort —
    O(S·maxN·log maxN) total versus W independent O(S·maxN) radix walks —
    which wins for any W > a few, so the auto mode is the sort path on
    every backend (the selected values are input elements either way, so
    all modes stay bit-exact).  ``force`` keeps the radix / Pallas twins
    reachable (they broadcast the table into the batched entry point) for
    differential coverage."""
    if (force or "sort") == "sort":
        srt = jnp.sort(node_free, axis=-1)                       # [S, maxN]
        idx = jnp.clip(n_req - 1, 0, node_free.shape[-1] - 1)    # [W, S]
        return srt[jnp.arange(node_free.shape[0])[None, :], idx]
    free_b = jnp.broadcast_to(node_free, n_req.shape[:1] + node_free.shape)
    return kth_free_time_batched(free_b, n_req, force=force)


@partial(jax.jit, static_argnames=("force",))
def kth_free_time_rows(node_free, sels, n_req, *, force: str | None = None):
    """Reservation-table recheck for conservative backfilling: every
    pending reservation against ONE node-free table, in one call.

    node_free: [S, maxN] f32; sels: [W] int — each pending slot's RESERVED
    system; n_req: [W] int — nodes the slot needs there.  Returns [W] f32
    where ``out[e]`` is the earliest time reservation e's
    ``(sels[e], n_req[e])`` is satisfiable under the table — i.e. the
    n_req[e]-th smallest entry of row ``sels[e]``.

    The event core's conservative step compares ``out[e] <= r_e`` (the
    start each reservation was promised at admission) to decide which
    reservations are realizable at the current event.  Reserved systems
    repeat across the window (W slots draw from S << W systems), so the
    auto mode sorts the table ONCE and gathers every (slot, kth) pair
    from it — the PR 4 shared-sort trick; ``force`` routes through the
    per-row radix/Pallas twins on the gathered [W, maxN] row stack for
    differential coverage.  Every mode returns input elements, so all
    stay bit-exact."""
    if (force or "sort") == "sort":
        srt = jnp.sort(node_free, axis=-1)                       # [S, maxN]
        idx = jnp.clip(n_req - 1, 0, node_free.shape[-1] - 1)    # [W]
        return srt[sels, idx]
    rows = node_free[sels]                                       # [W, maxN]
    return kth_free_time(rows, n_req, force=force)


@partial(jax.jit, static_argnames=("force",))
def kth_free_time_batched(node_free, n_req, *, force: str | None = None):
    """Batched twin of ``kth_free_time`` over a leading candidate axis.
    node_free: [W, S, maxN] f32 (one node-free table per candidate —
    broadcast a shared table for same-state candidate scoring, or stack W
    tentative allocations for the EASY head recheck); n_req: [W, S] int.
    Returns [W, S] f32.  Same dispatch modes, bit-exact per slice against
    the unbatched entry point."""
    mode = force or ("pallas" if jax.default_backend() == "tpu" else "jnp")
    if mode == "pallas":
        return kth_free_pallas_batched(node_free, n_req, interpret=False)
    if mode == "pallas_interpret":
        return kth_free_pallas_batched(node_free, n_req, interpret=True)
    if mode == "jnp":
        return radix_select_kth_batched(node_free, n_req)
    if mode == "sort":
        return kth_free_batched_ref(node_free, n_req)
    raise ValueError(f"unknown kth_free mode {mode!r}")
