"""Dispatch wrapper for the kth-free-time placement kernel.

Modes (``force``):
  pallas            — compiled Pallas kernel (TPU)
  pallas_interpret  — Pallas interpreter (any backend; tests)
  jnp               — pure-jnp radix select (same algorithm, scan/vmap safe)
  sort              — jnp.sort reference oracle

Default: Pallas on TPU, radix-select jnp elsewhere.  All four agree
bit-exactly (the selected value is an element of the input).
"""

from __future__ import annotations

from functools import partial

import jax

from repro.kernels.kth_free.kernel import kth_free_pallas, radix_select_kth
from repro.kernels.kth_free.ref import kth_free_ref


@partial(jax.jit, static_argnames=("force",))
def kth_free_time(node_free, n_req, *, force: str | None = None):
    """node_free: [S, maxN] f32 per-node free times; n_req: [S] int.
    Returns [S] f32: earliest time n_req[s] nodes of system s are free."""
    mode = force or ("pallas" if jax.default_backend() == "tpu" else "jnp")
    if mode == "pallas":
        return kth_free_pallas(node_free, n_req, interpret=False)
    if mode == "pallas_interpret":
        return kth_free_pallas(node_free, n_req, interpret=True)
    if mode == "jnp":
        return radix_select_kth(node_free, n_req)
    if mode == "sort":
        return kth_free_ref(node_free, n_req)
    raise ValueError(f"unknown kth_free mode {mode!r}")
