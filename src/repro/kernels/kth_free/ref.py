"""Full-sort oracle for the kth-free-time kernel.

The simulator's placement question per step: "when are the n_req[s]
earliest-free nodes of system s all free?" — i.e. the n_req[s]-th smallest
entry of the node-free row.  The reference answers it the obvious way
(sort every row, gather the kth column); the kernel answers it without
sorting.  The two must agree bit-exactly.
"""

import jax
import jax.numpy as jnp


def kth_free_ref(node_free, n_req):
    """node_free: [S, maxN] f32; n_req: [S] int (1-indexed count).
    Returns [S] f32: per row, the n_req-th smallest value."""
    sorted_free = jnp.sort(node_free, axis=1)
    idx = jnp.clip(n_req - 1, 0, node_free.shape[1] - 1)
    return jnp.take_along_axis(sorted_free, idx[:, None], axis=1)[:, 0]


def kth_free_batched_ref(node_free, n_req):
    """Vmapped sort oracle for the batched entry point.  node_free:
    [W, S, maxN] f32 (one node-free table per candidate); n_req: [W, S]
    int.  Returns [W, S] f32."""
    return jax.vmap(kth_free_ref)(node_free, n_req)
