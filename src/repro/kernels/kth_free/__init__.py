from repro.kernels.kth_free.ops import kth_free_time
from repro.kernels.kth_free.kernel import kth_free_pallas, radix_select_kth
from repro.kernels.kth_free.ref import kth_free_ref
