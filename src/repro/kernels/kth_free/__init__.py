from repro.kernels.kth_free.ops import (kth_free_time, kth_free_time_batched,
                                        kth_free_time_rows,
                                        kth_free_time_shared)
from repro.kernels.kth_free.kernel import (kth_free_pallas,
                                           kth_free_pallas_batched,
                                           radix_select_kth,
                                           radix_select_kth_batched)
from repro.kernels.kth_free.ref import kth_free_batched_ref, kth_free_ref
