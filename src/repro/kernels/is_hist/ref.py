"""Pure-jnp oracle for the IS key-histogram kernel."""

import jax.numpy as jnp


def key_histogram_ref(keys, *, n_buckets: int, bucket_shift: int):
    bucket = (keys >> bucket_shift).astype(jnp.int32)
    return jnp.zeros((n_buckets,), jnp.float32).at[bucket].add(1.0)
