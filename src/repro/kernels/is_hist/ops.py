"""jit'd dispatch wrapper for the IS histogram kernel."""

from __future__ import annotations

from functools import partial

import jax

from repro.kernels.is_hist.kernel import key_histogram_pallas
from repro.kernels.is_hist.ref import key_histogram_ref


@partial(jax.jit, static_argnames=("n_buckets", "bucket_shift", "block_n", "force"))
def key_histogram(keys, *, n_buckets: int, bucket_shift: int = 0,
                  block_n: int = 4096, force: str | None = None):
    mode = force or ("pallas" if jax.default_backend() == "tpu" else "jnp")
    if mode == "pallas":
        return key_histogram_pallas(keys, n_buckets=n_buckets,
                                    bucket_shift=bucket_shift,
                                    block_n=block_n, interpret=False)
    if mode == "pallas_interpret":
        return key_histogram_pallas(keys, n_buckets=n_buckets,
                                    bucket_shift=bucket_shift,
                                    block_n=block_n, interpret=True)
    return key_histogram_ref(keys, n_buckets=n_buckets, bucket_shift=bucket_shift)
