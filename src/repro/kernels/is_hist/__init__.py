from repro.kernels.is_hist.ops import key_histogram
from repro.kernels.is_hist.kernel import key_histogram_pallas
from repro.kernels.is_hist.ref import key_histogram_ref
