"""NPB IS key-histogram kernel in Pallas.

IS (integer sort) ranks keys by bucket counting; the hot loop is the key
histogram.  TPU adaptation: scatter-add is not a natural TPU primitive —
instead each grid step loads a [block_n] key tile into VMEM and reduces a
one-hot [n_buckets, block_n] comparison matrix over lanes (VPU-friendly),
accumulating the bucket counts in VMEM across the grid.

Grid: (n // block_n,)
  keys : [n] int32                        block (block_n,)
  hist : [n_buckets] f32 (accumulated)    single block
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _hist_kernel(keys_ref, hist_ref, *, n_buckets: int, bucket_shift: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        hist_ref[...] = jnp.zeros_like(hist_ref)

    keys = keys_ref[...]
    bucket = (keys >> bucket_shift).astype(jnp.int32)
    bins = jax.lax.broadcasted_iota(jnp.int32, (n_buckets, keys.shape[0]), 0)
    onehot = (bins == bucket[None, :])
    hist_ref[...] += onehot.astype(jnp.float32).sum(axis=1)


def key_histogram_pallas(keys, *, n_buckets: int, bucket_shift: int,
                         block_n: int = 4096, interpret: bool = True):
    """keys: [n] int32 in [0, n_buckets << bucket_shift).
    Returns bucket counts [n_buckets] f32."""
    n = keys.shape[0]
    block_n = min(block_n, n)
    assert n % block_n == 0
    return pl.pallas_call(
        functools.partial(_hist_kernel, n_buckets=n_buckets,
                          bucket_shift=bucket_shift),
        grid=(n // block_n,),
        in_specs=[pl.BlockSpec((block_n,), lambda i: (i,))],
        out_specs=pl.BlockSpec((n_buckets,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((n_buckets,), jnp.float32),
        interpret=interpret,
    )(keys)
