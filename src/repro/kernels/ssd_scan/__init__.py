from repro.kernels.ssd_scan.ops import ssd_scan
from repro.kernels.ssd_scan.kernel import ssd_scan_pallas
from repro.kernels.ssd_scan.ref import ssd_scan_ref
