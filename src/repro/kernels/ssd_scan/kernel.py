"""Mamba-2 SSD chunked scan as a Pallas TPU kernel.

The SSD hot loop (arXiv:2405.21060 §6): per chunk, a quadratic intra-chunk
term plus a recurrent inter-chunk state update.  TPU adaptation: the grid is
(batch*heads, n_chunks) with the chunk axis innermost and the running state
[p, n] living in VMEM scratch — it persists across the chunk grid dimension
(same revisiting idiom as flash attention's (m, l, acc)), so the sequential
recurrence never round-trips HBM.  The [Q, Q] decay/score tile stays in
VMEM; per grid step the kernel streams one [Q, p] x-tile and one [Q, n]
B/C-tile.  B/C are per-group (GVA): the index map points each head at its
group — no replication in HBM.

Grid: (b*h, l // Q)
  x    : [b*h, l, p]    block (1, Q, p)
  dt   : [b*h, l]       block (1, Q)      (already softplus'd)
  dA   : [b*h, l]       block (1, Q)      (dt * A[head], A negative)
  B, C : [b*g, l, n]    block (1, Q, n)   (g groups; head -> group map)
  y    : [b*h, l, p]    block (1, Q, p)
  state: [b*h, p, n]    block (1, p, n)   written at the last chunk
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, dA_ref, b_ref, c_ref, y_ref, st_out_ref,
                state_ref, *, chunk: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0].astype(jnp.float32)          # [Q, p]
    dt = dt_ref[0].astype(jnp.float32)        # [Q]
    dA = dA_ref[0].astype(jnp.float32)        # [Q]
    B = b_ref[0].astype(jnp.float32)          # [Q, n]
    C = c_ref[0].astype(jnp.float32)          # [Q, n]

    cum = jnp.cumsum(dA)                      # [Q]
    # intra-chunk: y_diag[i] = sum_{j<=i} exp(cum_i - cum_j) dt_j (C_i.B_j) x_j
    diff = cum[:, None] - cum[None, :]
    row = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    L = jnp.where(row >= col, jnp.exp(diff), 0.0)
    S = jax.lax.dot_general(C, B, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)   # [Q, Q]
    M = S * L * dt[None, :]
    y = jax.lax.dot_general(M, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)   # [Q, p]

    # inter-chunk: y_off[i] = exp(cum_i) * C_i . state^T        [Q, p]
    state = state_ref[...]                                        # [p, n]
    y_off = jax.lax.dot_general(C, state, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
    y = y + y_off * jnp.exp(cum)[:, None]
    y_ref[0, ...] = y.astype(y_ref.dtype)

    # state update: state' = state * exp(cum[-1]) + x^T (B * w[:, None])
    w = jnp.exp(cum[-1] - cum) * dt                               # [Q]
    upd = jax.lax.dot_general(x, B * w[:, None], (((0,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)  # [p, n]
    state_ref[...] = state * jnp.exp(cum[-1]) + upd

    @pl.when(ci == pl.num_programs(1) - 1)
    def _finish():
        st_out_ref[0, ...] = state_ref[...]


def ssd_scan_pallas(x, dt, dA, B, C, *, chunk: int = 256,
                    interpret: bool = True):
    """x: [bh, l, p]; dt/dA: [bh, l]; B, C: [bg, l, n] with bh = bg * rep
    (heads grouped GVA-style).  Returns (y [bh, l, p] f32, state [bh, p, n])."""
    bh, l, p = x.shape
    bg, _, n = B.shape
    rep = bh // bg
    chunk = min(chunk, l)
    assert l % chunk == 0
    nc = l // chunk

    def xm(i, c):
        return (i, c, 0)

    def dm(i, c):
        return (i, c)

    y, state = pl.pallas_call(
        functools.partial(_ssd_kernel, chunk=chunk),
        grid=(bh, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, p), xm),
            pl.BlockSpec((1, chunk), dm),
            pl.BlockSpec((1, chunk), dm),
            pl.BlockSpec((1, chunk, n), lambda i, c: (i // rep, c, 0)),
            pl.BlockSpec((1, chunk, n), lambda i, c: (i // rep, c, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, p), xm),
            pl.BlockSpec((1, p, n), lambda i, c: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, l, p), jnp.float32),
            jax.ShapeDtypeStruct((bh, p, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
    )(x, dt, dA, B, C)
    return y, state
