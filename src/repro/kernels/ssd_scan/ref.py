"""Pure-jnp oracle for the SSD chunk-scan kernel (flat [bh, l, ...] layout,
delegating to the model's chunked implementation)."""

import jax.numpy as jnp

from repro.models.mamba import ssd_chunked


def ssd_scan_ref(x, dt, dA, B, C, *, chunk: int = 256):
    """x: [bh, l, p]; dt/dA: [bh, l]; B, C: [bg, l, n], bh = bg * rep.
    Returns (y [bh, l, p] f32, state [bh, p, n] f32)."""
    bh, l, p = x.shape
    bg, _, n = B.shape
    rep = bh // bg
    # reshape to the model layout [b=bg, l, h=rep, p] with per-head A folded
    xm = x.reshape(bg, rep, l, p).transpose(0, 2, 1, 3)
    dtm = dt.reshape(bg, rep, l).transpose(0, 2, 1)
    # ssd_chunked takes A[h] and dt separately with dA = dt*A; recover A-like
    # behaviour by passing dt'=dt and A'=dA/dt elementwise via a wrapper:
    # simplest exact route: call with dt=dA/A ... instead we inline the same
    # math using dA directly (copy of ssd_chunked with dA input).
    y, st = _ssd_chunked_dA(xm, dtm,
                            dA.reshape(bg, rep, l).transpose(0, 2, 1),
                            B.reshape(bg, 1, l, n).transpose(0, 2, 1, 3),
                            C.reshape(bg, 1, l, n).transpose(0, 2, 1, 3),
                            chunk)
    return (y.transpose(0, 2, 1, 3).reshape(bh, l, p),
            st.reshape(bh, p, n))


def _ssd_chunked_dA(x, dt, dA, B, C, chunk):
    """ssd_chunked with dA supplied directly (instead of dt*A[h])."""
    import jax
    b, l, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    nc, q = l // chunk, chunk
    rep = h // g
    xf = x.astype(jnp.float32).reshape(b, nc, q, h, p)
    dtf = dt.astype(jnp.float32).reshape(b, nc, q, h)
    dAf = dA.astype(jnp.float32).reshape(b, nc, q, h)
    Bf = B.astype(jnp.float32).reshape(b, nc, q, g, n)
    Cf = C.astype(jnp.float32).reshape(b, nc, q, g, n)
    cum = jnp.cumsum(dAf, axis=2)
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]
    tri = jnp.tril(jnp.ones((q, q), bool))
    L = jnp.where(tri[None, None, :, :, None], jnp.exp(diff), 0.0)
    S = jnp.einsum("bcign,bcjgn->bcijg", Cf, Bf)
    S = jnp.repeat(S, rep, axis=-1)
    y_diag = jnp.einsum("bcijh,bcjhp->bcihp", S * L * dtf[:, :, None], xf)
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)
    Bh = jnp.repeat(Bf, rep, axis=3)
    states = jnp.einsum("bcqh,bcqhn,bcqhp->bchpn", decay_to_end * dtf, Bh, xf)
    chunk_decay = jnp.exp(cum[:, :, -1, :])

    def step(prev, inp):
        dec, st = inp
        return prev * dec[:, :, None, None] + st, prev

    init = jnp.zeros((b, h, p, n), jnp.float32)
    final, prevs = jax.lax.scan(step, init,
                                (jnp.moveaxis(chunk_decay, 1, 0),
                                 jnp.moveaxis(states, 1, 0)))
    prevs = jnp.moveaxis(prevs, 0, 1)
    Ch = jnp.repeat(Cf, rep, axis=3)
    y_off = jnp.einsum("bcqhn,bchpn->bcqhp", Ch, prevs) * jnp.exp(cum)[..., None]
    return (y_diag + y_off).reshape(b, l, h, p), final
