"""jit'd dispatch wrapper for the SSD chunk-scan kernel."""

from __future__ import annotations

from functools import partial

import jax

from repro.kernels.ssd_scan.kernel import ssd_scan_pallas
from repro.kernels.ssd_scan.ref import ssd_scan_ref


@partial(jax.jit, static_argnames=("chunk", "force"))
def ssd_scan(x, dt, dA, B, C, *, chunk: int = 256, force: str | None = None):
    mode = force or ("pallas" if jax.default_backend() == "tpu" else "jnp")
    if mode == "pallas":
        return ssd_scan_pallas(x, dt, dA, B, C, chunk=chunk, interpret=False)
    if mode == "pallas_interpret":
        return ssd_scan_pallas(x, dt, dA, B, C, chunk=chunk, interpret=True)
    return ssd_scan_ref(x, dt, dA, B, C, chunk=chunk)
