"""jit'd dispatch wrapper for flash attention.

On TPU backends the Pallas/Mosaic kernel is used; elsewhere (this CPU
container, and the 512-host-device dry-run) the numerically-identical
blocked-jnp flash implementation from repro.models.attention is used —
same FLOPs, same memory behaviour class, so roofline terms are unaffected
(DESIGN.md §3).
"""

from __future__ import annotations

from functools import partial

import jax

from repro.kernels.flash_attention.kernel import flash_attention_bhsd
from repro.models.attention import blocked_attention


def _on_tpu() -> bool:
    try:
        return jax.default_backend() == "tpu"
    except Exception:
        return False


@partial(jax.jit, static_argnames=("causal", "block_q", "block_k", "force"))
def flash_attention(q, k, v, *, causal: bool = True, block_q: int = 128,
                    block_k: int = 128, force: str | None = None):
    """force: None (auto) | 'pallas' | 'pallas_interpret' | 'jnp'."""
    mode = force or ("pallas" if _on_tpu() else "jnp")
    if mode == "pallas":
        return flash_attention_bhsd(q, k, v, causal=causal, block_q=block_q,
                                    block_k=block_k, interpret=False)
    if mode == "pallas_interpret":
        return flash_attention_bhsd(q, k, v, causal=causal, block_q=block_q,
                                    block_k=block_k, interpret=True)
    return blocked_attention(q, k, v, causal=causal, block_q=block_q,
                             block_k=block_k)
