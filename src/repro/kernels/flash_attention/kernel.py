"""Flash attention as a Pallas TPU kernel (BlockSpec VMEM tiling).

TPU adaptation (DESIGN.md §3): block sizes are MXU/VREG aligned (multiples
of 128 on the contracting/lane dims); the online-softmax running state
(m, l, acc) lives in VMEM scratch across the k-grid dimension; the kv grid
axis is innermost so k/v blocks stream through VMEM while the q block stays
resident.  GQA is handled by an index map that points each query head at
its kv group — no kv replication in HBM.

Grid: (batch*heads, n_q_blocks, n_k_blocks)   [k innermost]
  q   : [b*h,  sq, hd]   block (1, bq, hd) at (bh, iq)
  k/v : [b*kv, sk, hd]   block (1, bk, hd) at (group(bh), ik)
  out : [b*h,  sq, hd]   block (1, bq, hd) at (bh, iq)
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  causal: bool, sm_scale: float, block_q: int, block_k: int):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32) * sm_scale          # [bq, hd]
    k = k_ref[0].astype(jnp.float32)                     # [bk, hd]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # [bq, bk]

    if causal:
        q_pos = iq * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        k_pos = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        s = jnp.where(q_pos >= k_pos, s, NEG_INF)

    m_prev = m_ref[...]
    l_prev = l_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    p = jnp.exp(s - m_new[:, None])
    alpha = jnp.exp(m_prev - m_new)
    l_new = l_prev * alpha + p.sum(axis=-1)
    v = v_ref[0].astype(jnp.float32)                     # [bk, hd]
    pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + pv
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(ik == pl.num_programs(2) - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, ...] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_bhsd(q, k, v, *, causal: bool = True,
                         block_q: int = 128, block_k: int = 128,
                         interpret: bool = True):
    """q: [b, sq, h, hd]; k, v: [b, sk, kv, hd]. Returns [b, sq, h, hd].

    ``interpret=True`` executes the kernel body in Python on CPU (the only
    runtime available here); on real TPU pass interpret=False to lower via
    Mosaic.
    """
    b, sq, h, hd = q.shape
    _, sk, kv, _ = k.shape
    rep = h // kv
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    assert sq % block_q == 0 and sk % block_k == 0

    qr = q.transpose(0, 2, 1, 3).reshape(b * h, sq, hd)
    kr = k.transpose(0, 2, 1, 3).reshape(b * kv, sk, hd)
    vr = v.transpose(0, 2, 1, 3).reshape(b * kv, sk, hd)

    grid = (b * h, sq // block_q, sk // block_k)

    def q_map(bh, iq, ik):
        return (bh, iq, 0)

    def kv_map(bh, iq, ik):
        b_idx = bh // h
        h_idx = bh % h
        return (b_idx * kv + h_idx // rep, ik, 0)

    out = pl.pallas_call(
        functools.partial(_flash_kernel, causal=causal, sm_scale=hd ** -0.5,
                          block_q=block_q, block_k=block_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, hd), q_map),
            pl.BlockSpec((1, block_k, hd), kv_map),
            pl.BlockSpec((1, block_k, hd), kv_map),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), q_map),
        out_shape=jax.ShapeDtypeStruct((b * h, sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),       # m
            pltpu.VMEM((block_q,), jnp.float32),       # l
            pltpu.VMEM((block_q, hd), jnp.float32),    # acc
        ],
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(b, h, sq, hd).transpose(0, 2, 1, 3)
