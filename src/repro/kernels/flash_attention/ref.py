"""Pure-jnp oracle for the flash attention kernel."""

from repro.models.attention import plain_attention


def attention_ref(q, k, v, *, causal: bool = True):
    """q: [b, sq, h, hd]; k, v: [b, sk, kv, hd]. fp32 softmax reference."""
    return plain_attention(q, k, v, causal=causal)
