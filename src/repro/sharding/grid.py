"""Campaign-grid sharding primitives.

The training side already spreads work over devices (``train/dp.py``);
this module gives the scheduler's campaign engine the same machinery:
a version-portable ``shard_map`` entry and the PartitionSpecs for a 1-D
``("grid",)`` mesh that partitions the flat (fault x policy x seed)
batch axis.  Importing this module never touches jax device state (the
dry-run contract shared with ``launch/mesh.py``).

Per-grid-point simulations are embarrassingly parallel — the scan core
never communicates across the batch axis — so sharding the vmapped
batch is a pure partition: each device runs the identical per-lane op
sequence on its slice and results are bit-identical to the
single-device vmap (asserted in tests/test_sharded_campaign.py).
"""

from __future__ import annotations

from functools import partial

import jax
from jax.sharding import PartitionSpec

# jax >= 0.5 exposes shard_map at top level with check_vma; older jaxlibs
# keep the experimental entry with check_rep (same dance as train/dp.py)
if hasattr(jax, "shard_map"):
    shard_map = partial(jax.shard_map, check_vma=False)
else:
    from jax.experimental.shard_map import shard_map as _shard_map_experimental
    shard_map = partial(_shard_map_experimental, check_rep=False)

#: the campaign mesh's one axis name (see launch.mesh.make_grid_mesh)
GRID_AXIS = "grid"

#: spec for leaves sharded along the flat batch axis (leading dim)
grid_spec = PartitionSpec(GRID_AXIS)

#: spec for leaves replicated to every device (workload arrays, xs chunks)
replicated = PartitionSpec()
