"""Parameter partition rules: path + shape -> PartitionSpec.

Megatron-style TP on the 'model' axis (heads / ff / experts / vocab),
optional ZeRO/FSDP on the 'data' axis (embed dims), with automatic
divisibility fallback: an axis is only assigned if the dim divides evenly
(e.g. llama4's 40 q-heads and qwen2's 12 do NOT divide a 16-way model axis
-> those weights fall back to FSDP sharding, and attention math stays
data-parallel; recorded per-arch in EXPERIMENTS.md).

Stacked leading dims (scan-over-layers: 'groups/...', 'enc_layers/...',
'dec_layers/...') get a None prefix.
"""

from __future__ import annotations

import numpy as np
from jax.sharding import PartitionSpec as P

from repro.sharding.ctx import spec_for


def _axis_size(ax, axis_sizes) -> int:
    if ax is None:
        return 1
    names = (ax,) if isinstance(ax, str) else tuple(ax)
    n = 1
    for a in names:
        n *= axis_sizes[a]
    return n


def _fit(dim: int, ax, axis_sizes):
    """Return ax if it divides dim, else None."""
    return ax if (ax is not None and dim % _axis_size(ax, axis_sizes) == 0) else None


def logical_axes_for(path: str, ndim: int) -> tuple:
    """Map a param path to logical axis names (pre-divisibility-check)."""
    parts = path.split("/")
    name = parts[-1]
    parent = parts[-2] if len(parts) > 1 else ""

    if name == "table":                       # embed [V, d]
        return ("vocab", "embed")
    if parent == "head" and name == "w":      # lm head [V, d]
        return ("vocab", "embed")
    if name in ("enc_pos", "dec_pos"):
        return (None, "embed")

    if parent in ("attn", "xattn"):
        if name == "wq":
            return ("embed", "heads", None)
        if name in ("wk", "wv"):
            return ("embed", "kv_heads", None)
        if name == "wo":
            return ("heads", None, "embed")
        if name == "bq":
            return ("heads", None)
        if name in ("bk", "bv"):
            return ("kv_heads", None)

    if parent == "moe":
        if name == "router":
            return ("embed", None)
        # experts consume the 'model' axis (EP); ff must NOT also map to it
        if name in ("wi", "wu"):
            return ("experts", "embed", None)
        if name == "wo":
            return ("experts", None, "embed")

    if parent == "mlp":
        if name in ("wi", "wu"):
            return ("embed", "ff")
        if name == "wo":
            return ("ff", "embed")
        if name == "bi":
            return ("ff",)
        if name == "bo":
            return ("embed",)

    if parent == "mamba":
        if name == "in_proj":
            return ("embed", None)
        if name == "out_proj":
            return (None, "embed")
        # conv_w/conv_b/dt_bias/A_log/D/norm_scale: replicate
        return (None,) * ndim

    # norms & anything else: replicated
    return (None,) * ndim


def param_partition_spec(path: str, shape: tuple, rules: dict,
                         axis_sizes: dict) -> P:
    parts = path.split("/")
    # stacked trees: 'groups/posN/...' leaves carry a leading n_groups dim;
    # encdec stacked trees are 'enc_layers/...' / 'dec_layers/...'
    stacked = 1 if parts[0] in ("groups", "enc_layers", "dec_layers") else 0
    core_ndim = len(shape) - stacked
    logical = logical_axes_for("/".join(p for p in parts if not p.startswith("pos")),
                               core_ndim)
    if len(logical) != core_ndim:
        logical = (None,) * core_ndim
    mesh_axes = [rules.get(l) if l else None for l in logical]
    fitted = [_fit(d, ax, axis_sizes)
              for d, ax in zip(shape[stacked:], mesh_axes)]
    return P(*([None] * stacked + fitted))


def tree_partition_specs(spec_tree, rules, mesh):
    """Map a ShapeDtypeStruct tree to a PartitionSpec tree."""
    import jax
    from repro.utils.tree import flatten_with_names

    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    flat = flatten_with_names(spec_tree)
    specs = [param_partition_spec(name, tuple(x.shape), rules, axis_sizes)
             for name, x in flat]
    return jax.tree.unflatten(jax.tree.structure(spec_tree), specs)
