from repro.sharding.ctx import annotate, use_rules, spec_for, lm_rules, current_rules
