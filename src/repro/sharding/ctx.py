"""Logical-axis sharding annotations, decoupled from model code.

Model code calls ``annotate(x, ("batch", None, "embed"))`` with *logical*
axis names.  The launcher installs a rule set mapping logical names to mesh
axes (via ``use_rules``); with no rules installed, ``annotate`` is a no-op —
so smoke tests and single-device runs never touch device state.
"""

from __future__ import annotations

import threading
from contextlib import contextmanager

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()


def current_rules():
    return getattr(_state, "rules", None), getattr(_state, "mesh", None)


@contextmanager
def use_rules(mesh: Mesh, rules: dict):
    """rules: {logical_name: mesh_axis | tuple | None}"""
    old = current_rules()
    _state.rules, _state.mesh = rules, mesh
    try:
        yield
    finally:
        _state.rules, _state.mesh = old


def spec_for(logical_axes, rules) -> P:
    parts = []
    for name in logical_axes:
        if name is None:
            parts.append(None)
        else:
            parts.append(rules.get(name))
    return P(*parts)


def annotate(x, logical_axes):
    """Apply a sharding constraint if rules are installed; else no-op."""
    rules, mesh = current_rules()
    if rules is None or mesh is None:
        return x
    spec = spec_for(logical_axes, rules)
    # drop axes whose mesh axis does not divide the dim
    fixed = []
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    for dim, part in zip(x.shape, spec):
        if part is None:
            fixed.append(None)
            continue
        names = (part,) if isinstance(part, str) else tuple(part)
        total = 1
        for n in names:
            total *= axis_sizes[n]
        fixed.append(part if dim % total == 0 else None)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*fixed)))


# Default logical → mesh-axis rule sets -----------------------------------

def lm_rules(multi_pod: bool, fsdp: bool = False) -> dict:
    batch_axes = ("pod", "data") if multi_pod else ("data",)
    return {
        "batch": batch_axes,
        "seq_shard": batch_axes,    # sequence-sharded KV caches (long decode)
        "heads": "model",
        "kv_heads": "model",
        "vocab": "model",
        "ff": "model",
        "experts": "model",
        "embed": ("data" if fsdp else None),
        "seq_sp": "model",          # sequence-parallel residual stream
        # MoE dispatch locality: tokens are sorted/capacity-bucketed PER data
        # shard (GShard-style), so only the true expert all-to-all crosses
        # links. 32 = pod x data on the multi-pod mesh.
        "dp_shards": 32 if multi_pod else 16,
    }


def dispatch_shards() -> int:
    """Number of data shards for MoE-local dispatch (1 when no rules)."""
    rules, _ = current_rules()
    if not rules:
        return 1
    return int(rules.get("dp_shards", 1))
