"""Serving demo: batched prefill + decode with the production decode path
(grouped KV/state caches, one jitted step per token) on a reduced arch.

    PYTHONPATH=src python examples/serve_demo.py --arch tinyllama-1.1b --tokens 16
Works for hybrid/SSM archs too (mamba2-780m, jamba-v0.1-52b): their decode
carries conv+SSD state instead of (or alongside) KV.
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config, smoke_reduce
from repro.models import build_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b", choices=ARCH_IDS)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--max-seq", type=int, default=64)
    args = ap.parse_args()

    cfg = smoke_reduce(get_config(args.arch))
    api = build_model(cfg)
    key = jax.random.key(0)
    params = api.init_params(key)

    cache = api.init_decode_cache(args.batch, args.max_seq)
    step = jax.jit(api.decode_step, donate_argnums=(1,))

    tok = jax.random.randint(key, (args.batch, 1), 2, cfg.vocab_size, jnp.int32)
    out_tokens = [np.asarray(tok)[:, 0]]
    # warmup/compile
    logits, cache = step(params, cache, tok, jnp.int32(0))
    t0 = time.perf_counter()
    for pos in range(1, args.tokens):
        tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        logits, cache = step(params, cache, tok, jnp.int32(pos))
        out_tokens.append(np.asarray(tok)[:, 0])
    dt = time.perf_counter() - t0
    rate = args.batch * (args.tokens - 1) / dt
    print(f"arch={cfg.name} (reduced): decoded {args.tokens} tokens x "
          f"batch {args.batch} -> {rate:.1f} tok/s on CPU")
    print("sequences (greedy):")
    seq = np.stack(out_tokens, axis=1)
    for row in seq:
        print(" ", row[:16].tolist())
    assert np.isfinite(np.asarray(logits)).all()


if __name__ == "__main__":
    main()
