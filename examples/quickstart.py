"""Quickstart: reproduce the paper's experiment in ~5 seconds on CPU.

Builds the four JSCC systems, submits the NPB class-D suite simultaneously,
sweeps the K parameter, and prints the energy/runtime trade-off (paper
Figs 1-2) plus the placements.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import JSCC_SYSTEMS, SimConfig, make_npb_workload, sweep_k


def main():
    w = make_npb_workload(JSCC_SYSTEMS)
    ks = np.array([0.0, 0.05, 0.10, 0.20, 0.50, 0.85])
    res = sweep_k(w, SimConfig(mode="paper", warm_start=True), ks)

    E = np.asarray(res["total_energy"])
    M = np.asarray(res["makespan"])
    sel = np.asarray(res["system"])
    names = w.systems

    print("EcoSched quickstart — NPB BT/EP/IS/LU/SP on KNL/BDW/SKX/CLK")
    print(f"{'K':>5} {'energy':>10} {'dE%':>7} {'runtime':>9} {'dT%':>7}  placements")
    for i, k in enumerate(ks):
        placem = ",".join(names[s][:3] for s in sel[i])
        print(f"{int(k*100):4d}% {E[i]/1e3:9.1f}kJ {100*(E[i]-E[0])/E[0]:+6.1f}% "
              f"{M[i]:8.1f}s {100*(M[i]-M[0])/M[0]:+6.1f}%  {placem}")

    i10 = list(ks).index(0.20)
    print(f"\npaper claim: ~21.5% energy reduction at ~3.8% runtime increase")
    print(f"ours (K=20%): {100*(E[i10]-E[0])/E[0]:+.1f}% energy, "
          f"{100*(M[i10]-M[0])/M[0]:+.1f}% runtime")


if __name__ == "__main__":
    main()
