"""End-to-end training driver on CPU: a reduced assigned-architecture LM
trained with the full production stack (packed synthetic data, AdamW with
warmup+cosine, grad accumulation, atomic async checkpointing, resume,
straggler detection).

    PYTHONPATH=src python examples/train_smoke.py --arch qwen2-1.5b --steps 30
    # kill it mid-run and re-run: it resumes from the last checkpoint.
"""

import argparse

from repro.configs import ARCH_IDS, get_config, smoke_reduce
from repro.configs.base import ShapeConfig
from repro.models import build_model
from repro.optim import AdamWConfig
from repro.train import LoopConfig, run_training


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b", choices=ARCH_IDS)
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--microbatches", type=int, default=2)
    ap.add_argument("--ckpt-dir", default="/tmp/ecosched_train_smoke")
    args = ap.parse_args()

    cfg = smoke_reduce(get_config(args.arch))
    api = build_model(cfg)
    shape = ShapeConfig("smoke", seq_len=args.seq, global_batch=args.batch,
                        kind="train")
    ocfg = AdamWConfig(lr_peak=3e-3, warmup_steps=5, total_steps=args.steps)
    lcfg = LoopConfig(steps=args.steps, ckpt_dir=args.ckpt_dir,
                      ckpt_every=10, microbatches=args.microbatches,
                      log_every=5)
    res = run_training(api, shape, ocfg, lcfg,
                       metrics_path=args.ckpt_dir + ".metrics.jsonl")
    print(f"\narch={cfg.name} (reduced) steps={res.final_step} "
          f"resumed_from={res.resumed_from}")
    print(f"loss: {res.losses[0]:.3f} -> {res.losses[-1]:.3f}")
    print(f"median step time: {sorted(res.step_times)[len(res.step_times)//2]:.2f}s; "
          f"straggler events: {len(res.straggler_events)}")
    assert res.losses[-1] < res.losses[0], "training must reduce loss"


if __name__ == "__main__":
    main()
