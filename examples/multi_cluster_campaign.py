"""End-to-end campaign driver: the paper's scheduler placing and EXECUTING
real jobs.

A stream of NPB-analogue jobs arrives at a simulated SCC with the four JSCC
systems.  The EcoSched meta-scheduler places each job per the paper's
algorithm (learning (C, T) profiles as jobs complete — cold start, real
exploration); each placement then actually EXECUTES the reduced-scale JAX
workload on this host, with wall time scaled onto the simulated clock, so
the profile store is fed by measured runtimes, exactly as SUPPZ feeds the
algorithm in the paper.

Compares against fastest-first and first-free baselines; injects one
degraded system mid-campaign to show history-driven routing-around
(fault tolerance).

After the executed campaign, the same scheduler is extrapolated to a
10,000-job scenario stream with the ``Scheduler`` facade — the whole
K x seed grid is one leaf-batched Policy simulated in one jitted call
(the campaign-scale engine the measured 15-job run feeds), returning a
structured ``CampaignResult`` with named axes and derived metrics.

    PYTHONPATH=src python examples/multi_cluster_campaign.py --jobs 15
"""

import argparse
import time

import numpy as np

from repro.core import JSCC_SYSTEMS, Scheduler, make_policy
from repro.core.profiles import ProfileStore
from repro.core.algorithm import select_system
from repro.core.workload_model import (NPB_NODES, NPB_PROFILES,
                                       predict_energy)
from repro.data.scenarios import make_stream_workload, sample_programs
from repro.workloads import run_benchmark

import jax
import jax.numpy as jnp


def place(mode, store, p, avail, k):
    c_row = jnp.asarray(store.C[p], jnp.float32)
    t_row = jnp.asarray(store.T[p], jnp.float32)
    return int(select_system(
        mode, c_row=c_row, t_row=t_row,
        runs_row=jnp.asarray(store.runs[p], jnp.int32),
        avail_row=jnp.asarray(avail, jnp.float32), k=jnp.float32(k),
        c_pred_row=c_row, t_pred_row=t_row, key=jax.random.key(p)))


def campaign(mode, jobs, k=0.10, degrade_after=None, seed=0):
    systems = list(JSCC_SYSTEMS)
    names = [s.name for s in systems]
    progs = sorted(set(jobs))
    pidx = {n: i for i, n in enumerate(progs)}
    store = ProfileStore(len(progs), len(systems))
    free = np.zeros(len(systems))
    clock = 0.0
    total_e = 0.0
    slowdown = np.ones(len(systems))
    log = []
    for j, prog in enumerate(jobs):
        if degrade_after is not None and j == degrade_after:
            slowdown[names.index("Skylake")] = 3.0      # degraded system
        p = pidx[prog]
        avail = np.maximum(free, clock)
        s = place(mode, store, p, avail, k)

        # EXECUTE the real (reduced) workload; wall time feeds the profile
        t0 = time.perf_counter()
        res, ok, flops = run_benchmark(prog, scale="smoke")
        jax.block_until_ready(res)
        wall = time.perf_counter() - t0
        assert ok, (prog, "verification failed")

        # map measured wall time onto the simulated system's clock
        prof = NPB_PROFILES[prog]
        n = NPB_NODES[prog][names[s]]
        e_model, w_avg, t_model = predict_energy(prof, systems[s], n)
        t_run = t_model * slowdown[s] * (0.9 + 0.2 * (wall % 1.0))
        e_run = w_avg * t_run
        c_run = e_run / (prof.flops / 1e6)

        start = avail[s]
        free[s] = start + t_run
        total_e += e_run
        store.update(p, s, c_run, t_run)
        log.append((prog, names[s], t_run, e_run))
    makespan = free.max()
    return total_e, makespan, log


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--jobs", type=int, default=28)
    ap.add_argument("--sim-jobs", type=int, default=10_000,
                    help="length of the simulated extrapolation stream")
    ap.add_argument("--k", type=float, default=0.10)
    args = ap.parse_args()

    jobs = list(sample_programs(args.jobs, seed=0))
    print(f"campaign: {args.jobs} jobs, K={args.k:.0%}, degraded Skylake "
          f"after job {args.jobs // 2}\n")

    results = {}
    for mode in ("paper", "fastest", "first_free"):
        e, m, log = campaign(mode, jobs, k=args.k,
                             degrade_after=args.jobs // 2)
        results[mode] = (e, m)
        placem = ",".join(f"{p}->{s[:3]}" for p, s, _, _ in log[:8])
        print(f"{mode:12s} energy={e/1e3:8.1f}kJ makespan={m:7.1f}s "
              f"[{placem}...]")

    e_p, m_p = results["paper"]
    e_f, m_f = results["fastest"]
    print(f"\nEcoSched vs fastest-first: "
          f"{100*(e_p-e_f)/e_f:+.1f}% energy, {100*(m_p-m_f)/m_f:+.1f}% makespan")

    # -------- campaign-scale extrapolation: one jitted (K x seed) grid ----
    n_sim = args.sim_jobs
    print(f"\nextrapolating to {n_sim} simulated jobs "
          f"(bursty arrivals, mixed size classes) ...")
    w = make_stream_workload(JSCC_SYSTEMS, n_sim, arrival="bursty",
                             rate=0.25, seed=0, pred_noise=0.05)
    ks = np.array([0.0, 0.05, 0.10, 0.20], np.float32)
    t0 = time.perf_counter()
    res = Scheduler(make_policy("paper", k=ks), seeds=range(3)).run(
        w, totals_only=True)                   # aggregates only: no [K,R,J]
    E = np.asarray(res.total_energy)           # [K, R]
    slow = np.asarray(res.mean_slowdown)
    dt = time.perf_counter() - t0
    print(f"grid {len(ks)}K x 3 seeds x {n_sim} jobs in {dt:.1f}s "
          f"(one jit, axes={res.axes})")
    for i, k in enumerate(ks):
        print(f"  K={k:.0%}: energy={E[i].mean()/1e6:.2f} MJ "
              f"({100*(E[i].mean()-E[0].mean())/E[0].mean():+.1f}% vs K=0), "
              f"mean slowdown {slow[i].mean():.2f}")

    # -------- power-capped variant: the paper's grid limit as a hard
    # constraint.  The cap grid is ONE leaf-batched policy (power_cap is
    # a Policy leaf like K), simulated in a single jitted call on the
    # event-granular core with conservative backfilling.
    caps = np.array([45e3, 52e3, 60e3, np.inf], np.float32)
    print(f"\npower-capped campaign ({len(caps)}-cap grid, conservative "
          f"backfilling, one jit) ...")
    wcap = make_stream_workload(JSCC_SYSTEMS, min(n_sim, 1000),
                                arrival="diurnal", rate=0.8, seed=3)
    res = Scheduler(make_policy("conservative", k=args.k, power_cap=caps),
                    warm_start=True).run(wcap, totals_only=True)
    peak = np.asarray(res.peak_power)
    mk = np.asarray(res.makespan)
    cdel = np.asarray(res.capped_delay)
    idle = np.asarray(res.idle_energy)
    for i, cap in enumerate(caps):
        tag = "uncapped" if not np.isfinite(cap) else f"{cap/1e3:.0f} kW"
        print(f"  cap={tag:9s} peak={peak[i]/1e3:5.1f} kW  "
              f"makespan={mk[i]:7.1f} s  capped_delay={cdel[i]:7.1f} s  "
              f"idle_energy={idle[i]/1e6:.2f} MJ")
        if np.isfinite(cap):
            assert peak[i] <= cap * (1 + 1e-5)


if __name__ == "__main__":
    main()
